"""Launcher-layer unit tests (no 512-device compiles here — the heavy path
is exercised by the dry-run sweeps; see EXPERIMENTS.md §Dry-run)."""

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import dryrun
from repro.models.sharding import embed_dshard


def test_drop_fsdp_transform():
    specs = {"a": P("data", "model"), "b": P(("pod", "data"), None),
             "c": P("model", "data"), "d": P(None)}
    out = dryrun._drop_fsdp(specs)
    assert out["a"] == P(None, "model")
    assert out["b"] == P("pod", None)
    assert out["c"] == P("model", None)
    assert out["d"] == P(None)


def test_embed_dshard_only_touches_tables():
    params = {"embed": {"table": jax.ShapeDtypeStruct((64, 8), jnp.float32)},
              "layers": {"attn": {"wq": {"w": jax.ShapeDtypeStruct((8, 8),
                                                                   jnp.float32)}}}}
    specs = {"embed": {"table": P("model", None)},
             "layers": {"attn": {"wq": {"w": P("data", "model")}}}}
    out = embed_dshard(specs, params)
    assert out["embed"]["table"] == P(None, "model")
    assert out["layers"]["attn"]["wq"]["w"] == P("data", "model")


def test_train_cfg_microbatches_divide():
    from repro.configs.base import SHAPES
    from repro.models.registry import get_config

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("dbrx_132b")
    tcfg = dryrun._train_cfg_for(cfg, SHAPES["train_4k"], FakeMesh())
    assert SHAPES["train_4k"].global_batch % tcfg.microbatches == 0
    assert SHAPES["train_4k"].global_batch // tcfg.microbatches >= 16


def test_cell_plan_covers_all_archs():
    cells = dryrun.cell_plan()
    archs = {a for a, _ in cells}
    from repro.models.registry import ARCH_IDS
    assert archs == set(ARCH_IDS)
    # every arch has at least train + prefill
    for a in ARCH_IDS:
        shapes = {s for ar, s in cells if ar == a}
        assert {"train_4k", "prefill_32k"} <= shapes
