"""Distributed measure tuning + per-topology (schema v3) wisdom.

The acceptance story: ``plan_pfft(..., tune="measure", wisdom=...)`` over
a forced-4-device mesh measures the *full* ``pfft2_distributed`` pipeline
(all_to_all included), persists a v3 entry keyed by ``topology_digest``
(with the measured comm sample), and a second identical call is served
from wisdom with zero re-measurement.  Runs in a subprocess under
``--xla_force_host_platform_device_count=4`` via the conftest dist rig;
the in-process tests cover the key/versioning rules, the 1-device
fallback, and the eager SPMD rejection.
"""

import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import PlanConfig, plan_pfft
from repro.core.pfft_dist import (make_pfft2_fn, pfft2_distributed,
                                  validate_spmd_schedule)
from repro.plan import (SegmentSchedule, dist_comm_bytes, dist_panel_space,
                        load_wisdom, lookup_wisdom, record_wisdom,
                        topology_digest, wisdom_key)
from repro.plan.calibrate import _fit_comm_params
from repro.plan.cost import CostParams


def _mesh1():
    return jax.make_mesh((1,), ("fft",))


# ---------------------------------------------------------- topology keys

def test_topology_digest_distinguishes_topologies():
    a = topology_digest(devices=4, axis_name="fft", platform="cpu",
                        panels=(1, 2, 4))
    b = topology_digest(devices=8, axis_name="fft", platform="cpu",
                        panels=(1, 2, 4))
    c = topology_digest(devices=4, axis_name="rows", platform="cpu",
                        panels=(1, 2, 4))
    d = topology_digest(devices=4, axis_name="fft", platform="tpu",
                        panels=(1, 2, 4))
    e = topology_digest(devices=4, axis_name="fft", platform="cpu",
                        panels=(1, 2))
    assert len({a, b, c, d, e}) == 5  # every field is load-bearing
    assert a == "4xfft.cpu.k1-2-4"


def test_topology_digest_from_mesh():
    mesh = _mesh1()
    got = topology_digest(mesh, "fft", panels=(1,))
    assert got == f"1xfft.{jax.default_backend()}.k1"
    with pytest.raises(ValueError):
        topology_digest()  # neither mesh nor devices=


def test_topology_digest_two_axes():
    # The pencil pipeline's 2-D mesh: one <size>x<name> term per axis,
    # '+'-joined — injective against every 1-D digest ('+' never appears
    # there) and against the transposed axis order.
    mesh = jax.make_mesh((1, 1), ("fft_r", "fft_c"))
    got = topology_digest(mesh, ("fft_r", "fft_c"), panels=(1, 2))
    assert got == f"1xfft_r+1xfft_c.{jax.default_backend()}.k1-2"
    swapped = topology_digest(mesh, ("fft_c", "fft_r"), panels=(1, 2))
    assert swapped != got
    with pytest.raises(ValueError):
        topology_digest(None, ("fft_r", "fft_c"))  # multi-axis needs mesh=


def test_pfft3_panel_space_divides_both_extents():
    from repro.plan.tune import pfft3_panel_space
    assert pfft3_panel_space(64, 4, 2) == (1, 2, 4, 8)
    assert pfft3_panel_space(16, 4, 2) == (1, 2, 4)   # gcd(4, 8) = 4
    assert pfft3_panel_space(12, 3, 2) == (1, 2)      # gcd(4, 6) = 2
    assert pfft3_panel_space(12, 5, 2) == (1,)        # 5 does not divide 12
    assert pfft3_panel_space(64, 0, 2) == (1,)


def test_dist_panel_space_divisibility():
    """Satellite regression: 8 is reachable by default — the (1, 2, 4, 8)
    literal used to be silently capped at max_panels=4, so the 8-panel
    candidate was dead code in every default tuning run."""
    assert dist_panel_space(64, 4) == (1, 2, 4, 8)
    assert dist_panel_space(64, 4, max_panels=4) == (1, 2, 4)
    assert dist_panel_space(48, 4) == (1, 2, 4)  # 12 local rows: 8 drops out
    assert dist_panel_space(24, 4) == (1, 2)
    assert dist_panel_space(64, 0) == (1,)
    assert dist_panel_space(63, 4) == (1,)  # indivisible: monolithic only
    # The panel space digests into the v3 topology key, so the widened
    # default is a *different key* (re-tune), never a silently-served
    # stale plan; pin the digest spelling.
    assert topology_digest(devices=4, axis_name="fft", platform="cpu",
                           panels=dist_panel_space(64, 4)) \
        == "4xfft.cpu.k1-2-4-8"


def test_dist_comm_bytes_scaling():
    assert dist_comm_bytes(64, 1) == 0.0
    assert dist_comm_bytes(64, 2) == 64 * 64 * 8 / 2
    assert dist_comm_bytes(64, 4) == 64 * 64 * 8 * 3 / 4


# ----------------------------------------------- v2 -> v3 migration rules

def _write_store(path, version, entries):
    with open(path, "w") as fh:
        json.dump({"version": version, "entries": entries}, fh)


def test_v2_hits_single_host_but_misses_distributed(tmp_path):
    """A v2 store keeps serving single-host keys, but any topo= lookup
    against it is a miss even if the file (hand-edited, say) contains
    the key — v2 predates per-topology measurement."""
    path = str(tmp_path / "wisdom.json")
    cfg_dict = PlanConfig(radix=2).to_dict()
    host_key = wisdom_key(n=32, dtype="complex64", p=2, method="lb",
                          backend="cpu")
    dist_key = wisdom_key(n=32, dtype="complex64", p=2, method="lb",
                          backend="cpu", topology="2xfft.cpu.k1-2")
    _write_store(path, 2, {
        host_key: {"config": cfg_dict, "mode": "measure", "time_s": 1e-4},
        dist_key: {"config": cfg_dict, "mode": "measure", "time_s": 1e-4},
    })
    hit = lookup_wisdom(path, host_key)
    assert hit is not None and hit[0] == PlanConfig(radix=2)
    assert lookup_wisdom(path, dist_key) is None  # v2 is a dist miss
    # a v3 store serves the same dist key
    _write_store(path, 3, {
        dist_key: {"config": cfg_dict, "mode": "measure", "time_s": 1e-4}})
    assert lookup_wisdom(path, dist_key)[0] == PlanConfig(radix=2)


def test_recording_upgrades_v2_store_preserving_entries(tmp_path):
    path = str(tmp_path / "wisdom.json")
    host_key = wisdom_key(n=32, dtype="complex64", p=2, method="lb",
                          backend="cpu")
    _write_store(path, 2, {host_key: {"config": PlanConfig().to_dict(),
                                      "mode": "estimate"}})
    dist_key = wisdom_key(n=32, dtype="complex64", p=2, method="lb",
                          backend="cpu", topology="2xfft.cpu.k1-2")
    record_wisdom(path, dist_key, PlanConfig(radix=2), mode="measure",
                  time_s=2e-4, extra={"comm_bytes": 4096.0,
                                      "comm_time_s": 1e-4})
    doc = json.load(open(path))
    assert doc["version"] == 3
    assert set(doc["entries"]) == {host_key, dist_key}  # v2 entry survived
    assert lookup_wisdom(path, host_key) is not None
    assert lookup_wisdom(path, dist_key)[1]["comm_bytes"] == 4096.0


def test_v1_store_still_whole_file_miss(tmp_path):
    path = str(tmp_path / "wisdom.json")
    key = wisdom_key(n=32, dtype="complex64", p=2, method="lb", backend="cpu")
    _write_store(path, 1, {key: {"config": PlanConfig().to_dict(),
                                 "mode": "measure", "time_s": 1e-4}})
    assert load_wisdom(path) == {}
    assert lookup_wisdom(path, key) is None


# ------------------------------------------------- comm-sample calibration

def test_fit_comm_params_from_dist_entries():
    defaults = CostParams.for_backend("cpu")
    true_bw, true_lat = 5e9, 1e-4  # latency above the default 5e-5: the
    # single-sample fallback (bandwidth from t - default latency) stays
    # positive and therefore visibly moves off the default

    def entry(n, p):
        b = dist_comm_bytes(n, p)
        return {"config": PlanConfig().to_dict(), "mode": "measure",
                "time_s": 1e-3, "comm_bytes": b,
                "comm_time_s": 2.0 * (true_lat + b / true_bw)}

    entries = {
        wisdom_key(n=n, dtype="complex64", p=4, method="lb", backend="cpu",
                   topology="4xfft.cpu.k1"): entry(n, 4)
        for n in (32, 64, 128)}
    fitted = _fit_comm_params(entries, "cpu", defaults)
    assert fitted.interconnect_bytes_per_s == pytest.approx(true_bw, rel=1e-6)
    assert fitted.comm_latency_s == pytest.approx(true_lat, rel=1e-6)
    # single sample: bandwidth pinned with the default latency
    one = {k: v for k, v in list(entries.items())[:1]}
    fitted1 = _fit_comm_params(one, "cpu", defaults)
    assert fitted1.comm_latency_s == defaults.comm_latency_s
    assert fitted1.interconnect_bytes_per_s != defaults.interconnect_bytes_per_s
    # no samples / wrong backend: defaults kept
    assert _fit_comm_params({}, "cpu", defaults) == defaults
    assert _fit_comm_params(entries, "tpu", defaults) == defaults


# ---------------------------------------------------- eager SPMD rejection
# Since the device-group lowering (plan.groups), heterogeneity per se is
# not a rejection: mixed row-FFT variants branch per shard and mixed
# lengths run at the schedule's max.  The named SPMD error remains only
# for what the grouped program genuinely cannot express — program-level
# knob mixes (pad/fused/pipeline_panels) and entries that don't tile the
# mesh's equal shards.


def _unloweable_schedule(n=16):
    """Mixes fused with unfused: the two disagree on the all_to_all
    layout, so no single-SPMD lowering exists."""
    return SegmentSchedule.from_parts(
        n, [n // 2, n // 2], None,
        [PlanConfig(radix=4, fused=True), PlanConfig()])


def test_unloweable_schedule_raises_before_any_device_work(monkeypatch):
    """Satellite regression: the named SPMD error fires eagerly — before
    ``_local_phase`` (or any other device work) runs — and carries the
    schedule's describe() so the message names the offending mix."""
    import repro.core.pfft_dist as mod

    def boom(*a, **kw):  # pragma: no cover - must never be reached
        raise AssertionError("device work ran before SPMD validation")

    monkeypatch.setattr(mod, "_local_phase", boom)
    sched = _unloweable_schedule()
    m = jnp.ones((16, 16), jnp.complex64)
    with pytest.raises(ValueError, match="SPMD") as exc:
        pfft2_distributed(m, _mesh1(), "fft", schedule=sched)
    assert sched.describe() in str(exc.value)


def test_unmappable_rows_raise_eagerly_with_describe(monkeypatch):
    """A heterogeneous schedule whose entries don't tile the mesh's equal
    N/p shards has no device-group assignment — named error, eagerly."""
    import repro.core.pfft_dist as mod

    def boom(*a, **kw):  # pragma: no cover - must never be reached
        raise AssertionError("device work ran before SPMD validation")

    monkeypatch.setattr(mod, "_local_phase", boom)
    n = 16  # 1-device mesh: n_loc = 16, but each entry covers only 8 rows
    sched = SegmentSchedule.from_parts(
        n, [8, 8], None, [PlanConfig(), PlanConfig(radix=2)])
    with pytest.raises(ValueError, match="SPMD") as exc:
        pfft2_distributed(jnp.ones((n, n), jnp.complex64), _mesh1(), "fft",
                          schedule=sched)
    assert sched.describe() in str(exc.value)


def test_mixed_lengths_lower_at_max_length():
    """Mixed effective lengths no longer reject: the uniform-length rule
    runs every device at the schedule's max (here 64), the program-level
    analog of ragged_row_layout."""
    n = 48
    sched = SegmentSchedule.from_parts(
        n, [24, 24], np.array([48, 64]), [PlanConfig(pad="fpm")] * 2)
    rng = np.random.default_rng(11)
    m = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(np.complex64))
    out = pfft2_distributed(m, _mesh1(), "fft", schedule=sched)
    ref = pfft2_distributed(m, _mesh1(), "fft", padded="crop", pad_len=64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_make_pfft2_fn_validates_at_build_time():
    """The error must not wait for the first traced call — both the
    program-knob mix and the shard-tiling failure are build-time."""
    with pytest.raises(ValueError, match="SPMD"):
        make_pfft2_fn(_mesh1(), 16, schedule=_unloweable_schedule())
    unmappable = SegmentSchedule.from_parts(
        16, [8, 8], None, [PlanConfig(), PlanConfig(radix=2)])
    with pytest.raises(ValueError, match="SPMD"):
        make_pfft2_fn(_mesh1(), 16, schedule=unmappable)


def test_validate_spmd_schedule_relaxed():
    """The validator accepts what the grouped lowering can express and
    returns the program config (anchor of the makespan-dominant entry)."""
    n = 48
    mixed_len = SegmentSchedule.from_parts(
        n, [24, 24], np.array([48, 64]), [PlanConfig(pad="fpm")] * 2)
    assert validate_spmd_schedule(mixed_len) == PlanConfig(pad="fpm")
    assert validate_spmd_schedule(mixed_len, 64) == PlanConfig(pad="fpm")
    hetero = SegmentSchedule.from_parts(
        n, [16, 32], None, [PlanConfig(), PlanConfig(radix=2)])
    assert validate_spmd_schedule(hetero) == PlanConfig(radix=2)  # anchor
    with pytest.raises(ValueError, match="SPMD"):
        validate_spmd_schedule(_unloweable_schedule())
    mixed_panels = SegmentSchedule.from_parts(
        n, [24, 24], None,
        [PlanConfig(pipeline_panels=2), PlanConfig(radix=2)])
    with pytest.raises(ValueError, match="SPMD"):
        validate_spmd_schedule(mixed_panels)
    mixed_pad = SegmentSchedule.from_parts(
        n, [24, 24], np.array([96, 96]),
        [PlanConfig(pad="fpm"), PlanConfig(pad="czt")])
    with pytest.raises(ValueError, match="SPMD"):
        validate_spmd_schedule(mixed_pad)


# ----------------------------------------------- plan_pfft(mesh=) plumbing

def test_plan_pfft_mesh_method_validation():
    """The padded FPM methods are plannable on a mesh now (the
    device-group lowering drives them), but need an FPMSet covering
    exactly the mesh axis — one abstract processor per device — and
    plain 'fpm' stays rejected: on the even SPMD split it would run
    byte-identically to 'lb'."""
    mesh = _mesh1()
    with pytest.raises(ValueError, match="byte-identically"):
        plan_pfft(32, method="fpm", mesh=mesh)
    with pytest.raises(ValueError, match="requires fpms"):
        plan_pfft(32, method="fpm-pad", mesh=mesh)
    with pytest.raises(ValueError, match="conflicts with mesh axis"):
        plan_pfft(32, p=2, method="lb", mesh=mesh)
    xs = np.array([1, 16, 32])
    ys = np.array([32, 64])
    sp = np.outer(xs, np.log2(ys)) + 3.0
    from repro.core import FPMSet, SpeedFunction
    two = FPMSet([SpeedFunction(xs, ys, sp, name=f"P{i}") for i in range(2)])
    with pytest.raises(ValueError, match="one abstract processor per"):
        plan_pfft(32, fpms=two, method="fpm-pad", mesh=mesh)
    # (the N % p check needs p > 1; the 4-device acceptance script covers it)


def test_plan_pfft_mesh_fpm_pad_single_device():
    """plan_pfft(mesh=, method='fpm-pad') executes the uniform-length
    padded-crop semantics on the degenerate 1-device mesh."""
    mesh = _mesh1()
    n = 32
    xs = np.array([1, n // 2, n])
    ys = np.array(sorted({n, 64, 128}))
    sp = np.outer(xs, np.log2(ys)) + 3.0
    from repro.core import FPMSet, SpeedFunction
    fpms = FPMSet([SpeedFunction(xs, ys, sp, name="P0")])
    plan = plan_pfft(n, fpms=fpms, method="fpm-pad", mesh=mesh,
                     tune="estimate")
    assert plan.pad_lengths is not None and len(plan.pad_lengths) == 1
    rng = np.random.default_rng(3)
    m = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(np.complex64))
    out = plan.execute(m)
    L = max(int(plan.pad_lengths[0]), n)

    def crop_phase(mat):
        if L > n:
            mat = jnp.pad(mat, ((0, 0), (0, L - n)))
        return jnp.fft.fft(mat, axis=-1)[:, :n]

    ref = crop_phase(crop_phase(m).T).T
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-2)


def test_plan_pfft_one_device_mesh_measure_falls_back(tmp_path):
    """On a 1-device mesh there is no interconnect to measure: measure
    falls back to estimate (documented in DESIGN.md), the plan still
    persists under its topo key and is served back."""
    path = str(tmp_path / "wisdom.json")
    mesh = _mesh1()
    plan = plan_pfft(32, method="lb", mesh=mesh, tune="measure", wisdom=path)
    assert plan.tuning["source"] == "measure"
    assert plan.tuning["measure_fallback"].startswith("1-device mesh")
    assert "|topo=" in plan.tuning["wisdom_key"]
    assert json.load(open(path))["version"] == 3
    served = plan_pfft(32, method="lb", mesh=mesh, tune="measure",
                       wisdom=path)
    assert served.tuning["source"] == "wisdom"
    m = jnp.asarray((np.random.default_rng(0).standard_normal((32, 32))
                     + 1j * np.random.default_rng(1).standard_normal((32, 32))
                     ).astype(np.complex64))
    np.testing.assert_allclose(np.asarray(served.execute(m)),
                               np.asarray(jnp.fft.fft2(m)), atol=1e-2)


def test_mesh_and_host_plans_use_distinct_keys(tmp_path):
    """The same (n, p, method) planned with and without a mesh must not
    share wisdom: the dist entry is conditioned on the topology."""
    path = str(tmp_path / "wisdom.json")
    host = plan_pfft(32, p=1, method="lb", tune="estimate", wisdom=path)
    dist = plan_pfft(32, method="lb", mesh=_mesh1(), tune="estimate",
                     wisdom=path)
    assert host.tuning["wisdom_key"] != dist.tuning["wisdom_key"]
    assert "|topo=" in dist.tuning["wisdom_key"]
    assert "|topo=" not in host.tuning["wisdom_key"]


# --------------------------------------------- the 4-device acceptance rig

_ACCEPTANCE_SCRIPT = r"""
import json
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 4, jax.device_count()
from repro.core import plan_pfft
from repro.launch.mesh import make_fft_mesh
from repro.plan import load_wisdom, topology_digest
import repro.plan.tune as tune_mod

W = "WISDOM_PATH"
mesh = make_fft_mesh()  # 4x 'fft'
n = 64

# 1. measure end-to-end on the mesh, persist a v3 per-topology entry
p1 = plan_pfft(n, method="lb", mesh=mesh, tune="measure", wisdom=W)
assert p1.tuning["source"] == "measure", p1.tuning["source"]
assert "measure_fallback" not in p1.tuning, "4-device mesh must really measure"
assert p1.tuning["time_s"] > 0
key = p1.tuning["wisdom_key"]
assert "|topo=4xfft.cpu" in key, key
doc = json.load(open(W))
assert doc["version"] == 3, doc["version"]
entry = doc["entries"][key]
assert entry["mode"] == "measure" and entry["time_s"] > 0
assert entry["comm_bytes"] == 64 * 64 * 8 * 3 / 4, entry["comm_bytes"]
assert entry["comm_time_s"] >= 0
assert entry["topology"] == topology_digest(mesh, "fft", panels=(1, 2, 4, 8))

# 2. second identical call: served from wisdom with ZERO re-measurement
def no_measure(*a, **kw):
    raise AssertionError("re-measured on a warm store")
tune_mod.measure_dist_configs = no_measure
tune_mod._measure_local_phase = no_measure
p2 = plan_pfft(n, method="lb", mesh=mesh, tune="measure", wisdom=W)
assert p2.tuning["source"] == "wisdom", p2.tuning["source"]
assert p2.schedule == p1.schedule

# 3. the served plan computes the right transform on the mesh
rng = np.random.default_rng(7)
m = jnp.asarray((rng.standard_normal((n, n))
                 + 1j * rng.standard_normal((n, n))).astype(np.complex64))
assert float(jnp.max(jnp.abs(p2.execute(m) - jnp.fft.fft2(m)))) < 1e-2

# 4. a different mesh shape is a different topology_digest -> a miss
sub = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("fft",))
p3 = plan_pfft(n, method="lb", mesh=sub, tune="estimate", wisdom=W)
assert p3.tuning["wisdom_key"] != key
assert "|topo=2xfft.cpu" in p3.tuning["wisdom_key"]
assert p3.tuning["source"] == "estimate", p3.tuning["source"]  # miss: re-tuned
try:
    plan_pfft(33, method="lb", mesh=sub)
    raise SystemExit("expected N % p divisibility error")
except ValueError:
    pass

# 5. raw pfft2_distributed plumbs the same lifecycle (wisdom hit, no tuner)
from repro.core.pfft_dist import pfft2_distributed
out = pfft2_distributed(m, mesh, "fft", tune="measure", wisdom=W)
assert float(jnp.max(jnp.abs(out - jnp.fft.fft2(m)))) < 1e-2

# 6. a v2 rewrite of the same store stops serving the dist key
doc = json.load(open(W))
json.dump({"version": 2, "entries": doc["entries"]}, open(W, "w"))
p4 = plan_pfft(n, method="lb", mesh=mesh, tune="estimate", wisdom=W)
assert p4.tuning["source"] == "estimate", p4.tuning["source"]  # v2 = dist miss
print("DIST_TUNE_OK")
"""


def test_dist_measure_wisdom_roundtrip_4_devices(dist_subprocess, tmp_path):
    script = _ACCEPTANCE_SCRIPT.replace(
        "WISDOM_PATH", str(tmp_path / "wisdom.json"))
    dist_subprocess(script, devices=4, sentinel="DIST_TUNE_OK")


# --------------------------------------- in-process multi-device coverage

@pytest.mark.multi_device
def test_dist_tuner_inprocess_on_forced_topology(tmp_path):
    """Runs whenever this process sees >1 device — under the CI dist
    job's REPRO_FORCE_DEVICES=4, or in the full tier-1 suite (where
    importing repro.launch.dryrun fakes 512 CPU devices): the tuner
    measures end-to-end in-process and records the comm sample."""
    from repro.plan import tune_dist_config

    p = min(jax.device_count(), 4)  # a mesh needn't span every device
    mesh = jax.make_mesh((p,), ("fft",))
    cfg, info = tune_dist_config(32, mesh, "fft", mode="measure", reps=1,
                                 top_k=2)
    assert "measure_fallback" not in info
    assert info["time_s"] > 0
    assert info["dist"]["comm_time_meas_s"] >= 0
    assert info["dist"]["devices"] == p
