"""POPTA/HPOPTA: exactness against brute force (hypothesis), invariants."""

import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.fpm import FPMSet, SpeedFunction
from repro.core.partition import hpopta, lb_partition, popta, partition_rows


def brute_force_makespan(curves, n):
    p = len(curves)
    best = float("inf")
    for combo in itertools.product(range(n + 1), repeat=p - 1):
        if sum(combo) > n:
            continue
        d = list(combo) + [n - sum(combo)]
        t = max(curves[i][d[i]] for i in range(p))
        best = min(best, t)
    return best


@given(
    n=st.integers(4, 14),
    p=st.integers(2, 3),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=60, deadline=None)
def test_hpopta_is_optimal_vs_bruteforce(n, p, seed):
    rng = np.random.default_rng(seed)
    curves = []
    for _ in range(p):
        t = np.abs(rng.normal(1.0, 0.7, n + 1)).cumsum()  # increasing-ish
        t += rng.random(n + 1) * 2.0                       # plus variation
        t[0] = 0.0
        curves.append(t)
    res = hpopta(curves, n)
    assert res.d.sum() == n
    assert np.all(res.d >= 0)
    achieved = max(curves[i][res.d[i]] for i in range(p))
    np.testing.assert_allclose(achieved, res.tau, rtol=1e-12)
    np.testing.assert_allclose(res.tau, brute_force_makespan(curves, n),
                               rtol=1e-12)


def test_hpopta_prefers_faster_processor():
    n = 30
    base = np.linspace(0, 10, n + 1)
    fast, slow = base.copy(), 3 * base
    fast[0] = slow[0] = 0.0
    res = hpopta([fast, slow], n)
    assert res.d[0] > res.d[1]


def test_hpopta_exploits_nonmonotonic_profile():
    """The paper's core claim: optimum may be load-IMBALANCED.  Processor 0
    has a performance cliff at x=5..9 (slow zone); the optimum avoids it."""
    n = 12
    t0 = np.linspace(0, 2.0, n + 1)
    t0[5:10] = 50.0   # cliff
    t0[0] = 0.0
    t1 = np.linspace(0, 4.0, n + 1)
    res = hpopta([t0, t1], n)
    assert not (5 <= res.d[0] <= 9)
    assert res.tau < 10.0


def test_popta_equals_hpopta_on_identical():
    n = 20
    t = np.sqrt(np.arange(n + 1.0))
    a = popta(t, 3, n)
    b = hpopta([t, t, t], n)
    assert a.tau == b.tau
    assert a.method == "POPTA"


def test_infeasible_raises():
    t = np.full(11, np.inf)
    t[0] = 0.0
    with pytest.raises(ValueError):
        hpopta([t, t], 10)


def test_lb_partition_even():
    r = lb_partition(10, 3)
    assert sorted(r.d.tolist()) == [3, 3, 4]
    assert r.d.sum() == 10


def test_partition_rows_dispatch():
    xs = np.array([1, 4, 8, 16, 32])
    ys = np.array([16, 32, 64])
    v = np.outer(xs, np.log2(ys)) + 5.0
    ident = FPMSet([SpeedFunction(xs, ys, v), SpeedFunction(xs, ys, v)])
    r = partition_rows(32, ident, eps=0.05, y=32)
    assert r.method == "POPTA"
    hetero = FPMSet([SpeedFunction(xs, ys, v), SpeedFunction(xs, ys, 2 * v)])
    r = partition_rows(32, hetero, eps=0.05, y=32)
    assert r.method == "HPOPTA"
    assert r.d[1] > r.d[0]  # processor 1 is 2x faster
    assert r.d.sum() == 32
