"""Padding-selection (Determine_Pad_Length) properties."""

import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.fpm import SpeedFunction
from repro.core.padding import (determine_pad_length, is_smooth,
                                pad_to_smooth, smooth_candidates)


def test_pad_picks_faster_larger_size():
    xs = np.array([1, 8])
    ys = np.array([100, 128, 200])
    # speed at y=128 is so high that 8 rows of len 128 beat 8 rows of len 100
    sp = np.array([[1.0, 100.0, 1.0], [1.0, 100.0, 1.0]])
    f = SpeedFunction(xs, ys, sp)
    assert determine_pad_length(f, 8, 100) == 128


def test_pad_zero_when_no_benefit():
    xs = np.array([1, 8])
    ys = np.array([100, 128, 200])
    sp = np.ones((2, 3))  # flat speed: larger y always costs more time
    f = SpeedFunction(xs, ys, sp)
    assert determine_pad_length(f, 8, 100) == 100
    assert determine_pad_length(f, 0, 100) == 100  # idle processor


@given(n=st.integers(1, 4096))
@settings(max_examples=80, deadline=None)
def test_smooth_candidates_properties(n):
    c = smooth_candidates(n)
    assert len(c) >= 1
    assert np.all(c >= n)
    assert np.all(np.diff(c) > 0)
    p = pad_to_smooth(n)
    assert p >= n
    assert p == c[0]


def test_is_smooth():
    assert is_smooth(128) and is_smooth(3 * 128) and is_smooth(640)
    assert not is_smooth(127) and not is_smooth(7 * 128 // 7 * 7)
