"""Real-input rfft2 pipeline: packed-row kernels vs the rfft oracle,
Hermitian/round-trip property tests over odd/even N and both float
precisions, the FPM-partitioned limbs (padded real == padded complex
half spectrum, bin for bin), the planner's real-vs-complex race and
wisdom round trip, and the distributed half-spectrum exchange (via the
shared dist rigs — subprocess for tier-1, ``multi_device`` marks for
the forced-4-device CI job)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st  # hypothesis or fallback
from repro.core import FPMSet, PlanConfig, plan_pfft
from repro.core.fpm import SpeedFunction
from repro.core.pfft import (halfspec_distribution, pfft_fpm_pad, rpfft_fpm,
                             rpfft_fpm_pad, rpfft_lb, segment_row_rffts)
from repro.fft import irfft2, rfft2, rfft_rows, rfft_rows_then_transpose
from repro.plan import (dist_comm_bytes, estimate_cost, halfspec_cols,
                        rfft_pad_lengths, tune_rfft)


def real_signal(n, seed=0, dtype=np.float32, rows=None):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows or n, n)).astype(dtype))


def hetero_fpms(n, p=3):
    """One slow + (p-1) fast processors whose speed peaks at the next
    pow2, so the FPM pad selection actually engages (mirrors the
    test_pfft rig)."""
    xs = np.array(sorted({1, max(n // 2, 1), n}))
    npow2 = 1 << int(np.ceil(np.log2(n + 1)))
    ys = np.array(sorted({n, npow2, 2 * npow2}))
    fast = np.tile([1e9, 4e9, 1e9], (len(xs), 1))
    slow = np.full((len(xs), len(ys)), 2.5e8)
    return FPMSet([SpeedFunction(xs, ys, slow if i == 0 else fast,
                                 name=f"P{i}") for i in range(p)])


def _tol(x):
    # float64 stays fp64 only when scripts/test.sh enabled x64
    return 1e-3 if jnp.asarray(x).dtype == jnp.float32 else 1e-8


# ------------------------------------------------------------- kernels

@pytest.mark.parametrize("rows,n", [(8, 64), (7, 64), (1, 32), (13, 128)])
def test_packed_rfft_kernel_matches_oracle(rows, n):
    x = real_signal(n, seed=1, rows=rows)
    out = rfft_rows(x, backend="pallas")
    ref = np.fft.rfft(np.asarray(x), axis=-1)
    assert out.shape == (rows, n // 2 + 1)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-3)


def test_packed_rfft_kernel_leading_dims():
    x = jnp.asarray(np.random.default_rng(2)
                    .standard_normal((2, 3, 6, 32)).astype(np.float32))
    out = rfft_rows(x, backend="pallas")
    np.testing.assert_allclose(np.asarray(out),
                               np.fft.rfft(np.asarray(x), axis=-1),
                               atol=1e-3)


def test_fused_rfft_transpose_matches_unfused():
    x = real_signal(64, seed=3, rows=24)
    fused = rfft_rows_then_transpose(x)
    ref = np.fft.rfft(np.asarray(x), axis=-1).T
    assert fused.shape == (64 // 2 + 1, 24)
    np.testing.assert_allclose(np.asarray(fused), ref, atol=1e-3)


def test_stockham_backend_packs_rows_too():
    x = real_signal(32, seed=4, rows=5)
    out = rfft_rows(x, backend="stockham")
    np.testing.assert_allclose(np.asarray(out),
                               np.fft.rfft(np.asarray(x), axis=-1),
                               atol=1e-3)


# --------------------------------------------- rfft2 oracle & round trip

@settings(max_examples=25, deadline=None)
@given(n_i=st.integers(0, 5), dtype_i=st.integers(0, 1),
       seed=st.integers(0, 2 ** 16))
def test_rfft2_matches_library_oracle(n_i, dtype_i, seed):
    """Hermitian acceptance: the half spectrum equals jnp.fft.rfft2's
    across odd and even N and both float precisions (the oracle *is* the
    Hermitian-unique half — matching it bin for bin pins both the values
    and the symmetry convention)."""
    n = (7, 8, 15, 16, 33, 48)[n_i]
    dtype = (np.float32, np.float64)[dtype_i]
    x = real_signal(n, seed=seed, dtype=dtype)
    out = rfft2(x)
    ref = jnp.fft.rfft2(x)
    assert out.shape == (n, n // 2 + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=_tol(x), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n_i=st.integers(0, 5), dtype_i=st.integers(0, 1),
       seed=st.integers(0, 2 ** 16))
def test_irfft2_round_trips(n_i, dtype_i, seed):
    n = (7, 8, 15, 16, 33, 48)[n_i]
    dtype = (np.float32, np.float64)[dtype_i]
    x = real_signal(n, seed=seed, dtype=dtype)
    back = irfft2(rfft2(x), n=n)  # odd N needs the explicit length
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=_tol(x))


def test_full_spectrum_reconstructs_hermitian_symmetric():
    """The half spectrum really is the Hermitian-unique half: mirroring
    it reproduces the full complex fft2 of the real signal."""
    n = 16
    x = real_signal(n, seed=9)
    half = np.asarray(rfft2(x))
    full = np.asarray(jnp.fft.fft2(x.astype(jnp.complex64)))
    # X[-u, -v] == conj(X[u, v]): mirror the stored half into the rest
    rec = np.zeros_like(full)
    rec[:, :n // 2 + 1] = half
    for u in range(n):
        for v in range(n // 2 + 1, n):
            rec[u, v] = np.conj(half[(-u) % n, (n - v)])
    np.testing.assert_allclose(rec, full, atol=2e-3)


# ------------------------------------------------------ partitioned limbs

def test_rpfft_lb_matches_oracle():
    n = 64
    x = real_signal(n, seed=5)
    ref = np.fft.rfft2(np.asarray(x))
    for p in (1, 2, 3):
        out = rpfft_lb(x, p)
        np.testing.assert_allclose(np.asarray(out), ref, atol=2e-3)
    fused = rpfft_lb(x, 2, config=PlanConfig(radix=4, fused=True, real=True))
    np.testing.assert_allclose(np.asarray(fused), ref, atol=2e-3)


def test_rpfft_fpm_partitioned_matches_oracle():
    n = 48
    x = real_signal(n, seed=6)
    fpms = hetero_fpms(n)
    out, part = rpfft_fpm(x, fpms, return_partition=True)
    assert len(part.d) == 3 and int(np.sum(part.d)) == n
    np.testing.assert_allclose(np.asarray(out),
                               np.fft.rfft2(np.asarray(x)), atol=2e-3)


def test_rpfft_fpm_pad_equals_complex_half_spectrum():
    """The padded real phase must equal the padded *complex* path's half
    spectrum bin for bin — same partition, same pad lengths, same
    crop — or the planner's apples-to-apples race would be comparing
    different transforms.  (The pad-and-crop semantics are the paper's
    interpolation, deliberately != the exact DFT when padding engages,
    so the complex limb on identical (d, pads) is the only oracle.)"""
    from repro.core.pfft import _pfft_limb
    n = 48
    x = real_signal(n, seed=7)
    fpms = hetero_fpms(n)
    out, part, pads = rpfft_fpm_pad(x, fpms, return_partition=True)
    assert any(int(L) > n for L in pads)  # padding actually engages
    ref = _pfft_limb(x.astype(jnp.complex64), part.d, pad_lengths=pads,
                     config=PlanConfig(pad="fpm"))[:, :n // 2 + 1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_rfft_pad_lengths_are_even():
    n = 48
    fpms = hetero_fpms(n)
    d = np.array([16, 16, 16])
    pads = rfft_pad_lengths(fpms, d, n)
    assert pads.shape == (3,)
    assert all(int(L) == n or (int(L) > n and int(L) % 2 == 0)
               for L in pads)


def test_halfspec_distribution_prefix_clips():
    nh = 33  # n=64
    np.testing.assert_array_equal(
        halfspec_distribution(np.array([16, 16, 16, 16]), nh),
        [16, 16, 1, 0])
    np.testing.assert_array_equal(
        halfspec_distribution(np.array([40, 24]), nh), [33, 0])
    d2 = halfspec_distribution(np.array([10, 0, 30, 24]), nh)
    assert int(d2.sum()) == nh and (d2 >= 0).all()


def test_segment_row_rffts_heterogeneous_lengths():
    """Mixed padded/unpadded segments: each real segment must equal the
    complex segment path's crop under the same (d, pads) — the padded
    segments run the paper's pad-and-crop interpolation, so the complex
    path is the oracle."""
    from repro.core.pfft import segment_row_ffts
    n = 32
    x = real_signal(n, seed=8)
    d = np.array([10, 12, 10])
    pads = np.array([n, 64, n], dtype=np.int64)
    out = segment_row_rffts(x, d, pad_lengths=pads,
                            config=PlanConfig(pad="fpm", real=True))
    ref = segment_row_ffts(x.astype(jnp.complex64), d, pad_lengths=pads,
                           config=PlanConfig(pad="fpm"))[:, :n // 2 + 1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)
    # the unpadded segments additionally match the exact rfft
    exact = np.fft.rfft(np.asarray(x), axis=-1)
    np.testing.assert_allclose(np.asarray(out[:10]), exact[:10], atol=1e-3)


# ----------------------------------------------------------- cost model

def test_real_comm_bytes_at_most_60_percent():
    """The half-spectrum panel is strictly smaller everywhere and at
    most 60% of the complex panel on the CI-relevant shapes (small
    (n, p) pay a lane-padding tax on ceil(nh/p)*p, approaching the
    asymptotic 1/2 as n grows)."""
    for n in (16, 64, 128, 256):
        for p in (2, 4, 8):
            full = dist_comm_bytes(n, p)
            half = dist_comm_bytes(n, p, real=True)
            assert half <= full, (n, p)  # n=16,p=8 degenerates to equal
            assert half == n * halfspec_cols(n, p) * 8 * (p - 1) / p
    for n, p in ((64, 4), (128, 4), (256, 4), (256, 8)):
        ratio = dist_comm_bytes(n, p, real=True) / dist_comm_bytes(n, p)
        assert ratio <= 0.6, (n, p, ratio)


def test_estimate_prefers_real_config():
    n = 64
    cplx = PlanConfig()
    real = PlanConfig(real=True)
    assert estimate_cost(real, n=n) < estimate_cost(cplx, n=n)


# -------------------------------------------------------------- planner

def test_tune_rfft_measure_races_both_families():
    sched, info = tune_rfft(64, mode="measure", top_k=2, reps=2)
    fams = {c["real"] for c, _ in info["measured"]}
    assert fams == {True, False}
    assert info["chosen_path"] in ("real", "complex")
    assert sched.anchor_config.real == (info["chosen_path"] == "real")


def test_plan_pfft_real_methods_match_oracle():
    from repro.core.pfft import _pfft_limb
    n = 48
    x = real_signal(n, seed=10)
    ref = np.fft.rfft2(np.asarray(x))
    fpms = hetero_fpms(n)
    for kwargs in (dict(p=3, method="rfft-lb"),
                   dict(p=2, method="rfft-lb", tune="estimate"),
                   dict(fpms=fpms, method="rfft-fpm")):
        plan = plan_pfft(n, dtype="float32", **kwargs)
        out = plan.execute(x)
        assert out.shape == (n, n // 2 + 1)
        np.testing.assert_allclose(np.asarray(out), ref, atol=2e-3)
    # fpm-pad runs the padded interpolation, so its oracle is the complex
    # limb on the plan's own (d, pads), cropped to the half spectrum
    plan = plan_pfft(n, fpms=fpms, method="rfft-fpm-pad", tune="estimate",
                     dtype="float32")
    pad_ref = _pfft_limb(x.astype(jnp.complex64), plan.d,
                         pad_lengths=plan.pad_lengths,
                         config=PlanConfig(pad="fpm"))[:, :n // 2 + 1]
    np.testing.assert_allclose(np.asarray(plan.execute(x)),
                               np.asarray(pad_ref), atol=2e-3)


def test_plan_pfft_real_method_dtype_validation():
    with pytest.raises(ValueError, match="transforms real input"):
        plan_pfft(32, p=2, method="rfft-lb")  # default complex64
    with pytest.raises(ValueError, match="transforms complex input"):
        plan_pfft(32, p=2, method="lb", dtype="float32")
    with pytest.raises(ValueError, match="no Bluestein"):
        PlanConfig(real=True, pad="czt")


def test_plan_pfft_real_explicit_config_is_real_flagged():
    n = 32
    x = real_signal(n, seed=11)
    plan = plan_pfft(n, p=2, method="rfft-lb", dtype="float32",
                     config=PlanConfig(radix=2))
    assert plan.config.real
    np.testing.assert_allclose(np.asarray(plan.execute(x)),
                               np.fft.rfft2(np.asarray(x)), atol=2e-3)


def test_real_wisdom_round_trip_zero_remeasure(tmp_path):
    n = 32
    w = str(tmp_path / "wisdom.json")
    x = real_signal(n, seed=12)
    p1 = plan_pfft(n, p=2, method="rfft-lb", tune="measure", wisdom=w,
                   dtype="float32")
    assert p1.tuning["source"] == "measure"
    assert "method=rfft-lb" in p1.tuning["wisdom_key"]
    assert "dtype=float32" in p1.tuning["wisdom_key"]
    p2 = plan_pfft(n, p=2, method="rfft-lb", tune="measure", wisdom=w,
                   dtype="float32")
    assert p2.tuning["source"] == "wisdom"      # served from disk,
    assert "measured" not in p2.tuning          # zero re-measurement
    np.testing.assert_allclose(np.asarray(p2.execute(x)),
                               np.fft.rfft2(np.asarray(x)), atol=2e-3)


# ---------------------------------------------------------- distributed

_RFFT_DIST_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import plan_pfft
from repro.core.pfft_dist import (irpfft2_distributed, pfft2_distributed,
                                  rpfft2_distributed)
from repro.plan import PlanConfig, dist_comm_bytes

n = 64
mesh = jax.make_mesh((4,), ("fft",))
rng = np.random.default_rng(13)
x = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
ref = np.fft.rfft2(np.asarray(x))

out = rpfft2_distributed(x, mesh)
assert np.abs(np.asarray(out) - ref).max() < 2e-3, "dist oracle"
crop = np.asarray(pfft2_distributed(x.astype(jnp.complex64), mesh))[:, :n//2+1]
assert np.abs(np.asarray(out) - crop).max() < 2e-3, "vs complex crop"
back = irpfft2_distributed(out, mesh)
assert np.abs(np.asarray(back) - np.asarray(x)).max() < 1e-4, "round trip"
assert dist_comm_bytes(n, 4, real=True) <= 0.6 * dist_comm_bytes(n, 4)

plan = plan_pfft(n, method="rfft-lb", mesh=mesh, tune="measure",
                 dtype="float32")
assert np.abs(np.asarray(plan.execute(x)) - ref).max() < 2e-3, "planned"
assert plan.tuning["dist"]["comm_ratio_real"] <= 0.6
fams = {c["real"] for c, _ in plan.tuning["measured"]}
assert fams == {True, False}, f"one-family race: {fams}"
print("RFFT_DIST_OK")
"""


def test_real_distributed_via_subprocess(dist_subprocess):
    """Tier-1 acceptance: the half-spectrum exchange matches the oracle
    (and the complex path's crop) on a real 4-device mesh, the planner
    races both families end to end, and the recorded comm ratio is
    <= 0.6 — via the shared conftest dist rig."""
    dist_subprocess(_RFFT_DIST_SCRIPT, devices=4, sentinel="RFFT_DIST_OK")


@pytest.mark.multi_device
def test_real_distributed_forced_devices():
    """The forced-device CI job's in-process variant."""
    from repro.core.pfft_dist import irpfft2_distributed, rpfft2_distributed
    p = min(jax.device_count(), 4)
    n = 16 * p
    mesh = jax.make_mesh((p,), ("fft",))
    x = real_signal(n, seed=14)
    out = rpfft2_distributed(x, mesh)
    np.testing.assert_allclose(np.asarray(out),
                               np.fft.rfft2(np.asarray(x)), atol=2e-3)
    back = irpfft2_distributed(out, mesh)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


@pytest.mark.multi_device
def test_real_distributed_plan_forced_devices(tmp_path):
    p = min(jax.device_count(), 4)
    n = 16 * p  # hc = 9p for nh = 8p + 1, so the comm ratio is 0.5625
    mesh = jax.make_mesh((p,), ("fft",))
    x = real_signal(n, seed=15)
    ref = np.fft.rfft2(np.asarray(x))
    w = str(tmp_path / "wisdom.json")
    p1 = plan_pfft(n, method="rfft-lb", mesh=mesh, tune="measure",
                   wisdom=w, dtype="float32")
    np.testing.assert_allclose(np.asarray(p1.execute(x)), ref, atol=2e-3)
    assert p1.tuning["dist"]["comm_ratio_real"] <= 0.6
    p2 = plan_pfft(n, method="rfft-lb", mesh=mesh, tune="measure",
                   wisdom=w, dtype="float32")
    assert p2.tuning["source"] == "wisdom"
    np.testing.assert_allclose(np.asarray(p2.execute(x)), ref, atol=2e-3)


def test_real_dist_program_shape_is_validated():
    """The half-spectrum exchange supports the homogeneous unfused
    monolithic program only — everything else is refused eagerly."""
    from repro.core.pfft_dist import _validate_real_dist
    with pytest.raises(ValueError, match="real config"):
        _validate_real_dist(PlanConfig(), None)
    with pytest.raises(ValueError, match="unfused and monolithic"):
        _validate_real_dist(PlanConfig(real=True, fused=True), None)
    with pytest.raises(ValueError, match="unfused and monolithic"):
        _validate_real_dist(PlanConfig(real=True, pipeline_panels=2), None)


def test_plan_pfft_mesh_rejects_real_fpm_methods():
    fpms = hetero_fpms(64, p=1)
    mesh = jax.make_mesh((1,), ("fft",))
    with pytest.raises(ValueError, match="byte-identically"):
        plan_pfft(64, method="rfft-fpm", fpms=fpms, mesh=mesh,
                  dtype="float32")
    with pytest.raises(ValueError, match="homogeneous unpadded"):
        plan_pfft(64, method="rfft-fpm-pad", fpms=fpms, mesh=mesh,
                  dtype="float32")
