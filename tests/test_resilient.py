"""Self-healing runtime acceptance (ISSUE 6): online re-planning on
drift, elastic recovery on device loss, all on the forced-4-device rig.

The straggler script is the full loop: a healthy estimate-mode plan
picks homogeneous; an injected 3x slowdown of one device group is
*detected* by the probe EWMA, re-tuned with the monitor's degraded FPMs
— flipping the grouped-vs-homogeneous makespan race to the heterogeneous
device-group program — and hot-swapped at the next call boundary, with
the detect/re-plan/swap event recorded.  The recovered schedule equals
the from-scratch oracle tuned against the same degraded FPMs (identity,
so the <= 25% makespan acceptance bound holds by construction — on a
shared-core CPU rig wall-clock races between the two would only measure
scheduler noise).

The device-loss script: a raised ``DeviceLostError`` mid-stream rebuilds
the mesh from survivors (4 -> 3, N=48 stays divisible), re-keys wisdom
by the new ``topology_digest``, re-shards registered in-flight state,
and retries the failed call; a second runtime on the reduced topology is
served from wisdom with zero re-measurement.
"""

import numpy as np
import pytest


# ---------------------------------------------------------- in-process

def test_baseline_fpms_synthesized_when_absent():
    """Without user FPMs the re-planner still needs a baseline to fold
    drift into — a flat nominal-rate set of the right arity."""
    from repro.runtime.resilient import ResilientPlan

    class _P(ResilientPlan):
        def __init__(self):
            self.n, self.fpms, self.retune_params = 48, None, None

        @property
        def p(self):
            return 4

    fpms = _P()._baseline_fpms()
    assert fpms.p == 4
    assert all(np.isfinite(f.speed).all() for f in fpms)


def test_degraded_wisdom_key_isolated_from_healthy():
    """A degraded re-plan's wisdom entry must never collide with the
    healthy plan's key, and the same quantized drift signature must map
    to the same key (so recurring drift serves from the store)."""
    from repro.runtime.resilient import ResilientPlan

    class _P(ResilientPlan):
        def __init__(self):
            self.n, self.method, self.dtype = 48, "lb", "complex64"
            self.axis_name = "fft"
            from repro.launch.mesh import make_fft_mesh
            self.mesh = make_fft_mesh(1)

        @property
        def p(self):
            return 4

    rp = _P()
    k1 = rp._degraded_key(np.array([1.0, 1.0, 1.0, 0.33]), None)[0]
    k2 = rp._degraded_key(np.array([1.0, 1.0, 1.0, 0.34]), None)[0]
    k3 = rp._degraded_key(np.array([1.0, 1.0, 1.0, 0.50]), None)[0]
    assert "degraded-" in k1
    assert k1 == k2          # 1/16 quantization: same signature
    assert k1 != k3
    from repro.plan.wisdom import topology_digest, wisdom_key
    healthy = wisdom_key(n=48, dtype="complex64", p=4, method="lb",
                         backend="cpu",
                         topology=topology_digest(rp.mesh, "fft"))
    assert k1 != healthy


# --------------------------------------------- forced-4-device scripts

STRAGGLER_SCRIPT = r"""
import dataclasses
import numpy as np
import jax
from repro.core.fpm import FPMSet, SpeedFunction
from repro.plan.cost import CostParams
from repro.plan.tune import tune_dist_schedule
from repro.runtime.faults import inject
from repro.runtime.resilient import ResilientPlan

n = 48
xs = np.array(sorted({1, n // 4, n}))
ys = np.array(sorted({48, 64, 128}))
# devices 0-2: slow-ish, pow2-peaked -> pad to 64, kernel-eligible;
# device 3: fast and flat -> stays at 48, library-FFT-only candidates.
peaked = np.tile([2e8, 8e8, 2e8], (len(xs), 1))
flat = np.full((len(xs), len(ys)), 4e9)
fpms = FPMSet([SpeedFunction(xs, ys, peaked.copy(), name=f"d{i}")
               for i in range(3)]
              + [SpeedFunction(xs, ys, flat, name="d3")])
# Constants sized so the switch-dispatch overhead beats the healthy
# makespan savings (homogeneous wins) but loses once device 0 drifts
# (heterogeneous wins) — the re-plan is *caused* by the detection.
params = dataclasses.replace(
    CostParams.for_backend("cpu"),
    backend_factor={"xla": 1.0, "stockham": 0.25, "pallas": 300.0},
    dispatch_overhead_s=1e-5)

rng = np.random.default_rng(0)
x = (rng.standard_normal((n, n))
     + 1j * rng.standard_normal((n, n))).astype("complex64")

with inject() as inj:
    rp = ResilientPlan(n, method="fpm-pad", fpms=fpms, tune="estimate",
                       retune_params=params, alpha=0.6,
                       drift_threshold=1.3, cooldown=2)
    assert rp.plan.tuning.get("chosen") == "homogeneous", rp.plan.tuning
    assert len(rp.schedule.configs) == 1
    out0 = np.asarray(rp.execute(x))

    inj.slow_group(0, 3)
    swap = None
    for _ in range(30):
        out = rp.execute(x)
        swaps = [e for e in rp.events
                 if e["kind"] == "replan" and e.get("swap_call") is not None
                 and e.get("chosen") == "heterogeneous"]
        if swaps:
            swap = swaps[0]
            break
    assert swap is not None, f"no heterogeneous hot-swap: {rp.events}"

    # detection saw the drift on the right group, with real magnitude
    assert 0 in swap["slow_groups"], swap
    assert swap["relative_speeds"][0] < 0.7, swap
    assert swap["replan_s"] > 0 and swap["swap_call"] > swap["call"]

    # the swapped plan is a genuinely grouped device-group program
    assert len(rp.schedule.configs) == 2, rp.schedule.describe()
    assert rp.plan.tuning.get("source") == "estimate"

    # correctness is preserved across the hot swap (both programs run
    # the same uniform-length crop semantics)
    out1 = np.asarray(rp.execute(x))
    np.testing.assert_allclose(out1, out0, atol=1e-2)

    # acceptance: recovered steady-state equals the from-scratch oracle
    # tuned against the same degraded FPMs -> within any makespan bound
    degraded = rp.last_degraded_fpms
    assert degraded is not None and degraded.p == 4
    oracle, _ = tune_dist_schedule(
        n, rp.mesh, "fft", pad_lengths=rp._pad_lengths(degraded),
        mode="estimate", pad="fpm", fpms=degraded, params=params)
    assert oracle == rp.schedule, (oracle.describe(),
                                   rp.schedule.describe())
print("RESILIENT_STRAGGLER_OK")
"""


LOSS_SCRIPT = r"""
import os
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.runtime.faults import inject
from repro.runtime.resilient import ResilientPlan

n = 48
W = "WISDOM_PATH"
rng = np.random.default_rng(1)
x = (rng.standard_normal((n, n))
     + 1j * rng.standard_normal((n, n))).astype("complex64")
ref = np.fft.fft2(x)

with inject() as inj:
    rp = ResilientPlan(n, method="lb", tune="measure", wisdom=W)
    assert rp.p == 4
    topo4 = rp.plan.tuning.get("topology")
    out = rp.execute(x)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-2)

    rp.register_state({"acc": jnp.zeros((n, n), "complex64")},
                      {"acc": P("fft", None)})
    inj.fail_execute(rp.calls, lost=(3,))
    out = rp.execute(x)    # raises inside, recovers, retries same call
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-2)

    ev = [e for e in rp.events if e["kind"] == "device_loss"]
    assert len(ev) == 1, rp.events
    ev = ev[0]
    assert ev["lost"] == [3] and ev["devices"] == 3 and ev["dropped"] == 0
    assert ev["recover_s"] > 0

    # the rebuilt mesh is a distinct wisdom topology
    topo3 = ev["topology"]
    assert topo3 is not None and topo3 != topo4, (topo3, topo4)
    assert rp.p == 3

    # registered in-flight state was re-sharded onto the rebuilt mesh
    assert rp.state["acc"].sharding.mesh.shape["fft"] == 3

# zero re-measurement on the reduced topology: poison every measure
# entry point, then plan again on a fresh 3-device mesh — wisdom serves.
import repro.plan.tune as tune_mod
def boom(*a, **k):
    raise AssertionError("re-measured a wisdom-served topology")
tune_mod.measure_dist_configs = boom
tune_mod._measure_local_phase = boom
from repro.launch.mesh import make_fft_mesh
rp2 = ResilientPlan(n, method="lb", tune="measure", wisdom=W,
                    mesh=make_fft_mesh(3))
assert rp2.plan.tuning.get("source") == "wisdom", rp2.plan.tuning
out2 = rp2.execute(x)
np.testing.assert_allclose(np.asarray(out2), ref, atol=1e-2)
print("RESILIENT_ELASTIC_OK")
"""


def test_straggler_replan_and_hot_swap(dist_subprocess):
    dist_subprocess(STRAGGLER_SCRIPT, devices=4,
                    sentinel="RESILIENT_STRAGGLER_OK")


def test_device_loss_recovery_and_wisdom_rekey(dist_subprocess, tmp_path):
    script = LOSS_SCRIPT.replace("WISDOM_PATH",
                                 str(tmp_path / "wisdom.json"))
    dist_subprocess(script, devices=4, sentinel="RESILIENT_ELASTIC_OK")
