"""Hypothesis shim: re-export the real library when installed, otherwise a
deterministic fallback so property tests still run (as seeded example sweeps)
on minimal environments.

The fallback implements exactly the strategy surface this suite uses
(``st.integers``, ``st.sampled_from``) and runs each property
``max_examples`` times with draws from a fixed-seed generator.  It is not a
replacement for hypothesis (no shrinking, no database) — install
``requirements-dev.txt`` for the real thing.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[rng.integers(len(elems))])

    st = _Strategies()

    def settings(max_examples=100, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            max_examples = getattr(fn, "_max_examples", 100)

            def runner():
                rng = _np.random.default_rng(0)
                for _ in range(max_examples):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
