"""Fused FFT->transpose path, radix-4 stages, and batched segment dispatch:
equivalence against the unfused/radix-2/looped references."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.pfft import (plan_segment_batches, pfft_lb,
                             segment_row_ffts)
from repro.fft.fft2d import fft2d_rowcol, fft_rows_then_transpose
from repro.plan import PlanConfig
from repro.kernels.fft.kernel import (stockham_planes, stockham_planes_radix4,
                                      stockham_stage_count)
from repro.kernels.fft.ops import fft_rows_op, pick_radix
from repro.kernels.fused.kernel import fft_rows_transpose_pallas
from repro.kernels.fused.ops import fft_rows_transpose_op


def csignal(rng, rows, n, dtype=np.complex64):
    return jnp.asarray((rng.standard_normal((rows, n))
                        + 1j * rng.standard_normal((rows, n))).astype(dtype))


# ------------------------------------------------------------- radix-4 stages

@pytest.mark.parametrize("n", [2, 4, 8, 16, 64, 128, 1024])
def test_radix4_matches_radix2(rng, n):
    re = jnp.asarray(rng.standard_normal((3, n)).astype(np.float32))
    im = jnp.asarray(rng.standard_normal((3, n)).astype(np.float32))
    r2 = stockham_planes(re, im)
    r4 = stockham_planes_radix4(re, im)
    tol = 1e-3 * n ** 0.5
    np.testing.assert_allclose(np.asarray(r4[0]), np.asarray(r2[0]), atol=tol)
    np.testing.assert_allclose(np.asarray(r4[1]), np.asarray(r2[1]), atol=tol)


@pytest.mark.parametrize("inverse", [False, True])
def test_radix4_inverse_roundtrip(rng, inverse):
    n = 32
    re = jnp.asarray(rng.standard_normal((2, n)).astype(np.float32))
    im = jnp.asarray(rng.standard_normal((2, n)).astype(np.float32))
    fr, fi = stockham_planes_radix4(re, im, inverse=inverse)
    br, bi = stockham_planes_radix4(fr, fi, inverse=not inverse)
    np.testing.assert_allclose(np.asarray(br), np.asarray(re), atol=1e-4)
    np.testing.assert_allclose(np.asarray(bi), np.asarray(im), atol=1e-4)


def test_stage_counts():
    for log2n in range(1, 12):
        n = 1 << log2n
        assert stockham_stage_count(n, 2) == log2n
        assert stockham_stage_count(n, 4) == (log2n + 1) // 2
    with pytest.raises(ValueError):
        stockham_stage_count(12)
    with pytest.raises(ValueError):
        stockham_stage_count(16, radix=8)


def test_pick_radix():
    assert pick_radix(2) == 2
    assert pick_radix(4) == 4
    assert pick_radix(1024) == 4


@pytest.mark.parametrize("n", [16, 128])
def test_fft_op_radix4_vs_oracle(rng, n):
    x = csignal(rng, 5, n)
    out = fft_rows_op(x, radix=4, block_rows=2, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft(x, axis=-1)), atol=2e-3)


# ------------------------------------------------------------- fused kernel

@pytest.mark.parametrize("radix", [2, 4])
@pytest.mark.parametrize("block_rows", [1, 4])
def test_fused_kernel_pallas_call(rng, radix, block_rows):
    rows, n = 8, 64
    re = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))
    im = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))
    ore, oim = fft_rows_transpose_pallas(re, im, block_rows=block_rows,
                                         radix=radix, interpret=True)
    ref = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=-1).T
    np.testing.assert_allclose(np.asarray(ore), ref.real, atol=2e-3)
    np.testing.assert_allclose(np.asarray(oim), ref.imag, atol=2e-3)


@pytest.mark.parametrize("rows,n", [(8, 64), (13, 32), (64, 256)])
def test_fused_op_vs_unfused(rng, rows, n):
    x = csignal(rng, rows, n)
    out = fft_rows_transpose_op(x, interpret=True)
    assert out.shape == (n, rows)
    ref = jnp.fft.fft(x, axis=-1).T
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-3)


def test_fused_op_rejects_bad_inputs():
    with pytest.raises(ValueError):
        fft_rows_transpose_op(jnp.ones((4, 12), jnp.complex64), interpret=True)
    with pytest.raises(ValueError):
        fft_rows_transpose_op(jnp.ones((2, 4, 8), jnp.complex64),
                              interpret=True)


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
@pytest.mark.parametrize("n", [16, 32, 64, 128])
def test_fft2d_fused_vs_unfused_equivalence(rng, dtype, n):
    """The tentpole equivalence: fused=True computes the same 2-D DFT."""
    m = csignal(rng, n, n, dtype=dtype)
    fused = fft2d_rowcol(m, fused=True)
    unfused = fft2d_rowcol(m)
    # complex128 (when x64 is enabled) must take the full-precision
    # fallback, not the f32-plane kernel; judge by the realised dtype.
    tol = 1e-8 if m.dtype == jnp.complex128 else 1e-2 * n ** 0.5
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(jnp.fft.fft2(m)),
                               atol=tol)


def test_fused_phase_fallbacks(rng):
    # non-pow2 length and batched input take the unfused fallback path
    x = csignal(rng, 6, 12)
    np.testing.assert_allclose(
        np.asarray(fft_rows_then_transpose(x)),
        np.asarray(jnp.fft.fft(x, axis=-1).T), atol=1e-4)
    xb = jnp.stack([csignal(rng, 4, 8), csignal(rng, 4, 8)])
    np.testing.assert_allclose(
        np.asarray(fft_rows_then_transpose(xb)),
        np.asarray(jnp.fft.fft(xb, axis=-1).swapaxes(-1, -2)), atol=1e-4)


def test_pfft_lb_fused_matches(rng):
    m = csignal(rng, 64, 64)
    np.testing.assert_allclose(
        np.asarray(pfft_lb(m, 3, config=PlanConfig(fused=True))),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)


# ------------------------------------------------- batched segment dispatch

def test_segment_batching_plan(rng):
    n = 32
    d = np.array([10, 7, 0, 15])
    pads = np.array([40, 32, 48, 40])
    plan = plan_segment_batches(d, pads, n)
    # one dispatch per *distinct* pad length among non-empty segments
    assert sorted(plan.keys()) == [32, 40]
    covered = np.sort(np.concatenate(list(plan.values())))
    np.testing.assert_array_equal(covered, np.arange(n))


@pytest.mark.parametrize("pads", [None, [40, 32, 40]])
def test_segment_batched_equals_looped(rng, pads):
    n = 32
    m = csignal(rng, n, n)
    d = np.array([10, 7, 15])
    pads = np.array(pads) if pads is not None else None
    batched = segment_row_ffts(m, d, pad_lengths=pads,
                               config=PlanConfig(batched=True))
    looped = segment_row_ffts(m, d, pad_lengths=pads,
                              config=PlanConfig(batched=False))
    np.testing.assert_allclose(np.asarray(batched), np.asarray(looped),
                               atol=1e-4)
