"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel body on CPU)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.kernels.fft.kernel import fft_rows_pallas, stockham_planes
from repro.kernels.fft.ops import fft_rows_op, pick_block_rows
from repro.kernels.fft.ref import fft_rows_ref
from repro.kernels.transpose.kernel import transpose_pallas
from repro.kernels.transpose.ops import transpose_op
from repro.kernels.transpose.ref import transpose_ref


def cplanes(rng, rows, n, dtype=np.float32):
    re = rng.standard_normal((rows, n)).astype(dtype)
    im = rng.standard_normal((rows, n)).astype(dtype)
    return jnp.asarray(re), jnp.asarray(im)


# ---------------------------------------------------------------- fft kernel

@pytest.mark.parametrize("n", [8, 32, 128, 512, 2048])
@pytest.mark.parametrize("rows", [1, 4, 8])
def test_stockham_planes_shape_sweep(rng, n, rows):
    re, im = cplanes(rng, rows, n)
    ore, oim = stockham_planes(re, im)
    rre, rim = fft_rows_ref(re, im)
    tol = 1e-3 * n ** 0.5
    np.testing.assert_allclose(np.asarray(ore), np.asarray(rre), atol=tol)
    np.testing.assert_allclose(np.asarray(oim), np.asarray(rim), atol=tol)


@pytest.mark.parametrize("inverse", [False, True])
@pytest.mark.parametrize("block_rows", [1, 2, 8])
def test_fft_kernel_pallas_call(rng, inverse, block_rows):
    rows, n = 16, 64
    re, im = cplanes(rng, rows, n)
    ore, oim = fft_rows_pallas(re, im, block_rows=block_rows, inverse=inverse,
                               interpret=True)
    rre, rim = fft_rows_ref(re, im, inverse=inverse)
    np.testing.assert_allclose(np.asarray(ore), np.asarray(rre), atol=1e-3)
    np.testing.assert_allclose(np.asarray(oim), np.asarray(rim), atol=1e-3)


def test_fft_kernel_rejects_bad_rows(rng):
    re, im = cplanes(rng, 5, 16)
    with pytest.raises(ValueError):
        fft_rows_pallas(re, im, block_rows=4, interpret=True)


@pytest.mark.parametrize("rows", [3, 8, 13])
@pytest.mark.parametrize("n", [16, 256])
def test_fft_op_complex_roundtrip(rng, rows, n):
    x = (rng.standard_normal((rows, n))
         + 1j * rng.standard_normal((rows, n))).astype(np.complex64)
    x = jnp.asarray(x)
    out = fft_rows_op(x, block_rows=4, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft(x, axis=-1)),
                               atol=2e-3)
    back = fft_rows_op(out, inverse=True, block_rows=4, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=2e-3)


def test_fft_op_batched_leading_dims(rng):
    x = (rng.standard_normal((2, 3, 32))
         + 1j * rng.standard_normal((2, 3, 32))).astype(np.complex64)
    out = fft_rows_op(jnp.asarray(x), block_rows=2, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft(x, axis=-1)), atol=2e-3)


def test_fft_op_rejects_non_pow2():
    with pytest.raises(ValueError):
        fft_rows_op(jnp.ones((4, 12), jnp.complex64), interpret=True)


def test_pick_block_rows_vmem_budget():
    assert pick_block_rows(128) >= 8
    assert pick_block_rows(1 << 16) >= 1
    assert pick_block_rows(1 << 16) * (1 << 16) * 4 * 6 <= 16 * 1024 * 1024


@given(n=st.sampled_from([8, 16, 64, 256]), rows=st.integers(1, 6),
       seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_fft_kernel_property_linear(n, rows, seed):
    """DFT linearity: F(a x + y) = a F(x) + F(y)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, n)).astype(np.complex64))
    y = jnp.asarray(rng.standard_normal((rows, n)).astype(np.complex64))
    a = 2.5
    lhs = fft_rows_op(a * x + y, block_rows=2, interpret=True)
    rhs = a * fft_rows_op(x, block_rows=2, interpret=True) + \
        fft_rows_op(y, block_rows=2, interpret=True)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=5e-3)


# ---------------------------------------------------------- transpose kernel

@pytest.mark.parametrize("shape", [(128, 128), (256, 128), (384, 256)])
def test_transpose_kernel_exact(rng, shape):
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    out = transpose_pallas(x, block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(transpose_ref(x)))


def test_transpose_kernel_rejects_unaligned(rng):
    with pytest.raises(ValueError):
        transpose_pallas(jnp.ones((100, 128)), block=128, interpret=True)


@given(r=st.integers(1, 300), c=st.integers(1, 300), seed=st.integers(0, 20))
@settings(max_examples=30, deadline=None)
def test_transpose_op_any_shape(r, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((r, c)).astype(np.float32))
    out = transpose_op(x, block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x.T))


def test_transpose_op_complex(rng):
    x = (rng.standard_normal((130, 70))
         + 1j * rng.standard_normal((130, 70))).astype(np.complex64)
    out = transpose_op(jnp.asarray(x), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), x.T)


def test_transpose_involution(rng):
    x = jnp.asarray(rng.standard_normal((200, 150)).astype(np.float32))
    out = transpose_op(transpose_op(x, interpret=True), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
