"""Roofline extraction: HLO collective parsing + term arithmetic."""

import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.launch.roofline import (HW, RooflineTerms, collective_bytes,
                                   model_flops, roofline_terms)

HLO_SAMPLE = """
HloModule test
  %ag = bf16[128,1024]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%p1), to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%p2), dimensions={0}
  %a2a = bf16[16,32]{1,0} all-to-all(%p3), dimensions={0}
  %cp = u8[100]{0} collective-permute(%p4), source_target_pairs={{0,1}}
  %ags = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-gather-start(%p5)
  %agd = bf16[8,8]{1,0} all-gather-done(%ags)
  %dot = f32[32,32]{1,0} dot(%p6, %p7)
"""


def test_collective_bytes_parses_all_kinds():
    got = collective_bytes(HLO_SAMPLE)
    assert got["all-gather"] == 128 * 1024 * 2 + 8 * 8 * 2 * 2  # incl. -start
    assert got["all-reduce"] == 256 * 4
    assert got["reduce-scatter"] == 64 * 64 * 4
    assert got["all-to-all"] == 16 * 32 * 2
    assert got["collective-permute"] == 100
    # dot and -done must not be counted
    assert set(got) == {"all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"}


def test_roofline_terms_math():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    terms = roofline_terms(cost, HLO_SAMPLE, chips=4, mflops=100e12)
    assert terms.compute_s == pytest.approx(1.0)
    assert terms.memory_s == pytest.approx(1.0)
    assert terms.collective_s > 0
    assert terms.dominant in ("compute", "memory")
    assert terms.flops == pytest.approx(4 * 197e12)
    assert 0 < terms.roofline_fraction < 1
    d = terms.to_dict()
    assert d["dominant"] == terms.dominant


def test_model_flops_kinds():
    from repro.models.registry import get_config
    cfg = get_config("internlm2_1_8b")
    n = int(2e9)
    tr = model_flops(cfg, SHAPES["train_4k"], n)
    pf = model_flops(cfg, SHAPES["prefill_32k"], n)
    dec = model_flops(cfg, SHAPES["decode_32k"], n)
    assert tr == 6.0 * n * 256 * 4096
    assert pf == 2.0 * n * 32 * 32768
    assert dec == 2.0 * n * 128


def test_active_params_moe_less_than_total():
    from repro.launch.roofline import active_param_count
    from repro.models.registry import get_config
    cfg = get_config("dbrx_132b")
    total = 132_000_000_000
    active = active_param_count(cfg, total)
    assert active < total * 0.5   # top-4 of 16 experts
    assert active > total * 0.1


def test_cell_plan_skips():
    from repro.launch import dryrun
    cells = dryrun.cell_plan()
    assert ("hubert_xlarge", "decode_32k") not in cells
    assert ("hubert_xlarge", "long_500k") not in cells
    assert ("hubert_xlarge", "prefill_32k") in cells
    assert ("zamba2_7b", "long_500k") in cells
    assert ("xlstm_125m", "long_500k") in cells
    assert ("dbrx_132b", "long_500k") not in cells
    # 31 runnable cells: 7 decoders x3 + 2 subquadratic x4 + hubert x2
    assert len(cells) == 31
