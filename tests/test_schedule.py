"""Heterogeneous per-segment schedules: SegmentSchedule round-trips,
degenerate equivalence with the PR-2 config= paths, mixed-backend phase
correctness vs the naive DFT oracle, wisdom v2 schedule persistence (and
the v1 migration-to-miss), cost-param calibration, the distributed
routing, and the ISSUE-3 acceptance scenario (one slow + p-1 fast
processors => >= 2 distinct per-segment configs, makespan estimate no
worse than the best homogeneous config)."""

import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st  # hypothesis or fallback
from repro.core import FPMSet, PlanConfig, SpeedFunction, plan_pfft
from repro.core.pfft import (_pfft_limb, pfft_fpm_czt, plan_segment_batches,
                             segment_row_ffts)
from repro.core.partition import lb_partition
from repro.fft.dft_ref import dft1d_naive
from repro.plan import (CostParams, SegmentPlan, SegmentSchedule,
                        candidate_configs, estimate_cost,
                        estimate_schedule_cost, fit_cost_params, load_wisdom,
                        lookup_wisdom, record_wisdom, tune_schedule,
                        wisdom_key)
from repro.plan.wisdom import WISDOM_VERSION


def random_signal(n, seed=0, dtype=np.complex64):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal((n, n))
                        + 1j * rng.standard_normal((n, n))).astype(dtype))


def hetero_fpms(n, p=3, slow_factor=8.0):
    """One slow processor, p-1 fast ones, with a speed landscape that
    makes padding n -> next pow2 attractive for the fast processors."""
    xs = np.array(sorted({1, max(n // 4, 1), max(n // 2, 1), n}))
    npow2 = 1 << int(np.ceil(np.log2(n)))
    ys = np.array(sorted({n, npow2, 2 * npow2}))
    base = np.outer(np.maximum(xs, 1), np.log2(np.maximum(ys, 2))) + 5.0
    fns = [SpeedFunction(xs, ys, base / (slow_factor if i == 0 else 1.0),
                         name=f"P{i}") for i in range(p)]
    return FPMSet(fns)


# ------------------------------------------------------- schedule round-trip

def test_segment_schedule_dict_roundtrip():
    sched = SegmentSchedule.from_parts(
        48, [16, 32], [48, 64],
        [PlanConfig(pad="fpm"), PlanConfig(radix=4, pad="fpm")])
    assert SegmentSchedule.from_dict(sched.to_dict()) == sched
    assert len(sched) == 2 and sched.total_rows == 48
    assert sched.common_config is None
    assert len(sched.configs) == 2
    # anchor = makespan-dominant (most rows) entry's config
    assert sched.anchor_config == PlanConfig(radix=4, pad="fpm")
    with pytest.raises(ValueError):
        SegmentSchedule.from_dict({**sched.to_dict(), "warp_drive": 1})
    with pytest.raises(ValueError):
        SegmentPlan.from_dict({"index": 0, "rows": 1, "length": 8,
                               "config": {}, "alien": True})


def test_segment_schedule_validation():
    cfg = PlanConfig()
    with pytest.raises(ValueError):
        SegmentSchedule(n=8, entries=())
    with pytest.raises(ValueError):
        SegmentPlan(index=0, rows=0, length=8, config=cfg)
    with pytest.raises(TypeError):
        SegmentPlan(index=0, rows=4, length=8, config="xla")
    with pytest.raises(ValueError):  # non-ascending indices
        SegmentSchedule(n=8, entries=(
            SegmentPlan(index=1, rows=4, length=8, config=cfg),
            SegmentPlan(index=0, rows=4, length=8, config=cfg)))
    with pytest.raises(ValueError):  # more rows than N
        SegmentSchedule(n=4, entries=(
            SegmentPlan(index=0, rows=8, length=4, config=cfg),))


def test_schedule_matches_partition_structure():
    d = np.array([16, 0, 16])
    pads = np.array([32, 32, 40])
    sched = SegmentSchedule.homogeneous(PlanConfig(pad="fpm"), 32, d, pads)
    assert [e.index for e in sched] == [0, 2]  # empty segment skipped
    assert sched.matches(d, pads)
    assert not sched.matches(np.array([8, 8, 16]), pads)
    assert not sched.matches(d, np.array([32, 32, 48]))
    assert not sched.matches(np.array([16, 16]))


def test_batch_groups_merge_and_optout():
    shared = PlanConfig()
    loner = PlanConfig(batched=False)
    sched = SegmentSchedule.from_parts(
        32, [8, 8, 8, 8], None, [shared, shared, loner, loner])
    groups = sched.batch_groups()
    # two batched segments share one dispatch; each batched=False segment
    # opts out into its own
    assert len(groups) == 3
    assert [len(idx) for _, _, idx in groups] == [16, 8, 8]


def test_plan_segment_batches_by_length_and_config():
    n = 32
    d = np.array([8, 8, 8, 8])
    pads = np.array([n, 64, 64, n], dtype=np.int64)
    by_len = plan_segment_batches(d, pads, n)
    assert sorted(by_len) == [32, 64]
    fast = PlanConfig(radix=4, pad="fpm")
    slow = PlanConfig(pad="fpm")
    by_cfg = plan_segment_batches(d, pads, n,
                                  configs=[slow, fast, slow, slow])
    # same 64-length rows split across two dispatches now: one per config
    assert sorted(k[0] for k in by_cfg) == [32, 64, 64]
    total = np.sort(np.concatenate(list(by_cfg.values())))
    np.testing.assert_array_equal(total, np.arange(n))


# ------------------------------------------- degenerate (PR-2) equivalence

@pytest.mark.parametrize("cfg", [
    PlanConfig(),
    PlanConfig(batched=False),
    PlanConfig(radix=2),
    PlanConfig(radix=4, fused=True),
])
def test_degenerate_schedule_matches_config_path(cfg):
    """schedule=homogeneous(config) is bit-identical to config= — the PR-2
    API is now a shim over the schedule executor."""
    n = 32
    d = lb_partition(n, 3).d
    m = random_signal(n, seed=7)
    sched = SegmentSchedule.homogeneous(cfg, n, d)
    via_schedule = _pfft_limb(m, d, schedule=sched)
    via_config = _pfft_limb(m, d, config=cfg)
    np.testing.assert_array_equal(np.asarray(via_schedule),
                                  np.asarray(via_config))


def test_degenerate_schedule_matches_config_path_padded():
    n = 32
    d = lb_partition(n, 3).d
    pads = np.array([n, 2 * n, n], dtype=np.int64)
    m = random_signal(n, seed=8)
    for cfg in (PlanConfig(pad="fpm"), PlanConfig(pad="fpm", batched=False)):
        sched = SegmentSchedule.homogeneous(cfg, n, d, pads)
        via_schedule = segment_row_ffts(m, d, schedule=sched)
        via_config = segment_row_ffts(m, d, pad_lengths=pads, config=cfg)
        np.testing.assert_array_equal(np.asarray(via_schedule),
                                      np.asarray(via_config))


def test_schedule_and_config_conflict_is_an_error():
    n, d = 16, lb_partition(16, 2).d
    m = random_signal(n)
    sched = SegmentSchedule.homogeneous(PlanConfig(), n, d)
    with pytest.raises(ValueError):
        segment_row_ffts(m, d, schedule=sched, config=PlanConfig())
    with pytest.raises(ValueError):
        _pfft_limb(m, d, schedule=sched, config=PlanConfig())
    # pad_lengths conflicts too: the schedule carries its own lengths
    pads = np.array([16, 32], dtype=np.int64)
    with pytest.raises(ValueError):
        segment_row_ffts(m, d, schedule=sched, pad_lengths=pads)
    with pytest.raises(ValueError):
        _pfft_limb(m, d, schedule=sched, pad_lengths=pads)


def test_plan_segment_batches_configs_matches_executor_dispatch_count():
    """len(plan_segment_batches(configs=)) must equal the number of
    dispatch groups the executor actually runs, batched=False opt-outs
    included."""
    n = 32
    d = np.array([8, 8, 8, 8])
    pads = np.array([n, 64, 64, n], dtype=np.int64)
    cfgs = [PlanConfig(batched=False, pad="fpm")] * 4
    by_cfg = plan_segment_batches(d, pads, n, configs=cfgs)
    sched = SegmentSchedule.from_parts(n, d, pads, cfgs)
    assert len(by_cfg) == len(sched.batch_groups()) == 4
    total = np.sort(np.concatenate(list(by_cfg.values())))
    np.testing.assert_array_equal(total, np.arange(n))


# -------------------------------------------------- mixed-backend phases

def test_mixed_backend_phase_matches_dft_ref():
    """A schedule mixing the library FFT, the pure-jnp Stockham, and the
    Pallas kernel across segments of one phase computes the same row DFT
    as the naive oracle (satellite acceptance)."""
    n = 32
    d = np.array([12, 10, 10])
    m = random_signal(n, seed=11)
    sched = SegmentSchedule.from_parts(
        n, d, None,
        [PlanConfig(), PlanConfig(radix=2), PlanConfig(radix=4)])
    assert len(sched.configs) == 3
    out = segment_row_ffts(m, d, schedule=sched)
    ref = dft1d_naive(m, axis=-1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-2, rtol=1e-3)


def test_mixed_backend_full_limb_matches_fft2():
    n = 32
    d = np.array([16, 16])
    m = random_signal(n, seed=12)
    sched = SegmentSchedule.from_parts(
        n, d, None, [PlanConfig(), PlanConfig(radix=2)])
    out = _pfft_limb(m, d, schedule=sched)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft2(m)), atol=5e-2)


# ----------------------------------------------------------- wisdom v2

def test_wisdom_schedule_roundtrip(tmp_path):
    path = str(tmp_path / "wisdom.json")
    key = wisdom_key(n=48, dtype="complex64", p=3, method="fpm-pad",
                     backend="cpu", detail="cafe0123")
    sched = SegmentSchedule.from_parts(
        48, [16, 32], [48, 64],
        [PlanConfig(pad="fpm"), PlanConfig(radix=4, pad="fpm")])
    record_wisdom(path, key, sched, mode="measure", time_s=3e-4)
    got, entry = lookup_wisdom(path, key)
    assert isinstance(got, SegmentSchedule) and got == sched
    assert entry["mode"] == "measure" and "schedule" in entry
    # configs and schedules coexist in one store
    key2 = wisdom_key(n=48, dtype="complex64", p=3, method="lb", backend="cpu")
    record_wisdom(path, key2, PlanConfig(radix=2), mode="estimate")
    got2, _ = lookup_wisdom(path, key2)
    assert got2 == PlanConfig(radix=2)
    assert lookup_wisdom(path, key)[0] == sched  # first entry survived


def test_wisdom_v1_entries_become_misses(tmp_path):
    """A pre-schedule (v1) store is a whole-file miss — never a crash —
    and recording over it rewrites a clean v2 store."""
    path = str(tmp_path / "wisdom.json")
    key = wisdom_key(n=32, dtype="complex64", p=2, method="lb", backend="cpu")
    v1_doc = {"version": 1, "entries": {key: {
        "config": {"radix": None, "fused": False, "batched": True,
                   "pad": "none", "pipeline_panels": 1},
        "mode": "measure", "time_s": 1e-4}}}
    with open(path, "w") as fh:
        json.dump(v1_doc, fh)
    assert WISDOM_VERSION == 3
    assert load_wisdom(path) == {}
    assert lookup_wisdom(path, key) is None
    plan = plan_pfft(32, p=2, method="lb", wisdom=path)  # miss, no crash
    assert plan.tuning["source"] == "off"
    record_wisdom(path, key, PlanConfig(), mode="measure")
    assert json.load(open(path))["version"] == WISDOM_VERSION
    assert lookup_wisdom(path, key) is not None


def test_stale_schedule_structure_is_a_miss(tmp_path):
    """A stored schedule that no longer describes the current partition
    (e.g. a hand-edited store) is a miss, not an error."""
    path = str(tmp_path / "wisdom.json")
    key = wisdom_key(n=32, dtype="complex64", p=2, method="lb", backend="cpu")
    wrong = SegmentSchedule.from_parts(32, [10, 22], None,
                                       [PlanConfig(), PlanConfig()])
    record_wisdom(path, key, wrong, mode="measure")
    plan = plan_pfft(32, p=2, method="lb", wisdom=path)  # lb d = [16, 16]
    assert plan.tuning["source"] == "off"
    assert plan.schedule.matches(plan.d)


def test_explicit_config_keeps_method_pad_semantics():
    """pad is semantics owned by the method: an explicit config= with the
    wrong pad is normalized, so fpm-czt still runs Bluestein (exact DFT)
    instead of pad-and-crop at Bluestein lengths, and fpm-pad keeps its
    padded-signal semantics (PR-2 behavior)."""
    n = 16
    fpms = hetero_fpms(n)
    m = random_signal(n, seed=21)
    plan = plan_pfft(n, fpms=fpms, method="fpm-czt", config=PlanConfig())
    assert plan.config.pad == "czt"
    np.testing.assert_allclose(np.asarray(plan.execute(m)),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)
    plan_pad = plan_pfft(n, fpms=fpms, method="fpm-pad",
                         config=PlanConfig(radix=2))
    assert plan_pad.config.pad == "fpm" and plan_pad.config.radix == 2
    ref = plan_pfft(n, fpms=fpms, method="fpm-pad")
    np.testing.assert_allclose(np.asarray(plan_pad.execute(m)),
                               np.asarray(ref.execute(m)), atol=5e-2)
    # fused drops on padded methods, like the legacy shim documents
    plan_f = plan_pfft(n, fpms=fpms, method="fpm-pad",
                       config=PlanConfig(radix=4, fused=True))
    assert not plan_f.config.fused and plan_f.config.pad == "fpm"


def test_heterogeneous_schedule_served_from_wisdom(tmp_path):
    """A genuinely mixed per-segment schedule recorded for the plan's
    exact partition structure is served back intact and executes."""
    path = str(tmp_path / "wisdom.json")
    n = 48
    probe = plan_pfft(n, p=2, method="lb", wisdom=path)
    key = probe.tuning["wisdom_key"]
    mixed = SegmentSchedule.from_parts(
        n, probe.d, None, [PlanConfig(), PlanConfig(radix=2)])
    assert len(mixed.configs) == 2
    record_wisdom(path, key, mixed, mode="measure", time_s=1e-3)
    served = plan_pfft(n, p=2, method="lb", wisdom=path)
    assert served.tuning["source"] == "wisdom"
    assert served.schedule == mixed
    m = random_signal(n, seed=22)
    np.testing.assert_allclose(np.asarray(served.execute(m)),
                               np.asarray(jnp.fft.fft2(m)), atol=5e-2)


def test_plan_pfft_persists_and_serves_schedules(tmp_path):
    path = str(tmp_path / "wisdom.json")
    n = 32
    p1 = plan_pfft(n, p=2, method="lb", tune="measure", wisdom=path)
    assert p1.tuning["source"] == "measure"
    assert isinstance(p1.schedule, SegmentSchedule)
    p2 = plan_pfft(n, p=2, method="lb", tune="measure", wisdom=path)
    assert p2.tuning["source"] == "wisdom"
    assert p2.schedule == p1.schedule
    m = random_signal(n)
    np.testing.assert_allclose(np.asarray(p2.execute(m)),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)


# ------------------------------------------------------ acceptance scenario

def test_hetero_fpms_produce_multi_config_schedule():
    """ISSUE 3 acceptance: one slow + p-1 fast processors, estimate mode,
    accelerator cost constants => a schedule with >= 2 distinct configs
    whose makespan estimate is <= the best homogeneous config's."""
    n = 48  # non-pow2: the unpadded group keeps the library FFT
    d = np.array([16, 16, 16])
    pads = np.array([48, 64, 64], dtype=np.int64)  # fast procs pad to pow2
    fpms = hetero_fpms(n)
    params = CostParams.for_backend("tpu")
    sched, info = tune_schedule(n, d=d, pad_lengths=pads, fpms=fpms,
                                mode="estimate", pad="fpm", params=params)
    assert len(sched.configs) >= 2
    assert info["chosen"] == "heterogeneous"
    est_hetero = estimate_schedule_cost(sched, fpms=fpms, params=params)
    est_homo = min(
        estimate_cost(c, n=n, d=d, pad_lengths=pads, fpms=fpms, params=params)
        for c in candidate_configs(n, pad="fpm", d=d))
    assert est_hetero <= est_homo
    assert info["heterogeneous"]["est_s"] <= info["homogeneous"]["est_s"]

    # The schedule executes to the padded-signal oracle (pad-and-crop
    # DFT semantics, per segment) with the exact same values as the
    # homogeneous library path at the same lengths.
    m = random_signal(n, seed=13)
    out = _pfft_limb(m, d, schedule=sched)
    ref = _pfft_limb(m, d, pad_lengths=pads, config=PlanConfig(pad="fpm"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-2, rtol=1e-3)


def test_tune_schedule_single_length_delegates_to_homogeneous():
    n = 64
    d = lb_partition(n, 3).d
    sched, info = tune_schedule(n, d=d, mode="estimate",
                                params=CostParams.for_backend("cpu"))
    assert info["chosen"] == "homogeneous"
    assert sched.common_config is not None
    assert "ranked" in info  # PR-2 audit trail preserved


def test_tune_schedule_measure_mode_multi_length():
    n = 24
    d = np.array([8, 8, 8])
    pads = np.array([24, 32, 32], dtype=np.int64)
    sched, info = tune_schedule(n, d=d, pad_lengths=pads, mode="measure",
                                pad="fpm", top_k=2, reps=1)
    assert sched.matches(d, pads)
    assert info["time_s"] > 0
    assert "group_measured" in info and "measured" in info
    m = random_signal(n, seed=14)
    out = _pfft_limb(m, d, schedule=sched)
    ref = _pfft_limb(m, d, pad_lengths=pads, config=PlanConfig(pad="fpm"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-2, rtol=1e-3)


# ------------------------------------------------------------ batched czt

def test_czt_same_length_segments_share_a_dispatch():
    n = 16
    d = np.array([6, 6, 4])
    lens = np.array([32, 32, 32], dtype=np.int64)
    cfgs = [PlanConfig(pad="czt")] * 3
    sched = SegmentSchedule.from_parts(n, d, lens, cfgs)
    assert len(sched.batch_groups()) == 1  # one Bluestein dispatch
    m = random_signal(n, seed=15)
    out = _pfft_limb(m, d, schedule=sched)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)


def test_pfft_fpm_czt_matches_exact_dft_via_schedule_path():
    n = 24
    fpms = hetero_fpms(n)
    m = random_signal(n, seed=16)
    out, part, lens = pfft_fpm_czt(m, fpms, return_partition=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)
    assert np.all(lens >= 2 * n - 1)


# ------------------------------------------------------------- calibration

def _synth_wisdom_entries(params: CostParams, n_entries: int = 10) -> dict:
    """Measured entries whose times are exactly the cost model's
    prediction under ``params`` — a fit must recover the constants."""
    entries = {}
    for i in range(n_entries):
        n = 32 * (1 + i % 4)
        p = 2 + i % 3
        cfg = PlanConfig(radix=2 if i % 2 else None)
        d = lb_partition(n, p).d
        t = estimate_cost(cfg, n=n, d=d, params=params)
        key = wisdom_key(n=n, dtype="complex64", p=p, method="lb",
                         backend="cpu")
        entries[f"{key}|i={i}"] = {"config": cfg.to_dict(),
                                   "mode": "measure", "time_s": float(t)}
    return entries


def test_fit_cost_params_recovers_synthetic_constants():
    true = CostParams.for_backend("cpu")
    entries = _synth_wisdom_entries(true, 12)
    fitted = fit_cost_params(entries, backend="cpu")
    assert fitted.backend_factor["xla"] == pytest.approx(
        true.backend_factor["xla"], rel=0.2)
    assert fitted.backend_factor["stockham"] == pytest.approx(
        true.backend_factor["stockham"], rel=0.2)
    assert fitted.dispatch_overhead_s == pytest.approx(
        true.dispatch_overhead_s, rel=0.2)
    # pallas never sampled -> hard-coded constant kept
    assert fitted.backend_factor["pallas"] == true.backend_factor["pallas"]


def test_fit_cost_params_falls_back_below_threshold():
    true = CostParams.for_backend("cpu")
    entries = _synth_wisdom_entries(true, 3)
    assert fit_cost_params(entries, backend="cpu") == true  # < 8 entries
    assert fit_cost_params({}, backend="cpu") == true
    # corrupt entries are skipped, not fatal
    bad = dict(entries)
    bad["n=oops"] = {"time_s": "NaN?"}
    assert fit_cost_params(bad, backend="cpu") == true


def test_fit_cost_params_from_file(tmp_path):
    path = str(tmp_path / "wisdom.json")
    true = CostParams.for_backend("cpu")
    for key, entry in _synth_wisdom_entries(true, 9).items():
        record_wisdom(path, key, PlanConfig.from_dict(entry["config"]),
                      mode="measure", time_s=entry["time_s"])
    fitted = fit_cost_params(path, backend="cpu")
    assert fitted.backend_factor["xla"] == pytest.approx(
        true.backend_factor["xla"], rel=0.2)


# ------------------------------------------------------------- distributed

def test_dist_rejects_unloweable_heterogeneous_schedule():
    """Heterogeneous row-FFT mixes lower as device-group programs now;
    what still raises the named SPMD error are program-knob mixes (fused
    here) and entries that cannot tile the mesh's equal shards."""
    from repro.core.pfft_dist import pfft2_distributed
    mesh = jax.make_mesh((1,), ("fft",))
    n = 16
    fused_mix = SegmentSchedule.from_parts(
        n, [8, 8], None, [PlanConfig(radix=4, fused=True), PlanConfig()])
    with pytest.raises(ValueError, match="SPMD"):
        pfft2_distributed(random_signal(n), mesh, "fft", schedule=fused_mix)
    # 1-device mesh: n_loc = 16, entries of 8 rows can't tile the shard
    untileable = SegmentSchedule.from_parts(
        n, [8, 8], None, [PlanConfig(), PlanConfig(radix=2)])
    with pytest.raises(ValueError, match="SPMD"):
        pfft2_distributed(random_signal(n), mesh, "fft", schedule=untileable)


def test_dist_schedule_carries_fpm_pad_length():
    """The schedule's FPM-chosen effective length reaches the local
    phase (not the model-free smooth default); mixed lengths run at the
    schedule's max — the device-group uniform-length rule."""
    from repro.core.pfft_dist import pfft2_distributed
    mesh = jax.make_mesh((1,), ("fft",))
    n = 48
    m = random_signal(n, seed=23)
    sched = SegmentSchedule.homogeneous(PlanConfig(pad="fpm"), n, [n],
                                        np.array([64]))
    out = pfft2_distributed(m, mesh, "fft", schedule=sched)
    ref = pfft2_distributed(m, mesh, "fft", padded="crop", pad_len=64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    mixed_len = SegmentSchedule.from_parts(
        n, [24, 24], np.array([48, 64]), [PlanConfig(pad="fpm")] * 2)
    out_mixed = pfft2_distributed(m, mesh, "fft", schedule=mixed_len)
    np.testing.assert_array_equal(np.asarray(out_mixed), np.asarray(ref))


def test_dist_schedule_and_fused_single_device():
    from repro.core.pfft_dist import pfft2_distributed
    mesh = jax.make_mesh((1,), ("fft",))
    n = 32
    m = random_signal(n, seed=17)
    ref = jnp.fft.fft2(m)
    sched = SegmentSchedule.homogeneous(PlanConfig(radix=4, fused=True), n, [n])
    out = pfft2_distributed(m, mesh, "fft", schedule=sched)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-2)
    # fused pipelined panels agree with the unfused path too
    out_p = pfft2_distributed(
        m, mesh, "fft", config=PlanConfig(radix=4, fused=True,
                                          pipeline_panels=4))
    un = pfft2_distributed(m, mesh, "fft")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(un), atol=2e-2)


_FUSED_2DEV_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.pfft_dist import pfft2_distributed
from repro.plan import PlanConfig

mesh = jax.make_mesh((2,), ("fft",))
rng = np.random.default_rng(5)
m = jnp.asarray((rng.standard_normal((32, 32))
                 + 1j*rng.standard_normal((32, 32))).astype(np.complex64))
ref = jnp.fft.fft2(m)
unfused = pfft2_distributed(m, mesh, "fft")
fused = pfft2_distributed(m, mesh, "fft", config=PlanConfig(radix=4, fused=True))
assert float(jnp.max(jnp.abs(fused - ref))) < 1e-2, "fused vs fft2"
assert float(jnp.max(jnp.abs(fused - unfused))) < 1e-2, "fused vs unfused"
fp = pfft2_distributed(m, mesh, "fft",
                       config=PlanConfig(radix=4, fused=True, pipeline_panels=2))
assert float(jnp.max(jnp.abs(fp - unfused))) < 1e-2, "fused pipelined"
print("FUSED_DIST_OK")
"""


def test_fused_equals_unfused_on_two_device_mesh(dist_subprocess):
    """Satellite acceptance: the planner's fused pick reaches the
    distributed local phase and matches the unfused path on a real
    (faked) 2-device mesh — via the shared conftest dist rig."""
    dist_subprocess(_FUSED_2DEV_SCRIPT, devices=2, sentinel="FUSED_DIST_OK")


# ------------------------------------------------------- property tests
# Randomly generated valid field values: wisdom keys must be injective
# over every field (topology included — the schema-v3 point), and the
# dict round-trips that back the wisdom wire format must be identity.

_KEY_NS = (16, 32, 48)
# The real pipeline plans float inputs and its method names carry the
# "rfft-" prefix — both dimensions must stay injective alongside the
# complex vocabulary (a real plan served to a complex problem, or one
# precision's plan served to another, would execute the wrong transform).
_KEY_DTYPES = ("complex64", "complex128", "float32", "float64")
_KEY_METHODS = ("lb", "fpm", "fpm-pad", "fpm-czt",
                "rfft-lb", "rfft-fpm", "rfft-fpm-pad",
                # The 3-D pencil family and the four-step huge-1-D method
                # share the store with the 2-D vocabulary.
                "pfft3-lb", "pfft1-large")
_KEY_BACKENDS = ("cpu", "tpu")
_KEY_DETAILS = (None, "cafe0123", "70a61b03")
# The 2-D-mesh digest ('+'-joined per-axis terms) must stay injective
# against every 1-D digest and against its own transposed mesh, and the
# multi-host prefix ("<hosts>hx") against every single-host form and
# every other host factorization of the same device count.
_KEY_TOPOS = (None, "2xfft.cpu.k1", "4xfft.cpu.k1-2-4", "4xrows.cpu.k1",
              "4xfft_r+2xfft_c.cpu.k1-2", "2xfft_r+4xfft_c.cpu.k1-2",
              "8xfft.cpu.k1-2-4-8", "2hx8xfft.cpu.k1-2-4-8",
              "4hx8xfft.cpu.k1-2-4-8", "2hx4xfft_r+2xfft_c.cpu.k1-2")


def _key_tuple_from_draws(n_i, dtype_i, p, method_i, backend_i, detail_i,
                          topo_i):
    return (_KEY_NS[n_i], _KEY_DTYPES[dtype_i], p, _KEY_METHODS[method_i],
            _KEY_BACKENDS[backend_i], _KEY_DETAILS[detail_i],
            _KEY_TOPOS[topo_i])


@given(a_n=st.integers(0, 2), a_dtype=st.integers(0, 3), a_p=st.integers(1, 8),
       a_method=st.integers(0, 8), a_backend=st.integers(0, 1),
       a_detail=st.integers(0, 2), a_topo=st.integers(0, 9),
       b_n=st.integers(0, 2), b_dtype=st.integers(0, 3), b_p=st.integers(1, 8),
       b_method=st.integers(0, 8), b_backend=st.integers(0, 1),
       b_detail=st.integers(0, 2), b_topo=st.integers(0, 9))
@settings(max_examples=150, deadline=None)
def test_wisdom_keys_never_collide(a_n, a_dtype, a_p, a_method, a_backend,
                                   a_detail, a_topo, b_n, b_dtype, b_p,
                                   b_method, b_backend, b_detail, b_topo):
    ta = _key_tuple_from_draws(a_n, a_dtype, a_p, a_method, a_backend,
                               a_detail, a_topo)
    tb = _key_tuple_from_draws(b_n, b_dtype, b_p, b_method, b_backend,
                               b_detail, b_topo)
    ka = wisdom_key(n=ta[0], dtype=ta[1], p=ta[2], method=ta[3],
                    backend=ta[4], detail=ta[5], topology=ta[6])
    kb = wisdom_key(n=tb[0], dtype=tb[1], p=tb[2], method=tb[3],
                    backend=tb[4], detail=tb[5], topology=tb[6])
    assert (ka == kb) == (ta == tb), f"{ta} vs {tb}: {ka!r} vs {kb!r}"


@settings(max_examples=100, deadline=None)
@given(radix_i=st.integers(0, 2), fused=st.sampled_from((False, True)),
       batched=st.sampled_from((False, True)),
       pad=st.sampled_from(("none", "fpm", "czt")),
       panels=st.integers(1, 8), real=st.sampled_from((False, True)))
def test_plan_config_roundtrip_is_identity(radix_i, fused, batched, pad,
                                           panels, real):
    if fused:
        pad = "none"  # the one structural constraint on valid configs
    if real and pad == "czt":
        pad = "fpm"  # the real pipeline has no Bluestein form
    cfg = PlanConfig(radix=(None, 2, 4)[radix_i], fused=fused,
                     batched=batched, pad=pad, pipeline_panels=panels,
                     real=real)
    assert PlanConfig.from_dict(cfg.to_dict()) == cfg


_CFG_POOL = (PlanConfig(), PlanConfig(radix=2), PlanConfig(radix=4),
             PlanConfig(batched=False), PlanConfig(pad="fpm"),
             PlanConfig(pad="czt"), PlanConfig(radix=4, fused=True),
             PlanConfig(pipeline_panels=4), PlanConfig(real=True),
             PlanConfig(radix=2, real=True, pad="fpm"))


@settings(max_examples=100, deadline=None)
@given(p=st.integers(1, 4), r1=st.integers(1, 8), r2=st.integers(1, 8),
       r3=st.integers(1, 8), r4=st.integers(1, 8),
       pad_mult=st.sampled_from((1, 2, 4)), slack=st.integers(0, 5),
       cfg0=st.integers(0, len(_CFG_POOL) - 1),
       cfg_step=st.integers(0, len(_CFG_POOL) - 1))
def test_segment_schedule_roundtrip_is_identity(p, r1, r2, r3, r4, pad_mult,
                                                slack, cfg0, cfg_step):
    rows = [r1, r2, r3, r4][:p]
    n = sum(rows) + slack  # schedules may cover fewer rows than N
    pads = np.array([n * pad_mult] * p, dtype=np.int64)
    configs = [_CFG_POOL[(cfg0 + k * cfg_step) % len(_CFG_POOL)]
               for k in range(p)]
    sched = SegmentSchedule.from_parts(n, np.array(rows), pads, configs)
    assert SegmentSchedule.from_dict(sched.to_dict()) == sched
    assert sched.total_rows == sum(rows)
    # the wire format survives a JSON round trip too (wisdom on disk)
    assert SegmentSchedule.from_dict(
        json.loads(json.dumps(sched.to_dict()))) == sched


@settings(max_examples=150, deadline=None)
@given(a_hosts=st.integers(1, 4), a_local=st.integers(1, 4),
       b_hosts=st.integers(1, 4), b_local=st.integers(1, 4))
def test_topology_digest_host_injectivity(a_hosts, a_local,
                                          b_hosts, b_local):
    """The host component keeps every (hosts, local) factorization of a
    device axis distinct — a 2-host x 4-device topology must never be
    served a 1x8 or 4x2 measurement — while single-host digests keep the
    exact pre-multi-host grammar, so v3 stores tuned before the host
    component keep serving single-host lookups."""
    from repro.plan.wisdom import topology_digest

    def digest(hosts, local):
        return topology_digest(None, "fft", devices=hosts * local,
                               platform="cpu", panels=(1,), hosts=hosts)

    da, db = digest(a_hosts, a_local), digest(b_hosts, b_local)
    assert (da == db) == ((a_hosts, a_local) == (b_hosts, b_local)), \
        f"{(a_hosts, a_local)} vs {(b_hosts, b_local)}: {da!r} vs {db!r}"
    if a_hosts == 1:
        # hosts=1 is the flat axis: the digest is byte-identical to the
        # host-agnostic form old stores were recorded under.
        assert da == topology_digest(None, "fft", devices=a_local,
                                     platform="cpu", panels=(1,))
        assert "hx" not in da
    else:
        assert da.startswith(f"{a_hosts}hx")
