"""Fault-injection layer: deterministic, un-optimizable, reversible.

The chaos harness is only trustworthy if the faults themselves are: the
``repeated`` slowdown must be bit-identical to the unfaulted program
(else a recovery test can't tell corruption from injection), one-shot
failures must fire exactly once, and the wisdom-store chaos must drive
the retry/timeout paths it exists to exercise.
"""

import json
import os
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.plan.config import PlanConfig
from repro.plan.wisdom import (load_wisdom, lookup_wisdom, record_wisdom,
                               wisdom_key)
from repro.runtime.faults import (DeviceLostError, FaultInjector,
                                  corrupt_wisdom, get_injector, inject,
                                  locked_wisdom, repeated,
                                  retry_with_backoff)


# ------------------------------------------------------------- repeated

def test_repeated_bit_identical_under_jit():
    """The slowdown multiplies wall time, never changes the answer: the
    exact power-of-two rescale keeps every repeat's output bit-equal for
    a linear fn, so the fold is exact."""
    rng = np.random.default_rng(3)
    x = jnp.asarray((rng.standard_normal((8, 32))
                     + 1j * rng.standard_normal((8, 32))).astype("complex64"))
    base = jnp.fft.fft
    for reps in (1, 2, 3, 5, 8):
        slowed = jax.jit(repeated(base, reps))
        np.testing.assert_array_equal(np.asarray(slowed(x)),
                                      np.asarray(jax.jit(base)(x)))


def test_repeated_reps_leq_one_is_identity():
    fn = lambda x: x
    assert repeated(fn, 1) is fn
    assert repeated(fn, 0) is fn


# ------------------------------------------------------------- injector

def test_injector_slow_group_epoch_and_repeats():
    inj = FaultInjector()
    e0 = inj.epoch
    assert inj.local_repeats(4) is None           # zero-overhead path
    inj.slow_group(2, 3)
    assert inj.epoch == e0 + 1                    # traced programs rebuild
    assert inj.local_repeats(4) == [1, 1, 3, 1]
    assert inj.repeat_for(2) == 3 and inj.repeat_for(0) == 1
    inj.slow_group(2, 1)                          # factor <= 1 clears
    assert inj.local_repeats(4) is None
    assert inj.epoch == e0 + 2


def test_injector_fail_execute_is_one_shot():
    inj = FaultInjector()
    inj.fail_execute(5, lost=(1,))
    inj.check_execute(4)                          # other calls untouched
    with pytest.raises(DeviceLostError) as err:
        inj.check_execute(5)
    assert err.value.lost == (1,)
    inj.check_execute(5)                          # fired once, now clear
    assert not inj.active


def test_inject_context_clears_and_bumps_epoch():
    inj = get_injector()
    e0 = inj.epoch
    with inject() as scoped:
        assert scoped is inj
        scoped.slow_group(0, 4)
        assert scoped.active
    assert not inj.active
    assert inj.epoch > e0 + 1    # the clear itself re-traces slowdowns
    assert any(ev["kind"] == "slow_group" for ev in inj.log)


# ---------------------------------------------------------------- retry

def test_retry_with_backoff_recovers_and_exhausts():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert retry_with_backoff(flaky, attempts=3, base_s=0.05,
                              sleep=sleeps.append) == "ok"
    assert sleeps == [0.05, 0.1]

    def always():
        raise ValueError("permanent")

    with pytest.raises(ValueError, match="permanent"):
        retry_with_backoff(always, attempts=2, sleep=sleeps.append)


# ---------------------------------------------------------- wisdom chaos

def _key():
    return wisdom_key(n=32, dtype="complex64", p=2, method="lb",
                      backend="cpu")


def test_corrupt_wisdom_is_a_miss_and_rewritable(tmp_path):
    path = str(tmp_path / "w.json")
    record_wisdom(path, _key(), PlanConfig(), mode="estimate")
    assert lookup_wisdom(path, _key()) is not None
    corrupt_wisdom(path)
    assert load_wisdom(path) == {}                # miss, never an error
    assert lookup_wisdom(path, _key()) is None
    record_wisdom(path, _key(), PlanConfig(radix=2), mode="estimate")
    plan, _ = lookup_wisdom(path, _key())
    assert plan == PlanConfig(radix=2)            # store healed by rewrite
    with open(path) as fh:
        json.load(fh)                             # valid JSON again


def test_record_wisdom_write_retry(tmp_path, monkeypatch):
    path = str(tmp_path / "w.json")
    real_replace = os.replace
    fails = {"n": 2}

    def flaky_replace(src, dst):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("EIO")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", flaky_replace)
    monkeypatch.setattr(time, "sleep", lambda s: None)
    with pytest.raises(OSError):
        record_wisdom(path, _key(), PlanConfig(), mode="estimate", retries=1)
    fails["n"] = 2
    record_wisdom(path, _key(), PlanConfig(), mode="estimate", retries=2)
    assert lookup_wisdom(path, _key()) is not None


def test_locked_wisdom_times_out_then_succeeds(tmp_path):
    """flock attaches to the open file description, so a lock held in
    this same process genuinely contends: record_wisdom's bounded wait
    must raise TimeoutError while held and succeed after release."""
    pytest.importorskip("fcntl")
    path = str(tmp_path / "w.json")
    with locked_wisdom(path):
        with pytest.raises(TimeoutError, match="still held"):
            record_wisdom(path, _key(), PlanConfig(), mode="estimate",
                          lock_timeout_s=0.2)
    record_wisdom(path, _key(), PlanConfig(), mode="estimate",
                  lock_timeout_s=0.2)
    assert lookup_wisdom(path, _key()) is not None


def test_locked_wisdom_blocking_default_waits(tmp_path):
    """Without a timeout the writer blocks (historical behavior) and
    lands once the lock is released."""
    pytest.importorskip("fcntl")
    path = str(tmp_path / "w.json")
    release = threading.Event()
    done = threading.Event()

    def holder():
        with locked_wisdom(path):
            release.set()
            done.wait(5.0)

    t = threading.Thread(target=holder, daemon=True)
    t.start()
    assert release.wait(5.0)
    writer_done = []

    def writer():
        record_wisdom(path, _key(), PlanConfig(), mode="estimate")
        writer_done.append(True)

    w = threading.Thread(target=writer, daemon=True)
    w.start()
    time.sleep(0.1)
    assert not writer_done                        # genuinely blocked
    done.set()
    w.join(5.0)
    assert writer_done and lookup_wisdom(path, _key()) is not None
