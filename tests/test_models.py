"""Per-arch smoke tests + cross-mode consistency (prefill/decode == full
forward) + MoE dispatch against a direct per-token reference."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.models import transformer as T
from repro.models.moe import moe_apply, moe_init, moe_capacity
from repro.models.registry import ARCH_IDS, get_config, get_smoke_config


def batch_for(cfg, key, B, S):
    if cfg.modality == "audio":
        return {"features": jax.random.normal(key, (B, S, cfg.d_model)),
                "mask": jax.random.bernoulli(key, 0.2, (B, S)),
                "targets": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.modality == "vision":
        P = cfg.n_prefix_embeds
        return {"tokens": jax.random.randint(key, (B, S - P), 0, cfg.vocab),
                "patches": jax.random.normal(key, (B, P, cfg.d_model)),
                "targets": jax.random.randint(key, (B, S - P), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_train_step(arch):
    """Reduced config: one forward/train step on CPU, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 32
    batch = batch_for(cfg, key, B, S)
    loss, metrics = jax.jit(
        lambda p, b: T.loss_fn(p, b, cfg, vocab_chunk=16))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    hidden, aux = T.forward(params, batch, cfg)
    exp_T = S if cfg.modality != "vision" else S  # patches + text = S
    assert hidden.shape == (B, exp_T, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact assigned numbers."""
    cfg = get_config(arch)
    expected = {
        "dbrx_132b": (40, 6144, 48, 8, 100352),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 102400),
        "internlm2_1_8b": (24, 2048, 16, 8, 92544),
        "qwen2_5_3b": (36, 2048, 16, 2, 151936),
        "chatglm3_6b": (28, 4096, 32, 2, 65024),
        "stablelm_3b": (32, 2560, 32, 32, 50304),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 32000),
        "xlstm_125m": (12, 768, 4, 4, 50304),
        "zamba2_7b": (81, 3584, 32, 32, 32000),
        "hubert_xlarge": (48, 1280, 16, 16, 504),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == expected


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "qwen2_5_3b",
                                  "chatglm3_6b", "stablelm_3b",
                                  "deepseek_v2_lite_16b", "zamba2_7b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy decode continuation must equal teacher-forced forward logits.
    MoE archs get ample capacity: token->capacity-slot assignment depends on
    batch composition, so capacity *drops* legitimately differ between
    prefill and decode (inherent to capacity-routed MoE)."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # full forward logits at the last position
    hidden, _ = T.forward(params, {"tokens": toks}, cfg)
    from repro.models.transformer import logits_fn, apply_norm
    h = apply_norm(params["final_norm"], hidden[:, -1:], cfg.norm)
    full_logits = logits_fn(params, h, cfg)[:, 0]

    # prefill path
    cache = T.init_cache(cfg, B, S + 4)
    pf_logits, cache = T.prefill(params, {"tokens": toks}, cfg, cache)
    np.testing.assert_allclose(np.asarray(pf_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=0.15, rtol=0.05)

    # decode path: feed tokens one by one, compare against prefill of S+1
    cache2 = T.init_cache(cfg, B, S + 4)
    pf2_logits, cache2 = T.prefill(params, {"tokens": toks[:, :S - 1]}, cfg,
                                   cache2)
    dec_logits, cache2 = T.decode_step(params, cache2, toks[:, S - 1],
                                       jnp.int32(S - 1), cfg)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=0.15, rtol=0.05)


def test_unrolled_matches_scanned():
    """scan_layers=False (analysis lowering) must be numerically identical."""
    cfg = get_smoke_config("qwen2_5_3b")
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    batch = batch_for(cfg, key, 2, 16)
    l1, _ = T.loss_fn(params, batch, cfg, vocab_chunk=8, scan_layers=True)
    l2, _ = T.loss_fn(params, batch, cfg, vocab_chunk=None, scan_layers=False)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-2)


# ------------------------------------------------------------------- MoE

def moe_reference(p, x, cfg: MoECfg, mlp_kind="swiglu"):
    """Direct per-token loop: y_t = sum_j gate_j * FFN_{e_j}(x_t) (no
    capacity drops).  Oracle for the einsum dispatch."""
    G, T_, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    y = np.zeros((G, T_, d), np.float32)
    xe = np.asarray(x, np.float32)
    wg, wu, wd = (np.asarray(p[k], np.float32) for k in ("wg", "wu", "wd"))
    for g in range(G):
        for t in range(T_):
            for j in range(cfg.top_k):
                e = int(gi[g, t, j])
                h = xe[g, t] @ wg[e]
                h = h / (1 + np.exp(-h)) * (xe[g, t] @ wu[e])
                y[g, t] += float(gv[g, t, j]) * (h @ wd[e])
    return y


def test_moe_matches_per_token_reference():
    cfg = MoECfg(n_experts=4, top_k=2, d_expert=16, capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    p = moe_init(key, 8, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 6, 8), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    ref = moe_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor tiny, overflow tokens are dropped, not mangled."""
    cfg = MoECfg(n_experts=2, top_k=1, d_expert=8, capacity_factor=0.01)
    p = moe_init(jax.random.PRNGKey(5), 4, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 64, 4), jnp.float32)
    y, _ = moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    # capacity 8 (min) of 64 tokens -> most outputs are exactly zero
    zero_rows = int(jnp.sum(jnp.all(y == 0, axis=-1)))
    assert zero_rows >= 40


def test_moe_capacity_helper():
    cfg = MoECfg(n_experts=8, top_k=2, d_expert=4, capacity_factor=1.25)
    c = moe_capacity(1024, cfg)
    assert c >= 1024 * 2 / 8 * 1.25
    assert c % 8 == 0
