"""3-D DFT extension (paper future work §VII): oracles + distributed."""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp

from repro.core.pfft3d import pfft3_fpm, pfft3_fpm_pad, pfft3_lb
from test_pfft import fpms_for


def cube(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal((n, n, n))
                        + 1j * rng.standard_normal((n, n, n))).astype(np.complex64))


def test_pfft3_lb_matches_fftn():
    m = cube(16)
    np.testing.assert_allclose(np.asarray(pfft3_lb(m, 3)),
                               np.asarray(jnp.fft.fftn(m)), atol=2e-2)


def test_pfft3_fpm_matches_fftn():
    m = cube(16)
    out, part = pfft3_fpm(m, fpms_for(16), return_partition=True)
    assert part.d.sum() == 16
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fftn(m)),
                               atol=2e-2)


def test_pfft3_pad_runs_and_is_finite():
    m = cube(12)
    out, part, pads = pfft3_fpm_pad(m, fpms_for(12), return_partition=True)
    assert out.shape == (12, 12, 12)
    assert bool(jnp.all(jnp.isfinite(jnp.abs(out))))


SRC = os.path.join(os.path.dirname(__file__), "..", "src")
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {src!r})
import numpy as np, jax, jax.numpy as jnp
from repro.core.pfft3d import pfft3_distributed
mesh = jax.make_mesh((8,), ("fft",))
rng = np.random.default_rng(1)
m = jnp.asarray((rng.standard_normal((16,16,16))
                 + 1j*rng.standard_normal((16,16,16))).astype(np.complex64))
out = pfft3_distributed(m, mesh, "fft")
err = float(jnp.max(jnp.abs(out - jnp.fft.fftn(m))))
assert err < 2e-2, err
print("DIST3D_OK")
"""


def test_pfft3_distributed_8_devices():
    code = SCRIPT.format(src=os.path.abspath(SRC))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600)
    assert "DIST3D_OK" in proc.stdout, proc.stderr[-2000:]
