"""3-D DFT extension (paper future work §VII): oracles + distributed.

The padded-FPM tests compare against an explicit *padded-DFT oracle* —
numpy reproducing ``_pfft3``'s pad-crop-rotate dataflow bin for bin —
not a finiteness smoke check: the historical drift in ``pfft3_fpm_pad``
(a private pad-length loop, no ``normalize_pad`` routing) was exactly
the kind of semantic slip a finiteness check can never catch.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.pfft3d import (pfft3_fpm, pfft3_fpm_pad, pfft3_lb,
                               pfft3_pencil)
from repro.plan.config import PlanConfig
from test_pfft import fpms_for


def cube(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal((n, n, n))
                        + 1j * rng.standard_normal((n, n, n))).astype(np.complex64))


def test_pfft3_lb_matches_fftn():
    m = cube(16)
    np.testing.assert_allclose(np.asarray(pfft3_lb(m, 3)),
                               np.asarray(jnp.fft.fftn(m)), atol=2e-2)


def test_pfft3_lb_uneven_partition():
    # lb partitions split 14 rows over 4 segments unevenly by design.
    m = cube(14)
    np.testing.assert_allclose(np.asarray(pfft3_lb(m, 4)),
                               np.asarray(jnp.fft.fftn(m)), atol=2e-2)


def test_pfft3_fpm_matches_fftn():
    m = cube(16)
    out, part = pfft3_fpm(m, fpms_for(16), return_partition=True)
    assert part.d.sum() == 16
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fftn(m)),
                               atol=2e-2)


def test_pfft3_rejects_non_cube():
    with pytest.raises(ValueError, match="cubic"):
        pfft3_lb(jnp.zeros((4, 4, 8), jnp.complex64), 2)


# ---------------------------------------------------------------- fpm-pad

def _padded_dft_oracle(m, d, pads):
    """Numpy mirror of ``_pfft3``'s dataflow: three axis passes, each
    padding every segment's rows to its declared length, transforming at
    that length, cropping back to n bins, then rotating the axes."""
    m = np.asarray(m)
    n = m.shape[0]
    for _ in range(3):
        out = np.empty_like(m)
        start = 0
        for i, rows in enumerate(np.asarray(d)):
            rows = int(rows)
            if rows == 0:
                continue
            seg = m[start:start + rows].reshape(-1, n)
            length = int(pads[i]) if pads is not None and pads[i] > n else n
            if length > n:
                seg = np.fft.fft(
                    np.pad(seg, ((0, 0), (0, length - n))), axis=-1)[:, :n]
            else:
                seg = np.fft.fft(seg, axis=-1)
            out[start:start + rows] = seg.reshape((rows, n, n))
            start += rows
        m = np.moveaxis(out, -1, 0)
    return m


def test_pfft3_pad_matches_padded_dft_oracle():
    m = cube(12)
    out, part, pads = pfft3_fpm_pad(m, fpms_for(12), return_partition=True)
    assert out.shape == (12, 12, 12)
    ref = _padded_dft_oracle(m, part.d, pads)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-2)


def test_pfft3_pad_ignores_drifted_config_pad():
    # The method owns the pad semantics (normalize_pad): an explicit
    # config whose pad field drifted to czt must still run the paper's
    # pad-and-crop program, bin for bin.
    m = cube(12)
    base, part, pads = pfft3_fpm_pad(m, fpms_for(12), return_partition=True)
    drifted = pfft3_fpm_pad(m, fpms_for(12), config=PlanConfig(pad="czt"))
    np.testing.assert_allclose(np.asarray(drifted), np.asarray(base),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(drifted),
                               _padded_dft_oracle(m, part.d, pads), atol=2e-2)


# ------------------------------------------------------------ divisibility

def test_divisibility_message_is_not_inverted():
    # The 3-D path's message once drifted into the inverted "N must
    # divide the mesh axis"; the shared helper is the one home of the
    # correctly-worded rule for every distributed entry point.
    from repro.core.pfft_dist import require_mesh_divisible
    with pytest.raises(ValueError,
                       match=r"N=10 must be divisible by mesh axis fft_r=4"):
        require_mesh_divisible(10, 4, "fft_r")
    require_mesh_divisible(12, 4, "fft_r")  # divides: no raise


def test_pencil_rejects_fused_config():
    import jax
    mesh = jax.make_mesh((1, 1), ("fft_r", "fft_c"))
    with pytest.raises(ValueError, match="unfused"):
        pfft3_pencil(cube(8), mesh, config=PlanConfig(radix=4, fused=True))


# --------------------------------------------------------------- planners

def test_plan_pfft3_single_host_matches_fftn():
    from repro.core.api import plan_pfft3
    m = cube(12)
    plan = plan_pfft3(12, p=3, tune="estimate")
    assert plan.tuning["source"] == "estimate"
    np.testing.assert_allclose(np.asarray(plan.execute(m)),
                               np.asarray(jnp.fft.fftn(m)), atol=2e-2)
    with pytest.raises(ValueError, match="signals"):
        plan.execute(cube(8))


def test_plan_pfft3_explicit_config_skips_tuner():
    from repro.core.api import plan_pfft3
    plan = plan_pfft3(8, config=PlanConfig(radix=2))
    assert plan.tuning["source"] == "explicit"
    m = cube(8)
    np.testing.assert_allclose(np.asarray(plan.execute(m)),
                               np.asarray(jnp.fft.fftn(m)), atol=2e-2)


# ------------------------------------------------------------- pfft1_large

@pytest.mark.parametrize("n", [64, 360, 97, 12])
def test_pfft1_large_matches_fft(n):
    # pow2, composite non-pow2, prime (degenerate n1=1), and small.
    from repro.core.pfft_large import pfft1_large_apply
    rng = np.random.default_rng(n)
    x = (rng.standard_normal(n)
         + 1j * rng.standard_normal(n)).astype(np.complex64)
    np.testing.assert_allclose(np.asarray(pfft1_large_apply(jnp.asarray(x))),
                               np.fft.fft(x), atol=2e-3)


def test_four_step_factors():
    from repro.core.pfft_large import four_step_factors
    assert four_step_factors(360) == (18, 20)
    assert four_step_factors(64) == (8, 8)
    assert four_step_factors(97) == (1, 97)       # prime: degenerate
    assert four_step_factors(360, n1=8) == (8, 45)
    assert four_step_factors(360, n2=36) == (10, 36)
    with pytest.raises(ValueError, match="divide"):
        four_step_factors(360, n1=7)
    with pytest.raises(ValueError, match="multiply"):
        four_step_factors(360, n1=8, n2=44)


def test_plan_pfft1_large_lifecycle(tmp_path):
    from repro.core.api import plan_pfft1_large
    wis = str(tmp_path / "wisdom.json")
    p1 = plan_pfft1_large(360, tune="measure", wisdom=wis)
    assert p1.tuning["source"] == "measure"
    p2 = plan_pfft1_large(360, tune="measure", wisdom=wis)
    assert p2.tuning["source"] == "wisdom"
    assert "measured" not in p2.tuning          # zero re-measurement
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(360)
         + 1j * rng.standard_normal(360)).astype(np.complex64)
    np.testing.assert_allclose(np.asarray(p2.execute(jnp.asarray(x))),
                               np.fft.fft(x), atol=2e-3)


# ------------------------------------------------------------- distributed

SLAB_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.pfft3d import pfft3_distributed
mesh = jax.make_mesh((8,), ("fft",))
rng = np.random.default_rng(1)
m = jnp.asarray((rng.standard_normal((16,16,16))
                 + 1j*rng.standard_normal((16,16,16))).astype(np.complex64))
out = pfft3_distributed(m, mesh, "fft")
err = float(jnp.max(jnp.abs(out - jnp.fft.fftn(m))))
assert err < 2e-2, err
print("DIST3D_OK")
"""


def test_pfft3_slab_8_devices(dist_subprocess):
    dist_subprocess(SLAB_SCRIPT, devices=8, sentinel="DIST3D_OK")


PENCIL_SCRIPT = r"""
import tempfile, os
import numpy as np, jax, jax.numpy as jnp
from repro.core.api import plan_pfft3
from repro.launch.mesh import make_pfft3_mesh

mesh = make_pfft3_mesh(4, 2)
rng = np.random.default_rng(1)
m = jnp.asarray((rng.standard_normal((16,16,16))
                 + 1j*rng.standard_normal((16,16,16))).astype(np.complex64))
wis = os.path.join(tempfile.mkdtemp(), "wisdom.json")

# Acceptance 1: a measured pencil plan on the 2-D mesh matches fftn.
p1 = plan_pfft3(16, mesh=mesh, tune="measure", wisdom=wis)
assert p1.tuning["source"] == "measure", p1.tuning["source"]
err = float(jnp.max(jnp.abs(p1.execute(m) - jnp.fft.fftn(m))))
assert err < 2e-2, err

# Acceptance 2: the second plan is served from the v3 topo-keyed wisdom
# store with zero re-measurement, executes identically, and replays the
# tuned orientation.
p2 = plan_pfft3(16, mesh=mesh, tune="measure", wisdom=wis)
assert p2.tuning["source"] == "wisdom", p2.tuning["source"]
assert "measured" not in p2.tuning
assert p2.axis_names == p1.axis_names, (p2.axis_names, p1.axis_names)
err2 = float(jnp.max(jnp.abs(p2.execute(m) - jnp.fft.fftn(m))))
assert err2 < 2e-2, err2
assert "|topo=" in p1.tuning["wisdom_key"]
assert "+" in p1.tuning["topology"]   # 2-D digest form

# Acceptance 3: a different mesh shape digests differently and re-tunes.
mesh_t = make_pfft3_mesh(2, 4)
p3 = plan_pfft3(16, mesh=mesh_t, tune="estimate", wisdom=wis)
assert p3.tuning["topology"] != p1.tuning["topology"]
assert p3.tuning["source"] == "estimate", p3.tuning["source"]

# The shared divisibility message, from the pencil path.
try:
    plan_pfft3(10, mesh=mesh)
except ValueError as e:
    assert "N=10 must be divisible by mesh axis fft_r=4" in str(e), e
else:
    raise AssertionError("divisibility check did not fire")
print("PENCIL3D_OK")
"""


def test_plan_pfft3_pencil_8_devices(dist_subprocess):
    dist_subprocess(PENCIL_SCRIPT, devices=8, sentinel="PENCIL3D_OK")
