"""Distributed PFFT correctness on fake multi-device meshes.

Device count is locked at first jax init, so the multi-device cases run in
a subprocess with XLA_FLAGS set; the in-process tests cover the 1-device
degenerate mesh.
"""

import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {src!r})
import numpy as np, jax, jax.numpy as jnp
from repro.core.pfft_dist import pfft2_distributed, make_pfft2_fn, ragged_row_layout
from repro.plan import PlanConfig

mesh = jax.make_mesh((8,), ("fft",))
rng = np.random.default_rng(3)
m = (rng.standard_normal((64, 64)) + 1j*rng.standard_normal((64, 64))).astype(np.complex64)
m = jnp.asarray(m)
ref = jnp.fft.fft2(m)

out = pfft2_distributed(m, mesh, "fft")
assert float(jnp.max(jnp.abs(out - ref))) < 1e-2, "plain"

out = pfft2_distributed(m, mesh, "fft", padded="czt")
assert float(jnp.max(jnp.abs(out - ref))) < 1e-2, "czt"

out = pfft2_distributed(m, mesh, "fft", config=PlanConfig(radix=2))
assert float(jnp.max(jnp.abs(out - ref))) < 1e-2, "stockham"

out = make_pfft2_fn(mesh, 64)(m)
assert float(jnp.max(jnp.abs(out - ref))) < 1e-2, "jit"

# padded='crop' = padded-signal DFT semantics; compare vs that oracle
pad = 80
out = pfft2_distributed(m, mesh, "fft", padded="crop", pad_len=pad)
def crop_phase(mat):
    t = jnp.fft.fft(jnp.pad(mat, ((0,0),(0,pad-64))), axis=-1)[:, :64]
    return t
ref2 = crop_phase(crop_phase(m).T).T
assert float(jnp.max(jnp.abs(out - ref2))) < 1e-2, "crop semantics"

rows, counts = ragged_row_layout(np.array([10, 6, 8, 8, 8, 8, 8, 8]), 8)
assert rows == 10 and counts.sum() == 64

# software-pipelined panels: identical result to the monolithic phase
for k in (2, 4, 8):
    out = pfft2_distributed(m, mesh, "fft", config=PlanConfig(pipeline_panels=k))
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-2, "panels %d" % k
out = pfft2_distributed(m, mesh, "fft", config=PlanConfig(pad="czt", pipeline_panels=4))
assert float(jnp.max(jnp.abs(out - ref))) < 1e-2, "czt panels"
out = pfft2_distributed(m, mesh, "fft", pad_len=pad,
                        config=PlanConfig(pad="fpm", pipeline_panels=2))
assert float(jnp.max(jnp.abs(out - ref2))) < 1e-2, "crop panels"
try:
    pfft2_distributed(m, mesh, "fft", config=PlanConfig(pipeline_panels=3))
    raise SystemExit("expected ValueError for non-dividing panel count")
except ValueError:
    pass
print("DIST_OK")
"""


def test_distributed_pfft_8_devices():
    code = SCRIPT.format(src=os.path.abspath(SRC))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600)
    assert "DIST_OK" in proc.stdout, proc.stderr[-2000:]


def test_distributed_pfft_single_device_mesh():
    mesh = jax.make_mesh((1,), ("fft",))
    from repro.core.pfft_dist import pfft2_distributed
    rng = np.random.default_rng(0)
    m = jnp.asarray((rng.standard_normal((32, 32))
                     + 1j * rng.standard_normal((32, 32))).astype(np.complex64))
    out = pfft2_distributed(m, mesh, "fft")
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fft2(m)),
                               atol=1e-2)


def test_pipelined_single_device_mesh():
    """pipeline_panels on the degenerate 1-device mesh (pure reshuffle)."""
    mesh = jax.make_mesh((1,), ("fft",))
    from repro.core.pfft_dist import pfft2_distributed
    rng = np.random.default_rng(1)
    m = jnp.asarray((rng.standard_normal((32, 32))
                     + 1j * rng.standard_normal((32, 32))).astype(np.complex64))
    from repro.plan import PlanConfig
    out = pfft2_distributed(m, mesh, "fft", config=PlanConfig(pipeline_panels=4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fft2(m)),
                               atol=1e-2)


def test_unknown_axis_raises():
    from repro.core.pfft_dist import pfft2_distributed
    with pytest.raises(KeyError):
        pfft2_distributed(jnp.ones((32, 32), jnp.complex64),
                          jax.make_mesh((1,), ("fft",)), "nope")


def test_local_phase_refuses_silent_monolithic_fallback():
    """Satellite regression: a panel count that doesn't divide the local
    rows used to fall back to the monolithic phase silently — a direct
    caller (or tuner drift) would time/run a different program than
    requested.  Now it's a named error, raised before any lax op."""
    from repro.core.pfft_dist import _local_phase
    from repro.plan import PlanConfig
    block = jnp.ones((16, 16), jnp.complex64)
    with pytest.raises(ValueError, match="divide local rows"):
        _local_phase(block, "fft", 16, padded=None, pad_len=16,
                     config=PlanConfig(), pipeline_panels=3)
    # pfft2_distributed still validates up front with its own message
    from repro.core.pfft_dist import pfft2_distributed
    with pytest.raises(ValueError, match="divide local rows"):
        pfft2_distributed(jnp.ones((32, 32), jnp.complex64),
                          jax.make_mesh((1,), ("fft",)), "fft",
                          config=PlanConfig(pipeline_panels=3))
