"""Training-loop integration: loss decreases, microbatching is exact,
grad compression converges, FPM schedule picks sensible configs."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import TrainCfg
from repro.data.pipeline import SyntheticTokenPipeline, make_batch
from repro.models.registry import get_smoke_config
from repro.optim.grad_compress import (compressed_psum, error_feedback_update,
                                       int8_compress, int8_decompress,
                                       topk_compress, topk_decompress)
from repro.optim.schedule import cosine_warmup
from repro.train.fpm_schedule import build_step_fpm, choose_schedule, fpm_batch_partition
from repro.train.step import init_train_state, make_train_step


def test_train_loss_decreases():
    cfg = get_smoke_config("internlm2_1_8b")
    tcfg = TrainCfg(lr=1e-2, microbatches=2, total_steps=60, warmup=3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(cfg, batch=16, seq=32, seed=0)
    losses = []
    for _ in range(60):
        state, m = step(state, pipe.next())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
    assert np.isfinite(losses).all()


def test_microbatching_matches_full_batch_grads():
    """sum of microbatch grads / n == full-batch grad (loss is a mean)."""
    cfg = get_smoke_config("qwen2_5_3b")
    from repro.models.transformer import loss_fn
    key = jax.random.PRNGKey(1)
    from repro.models.transformer import init_params
    params = init_params(key, cfg)
    batch = make_batch(cfg, 4, 16, seed=0, step=0)

    def loss_of(p, b):
        return loss_fn(p, b, cfg, vocab_chunk=16)[0]

    g_full = jax.grad(loss_of)(params, batch)
    halves = [jax.tree.map(lambda x: x[:2], batch),
              jax.tree.map(lambda x: x[2:], batch)]
    g_mb = jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) + b.astype(jnp.float32)) / 2,
        jax.grad(loss_of)(params, halves[0]),
        jax.grad(loss_of)(params, halves[1]))
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_mb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_train_with_int8_compression_still_learns():
    cfg = get_smoke_config("internlm2_1_8b")
    tcfg = TrainCfg(lr=1e-2, microbatches=1, total_steps=60, warmup=3,
                    grad_compress="int8")
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    assert state.residual  # error-feedback buffers allocated
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(cfg, batch=16, seq=32, seed=0)
    losses = []
    for _ in range(60):
        state, m = step(state, pipe.next())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses


# --------------------------------------------------------------- codecs

def test_int8_codec_bounded_error(rng):
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, s = int8_compress(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(int8_decompress(q, s)) - np.asarray(g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_topk_codec_keeps_largest(rng):
    g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    v, i, shp = topk_compress(g, k_frac=0.1)
    dec = np.asarray(topk_decompress(v, i, shp))
    kept = np.nonzero(dec)[0]
    thresh = np.sort(np.abs(np.asarray(g)))[-len(kept)]
    assert np.all(np.abs(np.asarray(g)[kept]) >= thresh - 1e-6)


def test_error_feedback_residual_is_exact(rng):
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    r = jnp.zeros_like(g)
    dec, r2 = error_feedback_update(g, r, codec="int8")
    np.testing.assert_allclose(np.asarray(dec + r2), np.asarray(g), atol=1e-5)


def test_compressed_psum_multidevice_equivalence():
    """int8 psum over a fake 'pods' axis approximates the exact psum."""
    import jax.experimental.shard_map as shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    mesh = Mesh(np.array(devs[:1]), ("pods",))
    g = jnp.linspace(-1, 1, 128)

    f = shard_map.shard_map(
        lambda x: compressed_psum(x, "pods"), mesh=mesh,
        in_specs=P(), out_specs=P())
    out = f(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=2e-2)


# --------------------------------------------------------------- schedules

def test_cosine_warmup_shape():
    lr = [float(cosine_warmup(jnp.int32(s), lr=1.0, warmup=10, total=100))
          for s in range(100)]
    assert lr[0] < lr[9] <= 1.0
    assert lr[-1] < lr[50] < lr[11]


def test_choose_schedule_prefers_fast_padded_size():
    # seq 100 is slow; padded 128 is 4x faster per flop
    def timer(mb, seq):
        base = mb * seq * 1e-6
        return base * (4.0 if seq % 128 else 1.0)
    fpm = build_step_fpm(timer, [1, 2, 4], [100, 128, 256])
    mb, pad = choose_schedule(fpm, tokens_per_device=512, seq_len=100,
                              pad_candidates=[128, 256])
    assert pad == 128


def test_fpm_batch_partition_heterogeneous():
    from repro.core.fpm import FPMSet, SpeedFunction
    xs = np.array([1, 8, 16, 32])
    ys = np.array([64, 128])
    v = np.outer(xs, [1.0, 1.1]) + 1
    fpms = FPMSet([SpeedFunction(xs, ys, v), SpeedFunction(xs, ys, 3 * v)])
    res = fpm_batch_partition(fpms, 32, 128)
    assert res.d.sum() == 32
    assert res.d[1] > res.d[0]
