"""Sharding-rule unit tests: param/batch/cache specs + sanitization."""

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.registry import get_smoke_config
from repro.models.sharding import (batch_pspecs, cache_pspecs, param_pspecs,
                                   sanitize_pspecs)


def _find(specs_flat, needle):
    return [s for path, s in specs_flat if needle in path]


def flat_with_path(tree):
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out


def test_param_rules_dense():
    cfg = get_smoke_config("qwen2_5_3b")
    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(params)
    fs = dict(flat_with_path(specs))
    assert fs["layers/attn/wq/w"][-1] == "model"     # TP on out dim
    assert fs["layers/attn/wo/w"][-2] == "model"     # TP on in dim
    assert fs["layers/attn/wo/w"][-1] == "data"      # FSDP storage
    assert fs["layers/attn/wq/b"] == P(None, None)   # bias replicated
    # train default: vocab-sharded (inference lowerings flip via embed_dshard)
    assert fs["embed/table"] == P("model", None)
    assert all(x is None for x in fs["layers/ln1/scale"])


def test_param_rules_moe():
    cfg = get_smoke_config("dbrx_132b")
    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    fs = dict(flat_with_path(param_pspecs(params)))
    assert fs["layers/moe/wg"][1] == "model"         # EP on expert dim
    assert fs["layers/moe/wd"][1] == "model"
    assert fs["layers/moe/router/w"] == P(None, None, None)


def test_cache_rules():
    cfg = get_smoke_config("internlm2_1_8b")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 4, 64))
    fs = dict(flat_with_path(cache_pspecs(cache)))
    # stacked (L, B, S, KV, hd): batch->data, seq->model
    assert fs["k"] == P(None, "data", "model", None, None)


def test_batch_specs_pod_axes():
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = batch_pspecs(batch, have_pod=True)
    assert specs["tokens"][0] == ("pod", "data")


def test_sanitize_drops_indivisible_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake a 2-axis mesh of extent 1; use a bigger virtual mesh via axis dict
    from jax.sharding import PartitionSpec as PS
    import repro.models.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), object)

    specs = {"w": PS("data", "model"), "v": PS("model"), "ok": PS(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((8, 32), jnp.float32),   # 8 % 16 != 0
              "v": jax.ShapeDtypeStruct((504,), jnp.float32),    # 504 % 16 != 0
              "ok": jax.ShapeDtypeStruct((4, 64), jnp.float32)}  # 64 % 16 == 0
    out = sh.sanitize_pspecs(specs, shapes, FakeMesh())
    assert out["w"] == PS(None, "model")
    assert out["v"] == PS(None)
    assert out["ok"] == PS(None, "model")


def test_constrain_batch_noop_outside_mesh():
    from repro.models.sharding import constrain_batch
    x = jnp.ones((4, 8, 16))
    np.testing.assert_array_equal(np.asarray(constrain_batch(x)), np.asarray(x))


def test_constrain_batch_applies_in_mesh_context():
    from repro.models.sharding import constrain_batch, set_seq_shard
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        x = jnp.ones((4, 8, 16))
        out = constrain_batch(x)  # extent-1 axes: no-op path but must not raise
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    set_seq_shard(True)
    try:
        with mesh:
            out = constrain_batch(jnp.ones((4, 8, 16)))
            assert out.shape == (4, 8, 16)
    finally:
        set_seq_shard(False)


def test_sanitize_tuple_axes_prefix():
    import repro.models.sharding as sh
    from jax.sharding import PartitionSpec as PS

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        devices = np.empty((2, 16, 16), object)

    # batch 32 divides pod*data=32 fully; batch 16 only divides pod*...=2*8? ->
    # prefix ('pod',) kept since 16 % 2 == 0 but 16 % 32 != 0
    specs = {"a": PS(("pod", "data")), "b": PS(("pod", "data"))}
    shapes = {"a": jax.ShapeDtypeStruct((32, 4), jnp.float32),
              "b": jax.ShapeDtypeStruct((16, 4), jnp.float32)}
    out = sh.sanitize_pspecs(specs, shapes, FakeMesh())
    assert out["a"][0] == ("pod", "data")
    assert out["b"][0] == "pod"
