"""Transform-serving layer: plan cache, coalescing tick loop, priced
admission, and wisdom-store contention under concurrent serve ticks.

The serving acceptance story in one file: correctness of every cohort
member against numpy, one dispatch per coalesced cohort, deterministic
budget splits from the cost model's own numbers, priced rejections, and
the zero-retune audit (warm plan cache in-process, warm wisdom store
across services).
"""

import asyncio
import os
import threading
import time

import numpy as np
import pytest

from repro.core.api import plan_pfft
from repro.launch.serve_fft import (AdmissionError, CohortKey,
                                    DeadlineExceeded, FFTService)
from repro.plan.cache import PlanCache
from repro.plan.config import PlanConfig
from repro.plan.wisdom import load_wisdom, record_wisdom


def _signal(rng, n, dtype="complex64"):
    if dtype.startswith("float"):
        return rng.standard_normal((n, n)).astype(dtype)
    return (rng.standard_normal((n, n))
            + 1j * rng.standard_normal((n, n))).astype(dtype)


class _FakePlan:
    def __init__(self, source="wisdom"):
        self.tuning = {"source": source}


# ---------------------------------------------------------------- PlanCache

class TestPlanCache:
    def test_lru_bound_and_eviction_counters(self):
        cache = PlanCache(maxsize=2)
        for k in "abc":
            cache.get(k, _FakePlan)
        assert len(cache) == 2
        assert cache.stats.evictions == 1
        assert cache.stats.misses == 3
        assert "a" not in cache and "b" in cache and "c" in cache

    def test_hit_refreshes_recency(self):
        cache = PlanCache(maxsize=2)
        cache.get("a", _FakePlan)
        cache.get("b", _FakePlan)
        cache.get("a", _FakePlan)          # refresh a
        cache.get("c", _FakePlan)          # evicts b, not a
        assert "a" in cache and "b" not in cache
        assert cache.stats.hits == 1

    def test_retune_counter_tracks_tuned_sources_only(self):
        cache = PlanCache()
        cache.get("w", lambda: _FakePlan("wisdom"))
        cache.get("e", lambda: _FakePlan("estimate"))
        cache.get("m", lambda: _FakePlan("measure"))
        cache.get("x", lambda: _FakePlan("explicit"))
        assert cache.stats.retunes == 2
        cache.get("e", lambda: _FakePlan("estimate"))   # hit: no retune
        assert cache.stats.retunes == 2

    def test_peek_mutates_nothing(self):
        cache = PlanCache(maxsize=2)
        cache.get("a", _FakePlan)
        assert cache.peek("a") is not None
        assert cache.peek("zzz") is None
        assert cache.stats.hits == 0 and cache.stats.misses == 1

    def test_reset_stats_keeps_entries(self):
        cache = PlanCache()
        cache.get("a", _FakePlan)
        cache.reset_stats()
        assert cache.stats_dict()["misses"] == 0
        assert "a" in cache
        _, hit = cache.get("a", _FakePlan)
        assert hit

    def test_build_failure_not_cached(self):
        cache = PlanCache()
        with pytest.raises(RuntimeError):
            cache.get("a", lambda: (_ for _ in ()).throw(RuntimeError("x")))
        assert "a" not in cache
        cache.get("a", _FakePlan)   # succeeds after the failed build


# ------------------------------------------------------------- execute_many

class TestExecuteMany:
    def test_matches_per_item_execute(self, rng):
        plan = plan_pfft(16, p=1, method="lb", dtype="complex64")
        ms = [_signal(rng, 16) for _ in range(5)]
        outs = plan.execute_many(ms)
        assert len(outs) == 5
        for m, out in zip(ms, outs):
            np.testing.assert_allclose(np.asarray(out), np.fft.fft2(m),
                                       atol=1e-2)

    def test_pad_to_is_invisible_in_results(self, rng):
        plan = plan_pfft(16, p=1, method="lb", dtype="complex64")
        ms = [_signal(rng, 16) for _ in range(3)]
        plain = plan.execute_many(ms)
        padded = plan.execute_many(ms, pad_to=8)
        for a, b in zip(plain, padded):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)

    def test_shape_validation(self, rng):
        plan = plan_pfft(16, p=1, method="lb", dtype="complex64")
        with pytest.raises(ValueError, match="stacks"):
            plan.execute_many([_signal(rng, 8)])
        assert plan.execute_many([]) == []


# ------------------------------------------------------- service end to end

class TestServiceCorrectness:
    def test_mixed_cohorts_match_numpy(self, rng, tmp_path):
        svc = FFTService(wisdom=str(tmp_path / "w.json"), tune="estimate")
        cases = []
        for n in (16, 32):
            for method in ("lb", "rfft-lb"):
                dtype = "float32" if method.startswith("rfft") else "complex64"
                for _ in range(3):
                    m = _signal(rng, n, dtype)
                    cases.append((m, method, svc.enqueue(m, method=method)))
        assert svc.drain() == len(cases)
        for m, method, ticket in cases:
            ref = (np.fft.rfft2(m) if method.startswith("rfft")
                   else np.fft.fft2(m))
            np.testing.assert_allclose(np.asarray(ticket.result()), ref,
                                       atol=1e-2)
            assert ticket.done and ticket.latency_s > 0

    def test_cohort_is_one_dispatch(self, rng):
        svc = FFTService(tune="estimate")
        for _ in range(6):
            svc.enqueue(_signal(rng, 16), method="lb")
        svc.tick()
        s = svc.stats()
        assert s["dispatches"] == 1
        assert s["max_coalesced"] == 6
        assert s["coalesced_dispatches"] == 1
        assert s["batching_efficiency"] == 6.0

    def test_non_square_and_unknown_method_rejected(self, rng):
        svc = FFTService()
        with pytest.raises(ValueError, match="square"):
            svc.enqueue(np.zeros((4, 8), np.complex64))
        with pytest.raises(ValueError, match="not served"):
            svc.enqueue(np.zeros((4, 4), np.complex64), method="fpm-czt")

    def test_result_before_tick_raises(self, rng):
        svc = FFTService()
        t = svc.enqueue(_signal(rng, 16))
        with pytest.raises(RuntimeError, match="tick pending"):
            t.result()


# ------------------------------------------------- priced admission + shed

class TestAdmission:
    def test_oversize_is_priced_rejection(self):
        svc = FFTService(tick_budget_s=0.05)
        big = np.zeros((2048, 2048), np.complex64)
        with pytest.raises(AdmissionError) as ei:
            svc.enqueue(big)
        assert ei.value.predicted_s > ei.value.budget_s
        assert ei.value.budget_s == pytest.approx(0.05)
        assert svc.stats()["rejected"] == 1
        assert svc.pending_count == 0

    def test_queue_full_is_priced_rejection(self, rng):
        svc = FFTService(max_queue=2)
        svc.enqueue(_signal(rng, 16))
        svc.enqueue(_signal(rng, 16))
        with pytest.raises(AdmissionError, match="queue full") as ei:
            svc.enqueue(_signal(rng, 16))
        assert ei.value.predicted_s > 0

    def test_deadline_shed_with_priced_error(self, rng):
        svc = FFTService(tune="estimate")
        doomed = svc.enqueue(_signal(rng, 16), deadline_s=1e-4)
        kept = svc.enqueue(_signal(rng, 16))
        time.sleep(0.002)
        svc.drain()
        with pytest.raises(DeadlineExceeded):
            doomed.result()
        assert kept.done and kept.result() is not None
        s = svc.stats()
        assert s["shed_deadline"] == 1 and s["served"] == 1

    def test_budget_splits_cohort_deterministically(self, rng):
        svc = FFTService(tune="estimate")
        first = svc.enqueue(_signal(rng, 32), method="lb")
        svc.drain()                      # builds the plan: prices settle
        assert first.done
        # Budget admits exactly two 32s per tick by the model's own law.
        svc.tick_budget_s = svc.price(32, "lb", batch=2) * 1.01
        svc.reset_stats()
        tickets = [svc.enqueue(_signal(rng, 32), method="lb")
                   for _ in range(6)]
        svc.drain()
        s = svc.stats()
        assert s["ticks"] == 3
        assert s["splits"] == 2          # final tick takes the remainder
        assert s["max_coalesced"] == 2
        assert all(t.done for t in tickets)

    def test_priority_beats_fifo(self, rng):
        svc = FFTService(tune="estimate")
        # Warm both plans so the priority tick is pure queue mechanics.
        svc.enqueue(_signal(rng, 16), method="lb")
        svc.enqueue(_signal(rng, 32), method="lb")
        svc.drain()
        # Tiny budget: only the head cohort dispatches per tick (progress
        # guarantee), so the tick order is the priority order.  Admission
        # keeps its own cap — the budget squeeze is about tick assembly.
        svc.tick_budget_s = 1e-9
        svc.max_request_s = 1.0
        svc.reset_stats()
        low = svc.enqueue(_signal(rng, 16), method="lb", priority=0)
        high = svc.enqueue(_signal(rng, 32), method="lb", priority=5)
        svc.tick()
        assert high.done and not low.done
        assert svc.stats()["deferred_cohorts"] == 1
        svc.drain()
        assert low.done

    def test_progress_guarantee_over_tiny_budget(self, rng):
        svc = FFTService(tune="estimate", tick_budget_s=1e-12,
                         max_request_s=1.0)
        tickets = [svc.enqueue(_signal(rng, 16)) for _ in range(3)]
        assert svc.drain() == 3          # never wedges
        assert all(t.done for t in tickets)


# ------------------------------------------------ cache hierarchy / wisdom

class TestCacheHierarchy:
    def test_plan_cache_hit_zero_retune(self, rng, tmp_path):
        svc = FFTService(wisdom=str(tmp_path / "w.json"), tune="estimate")
        svc.enqueue(_signal(rng, 16))
        svc.drain()
        assert svc.stats()["plan_cache"]["retunes"] == 1
        svc.reset_stats()
        svc.enqueue(_signal(rng, 16))
        svc.drain()
        s = svc.stats()["plan_cache"]
        assert s["hits"] == 1 and s["misses"] == 0 and s["retunes"] == 0

    def test_fresh_service_served_from_warm_wisdom(self, rng, tmp_path):
        wis = str(tmp_path / "w.json")
        svc1 = FFTService(wisdom=wis, tune="estimate")
        for method in ("lb", "rfft-lb"):
            dtype = "float32" if method.startswith("rfft") else "complex64"
            svc1.enqueue(_signal(rng, 16, dtype), method=method)
        svc1.drain()
        assert svc1.stats()["sources"] == {"estimate": 2}

        svc2 = FFTService(wisdom=wis, tune="estimate")
        for method in ("lb", "rfft-lb"):
            dtype = "float32" if method.startswith("rfft") else "complex64"
            svc2.enqueue(_signal(rng, 16, dtype), method=method)
        svc2.drain()
        s = svc2.stats()
        assert s["sources"] == {"wisdom": 2}
        assert s["plan_cache"]["retunes"] == 0

    def test_lru_eviction_in_service(self, rng):
        svc = FFTService(tune="estimate", cache_size=1)
        svc.enqueue(_signal(rng, 16))
        svc.drain()
        svc.enqueue(_signal(rng, 32))
        svc.drain()
        s = svc.stats()["plan_cache"]
        assert s["evictions"] == 1 and s["size"] == 1

    def test_price_uses_built_schedule_after_first_dispatch(self, rng):
        svc = FFTService(tune="estimate")
        before = svc.price(16, "lb")
        svc.enqueue(_signal(rng, 16))
        svc.drain()
        after = svc.price(16, "lb")
        assert before > 0 and after > 0
        assert CohortKey(16, "lb", "complex64") in svc._cache


# ------------------------------------- wisdom contention under concurrency

class TestWisdomContention:
    def test_threaded_writers_lose_no_entries(self, tmp_path):
        path = str(tmp_path / "w.json")
        errors = []

        def writer(tid):
            try:
                for i in range(8):
                    record_wisdom(path, f"t{tid}-k{i}", PlanConfig(),
                                  mode="estimate", retries=3,
                                  lock_timeout_s=30.0)
            except Exception as e:  # pragma: no cover - failure detail
                errors.append((tid, e))

        threads = [threading.Thread(target=writer, args=(t,))
                   for t in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors
        store = load_wisdom(path)
        keys = [k for k in store if not k.startswith("_")]
        assert len(keys) == 48

    def test_wedged_lock_times_out(self, tmp_path):
        fcntl = pytest.importorskip("fcntl")
        path = str(tmp_path / "w.json")
        record_wisdom(path, "seed", PlanConfig(), mode="estimate")
        with open(path + ".lock", "w") as holder:
            fcntl.flock(holder, fcntl.LOCK_EX)
            with pytest.raises(TimeoutError, match="still held"):
                record_wisdom(path, "blocked", PlanConfig(),
                              mode="estimate", lock_timeout_s=0.2)
        # lock released: the write goes through
        record_wisdom(path, "blocked", PlanConfig(), mode="estimate")
        assert "blocked" in load_wisdom(path)

    def test_concurrent_services_share_one_store(self, rng, tmp_path):
        """Two services' ticks race the same wisdom file: every request
        is served, the store stays parseable, and the PR 6 retry/timeout
        paths never deadlock the tick loop."""
        wis = str(tmp_path / "w.json")
        svcs = [FFTService(wisdom=wis, tune="estimate") for _ in range(2)]
        payloads = [[_signal(rng, n) for n in (16, 32, 16)]
                    for _ in svcs]
        errors = []

        def serve(svc, ms):
            try:
                for m in ms:
                    svc.enqueue(m, method="lb")
                svc.drain()
            except Exception as e:  # pragma: no cover - failure detail
                errors.append(e)

        threads = [threading.Thread(target=serve, args=(s, p))
                   for s, p in zip(svcs, payloads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors
        assert all(s.stats()["served"] == 3 for s in svcs)
        store = load_wisdom(wis)   # parseable, both sizes recorded
        assert sum(1 for k in store if "n=16" in k) >= 1
        assert sum(1 for k in store if "n=32" in k) >= 1


# ------------------------------------------------------------ async surface

class TestAsyncSurface:
    def test_submit_and_serve_forever(self, rng):
        m = _signal(rng, 16)

        async def main():
            svc = FFTService(tune="estimate")
            async with svc:
                out = await svc.submit(m, method="lb")
            return np.asarray(out), svc.stats()

        out, stats = asyncio.run(main())
        np.testing.assert_allclose(out, np.fft.fft2(m), atol=1e-2)
        assert stats["served"] == 1

    def test_service_survives_event_loop_recycling(self, rng):
        """Regression: the wake event must rebind per serve_forever run —
        a service reused across asyncio.run calls (warm pass after cold
        pass) used to deadlock on the first loop's dead Event."""
        svc = FFTService(tune="estimate")
        m = _signal(rng, 16)

        async def one_round():
            async with svc:
                return await asyncio.wait_for(svc.submit(m), timeout=30)

        for _ in range(2):
            out = asyncio.run(one_round())
            np.testing.assert_allclose(np.asarray(out), np.fft.fft2(m),
                                       atol=1e-2)
        assert svc.stats()["served"] == 2

    def test_concurrent_submitters_coalesce(self, rng):
        ms = [_signal(rng, 16) for _ in range(8)]

        async def main():
            svc = FFTService(tune="estimate")
            async with svc:
                outs = await asyncio.gather(
                    *(svc.submit(m, method="lb") for m in ms))
            return outs, svc.stats()

        outs, stats = asyncio.run(main())
        for m, out in zip(ms, outs):
            np.testing.assert_allclose(np.asarray(out), np.fft.fft2(m),
                                       atol=1e-2)
        assert stats["coalesced_dispatches"] >= 1
        assert stats["max_coalesced"] >= 2


# -------------------------------------------------------- shared percentile

class TestPercentiles:
    def test_basic_ordering_and_keys(self):
        from benchmarks.stats import percentiles
        p = percentiles(range(1, 101))
        assert set(p) == {"p50", "p90", "p99"}
        assert p["p50"] <= p["p90"] <= p["p99"]
        assert p["p50"] == pytest.approx(50.5)

    def test_empty_is_nan_not_crash(self):
        from benchmarks.stats import percentiles
        p = percentiles([])
        assert all(np.isnan(v) for v in p.values())
