"""Shared fixtures, including the forced-multi-device CPU test rig.

jax locks the device count at first init, so a single pytest process
cannot flip between 1 and 4 devices.  Two complementary rigs:

* **Env guard** — when ``REPRO_FORCE_DEVICES=k`` is set, this conftest
  injects ``--xla_force_host_platform_device_count=k`` into ``XLA_FLAGS``
  *before anything imports jax* (conftest imports precede test modules),
  so the whole pytest session sees a k-device CPU topology in-process.
  The CI ``dist`` job runs the multi-device subset this way; tests that
  need it carry ``@pytest.mark.multi_device`` and skip themselves on
  ordinary 1-device runs.
* **Subprocess runner** — the ``dist_subprocess`` fixture runs a script
  under a fresh interpreter with the forced flag, so the *default* tier-1
  suite still exercises multi-device behavior without constraining the
  parent process.  This replaces the per-test copies of the
  subprocess/XLA_FLAGS boilerplate that used to live in each dist test.
"""

import os
import re
import subprocess
import sys

import numpy as np
import pytest

_FORCE = os.environ.get("REPRO_FORCE_DEVICES")
if _FORCE and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_FORCE)}").strip()

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multi_device: needs >= 2 jax devices (run with REPRO_FORCE_DEVICES)")


def pytest_collection_modifyitems(config, items):
    import jax

    if jax.device_count() > 1:
        return
    skip = pytest.mark.skip(
        reason="needs a multi-device topology; set REPRO_FORCE_DEVICES=4")
    for item in items:
        if "multi_device" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def dist_subprocess():
    """Run ``script`` in a fresh interpreter on a forced k-device CPU.

    The script sees ``src/`` on ``sys.path`` and XLA_FLAGS set *before*
    its first jax import.  Asserts the script printed ``sentinel`` (the
    convention every dist script here ends with) and returns the
    completed process for further output checks.
    """

    def run(script: str, *, devices: int = 4, sentinel: str = "OK",
            timeout: int = 600) -> subprocess.CompletedProcess:
        env = dict(os.environ)
        # Drop any inherited force-device flag first (importing
        # repro.launch.dryrun plants a 512-device one in this process's
        # environ) so the child's count is exactly ``devices``.
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       env.get("XLA_FLAGS", ""))
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={devices}"
        ).strip()
        env["PYTHONPATH"] = SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
            + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True,
                              timeout=timeout, env=env)
        assert sentinel in proc.stdout, (
            f"dist subprocess did not reach {sentinel!r}:\n"
            f"--- stdout ---\n{proc.stdout[-2000:]}\n"
            f"--- stderr ---\n{proc.stderr[-2000:]}")
        return proc

    return run


@pytest.fixture(scope="session")
def multihost_subprocess():
    """Run ``script`` as ``procs`` coordinated ``jax.distributed``
    processes on localhost — the CPU emulation rig for real multi-host
    topologies (gloo collectives; each process forces ``devices`` local
    CPU devices, so 2 procs x 2 devices is a genuine 2-host, 4-device
    cluster with real ``process_index`` structure).

    Every process runs the *same* script under the ``REPRO_MH_*`` env
    contract (``launch.mesh.init_multihost_from_env``); scripts must call
    that before any other jax use and print ``sentinel`` from process 0
    only.  Asserts every process exited 0 and process 0 printed the
    sentinel; returns the list of (returncode, stdout, stderr).
    """
    import socket

    def run(script: str, *, procs: int = 2, devices: int = 2,
            sentinel: str = "OK", timeout: int = 600) -> list:
        with socket.socket() as s:
            s.bind(("localhost", 0))
            port = s.getsockname()[1]
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       os.environ.get("XLA_FLAGS", ""))
        workers = []
        for pid in range(procs):
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={devices}"
            ).strip()
            env["PYTHONPATH"] = SRC + os.pathsep * bool(env.get("PYTHONPATH")) \
                + env.get("PYTHONPATH", "")
            env["REPRO_MH_COORD"] = f"localhost:{port}"
            env["REPRO_MH_NPROCS"] = str(procs)
            env["REPRO_MH_PID"] = str(pid)
            workers.append(subprocess.Popen(
                [sys.executable, "-c", script], stdout=subprocess.PIPE,
                stderr=subprocess.PIPE, text=True, env=env))
        outs = []
        try:
            for p in workers:
                out, errs = p.communicate(timeout=timeout)
                outs.append((p.returncode, out, errs))
        finally:
            for p in workers:
                if p.poll() is None:
                    p.kill()
        report = "\n".join(
            f"--- proc {i} (rc={rc}) stdout ---\n{out[-2000:]}\n"
            f"--- proc {i} stderr ---\n{err[-2000:]}"
            for i, (rc, out, err) in enumerate(outs))
        assert all(rc == 0 for rc, _, _ in outs), report
        assert sentinel in outs[0][1], report
        return outs

    return run
