"""Device-group SPMD programs — grouped lowering of heterogeneous schedules.

The acceptance story (ISSUE 5): a genuinely heterogeneous
``SegmentSchedule`` (>= 2 distinct configs) executes through
``pfft2_distributed`` on the forced-4-device rig and matches the
reference transform; a grouped measured pick round-trips through v3
wisdom and is served with zero re-measurement; the named SPMD error
remains only for schedules the grouped lowering genuinely cannot
express.  In-process tests cover the pure mapping logic
(``plan.groups``) and the grouped cost/tuner plumbing.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.plan import (CostParams, PlanConfig, SegmentSchedule,
                        device_group_program, estimate_grouped_cost,
                        estimate_schedule_cost, grouped_dist_schedule,
                        spmd_program_config)


# ------------------------------------------------------------ the mapping

def _sched(n, d, pads, cfgs):
    return SegmentSchedule.from_parts(n, d, pads, cfgs)


def test_device_group_program_maps_contiguous_groups():
    sched = _sched(32, [16, 8, 8], None,
                   [PlanConfig(), PlanConfig(radix=2), PlanConfig(radix=2)])
    prog = device_group_program(sched, 4)
    assert prog.configs == (PlanConfig(), PlanConfig(radix=2))
    assert prog.group_of_device == (0, 0, 1, 1)  # 16 rows = 2 shards
    assert prog.pad_len == 32
    assert "radix=2" in prog.describe()


def test_device_group_program_dedups_nonadjacent_configs():
    """Non-adjacent entries with the same config share one traced branch
    — the switch has one branch per *distinct* config, not per entry."""
    a, b = PlanConfig(), PlanConfig(radix=2)
    sched = _sched(32, [8, 8, 8, 8], None, [a, b, a, b])
    prog = device_group_program(sched, 4)
    assert prog.configs == (a, b)
    assert prog.group_of_device == (0, 1, 0, 1)


def test_device_group_program_uniform_length_rule():
    sched = _sched(48, [24, 24], np.array([64, 96]),
                   [PlanConfig(pad="fpm"), PlanConfig(radix=2, pad="fpm")])
    assert device_group_program(sched, 2).pad_len == 96  # max entry length
    assert device_group_program(sched, 2, pad_len=128).pad_len == 128


def test_device_group_program_rejections():
    # rows that don't tile the equal shards
    with pytest.raises(ValueError, match="SPMD"):
        device_group_program(
            _sched(32, [12, 20], None, [PlanConfig(), PlanConfig(radix=2)]),
            4)
    # partial coverage: some device would have no branch
    partial = SegmentSchedule(n=32, entries=(
        SegmentSchedule.from_parts(
            32, [16], None, [PlanConfig()]).entries[0],))
    with pytest.raises(ValueError, match="no branch"):
        device_group_program(partial, 4)
    # indivisible mesh
    with pytest.raises(ValueError, match="divisible"):
        device_group_program(
            _sched(32, [16, 16], None, [PlanConfig(), PlanConfig(radix=2)]),
            3)


def test_spmd_program_config_knob_rules():
    """Only the local row-FFT variant may differ; the knobs that shape
    the collective structure must be uniform."""
    ok = _sched(32, [16, 16], None, [PlanConfig(), PlanConfig(radix=2)])
    assert spmd_program_config(ok) == PlanConfig()  # anchor: tied rows,
    # first-appearance order wins via max()
    with pytest.raises(ValueError, match="SPMD"):
        spmd_program_config(_sched(
            32, [16, 16], None,
            [PlanConfig(radix=4, fused=True), PlanConfig()]))
    with pytest.raises(ValueError, match="SPMD"):
        spmd_program_config(_sched(
            32, [16, 16], None,
            [PlanConfig(pipeline_panels=2), PlanConfig(radix=2)]))
    with pytest.raises(ValueError, match="SPMD"):
        spmd_program_config(_sched(
            32, [16, 16], np.array([64, 64]),
            [PlanConfig(pad="fpm"), PlanConfig(pad="czt")]))


# ------------------------------------------------------------ grouped cost

def test_estimate_grouped_cost_adds_switch_overhead():
    params = CostParams.for_backend("cpu")
    homo = SegmentSchedule.homogeneous(PlanConfig(), 32, [16, 16])
    hetero = _sched(32, [16, 16], None, [PlanConfig(), PlanConfig(radix=2)])
    assert estimate_grouped_cost(homo, params=params) \
        == estimate_schedule_cost(homo, params=params)
    extra = estimate_grouped_cost(hetero, params=params) \
        - estimate_schedule_cost(hetero, params=params)
    # one extra branch, two phases
    assert extra == pytest.approx(2.0 * params.dispatch_overhead_s)


def test_grouped_dist_schedule_mixed_lengths_yield_mixed_configs():
    """Accelerator constants + mixed pow2/non-pow2 per-device pads: the
    pow2-padded devices take a kernel variant while the rest keep the
    library FFT — the candidate is genuinely heterogeneous."""
    params = CostParams.for_backend("tpu")
    pads = np.array([48, 64, 48, 64])
    sched = grouped_dist_schedule(48, 4, pad_lengths=pads, pad="fpm",
                                  params=params)
    assert sched is not None and len(sched.configs) == 2
    by_index = {e.index: e for e in sched}
    assert by_index[0].config.fft_backend == "xla"       # 48: no kernel
    assert by_index[1].config.fft_backend != "xla"       # 64: kernel wins
    # uniform lengths (or a homogeneous argmin) degenerate to None
    assert grouped_dist_schedule(48, 4, pad_lengths=None, pad="none",
                                 params=params) is None
    assert grouped_dist_schedule(48, 1, pad_lengths=pads, pad="fpm",
                                 params=params) is None  # p=1: nothing to group


# --------------------------------------- the 4-device grouped acceptance

_GROUPED_SCRIPT = r"""
import dataclasses, json
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 4, jax.device_count()
from repro.core import FPMSet, SpeedFunction, plan_pfft
from repro.core.pfft_dist import make_pfft2_fn, pfft2_distributed
from repro.launch.mesh import make_fft_mesh
from repro.plan import (CostParams, PlanConfig, SegmentSchedule,
                        record_wisdom, tune_dist_schedule)
import repro.plan.tune as tune_mod

W = "WISDOM_PATH"
mesh = make_fft_mesh()  # 4x 'fft'
n = 48
n_loc = n // 4
rng = np.random.default_rng(7)
m = jnp.asarray((rng.standard_normal((n, n))
                 + 1j * rng.standard_normal((n, n))).astype(np.complex64))
ref = jnp.fft.fft2(m)

# 1. a genuinely heterogeneous grouped schedule (2 distinct configs)
#    executes through pfft2_distributed and matches the reference DFT
hetero = SegmentSchedule.from_parts(
    n, [n_loc * 2, n_loc, n_loc], None,
    [PlanConfig(), PlanConfig(radix=2), PlanConfig(radix=2)])
assert len(hetero.configs) == 2
out = pfft2_distributed(m, mesh, "fft", schedule=hetero)
assert float(jnp.max(jnp.abs(out - ref))) < 1e-2, "grouped vs fft2"

# ... under jit (build-time lowering), and software-pipelined
fn = make_pfft2_fn(mesh, n, schedule=hetero)
assert float(jnp.max(jnp.abs(fn(m) - ref))) < 1e-2, "grouped jit"
panels = SegmentSchedule.from_parts(
    n, [n // 2, n // 2], None,
    [PlanConfig(pipeline_panels=2), PlanConfig(radix=2, pipeline_panels=2)])
outp = pfft2_distributed(m, mesh, "fft", schedule=panels)
assert float(jnp.max(jnp.abs(outp - ref))) < 1e-2, "grouped pipelined"

# ... grouped czt stays exact at mixed declared lengths (uniform max)
czt = SegmentSchedule.from_parts(
    n, [n // 2, n // 2], np.array([128, 256]),
    [PlanConfig(pad="czt"), PlanConfig(pad="czt", batched=False)])
outc = pfft2_distributed(m, mesh, "fft", schedule=czt)
assert float(jnp.max(jnp.abs(outc - ref))) < 1e-2, "grouped czt"

# 2. the grown heterogeneous candidate is raced end-to-end in measure
#    mode (constants favor the pure-jnp radix-2 kernel on pow2 pads so
#    the race stays cheap on this CPU rig)
params = dataclasses.replace(
    CostParams.for_backend("cpu"),
    backend_factor={"xla": 1.0, "stockham": 0.5, "pallas": 300.0})
xs = np.array(sorted({1, n_loc, n}))
ys = np.array(sorted({n, 64, 128}))
fast = np.tile([1e9, 4e9, 1e9], (len(xs), 1))
slow = np.full((len(xs), len(ys)), 2.5e8)
fpms = FPMSet([SpeedFunction(xs, ys, slow if i == 0 else fast,
                             name=f"P{i}") for i in range(4)])
pads = np.array([n, 64, 64, 64])
sched, info = tune_dist_schedule(n, mesh, "fft", mode="measure", pad="fpm",
                                 pad_lengths=pads, fpms=fpms, params=params,
                                 reps=1)
assert "grouped_measured" in info, sorted(info)
assert len(info["grouped_measured"]) == 2, info["grouped_measured"]
assert info["heterogeneous"]["est_s"] > 0

# 3. a grouped measured pick persists under the v3 topo key and is
#    served back with ZERO re-measurement, then executes correctly
p1 = plan_pfft(n, fpms=fpms, method="fpm-pad", mesh=mesh, tune="estimate",
               wisdom=W)
key = p1.tuning["wisdom_key"]
assert "|topo=4xfft.cpu" in key, key
plan_pads = p1.pad_lengths
grouped_pick = SegmentSchedule.from_parts(
    n, [n_loc] * 4, plan_pads,
    [PlanConfig(pad="fpm") if int(plan_pads[i]) <= n
     else PlanConfig(radix=2, pad="fpm") for i in range(4)])
assert len(grouped_pick.configs) == 2, grouped_pick.describe()
record_wisdom(W, key, grouped_pick, mode="measure", time_s=1e-3)
assert json.load(open(W))["version"] == 3

def no_measure(*a, **kw):
    raise AssertionError("re-measured on a warm store")
tune_mod.measure_dist_configs = no_measure
tune_mod._measure_local_phase = no_measure
p2 = plan_pfft(n, fpms=fpms, method="fpm-pad", mesh=mesh, tune="measure",
               wisdom=W)
assert p2.tuning["source"] == "wisdom", p2.tuning["source"]
assert p2.schedule == grouped_pick
L = max(int(x) for x in plan_pads)
def crop_phase(mat):
    if L > n:
        mat = jnp.pad(mat, ((0, 0), (0, L - n)))
    return jnp.fft.fft(mat, axis=-1)[:, :n]
ref_pad = crop_phase(crop_phase(m).T).T
assert float(jnp.max(jnp.abs(p2.execute(m) - ref_pad))) < 1e-2, "served"

# 4. the raw entry point serves the same grouped schedule
out_raw = pfft2_distributed(m, mesh, "fft", padded="crop", wisdom=W,
                            pad_len=None, tune="off")
# (raw call has no FPM partition context: it looks up the lb-keyed entry,
# which this store does not hold -> default config; just check it runs)
assert out_raw.shape == (n, n)

# 5. what genuinely cannot lower still raises the named SPMD error
try:
    pfft2_distributed(m, mesh, "fft", schedule=SegmentSchedule.from_parts(
        n, [n // 2, n // 2], None,
        [PlanConfig(radix=4, fused=True), PlanConfig()]))
    raise SystemExit("expected the named SPMD error for a fused mix")
except ValueError as e:
    assert "SPMD" in str(e)
print("DIST_GROUPS_OK")
"""


def test_grouped_schedule_4_devices(dist_subprocess, tmp_path):
    script = _GROUPED_SCRIPT.replace(
        "WISDOM_PATH", str(tmp_path / "wisdom.json"))
    dist_subprocess(script, devices=4, sentinel="DIST_GROUPS_OK")


# ------------------------------------------- in-process multi-device rig

@pytest.mark.multi_device
def test_grouped_schedule_inprocess_on_forced_topology():
    """Runs under the CI dist job's REPRO_FORCE_DEVICES=4 (or any forced
    multi-device topology): the grouped program executes in-process and
    matches the homogeneous result bit-for-tolerance."""
    from repro.core.pfft_dist import pfft2_distributed

    p = min(jax.device_count(), 4)
    mesh = jax.make_mesh((p,), ("fft",))
    n = 16 * p
    rng = np.random.default_rng(2)
    m = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(np.complex64))
    cfgs = [PlanConfig() if i < p // 2 else PlanConfig(radix=2)
            for i in range(p)]
    sched = SegmentSchedule.from_parts(n, [n // p] * p, None, cfgs)
    assert len(sched.configs) == 2
    out = pfft2_distributed(m, mesh, "fft", schedule=sched)
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fft2(m)),
                               atol=1e-2)
