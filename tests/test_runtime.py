"""Fault-tolerance substrate: checkpoint/restart exactness, straggler
detection + FPM repartition, elastic mesh rebuild."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import TrainCfg
from repro.core.fpm import SpeedFunction
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.registry import get_smoke_config
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import largest_grid, rebuild_mesh, reshard
from repro.runtime.straggler import StragglerMonitor
from repro.train.step import init_train_state, make_train_step


def test_checkpoint_roundtrip_exact(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": [jnp.int32(7), jnp.zeros(2)]}}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(5, tree, extra={"note": "x"})
    assert mgr.latest_step() == 5
    restored, extra = mgr.restore(5, jax.eval_shape(lambda: tree))
    assert extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.zeros(1)})
    assert mgr.steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"x": jnp.arange(5)}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"x": jnp.zeros(4)})
    names = os.listdir(tmp_path)
    assert all(not n.endswith(".tmp") for n in names)


def test_kill_restart_continues_loss_curve(tmp_path):
    """Train 10 steps saving at 5; 'crash'; resume from 5 and verify the
    steps 5..9 produce identical losses (exact restart incl. data cursor)."""
    cfg = get_smoke_config("internlm2_1_8b")
    tcfg = TrainCfg(lr=1e-3, microbatches=1, total_steps=10, warmup=2)
    step = jax.jit(make_train_step(cfg, tcfg))

    def fresh():
        return (init_train_state(jax.random.PRNGKey(0), cfg, tcfg),
                SyntheticTokenPipeline(cfg, batch=4, seq=16, seed=0))

    # uninterrupted reference
    state, pipe = fresh()
    ref_losses = []
    for s in range(10):
        state, m = step(state, pipe.next())
        ref_losses.append(float(m["loss"]))

    # run-to-5, checkpoint, crash, restore, continue
    mgr = CheckpointManager(str(tmp_path))
    state, pipe = fresh()
    for s in range(5):
        state, m = step(state, pipe.next())
    mgr.save(5, state, extra={"pipeline": pipe.state_dict()})
    del state, pipe  # crash

    state2, pipe2 = fresh()
    state2, extra = mgr.restore(5, state2)
    pipe2.load_state_dict(extra["pipeline"])
    resumed = []
    for s in range(5, 10):
        state2, m = step(state2, pipe2.next())
        resumed.append(float(m["loss"]))
    np.testing.assert_allclose(resumed, ref_losses[5:], rtol=1e-4)


# ------------------------------------------------------------- straggler

def test_straggler_detects_slow_group():
    mon = StragglerMonitor(n_groups=4, threshold=1.3)
    for _ in range(10):
        for g in range(4):
            mon.record(g, 1.0 if g != 2 else 2.0)
    assert mon.slow_groups() == [2]
    rel = mon.relative_speeds()
    assert rel[2] == pytest.approx(0.5, rel=0.05)


def test_straggler_repartition_shifts_work():
    mon = StragglerMonitor(n_groups=2, threshold=1.3)
    for _ in range(5):
        mon.record(0, 1.0)
        mon.record(1, 3.0)   # 3x slower
    xs = np.array([1, 16, 32, 64])
    ys = np.array([64, 128])
    base = SpeedFunction(xs, ys, np.outer(xs, [1, 1.05]) + 1)
    res = mon.repartition(base, n_rows=64, y=128)
    assert res is not None
    assert res.d[0] > res.d[1]
    assert res.d.sum() == 64


def test_straggler_no_action_when_healthy():
    mon = StragglerMonitor(n_groups=3)
    for _ in range(5):
        for g in range(3):
            mon.record(g, 1.0)
    xs = np.array([1, 8]); ys = np.array([16])
    base = SpeedFunction(xs, ys, np.ones((2, 1)))
    assert mon.repartition(base, 8, 16) is None


# --------------------------------------------------------------- elastic

def test_largest_grid():
    assert largest_grid(512, 16) == (32, 16)
    assert largest_grid(256, 16) == (16, 16)
    assert largest_grid(8, 16) == (1, 8)     # shrink model axis to fit
    assert largest_grid(1, 16) == (1, 1)


def test_rebuild_and_reshard_on_local_devices():
    mesh = rebuild_mesh(model_axis=1)
    assert mesh.devices.size >= 1
    from jax.sharding import PartitionSpec as P
    tree = {"w": jnp.arange(8.0)}
    out = reshard(tree, mesh, {"w": P()})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))
