"""Fault-tolerance substrate: checkpoint/restart exactness, straggler
detection + FPM repartition, elastic mesh rebuild."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import TrainCfg
from repro.core.fpm import FPMSet, SpeedFunction
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.registry import get_smoke_config
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import (largest_fft_axis, largest_grid,
                                   rebuild_fft_mesh, rebuild_mesh, reshard)
from repro.runtime.straggler import StragglerMonitor
from repro.train.step import init_train_state, make_train_step


def test_checkpoint_roundtrip_exact(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": [jnp.int32(7), jnp.zeros(2)]}}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(5, tree, extra={"note": "x"})
    assert mgr.latest_step() == 5
    restored, extra = mgr.restore(5, jax.eval_shape(lambda: tree))
    assert extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.zeros(1)})
    assert mgr.steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"x": jnp.arange(5)}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"x": jnp.zeros(4)})
    names = os.listdir(tmp_path)
    assert all(not n.endswith(".tmp") for n in names)


def test_checkpoint_async_write_failure_surfaces_on_wait(tmp_path):
    """A background write that dies must not vanish with its thread:
    wait() re-raises the failure — exactly once — and the manager keeps
    working afterwards."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    real_write = mgr._write

    def boom(step, flat, meta):
        raise OSError("disk full")

    mgr._write = boom
    mgr.save(1, {"x": jnp.zeros(2)}, blocking=False)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    mgr.wait()  # error was consumed: loud exactly once
    mgr._write = real_write
    mgr.save(2, {"x": jnp.zeros(2)}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_checkpoint_async_write_failure_surfaces_on_next_save(tmp_path):
    """save(blocking=False) waits for the previous write first, so a
    died write surfaces there even when the caller never calls wait()."""
    mgr = CheckpointManager(str(tmp_path), keep=3)

    def boom(step, flat, meta):
        raise OSError("quota exceeded")

    mgr._write = boom
    mgr.save(1, {"x": jnp.zeros(2)}, blocking=False)
    if mgr._thread is not None:
        mgr._thread.join()  # let the failure land without consuming it
    with pytest.raises(OSError, match="quota exceeded"):
        mgr.save(2, {"x": jnp.zeros(2)}, blocking=False)


def test_checkpoint_steps_skips_stray_dirnames(tmp_path):
    """Stray ``step_*`` names (user backups, editor droppings, in-flight
    tmp dirs) must be skipped, not crash ``int()`` in the listing."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(3, {"x": jnp.zeros(1)})
    mgr.save(11, {"x": jnp.zeros(1)})
    for stray in ("step_backup", "step_5~", "step_000000000007.tmp",
                  "notes.txt"):
        os.makedirs(tmp_path / stray)
    assert mgr.steps() == [3, 11]
    assert mgr.latest_step() == 11


def test_kill_restart_continues_loss_curve(tmp_path):
    """Train 10 steps saving at 5; 'crash'; resume from 5 and verify the
    steps 5..9 produce identical losses (exact restart incl. data cursor)."""
    cfg = get_smoke_config("internlm2_1_8b")
    tcfg = TrainCfg(lr=1e-3, microbatches=1, total_steps=10, warmup=2)
    step = jax.jit(make_train_step(cfg, tcfg))

    def fresh():
        return (init_train_state(jax.random.PRNGKey(0), cfg, tcfg),
                SyntheticTokenPipeline(cfg, batch=4, seq=16, seed=0))

    # uninterrupted reference
    state, pipe = fresh()
    ref_losses = []
    for s in range(10):
        state, m = step(state, pipe.next())
        ref_losses.append(float(m["loss"]))

    # run-to-5, checkpoint, crash, restore, continue
    mgr = CheckpointManager(str(tmp_path))
    state, pipe = fresh()
    for s in range(5):
        state, m = step(state, pipe.next())
    mgr.save(5, state, extra={"pipeline": pipe.state_dict()})
    del state, pipe  # crash

    state2, pipe2 = fresh()
    state2, extra = mgr.restore(5, state2)
    pipe2.load_state_dict(extra["pipeline"])
    resumed = []
    for s in range(5, 10):
        state2, m = step(state2, pipe2.next())
        resumed.append(float(m["loss"]))
    np.testing.assert_allclose(resumed, ref_losses[5:], rtol=1e-4)


# ------------------------------------------------------------- straggler

def test_straggler_detects_slow_group():
    mon = StragglerMonitor(n_groups=4, threshold=1.3)
    for _ in range(10):
        for g in range(4):
            mon.record(g, 1.0 if g != 2 else 2.0)
    assert mon.slow_groups() == [2]
    rel = mon.relative_speeds()
    assert rel[2] == pytest.approx(0.5, rel=0.05)


def test_straggler_repartition_shifts_work():
    mon = StragglerMonitor(n_groups=2, threshold=1.3)
    for _ in range(5):
        mon.record(0, 1.0)
        mon.record(1, 3.0)   # 3x slower
    xs = np.array([1, 16, 32, 64])
    ys = np.array([64, 128])
    base = SpeedFunction(xs, ys, np.outer(xs, [1, 1.05]) + 1)
    res = mon.repartition(base, n_rows=64, y=128)
    assert res is not None
    assert res.d[0] > res.d[1]
    assert res.d.sum() == 64


def test_straggler_no_action_when_healthy():
    mon = StragglerMonitor(n_groups=3)
    for _ in range(5):
        for g in range(3):
            mon.record(g, 1.0)
    xs = np.array([1, 8]); ys = np.array([16])
    base = SpeedFunction(xs, ys, np.ones((2, 1)))
    assert mon.repartition(base, 8, 16) is None


def test_straggler_relative_speeds_before_warmup():
    """A partially-warmed monitor must stay neutral, never leak NaN —
    the guard slow_groups() always had, applied to relative_speeds()."""
    mon = StragglerMonitor(n_groups=4)
    rel = mon.relative_speeds()           # no samples at all
    assert not np.any(np.isnan(rel))
    np.testing.assert_array_equal(rel, np.ones(4))
    mon.record(0, 2.0)                    # one of four groups sampled
    mon.record(1, 1.0)
    rel = mon.relative_speeds()
    assert not np.any(np.isnan(rel))
    np.testing.assert_array_equal(rel[2:], [1.0, 1.0])  # unsampled: neutral
    assert rel[0] < rel[1]                # sampled groups still ranked
    xs = np.array([1, 8]); ys = np.array([16])
    fpms = mon.degraded_fpms(SpeedFunction(xs, ys, np.ones((2, 1))))
    assert all(np.isfinite(f.speed).all() for f in fpms)


def test_straggler_reset_forgets_drift():
    mon = StragglerMonitor(n_groups=2, threshold=1.3)
    for _ in range(5):
        mon.record(0, 1.0)
        mon.record(1, 3.0)
    assert mon.slow_groups() == [1]
    mon.reset()
    assert mon.slow_groups() == []        # drift must not re-trigger
    np.testing.assert_array_equal(mon.relative_speeds(), np.ones(2))


def test_straggler_degraded_fpms_per_group_scaling():
    mon = StragglerMonitor(n_groups=2)
    for _ in range(8):
        mon.record(0, 1.0)
        mon.record(1, 2.0)
    xs = np.array([1, 8]); ys = np.array([16, 32])
    base = SpeedFunction(xs, ys, np.full((2, 2), 1e9))
    degraded = mon.degraded_fpms(base)
    assert degraded.p == 2
    ratio = degraded[1].speed / degraded[0].speed
    np.testing.assert_allclose(ratio, 0.5, rtol=1e-6)


# --------------------------------------------------------------- elastic

def test_largest_grid():
    assert largest_grid(512, 16) == (32, 16)
    assert largest_grid(256, 16) == (16, 16)
    assert largest_grid(8, 16) == (1, 8)     # shrink model axis to fit
    assert largest_grid(1, 16) == (1, 1)


def test_largest_grid_non_power_of_two():
    """Non-pow2 survivor counts / model axes: halving must bottom out at
    a usable grid, never a zero axis."""
    assert largest_grid(5, 3) == (1, 3)      # 3 of 5 survivors fit
    assert largest_grid(2, 3) == (2, 1)      # model axis halves 3->1
    assert largest_grid(6, 4) == (1, 4)
    assert largest_grid(7, 16) == (1, 4)    # 16 halves to the largest fit
    for n, m in [(5, 3), (2, 3), (6, 4), (7, 16), (1, 1)]:
        data, model = largest_grid(n, m)
        assert data >= 1 and model >= 1 and data * model <= n


def test_rebuild_mesh_reports_dropped_survivors():
    res = rebuild_mesh(model_axis=1)
    n = len(jax.devices())
    assert res.used + res.dropped == n
    assert res.mesh.devices.size == res.used
    # an awkward model axis: whatever grid is found, every surviving
    # device is either placed or counted as dropped — none vanish
    res2 = rebuild_mesh(model_axis=max(2 * n - 1, 1))
    assert res2.used >= 1
    assert res2.used + res2.dropped == n
    assert res2.mesh.devices.size == res2.used


def test_largest_fft_axis_divisibility():
    assert largest_fft_axis(4, 48) == 4
    assert largest_fft_axis(3, 48) == 3      # non-pow2 axis kept
    assert largest_fft_axis(5, 48) == 4      # 5 does not divide 48
    assert largest_fft_axis(7, 48) == 6
    assert largest_fft_axis(1, 48) == 1
    assert largest_fft_axis(4, 7) == 1       # prime N: no parallel axis


def test_rebuild_fft_mesh_local_devices():
    res = rebuild_fft_mesh(48)
    assert res.mesh.shape["fft"] == res.used
    assert 48 % res.used == 0
    assert res.used + res.dropped == len(jax.devices())


def test_rebuild_and_reshard_on_local_devices():
    mesh = rebuild_mesh(model_axis=1).mesh
    assert mesh.devices.size >= 1
    from jax.sharding import PartitionSpec as P
    tree = {"w": jnp.arange(8.0)}
    out = reshard(tree, mesh, {"w": P()})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))
