"""PFFT algorithms vs oracles: exactness of LB/FPM/CZT, padded semantics of
PAD, plan API, and the naive-DFT cross-check of the FFT substrate."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import (FPMSet, SpeedFunction, czt_dft, pfft_fpm,
                        pfft_fpm_czt, pfft_fpm_pad, pfft_lb, plan_pfft)
from repro.fft import dft1d_naive, dft2d_naive, fft1d_stockham, fft2d_rowcol


def fpms_for(n, p=3, hetero=True):
    xs = np.array(sorted({1, max(n // 8, 1), max(n // 4, 1), max(n // 2, 1), n}))
    ys = np.array(sorted({n // 2, n, n + 64, 2 * n}))
    sp = np.outer(xs, np.log2(np.maximum(ys, 2))) + 3.0
    fns = [SpeedFunction(xs, ys, sp * (i + 1 if hetero else 1), name=f"P{i}")
           for i in range(p)]
    return FPMSet(fns)


def random_signal(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal((n, n))
                        + 1j * rng.standard_normal((n, n))).astype(np.complex64))


def test_fft1d_stockham_vs_naive_dft():
    rng = np.random.default_rng(1)
    x = jnp.asarray((rng.standard_normal((4, 32))
                     + 1j * rng.standard_normal((4, 32))).astype(np.complex64))
    np.testing.assert_allclose(np.asarray(fft1d_stockham(x)),
                               np.asarray(dft1d_naive(x)), atol=2e-3)


def test_fft1d_rejects_non_pow2():
    with pytest.raises(ValueError):
        fft1d_stockham(jnp.ones(12, jnp.complex64))


def test_fft2d_rowcol_vs_naive():
    m = random_signal(16)
    np.testing.assert_allclose(np.asarray(fft2d_rowcol(m)),
                               np.asarray(dft2d_naive(m)), atol=2e-2)


@pytest.mark.parametrize("n,p", [(32, 2), (64, 3), (48, 4)])
def test_pfft_lb_exact(n, p):
    m = random_signal(n)
    np.testing.assert_allclose(np.asarray(pfft_lb(m, p)),
                               np.asarray(jnp.fft.fft2(m)), atol=1e-2)


@pytest.mark.parametrize("n", [32, 64])
def test_pfft_fpm_exact(n):
    m = random_signal(n)
    out, part = pfft_fpm(m, fpms_for(n), return_partition=True)
    assert part.d.sum() == n
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fft2(m)),
                               atol=1e-2)


def test_pfft_fpm_pad_padded_semantics():
    """PAD computes the padded-signal DFT cropped to N bins (paper Alg. 7
    semantics).  Validate against exactly that oracle."""
    n = 32
    m = random_signal(n)
    out, part, pads = pfft_fpm_pad(m, fpms_for(n), return_partition=True)

    def padded_phase(mat):
        segs, off = [], 0
        for i, d in enumerate(part.d):
            if d == 0:
                continue
            seg = mat[off:off + d]
            np_i = int(pads[i])
            if np_i > n:
                seg = jnp.pad(seg, ((0, 0), (0, np_i - n)))
                segs.append(jnp.fft.fft(seg, axis=-1)[:, :n])
            else:
                segs.append(jnp.fft.fft(seg, axis=-1))
            off += int(d)
        return jnp.concatenate(segs, 0)

    ref = padded_phase(padded_phase(m).T).T
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-2)


def test_pfft_fpm_pad_normalizes_explicit_config_pad():
    """Satellite regression: the method owns the pad strategy.  An
    explicit ``config=`` whose pad drifted (czt, none, or a fused pick)
    must still run the paper's padded-signal crop — before the shared
    ``normalize_pad`` helper, ``pfft_fpm_pad(config=PlanConfig(pad=
    'czt'))`` silently ran Bluestein (the *exact* transform) instead of
    the documented interpolation."""
    from repro.plan import PlanConfig
    n = 32
    m = random_signal(n)
    # One slow/flat + two fast/pow2-peaked FPMs: the fast processors'
    # FPM-chosen pad is 64 > N, so the pad semantics actually engage
    # (fpms_for's smooth speeds never favor padding at this size).
    xs = np.array(sorted({1, n // 2, n}))
    ys = np.array(sorted({n, 64, 128}))
    fast = np.tile([1e9, 4e9, 1e9], (len(xs), 1))
    slow = np.full((len(xs), len(ys)), 2.5e8)
    fpms = FPMSet([SpeedFunction(xs, ys, slow if i == 0 else fast,
                                 name=f"P{i}") for i in range(3)])
    ref, part, pads = pfft_fpm_pad(m, fpms, return_partition=True)
    assert any(int(p_) > n for p_ in pads)  # padding actually engages
    # The padded-crop result differs from the exact DFT, so a czt drift
    # would be visible — the assertion below is load-bearing.
    exact = np.asarray(jnp.fft.fft2(m))
    assert float(np.max(np.abs(np.asarray(ref) - exact))) > 1e-3
    for drifted in (PlanConfig(pad="czt"), PlanConfig(pad="none"),
                    PlanConfig(radix=4, fused=True)):
        out = pfft_fpm_pad(m, fpms, config=drifted)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)


@pytest.mark.parametrize("n", [32, 48])
def test_pfft_fpm_czt_exact_despite_padding(n):
    m = random_signal(n)
    out = pfft_fpm_czt(m, fpms_for(n))
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fft2(m)),
                               atol=5e-2)


@given(n=st.sampled_from([8, 12, 16, 27, 37]), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_czt_dft_property_any_length(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal((2, n))
                     + 1j * rng.standard_normal((2, n))).astype(np.complex64))
    np.testing.assert_allclose(np.asarray(czt_dft(x)),
                               np.asarray(jnp.fft.fft(x, axis=-1)), atol=5e-3)


def test_czt_chirp_exact_past_int32_overflow():
    """Satellite regression: the chirp's quadratic residues are computed
    in int64 — the old traced ``jnp.arange(n)`` path squared in int32
    (x64 off), wrapping for j >= 46341 and silently corrupting the
    "exact" transform for every N > 46340.  Checked against the int64
    oracle at the overflow boundary without allocating a giant
    transform (the chirp is O(N), the transform would be O(N^2))."""
    from repro.core.pfft import _czt_chirp
    n = 46342  # j = 46341 is the first index where int32 j*j wraps
    chirp = _czt_chirp(n)
    assert chirp.shape == (n,)
    j = np.array([0, 1, 46340, 46341], dtype=np.int64)
    oracle = np.exp(-1j * np.pi * ((j * j) % (2 * n)) / n)
    np.testing.assert_allclose(chirp[j], oracle, rtol=0, atol=1e-12)
    # The int32 computation this replaces is genuinely wrong there — the
    # wrapped square lands on a different residue class mod 2N (2N has an
    # odd factor, so adding 2^32 can never preserve it), i.e. the test
    # is load-bearing, not vacuous.
    wrapped = (j * j) % (1 << 32)
    wrapped = np.where(wrapped >= (1 << 31), wrapped - (1 << 32), wrapped)
    bad = np.exp(-1j * np.pi * np.fmod(wrapped, 2 * n) / n)
    assert abs(bad[3] - oracle[3]) > 1e-3


def test_czt_dft_matches_oracle_at_unpadded_large_index_regime():
    """The fixed chirp keeps czt_dft exact for sizes well past any pow2
    boundary quirks (cheap sanity companion to the chirp unit test)."""
    n = 1031  # prime: no FFT shortcut, full Bluestein machinery
    rng = np.random.default_rng(9)
    x = jnp.asarray((rng.standard_normal((2, n))
                     + 1j * rng.standard_normal((2, n))).astype(np.complex64))
    np.testing.assert_allclose(np.asarray(czt_dft(x)),
                               np.asarray(jnp.fft.fft(x, axis=-1)), atol=2e-2)


def test_czt_rejects_short_fft():
    with pytest.raises(ValueError):
        czt_dft(jnp.ones((1, 16), jnp.complex64), m_fft=16)


@pytest.mark.parametrize("n", [7, 13, 31])
def test_czt_odd_lengths(n):
    """Odd N exercises the chirp's (j^2 mod 2N) exactness trick."""
    rng = np.random.default_rng(n)
    x = jnp.asarray((rng.standard_normal((3, n))
                     + 1j * rng.standard_normal((3, n))).astype(np.complex64))
    np.testing.assert_allclose(np.asarray(czt_dft(x)),
                               np.asarray(jnp.fft.fft(x, axis=-1)), atol=5e-3)


@pytest.mark.parametrize("m_fft", [31, 33, 40, 64])
def test_czt_explicit_m_fft(m_fft):
    """Any m_fft >= 2N-1 is valid, including non-power-of-two lengths."""
    n = 16  # 2N-1 = 31
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((2, n))
                     + 1j * rng.standard_normal((2, n))).astype(np.complex64))
    np.testing.assert_allclose(np.asarray(czt_dft(x, m_fft=m_fft)),
                               np.asarray(jnp.fft.fft(x, axis=-1)), atol=5e-3)


def test_czt_m_fft_boundary_error():
    """m_fft = 2N-2 is rejected; 2N-1 (exact boundary) is accepted."""
    n = 16
    x = jnp.ones((1, n), jnp.complex64)
    with pytest.raises(ValueError):
        czt_dft(x, m_fft=2 * n - 2)
    np.testing.assert_allclose(np.asarray(czt_dft(x, m_fft=2 * n - 1)),
                               np.asarray(jnp.fft.fft(x, axis=-1)), atol=5e-3)


def test_plan_api_all_methods():
    n = 32
    m = random_signal(n)
    oracle = np.asarray(jnp.fft.fft2(m))
    for method in ("lb", "fpm", "fpm-czt"):
        plan = plan_pfft(n, p=3, fpms=fpms_for(n), method=method)
        np.testing.assert_allclose(np.asarray(plan.execute(m)), oracle,
                                   atol=5e-2)
    plan = plan_pfft(n, fpms=fpms_for(n), method="fpm-pad")
    assert plan.pad_lengths is not None
    with pytest.raises(ValueError):
        plan.execute(jnp.ones((n + 1, n + 1), jnp.complex64))
    with pytest.raises(ValueError):
        plan_pfft(n, method="lb")  # p required
    with pytest.raises(ValueError):
        plan_pfft(n, p=2, method="fpm")  # fpms required


def test_parseval_property():
    """Energy conservation: ||FFT(x)||^2 = N^2 ||x||^2 for the 2-D DFT."""
    n = 64
    m = random_signal(n, seed=7)
    out = pfft_fpm(m, fpms_for(n))
    lhs = float(jnp.sum(jnp.abs(out) ** 2))
    rhs = float(n * n * jnp.sum(jnp.abs(m) ** 2))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3)


def test_fft_rows_pallas_backend():
    """The Pallas kernel is a drop-in backend for the PFFT row phases."""
    from repro.fft.fft2d import fft_rows
    from repro.core.pfft import segment_row_ffts
    rng = np.random.default_rng(5)
    m = jnp.asarray((rng.standard_normal((8, 64))
                     + 1j * rng.standard_normal((8, 64))).astype(np.complex64))
    ref = jnp.fft.fft(m, axis=-1)
    np.testing.assert_allclose(np.asarray(fft_rows(m, backend="pallas")),
                               np.asarray(ref), atol=2e-3)
    out = segment_row_ffts(m, np.array([5, 3]), backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)
    # non-pow2 lengths fall back to XLA
    m2 = jnp.ones((4, 48), jnp.complex64)
    np.testing.assert_allclose(np.asarray(fft_rows(m2, backend="pallas")),
                               np.asarray(jnp.fft.fft(m2, axis=-1)), atol=2e-3)
