"""Planner subsystem: PlanConfig, cost model, tuner, wisdom store, and the
plan_pfft tune/wisdom lifecycle (including equivalence with the
pre-refactor flag paths and batched execute)."""

import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import FPMSet, PlanConfig, SpeedFunction, plan_pfft
from repro.core.pfft import _pfft_limb, segment_row_ffts
from repro.core.partition import lb_partition
from repro.plan import (WISDOM_VERSION, CostParams, candidate_configs,
                        czt_fft_lengths, estimate_cost, fpm_pad_lengths,
                        load_wisdom, lookup_wisdom, record_wisdom,
                        tune_config, wisdom_key)
from repro.core.padding import determine_pad_length, smooth_candidates


def fpms_for(n, p=3, hetero=True):
    xs = np.array(sorted({1, max(n // 4, 1), max(n // 2, 1), n}))
    ys = np.array(sorted({n // 2, n, n + 64, 2 * n}))
    sp = np.outer(xs, np.log2(np.maximum(ys, 2))) + 3.0
    fns = [SpeedFunction(xs, ys, sp * (i + 1 if hetero else 1), name=f"P{i}")
           for i in range(p)]
    return FPMSet(fns)


def random_signal(n, seed=0, dtype=np.complex64):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal((n, n))
                        + 1j * rng.standard_normal((n, n))).astype(dtype))


# ---------------------------------------------------------------- PlanConfig

def test_config_validation():
    with pytest.raises(ValueError):
        PlanConfig(radix=3)
    with pytest.raises(ValueError):
        PlanConfig(pad="crop")  # dist vocabulary, not a strategy name
    with pytest.raises(ValueError):
        PlanConfig(pipeline_panels=0)
    with pytest.raises(ValueError):
        PlanConfig(fused=True, pad="fpm")  # fused has no per-segment pads


def test_config_dict_roundtrip_and_unknown_fields():
    cfg = PlanConfig(radix=4, fused=True, pipeline_panels=2)
    assert PlanConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError):
        PlanConfig.from_dict({"radix": 4, "warp_drive": True})


def test_config_flag_bridge_and_backend():
    cfg = PlanConfig.from_flags(use_stockham=True, batched=False)
    assert cfg.radix == 2 and cfg.use_stockham and not cfg.batched
    assert cfg.fft_backend == "stockham"
    assert PlanConfig().fft_backend == "xla"
    assert PlanConfig(radix=4).fft_backend == "pallas"
    assert PlanConfig(pad="fpm").dist_padded == "crop"
    assert PlanConfig(pad="czt").dist_padded == "czt"


# ------------------------------------------------------------- pads helpers

def test_fpm_pad_lengths_matches_per_processor_rule():
    n = 32
    fpms = fpms_for(n)
    d = lb_partition(n, fpms.p).d
    pads = fpm_pad_lengths(fpms, d, n)
    expect = [determine_pad_length(fpms[i], int(d[i]), n)
              for i in range(fpms.p)]
    np.testing.assert_array_equal(pads, expect)


def test_czt_fft_lengths_matches_argmin_loop():
    n = 32
    fpms = fpms_for(n)
    d = lb_partition(n, fpms.p).d
    lens = czt_fft_lengths(fpms, d, n)
    cands = smooth_candidates(2 * n - 1, limit_ratio=2.0)
    for i in range(fpms.p):
        times = [fpms[i].time_at(int(d[i]), int(c)) for c in cands]
        assert lens[i] == int(cands[int(np.argmin(times))])
    assert np.all(lens >= 2 * n - 1)


# ---------------------------------------------------------------- cost model

def test_cost_batched_beats_looped_on_dispatch_overhead():
    n = 64
    d = np.array([16, 16, 16, 16])
    params = CostParams.for_backend("cpu")
    c_b = estimate_cost(PlanConfig(batched=True), n=n, d=d, params=params)
    c_l = estimate_cost(PlanConfig(batched=False), n=n, d=d, params=params)
    assert c_b < c_l  # 1 dispatch/phase vs 4


def test_cost_cpu_prefers_library_accel_prefers_kernels():
    n, d = 256, np.array([64] * 4)
    cpu = CostParams.for_backend("cpu")
    tpu = CostParams.for_backend("tpu")
    lib = PlanConfig()
    fused = PlanConfig(radix=4, fused=True)
    assert estimate_cost(lib, n=n, d=d, params=cpu) < \
        estimate_cost(fused, n=n, d=d, params=cpu)  # interpret-mode penalty
    assert estimate_cost(fused, n=n, d=d, params=tpu) < \
        estimate_cost(lib, n=n, d=d, params=tpu)  # no HBM round trip


def test_cost_uses_fpm_times():
    n, d = 64, np.array([32, 32])
    slow = FPMSet([SpeedFunction([1, 32], [32, 64, 128],
                                 np.full((2, 3), s), name="P")
                   for s in (1e6, 1e6)])
    fast = FPMSet([SpeedFunction([1, 32], [32, 64, 128],
                                 np.full((2, 3), s), name="P")
                   for s in (1e9, 1e9)])
    cfg = PlanConfig()
    assert estimate_cost(cfg, n=n, d=d, fpms=slow) > \
        estimate_cost(cfg, n=n, d=d, fpms=fast)


# -------------------------------------------------------------------- tuner

def test_candidate_space_constraints():
    # non-pow2: no kernel radices, no fused
    cands = candidate_configs(48, d=np.array([24, 24]))
    assert all(c.radix is None and not c.fused for c in cands)
    # pow2 with pads strategy: fused excluded, pad carried through
    cands = candidate_configs(64, pad="fpm", d=np.array([32, 32]))
    assert all(not c.fused and c.pad == "fpm" for c in cands)
    # single-segment partitions don't enumerate batched=False
    cands = candidate_configs(64, d=np.array([64]))
    assert all(c.batched for c in cands)


def test_estimate_equals_bruteforce_cheapest_on_synthetic_fpms():
    """The planner's pick is exactly argmin of the cost model over the
    candidate space (satellite acceptance)."""
    n = 64
    fpms = fpms_for(n)
    d = lb_partition(n, fpms.p).d
    params = CostParams.for_backend("cpu")
    chosen, info = tune_config(n, d=d, fpms=fpms, mode="estimate",
                               params=params)
    brute = min(candidate_configs(n, d=d),
                key=lambda c: estimate_cost(c, n=n, d=d, fpms=fpms,
                                            params=params))
    assert chosen == brute
    ranked_costs = [c for _, c in info["ranked"]]
    assert ranked_costs == sorted(ranked_costs)


def test_measure_mode_times_finalists():
    n = 32
    d = lb_partition(n, 2).d
    chosen, info = tune_config(n, d=d, mode="measure", top_k=2, reps=1)
    assert len(info["measured"]) == 2
    assert chosen in candidate_configs(n, d=d)
    assert info["time_s"] > 0


def test_tune_rejects_bad_mode():
    with pytest.raises(ValueError):
        tune_config(32, mode="exhaustive")


def test_measure_mode_without_partition():
    """d=None means one whole-matrix segment in measure mode too (it would
    otherwise crash deep inside the limb)."""
    chosen, info = tune_config(16, mode="measure", top_k=1, reps=1)
    assert chosen in candidate_configs(16)
    assert info["time_s"] > 0


# ------------------------------------------------------------------- wisdom

def test_wisdom_miss_hit_and_overwrite(tmp_path):
    path = str(tmp_path / "wisdom.json")
    key = wisdom_key(n=64, dtype="complex64", p=4, method="lb", backend="cpu")
    assert lookup_wisdom(path, key) is None  # missing file -> miss
    cfg = PlanConfig(radix=4, fused=True)
    record_wisdom(path, key, cfg, mode="measure", time_s=1e-3)
    got, entry = lookup_wisdom(path, key)
    assert got == cfg and entry["mode"] == "measure"
    assert lookup_wisdom(path, key + "|x") is None  # other key -> miss
    record_wisdom(path, key, PlanConfig(), mode="estimate")
    got2, entry2 = lookup_wisdom(path, key)
    assert got2 == PlanConfig() and "time_s" not in entry2


def test_wisdom_version_mismatch_and_corruption_are_misses(tmp_path):
    path = str(tmp_path / "wisdom.json")
    key = wisdom_key(n=8, dtype="complex64", p=2, method="lb", backend="cpu")
    record_wisdom(path, key, PlanConfig(), mode="measure")
    doc = json.load(open(path))
    doc["version"] = WISDOM_VERSION + 1
    json.dump(doc, open(path, "w"))
    assert load_wisdom(path) == {} and lookup_wisdom(path, key) is None
    with open(path, "w") as fh:
        fh.write("{ not json")
    assert load_wisdom(path) == {}
    # recording over a corrupt store rewrites it cleanly
    record_wisdom(path, key, PlanConfig(), mode="measure")
    assert lookup_wisdom(path, key) is not None


def test_wisdom_hit_applies_even_with_tune_off(tmp_path):
    """Passing wisdom=path IS the request to use stored plans (FFTW reads
    wisdom regardless of planner rigor)."""
    path = str(tmp_path / "wisdom.json")
    plan_pfft(32, p=2, method="lb", tune="measure", wisdom=path)
    served = plan_pfft(32, p=2, method="lb", wisdom=path)  # tune defaults off
    assert served.tuning["source"] == "wisdom"
    cold = plan_pfft(32, p=2, method="lb")
    assert cold.tuning["source"] == "off"


def test_wisdom_key_digests_fpm_partition(tmp_path):
    """Different FPMSets give different partitions; one model's measured
    config must not be served to another model's plan."""
    path = str(tmp_path / "wisdom.json")
    n = 32
    hetero = fpms_for(n, hetero=True)
    homo = fpms_for(n, hetero=False)
    p1 = plan_pfft(n, fpms=hetero, method="fpm", tune="measure", wisdom=path)
    assert p1.tuning["source"] == "measure"
    p2 = plan_pfft(n, fpms=homo, method="fpm", tune="measure", wisdom=path)
    if np.array_equal(p1.d, p2.d):  # partitions happened to coincide
        assert p2.tuning["wisdom_key"] == p1.tuning["wisdom_key"]
    else:
        assert p2.tuning["wisdom_key"] != p1.tuning["wisdom_key"]
        assert p2.tuning["source"] == "measure"  # miss, re-measured
    # same model again: hit
    p3 = plan_pfft(n, fpms=hetero, method="fpm", tune="measure", wisdom=path)
    assert p3.tuning["source"] == "wisdom"


def test_plan_pfft_wisdom_lifecycle(tmp_path):
    """measure persists the choice; a later plan (fresh-process analogue)
    is served from wisdom without re-measuring."""
    path = str(tmp_path / "wisdom.json")
    n = 32
    p1 = plan_pfft(n, p=2, method="lb", tune="measure", wisdom=path)
    assert p1.tuning["source"] == "measure" and "measured" in p1.tuning
    p2 = plan_pfft(n, p=2, method="lb", tune="measure", wisdom=path)
    assert p2.tuning["source"] == "wisdom"
    assert "measured" not in p2.tuning  # no re-measure
    assert p2.config == p1.config
    m = random_signal(n)
    np.testing.assert_allclose(np.asarray(p2.execute(m)),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)


# --------------------------------------------------- plan_pfft tune plumbing

def test_plan_pfft_estimate_selects_without_flags():
    n = 64
    fpms = fpms_for(n)
    for method in ("fpm", "fpm-pad"):
        plan = plan_pfft(n, fpms=fpms, method=method, tune="estimate")
        assert plan.tuning["source"] == "estimate"
        assert plan.config in candidate_configs(
            n, pad=plan.config.pad, d=plan.d)
        m = random_signal(n)
        out = plan.execute(m)
        assert out.shape == (n, n)


def test_plan_pfft_explicit_config_skips_tuning():
    cfg = PlanConfig(radix=2, batched=False)
    plan = plan_pfft(32, p=2, method="lb", tune="estimate", config=cfg)
    assert plan.config == cfg and plan.tuning["source"] == "explicit"


def test_plan_pfft_rejects_bad_tune_mode():
    with pytest.raises(ValueError):
        plan_pfft(32, p=2, method="lb", tune="turbo")


# --------------------------------------- numerical identity with flag paths

@pytest.mark.parametrize("flags", [
    dict(use_stockham=True),
    dict(fused=True),
])
def test_config_paths_match_legacy_flag_paths_fp64(flags):
    """Planned execution is numerically identical (fp64 reference) to the
    pre-refactor flag-equivalent path (acceptance criterion)."""
    n = 32
    d = lb_partition(n, 3).d
    m64 = random_signal(n, seed=3, dtype=np.complex128)
    cfg = PlanConfig.from_flags(**flags)
    via_config = _pfft_limb(m64, d, config=cfg)
    with pytest.warns(DeprecationWarning):
        via_flags = _pfft_limb(m64, d, **flags)
    np.testing.assert_allclose(np.asarray(via_config), np.asarray(via_flags),
                               rtol=1e-12, atol=1e-9)
    # Oracle check at the precision actually in effect (the tier-1 driver
    # runs without JAX_ENABLE_X64, demoting complex128 to complex64).
    fp64 = via_config.dtype == jnp.complex128
    np.testing.assert_allclose(np.asarray(via_config),
                               np.asarray(jnp.fft.fft2(m64)),
                               rtol=1e-6 if fp64 else 2e-3,
                               atol=1e-6 if fp64 else 2e-2)


def test_segment_config_matches_legacy_batched_flag_fp64():
    n = 32
    d = lb_partition(n, 3).d
    m64 = random_signal(n, seed=4, dtype=np.complex128)
    pads = np.array([n, 2 * n, n], dtype=np.int64)
    for batched in (True, False):
        via_config = segment_row_ffts(
            m64, d, pad_lengths=pads, config=PlanConfig(batched=batched))
        with pytest.warns(DeprecationWarning):
            via_flag = segment_row_ffts(m64, d, pad_lengths=pads,
                                        batched=batched)
        np.testing.assert_allclose(np.asarray(via_config),
                                   np.asarray(via_flag),
                                   rtol=1e-12, atol=1e-9)


def test_planned_fpm_pad_matches_legacy_flag_path():
    n = 32
    fpms = fpms_for(n)
    m = random_signal(n, seed=5, dtype=np.complex128)
    plan = plan_pfft(n, fpms=fpms, method="fpm-pad", tune="estimate")
    with pytest.warns(DeprecationWarning):
        legacy = plan_pfft(n, fpms=fpms, method="fpm-pad",
                           use_stockham=plan.config.use_stockham)
    np.testing.assert_allclose(np.asarray(plan.execute(m)),
                               np.asarray(legacy.execute(m)),
                               rtol=1e-10, atol=1e-8)


# ------------------------------------------------------------ batched execute

def test_plan_execute_accepts_leading_batch_dims():
    n = 32
    plan = plan_pfft(n, p=2, method="lb")
    rng = np.random.default_rng(9)
    batch = jnp.asarray((rng.standard_normal((2, 3, n, n))
                         + 1j * rng.standard_normal((2, 3, n, n))
                         ).astype(np.complex64))
    out = plan.execute(batch)
    assert out.shape == (2, 3, n, n)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft2(batch)), atol=2e-2)
    # The vmapped wrapper is built once per batch rank and cached.
    plan.execute(batch)
    plan.execute(batch[0])
    assert sorted(plan._batched_fns) == [3, 4]


def test_plan_execute_shape_error_names_planned_size():
    n = 32
    plan = plan_pfft(n, p=2, method="lb")
    with pytest.raises(ValueError, match=r"\(32, 32\)"):
        plan.execute(jnp.ones((n + 1, n + 1), jnp.complex64))
    with pytest.raises(ValueError, match=r"\(32, 32\)"):
        plan.execute(jnp.ones((n,), jnp.complex64))


def test_plan_execute_czt_accepts_batch():
    """Batched czt execute used to be rejected with a named error; since
    the schedule executor took over the per-segment slicing it vmaps
    like every other method (satellite acceptance)."""
    n = 16
    plan = plan_pfft(n, fpms=fpms_for(n), method="fpm-czt")
    m = random_signal(n)
    np.testing.assert_allclose(np.asarray(plan.execute(m)),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)
    batch = jnp.stack([m, 2.0 * m])
    out = plan.execute(batch)
    assert out.shape == (2, n, n)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft2(batch)), atol=4e-2)


# -------------------------------------------------------------- shim hygiene

def test_fused_shim_ignored_on_padded_methods_like_pre_refactor():
    """The pre-refactor API silently ignored fused= for fpm-pad/fpm-czt
    (pad semantics are per-processor); the deprecation shim must not turn
    that into a crash."""
    n = 16
    fpms = fpms_for(n)
    m = random_signal(n)
    for method in ("fpm-pad", "fpm-czt"):
        with pytest.warns(DeprecationWarning):
            plan = plan_pfft(n, fpms=fpms, method=method, fused=True)
        assert not plan.config.fused
        assert plan.execute(m).shape == (n, n)


def test_measure_mode_respects_plan_dtype(tmp_path):
    """plan_pfft's dtype reaches the measurement (and the wisdom key), so
    a complex128 plan is not silently tuned on complex64 timings."""
    path = str(tmp_path / "wisdom.json")
    plan = plan_pfft(16, p=2, method="lb", tune="measure", wisdom=path,
                     dtype="complex128")
    assert "dtype=complex128" in plan.tuning["wisdom_key"]
    assert plan.tuning["source"] == "measure"
    # a complex64 plan misses the complex128 entry
    plan2 = plan_pfft(16, p=2, method="lb", tune="measure", wisdom=path)
    assert plan2.tuning["source"] == "measure"


def test_deprecated_shims_warn_and_conflict():
    n = 16
    m = random_signal(n)
    d = lb_partition(n, 2).d
    with pytest.warns(DeprecationWarning):
        segment_row_ffts(m, d, batched=False)
    with pytest.warns(DeprecationWarning):
        plan_pfft(n, p=2, method="lb", fused=False)
    with pytest.raises(ValueError):
        segment_row_ffts(m, d, config=PlanConfig(), batched=True)
    with pytest.raises(ValueError):
        plan_pfft(n, p=2, method="lb", config=PlanConfig(), fused=True)


def test_public_wrappers_share_the_shim_contract():
    """pfft_lb/pfft_fpm/pfft_fpm_pad warn on legacy flags and reject
    config + flags conflicts exactly like the inner layers."""
    from repro.core import pfft_fpm, pfft_fpm_pad, pfft_lb
    n = 16
    m = random_signal(n)
    fpms = fpms_for(n)
    with pytest.warns(DeprecationWarning):
        pfft_lb(m, 2, use_stockham=True)
    with pytest.warns(DeprecationWarning):
        pfft_fpm(m, fpms, fused=True)
    with pytest.warns(DeprecationWarning):
        pfft_fpm_pad(m, fpms, use_stockham=True)
    with pytest.raises(ValueError):
        pfft_lb(m, 2, use_stockham=True, config=PlanConfig(radix=4))
    with pytest.raises(ValueError):
        pfft_fpm_pad(m, fpms, use_stockham=False, config=PlanConfig())
    # config-only calls stay silent
    out = pfft_lb(m, 2, config=PlanConfig(batched=False))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.fft.fft2(m)), atol=2e-2)


def test_pfft2_distributed_config_and_shims():
    from repro.core.pfft_dist import pfft2_distributed
    mesh = jax.make_mesh((1,), ("fft",))
    n = 16
    m = random_signal(n)
    out = pfft2_distributed(m, mesh, "fft",
                            config=PlanConfig(pipeline_panels=4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.fft.fft2(m)),
                               atol=2e-2)
    with pytest.warns(DeprecationWarning):
        pfft2_distributed(m, mesh, "fft", pipeline_panels=2)
    with pytest.raises(ValueError):
        pfft2_distributed(m, mesh, "fft", config=PlanConfig(),
                          pipeline_panels=2)
    with pytest.raises(ValueError):  # config.pad conflicts with padded=
        pfft2_distributed(m, mesh, "fft", config=PlanConfig(), padded="czt")
