"""Unit + property tests for functional performance models."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.fpm import (FPMSet, SpeedFunction, build_fpm, fft_flops,
                            load_fpms, save_fpms)


def make_fn(scale=1.0, name="P"):
    xs = np.array([1, 2, 4, 8, 16])
    ys = np.array([16, 32, 64, 128])
    speed = scale * np.outer(xs, np.log2(ys)) + 1.0
    return SpeedFunction(xs, ys, speed, name=name)


def test_validation_rejects_bad_grids():
    with pytest.raises(ValueError):
        SpeedFunction(np.array([2, 1]), np.array([16]), np.ones((2, 1)))
    with pytest.raises(ValueError):
        SpeedFunction(np.array([1, 2]), np.array([16]), -np.ones((2, 1)))
    with pytest.raises(ValueError):
        SpeedFunction(np.array([1, 2]), np.array([16]), np.ones((3, 1)))


def test_section_matches_grid_points():
    f = make_fn()
    for j, y in enumerate(f.ys):
        np.testing.assert_allclose(f.section_y(int(y)), f.speed[:, j])
    for i, x in enumerate(f.xs):
        np.testing.assert_allclose(f.section_x(int(x)), f.speed[i, :])


def test_speed_at_interpolates_between_grid():
    f = make_fn()
    s_lo = f.speed_at(1, 16)
    s_hi = f.speed_at(2, 16)
    mid = f.speed_at(1.5, 16)
    assert min(s_lo, s_hi) <= mid <= max(s_lo, s_hi)


def test_time_zero_rows_is_zero():
    f = make_fn()
    assert f.time_at(0, 64) == 0.0
    assert f.time_curve(10, 64)[0] == 0.0


def test_time_curve_consistent_with_time_at():
    f = make_fn()
    tc = f.time_curve(16, 64)
    for x in [1, 4, 8, 16]:
        np.testing.assert_allclose(tc[x], f.time_at(x, 64), rtol=1e-9)


def test_nan_points_are_skipped():
    xs = np.array([1, 2, 4])
    ys = np.array([16, 32])
    sp = np.array([[1.0, np.nan], [2.0, 2.0], [4.0, 4.0]])
    f = SpeedFunction(xs, ys, sp)
    assert np.isfinite(f.time_at(2, 32))


def test_variation_and_average():
    s = FPMSet([make_fn(1.0), make_fn(2.0)])
    assert s.max_variation_at_plane(64) > 0.5
    avg = s.averaged()
    expected = 2.0 / (1.0 / s[0].speed + 1.0 / s[1].speed)  # harmonic mean
    np.testing.assert_allclose(avg.speed, expected, rtol=1e-12)
    ident = FPMSet([make_fn(1.0), make_fn(1.0)])
    assert ident.max_variation_at_plane(64) == 0.0


def test_build_and_roundtrip(tmp_path):
    f = build_fpm([1, 2], [16, 32], lambda x, y: x * y * 1e-6, name="bench")
    s = FPMSet([f, make_fn()])
    p = str(tmp_path / "fpm.npz")
    save_fpms(p, s)
    s2 = load_fpms(p)
    assert s2.p == 2
    np.testing.assert_allclose(s2[0].speed, s[0].speed)
    assert s2[0].name == "bench"


def test_build_marks_unmeasurable_as_nan():
    f = build_fpm([1], [16, 32], lambda x, y: float("inf") if y == 32 else 1.0)
    assert np.isnan(f.speed[0, 1])


def test_roundtrip_preserves_nan_unmeasured_points(tmp_path):
    """NaN marks points that exceeded memory (paper §V-B); persistence must
    keep them NaN, not zero or drop them."""
    xs = np.array([1, 2])
    ys = np.array([16, 32, 64])
    sp = np.array([[1.0, np.nan, 3.0], [np.nan, 2.0, 4.0]])
    s = FPMSet([SpeedFunction(xs, ys, sp, name="partial")])
    p = str(tmp_path / "fpm.npz")
    save_fpms(p, s)
    s2 = load_fpms(p)
    np.testing.assert_array_equal(np.isnan(s2[0].speed), np.isnan(sp))
    np.testing.assert_allclose(s2[0].speed[np.isfinite(sp)], sp[np.isfinite(sp)])
    assert s2[0].name == "partial"


def test_load_without_names_sidecar_defaults(tmp_path):
    """The .json names sidecar is advisory: deleting it degrades names to
    the default, never errors."""
    import os
    s = FPMSet([make_fn(name="A"), make_fn(name="B")])
    p = str(tmp_path / "fpm.npz")
    save_fpms(p, s)
    assert os.path.exists(p + ".json")
    with open(p + ".json") as fh:
        import json
        assert json.load(fh)["names"] == ["A", "B"]
    os.unlink(p + ".json")
    s2 = load_fpms(p)
    assert [f.name for f in s2] == ["P", "P"]
    np.testing.assert_allclose(s2[1].speed, s[1].speed)


def test_save_is_atomic_no_tmp_left_behind(tmp_path):
    import os
    s = FPMSet([make_fn()])
    p = str(tmp_path / "fpm.npz")
    save_fpms(p, s)
    assert not os.path.exists(p + ".tmp")
    # overwrite in place keeps the store readable
    save_fpms(p, FPMSet([make_fn(scale=2.0)]))
    np.testing.assert_allclose(load_fpms(p)[0].speed, make_fn(scale=2.0).speed)


@given(x=st.integers(1, 100), y=st.sampled_from([16, 64, 256, 1024]))
@settings(max_examples=50, deadline=None)
def test_fft_flops_positive_monotone(x, y):
    assert fft_flops(x, y) > 0
    assert fft_flops(x + 1, y) > fft_flops(x, y)
    assert fft_flops(x, 2 * y) > fft_flops(x, y)
