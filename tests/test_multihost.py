"""Multi-host scale-out: hierarchical transpose + two-tier comm model.

Three rigs, in increasing realism:

* plain unit tests — the two-tier byte/latency accounting
  (``plan.cost``), the tier fit (``plan.calibrate``), the host-aware
  digest grammar, and the whole-host fault helper need no devices;
* ``dist_subprocess`` — a single forced-multi-device process with
  *emulated* host structure (``make_fft_mesh(hosts=...)`` registers it)
  exercises the hierarchical exchange's bit-identity against the flat
  transpose and the elastic whole-host recovery path;
* ``multihost_subprocess`` — 2 real ``jax.distributed`` processes x 2
  forced devices on localhost (gloo) are a genuine 2-host cluster with
  real ``process_index`` structure: the acceptance rig for correctness,
  host-digest wisdom persistence with per-tier comm samples, and
  zero-re-measurement warm serving.
"""

import numpy as np
import pytest

from repro.plan.calibrate import _fit_comm_params, fit_cost_params
from repro.plan.config import PlanConfig
from repro.plan.cost import (CommTiers, CostParams, comm_phase_time,
                             dist_comm_bytes, dist_comm_time, exchange_time)
from repro.plan.wisdom import topology_digest
from repro.runtime.faults import lost_host


# --------------------------------------------------------------- two tiers

def test_comm_phase_time_guards_latency():
    # Satellite fix: both tuners' estimate sites price phases through this
    # one guarded helper — a phase that moves no bytes costs nothing.
    assert comm_phase_time(0.0, 1e9, 1e-3) == 0.0
    assert comm_phase_time(1e9, 1e9, 1e-3) == pytest.approx(1.0 + 1e-3)


def test_dist_comm_bytes_legacy_form_unchanged():
    # hosts=None keeps the single-tier float the pinned tests rely on.
    assert dist_comm_bytes(64, 4) == 64 * 64 * 8 * 3 / 4
    assert dist_comm_bytes(64, 1) == 0.0


def test_dist_comm_bytes_tier_split():
    m = 64 * 64 * 8  # whole-matrix bytes, p = 4 = 2 hosts x 2 local
    flat = dist_comm_bytes(64, 4, hosts=2, exchange="flat")
    hier = dist_comm_bytes(64, 4, hosts=2, exchange="hier")
    assert isinstance(flat, CommTiers) and isinstance(hier, CommTiers)
    # Flat: of the moved (p-1)/p, the (l-1)/p stays intra-host.
    assert flat.intra == pytest.approx(m * 1 / 4)
    assert flat.inter == pytest.approx(m * 2 / 4)
    # Hier: the intra stage aggregates M(l-1)/l; the slow-tier volume is
    # identical to flat — hierarchy trades intra volume for fewer
    # inter-host messages, never for fewer inter-host bytes.
    assert hier.intra == pytest.approx(m * 1 / 2)
    assert hier.inter == pytest.approx(m * 2 / 4)
    assert hier.inter == flat.inter
    # Degenerate axes carry no inter tier.
    assert dist_comm_bytes(64, 4, hosts=1, exchange="flat").inter == 0.0
    assert dist_comm_bytes(63, 3, hosts=2, exchange="flat").inter == 0.0


def test_exchange_time_single_host_reduces_to_legacy():
    params = CostParams.for_backend("cpu")
    total = dist_comm_bytes(64, 4)
    assert exchange_time(total, 4, params=params, hosts=1) == pytest.approx(
        comm_phase_time(total, params.interconnect_bytes_per_s,
                        params.comm_latency_s))


def test_exchange_time_hier_wins_on_latency_bound_topologies():
    # 2 hosts x 4 local: flat sends p-l = 4 inter-host messages per
    # device, hier sends h-1 = 1 — with a latency-dominated slow tier the
    # hierarchical exchange must price cheaper, and with a
    # bandwidth-dominated one its extra intra volume must make it lose.
    import dataclasses
    lat_bound = dataclasses.replace(CostParams.for_backend("cpu"),
                                    inter_latency_s=1e-2,
                                    inter_bytes_per_s=1e12)
    bw_bound = dataclasses.replace(CostParams.for_backend("cpu"),
                                   inter_latency_s=0.0,
                                   interconnect_bytes_per_s=1e9)
    total = dist_comm_bytes(256, 8)
    t_flat = exchange_time(total, 8, params=lat_bound, hosts=2,
                           exchange="flat")
    t_hier = exchange_time(total, 8, params=lat_bound, hosts=2,
                           exchange="hier")
    assert t_hier < t_flat
    t_flat = exchange_time(total, 8, params=bw_bound, hosts=2,
                           exchange="flat")
    t_hier = exchange_time(total, 8, params=bw_bound, hosts=2,
                           exchange="hier")
    assert t_flat < t_hier


def test_dist_comm_time_matches_manual_tier_sum():
    params = CostParams.for_backend("cpu")
    tiers = dist_comm_bytes(64, 4, hosts=2, exchange="hier")
    expect = (comm_phase_time(tiers.intra, params.interconnect_bytes_per_s,
                              params.comm_latency_s)
              + tiers.inter / params.inter_bytes_per_s
              + 1 * params.inter_latency_s)
    got = dist_comm_time(64, 4, params=params, hosts=2, exchange="hier")
    assert got == pytest.approx(expect)


def test_plan_config_exchange_knob():
    assert PlanConfig().exchange == "flat"
    assert "exch=hier" in PlanConfig(exchange="hier").describe()
    assert "exch" not in PlanConfig().describe()
    with pytest.raises(ValueError):
        PlanConfig(exchange="diagonal")
    cfg = PlanConfig.from_dict(PlanConfig(exchange="hier").to_dict())
    assert cfg.exchange == "hier"


def test_spmd_program_rejects_mixed_exchange():
    from repro.plan.groups import spmd_program_config
    from repro.plan.schedule import SegmentSchedule

    sched = SegmentSchedule.from_parts(
        64, [32, 32], None,
        [PlanConfig(exchange="flat"), PlanConfig(exchange="hier")])
    with pytest.raises(ValueError, match="SPMD"):
        spmd_program_config(sched)


# --------------------------------------------------------------- tier fit

def _tier_entry(n: int, true: dict) -> dict:
    m = n * n * 8.0
    intra_b, inter_b = m / 2, m / 2   # h = l = 2
    return {
        "time_s": 1.0, "config": {"pad": "none"}, "hosts": 2,
        "comm_samples": [
            {"tier": "intra", "bytes": intra_b, "msgs": 1,
             "time_s": true["intra_lat"] + intra_b / true["intra_bw"]},
            {"tier": "inter", "bytes": inter_b, "msgs": 1,
             "time_s": true["inter_lat"] + inter_b / true["inter_bw"]},
        ],
    }


def test_fit_comm_params_recovers_two_tiers():
    true = dict(intra_bw=1e10, intra_lat=1e-5, inter_bw=1e9, inter_lat=1e-3)
    entries = {
        f"n={n}|dtype=complex64|p=4|method=lb|backend=cpu"
        f"|topo=2hx4xfft.cpu.k1": _tier_entry(n, true)
        for n in (256, 512, 1024)}
    fitted = _fit_comm_params(entries, "cpu", CostParams.for_backend("cpu"))
    assert fitted.interconnect_bytes_per_s == pytest.approx(true["intra_bw"])
    assert fitted.comm_latency_s == pytest.approx(true["intra_lat"])
    assert fitted.inter_bytes_per_s == pytest.approx(true["inter_bw"])
    assert fitted.inter_latency_s == pytest.approx(true["inter_lat"])
    # Two genuinely distinct tiers came out of one store.
    assert fitted.inter_bytes_per_s != fitted.interconnect_bytes_per_s


def test_fit_cost_params_store_dict_two_tiers():
    # The public entry point, fed a store dict: the tier fit rides along
    # even below the compute-fit min_entries threshold.
    true = dict(intra_bw=2e10, intra_lat=2e-5, inter_bw=2e9, inter_lat=2e-3)
    entries = {
        f"n={n}|dtype=complex64|p=4|method=lb|backend=cpu"
        f"|topo=2hx4xfft.cpu.k1": _tier_entry(n, true)
        for n in (256, 512)}
    fitted = fit_cost_params(entries, backend="cpu")
    assert fitted.inter_bytes_per_s == pytest.approx(true["inter_bw"])
    assert fitted.interconnect_bytes_per_s == pytest.approx(true["intra_bw"])


def test_fit_comm_params_legacy_samples_feed_intra_tier():
    params = CostParams.for_backend("cpu")
    true_bw, true_lat = 5e9, 1e-4
    entries = {}
    for n in (256, 512, 1024):
        b = dist_comm_bytes(n, 4)
        entries[f"n={n}|dtype=complex64|p=4|method=lb|backend=cpu"
                f"|topo=4xfft.cpu.k1"] = {
            "time_s": 1.0, "config": {"pad": "none"},
            "comm_bytes": b,
            "comm_time_s": 2.0 * (true_lat + b / true_bw)}
    fitted = _fit_comm_params(entries, "cpu", params)
    assert fitted.interconnect_bytes_per_s == pytest.approx(true_bw)
    assert fitted.comm_latency_s == pytest.approx(true_lat)
    # No inter samples: the inter tier keeps its defaults untouched.
    assert fitted.inter_bytes_per_s == params.inter_bytes_per_s
    assert fitted.inter_latency_s == params.inter_latency_s


# ------------------------------------------------------------ digest + faults

def test_topology_digest_hosts_component():
    assert topology_digest(None, "fft", devices=4, platform="cpu",
                           panels=(1, 2, 4), hosts=2) == "2hx4xfft.cpu.k1-2-4"
    # hosts<=1 keeps the exact single-host grammar (old stores keep
    # serving single-host lookups).
    assert topology_digest(None, "fft", devices=4, platform="cpu",
                           panels=(1, 2, 4), hosts=1) == "4xfft.cpu.k1-2-4"
    assert topology_digest(None, "fft", devices=4, platform="cpu",
                           panels=(1, 2, 4)) == "4xfft.cpu.k1-2-4"


def test_lost_host_positions():
    assert lost_host(0, 4) == (0, 1, 2, 3)
    assert lost_host(2, 2) == (4, 5)


# ----------------------------------------------- emulated-host subprocess rig

_IDENT_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_fft_mesh, make_pfft3_mesh, mesh_host_shape
from repro.core.pfft_dist import pfft2_distributed
from repro.core.pfft3d import pfft3_pencil, pfft3_slab
from repro.plan.config import PlanConfig

rng = np.random.default_rng(0)
n = 64
x = jnp.asarray((rng.standard_normal((n, n))
                 + 1j * rng.standard_normal((n, n))).astype("complex64"))
ref = np.fft.fft2(np.asarray(x))

mesh_h = make_fft_mesh(hosts=2, local=4)
assert mesh_host_shape(mesh_h, "fft") == (2, 4)
mesh_f = make_fft_mesh(8)
assert mesh_host_shape(mesh_f, "fft") == (1, 8)

for kwargs in ({}, {"pipeline_panels": 2}, {"fused": True}):
    yf = pfft2_distributed(x, mesh=mesh_f,
                           config=PlanConfig(exchange="flat", **kwargs))
    yh = pfft2_distributed(x, mesh=mesh_h,
                           config=PlanConfig(exchange="hier", **kwargs))
    np.testing.assert_allclose(np.asarray(yh), ref, atol=1e-2)
    # The hierarchical transpose is the same permutation, not merely
    # close: two grouped stages compose to exactly the flat all_to_all.
    assert np.array_equal(np.asarray(yf), np.asarray(yh)), kwargs

# hier on a mesh without host structure degrades to flat, stays correct
yd = pfft2_distributed(x, mesh=mesh_f, config=PlanConfig(exchange="hier"))
np.testing.assert_allclose(np.asarray(yd), ref, atol=1e-2)

# the real distributed path is flat-only, by named error
from repro.core.pfft_dist import rpfft2_distributed
try:
    rpfft2_distributed(jnp.ones((n, n), "float32"), mesh_h,
                       config=PlanConfig(real=True, exchange="hier"))
except ValueError as err:
    assert "flat" in str(err)
else:
    raise AssertionError("real+hier must be rejected")

n3 = 16
x3 = jnp.asarray((rng.standard_normal((n3, n3, n3))
                  + 1j * rng.standard_normal((n3, n3, n3))
                  ).astype("complex64"))
ref3 = np.fft.fftn(np.asarray(x3))
m3h = make_pfft3_mesh(r=4, c=2, hosts=2)
assert mesh_host_shape(m3h, "fft_r") == (2, 2)
m3f = make_pfft3_mesh(r=4, c=2)
zf = pfft3_pencil(x3, mesh=m3f, config=PlanConfig(exchange="flat"))
zh = pfft3_pencil(x3, mesh=m3h, config=PlanConfig(exchange="hier"))
np.testing.assert_allclose(np.asarray(zh), ref3, atol=1e-2)
assert np.array_equal(np.asarray(zf), np.asarray(zh))
sf = pfft3_slab(x3, mesh=make_fft_mesh(8), config=PlanConfig())
sh = pfft3_slab(x3, mesh=make_fft_mesh(hosts=2, local=4),
                config=PlanConfig(exchange="hier"))
np.testing.assert_allclose(np.asarray(sh), ref3, atol=1e-2)
assert np.array_equal(np.asarray(sf), np.asarray(sh))
print("HIER_IDENT_OK")
"""


def test_hier_exchange_bit_identical_to_flat(dist_subprocess):
    dist_subprocess(_IDENT_SCRIPT, devices=8, sentinel="HIER_IDENT_OK")


_TUNE_SCRIPT = r"""
import json, numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_fft_mesh
from repro.core.pfft_dist import pfft2_distributed
from repro.plan.tune import tune_dist_config

W = "WISDOM_PATH"
n = 64
mesh = make_fft_mesh(hosts=2, local=2)

# The race includes the hierarchical exchange as a config dimension.
cfg, info = tune_dist_config(n, mesh, mode="measure", reps=2)
assert {r[0]["exchange"] for r in info["ranked"]} == {"flat", "hier"}
assert info["dist"]["hosts"] == 2
samples = info["dist"]["comm_samples"]
assert {s["tier"] for s in samples} == {"intra", "inter"}
assert all(s["time_s"] > 0 and s["bytes"] > 0 for s in samples)

# The raw-call resolver persists host digest + tier samples...
rng = np.random.default_rng(0)
x = jnp.asarray((rng.standard_normal((n, n))
                 + 1j * rng.standard_normal((n, n))).astype("complex64"))
y1 = pfft2_distributed(x, mesh=mesh, tune="measure", wisdom=W)
store = json.load(open(W))
key = [k for k in store["entries"] if "|topo=2hx4xfft" in k]
assert key, list(store["entries"])
entry = store["entries"][key[0]]
assert entry["hosts"] == 2
assert {s["tier"] for s in entry["comm_samples"]} == {"intra", "inter"}

# ...and a second plan on the same topology is served with *zero*
# re-measurement (every measure entry point poisoned).
import repro.plan.tune as tune_mod
def boom(*a, **k):
    raise AssertionError("re-measured a wisdom-served topology")
tune_mod.measure_dist_configs = boom
tune_mod._measure_local_phase = boom
tune_mod._measure_tier_exchange = boom
y2 = pfft2_distributed(x, mesh=mesh, tune="measure", wisdom=W)
assert np.allclose(np.asarray(y1), np.asarray(y2))
print("HIER_TUNE_OK")
"""


def test_tuner_races_hier_and_persists_tier_samples(dist_subprocess,
                                                    tmp_path):
    script = _TUNE_SCRIPT.replace("WISDOM_PATH",
                                  str(tmp_path / "wisdom.json"))
    dist_subprocess(script, devices=4, sentinel="HIER_TUNE_OK")


_HOST_LOSS_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_fft_mesh, mesh_host_shape
from repro.runtime.faults import inject
from repro.runtime.resilient import ResilientPlan

n = 48
rng = np.random.default_rng(1)
x = (rng.standard_normal((n, n))
     + 1j * rng.standard_normal((n, n))).astype("complex64")
ref = np.fft.fft2(x)

with inject() as inj:
    rp = ResilientPlan(n, method="lb", tune="estimate",
                       mesh=make_fft_mesh(hosts=4, local=2))
    topo8 = rp.plan.tuning.get("topology")
    assert topo8.startswith("4hx8x"), topo8
    out = rp.execute(x)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-2)

    # Whole-host loss: host 3's devices (positions 6, 7) die together.
    inj.fail_host(rp.calls, 3, 2)
    out = rp.execute(x)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-2)
    ev = [e for e in rp.events if e["kind"] == "device_loss"][0]
    assert ev["lost"] == [6, 7], ev
    # The rebuilt axis stays host-major at the reduced host count — a
    # distinct digest, so the re-plan was a correct wisdom miss.
    assert rp.p == 6
    assert mesh_host_shape(rp.mesh, "fft") == (3, 2)
    topo6 = ev["topology"]
    assert topo6.startswith("3hx6x"), topo6

    # Partial host loss breaks host-majority: the axis degrades to flat.
    inj.fail_execute(rp.calls, lost=(5,))
    out = rp.execute(x)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-2)
    assert rp.p == 4
    assert mesh_host_shape(rp.mesh, "fft") == (1, 4)
    ev = [e for e in rp.events if e["kind"] == "device_loss"][1]
    assert "hx" not in ev["topology"], ev["topology"]
print("HOST_LOSS_OK")
"""


def test_whole_host_loss_preserves_host_majority(dist_subprocess):
    dist_subprocess(_HOST_LOSS_SCRIPT, devices=8, sentinel="HOST_LOSS_OK")


# ------------------------------------------- real multi-process localhost rig

_MH_ACCEPT_SCRIPT = r"""
from repro.launch.mesh import init_multihost_from_env
assert init_multihost_from_env()
import json, numpy as np, jax, jax.numpy as jnp
from jax.experimental import multihost_utils
from repro.launch.mesh import make_fft_mesh, make_pfft3_mesh, mesh_host_shape
from repro.core.pfft_dist import pfft2_distributed
from repro.core.pfft3d import pfft3_pencil
from repro.plan.calibrate import fit_cost_params
from repro.plan.config import PlanConfig

pid = jax.process_index()
assert jax.process_count() == 2 and jax.device_count() == 4

mesh = make_fft_mesh(hosts=2, local=2)
# Real process_index structure, no emulation registry involved.
assert mesh_host_shape(mesh, "fft") == (2, 2)

n = 64
rng = np.random.default_rng(0)
x = jnp.asarray((rng.standard_normal((n, n))
                 + 1j * rng.standard_normal((n, n))).astype("complex64"))
ref = np.fft.fft2(np.asarray(x))

yh = pfft2_distributed(x, mesh=mesh, config=PlanConfig(exchange="hier"))
yf = pfft2_distributed(x, mesh=mesh, config=PlanConfig(exchange="flat"))
gh = multihost_utils.process_allgather(yh, tiled=True)
gf = multihost_utils.process_allgather(yf, tiled=True)
np.testing.assert_allclose(np.asarray(gh), ref, atol=1e-2)
assert np.array_equal(np.asarray(gf), np.asarray(gh))

n3 = 16
x3 = jnp.asarray((rng.standard_normal((n3, n3, n3))
                  + 1j * rng.standard_normal((n3, n3, n3))
                  ).astype("complex64"))
m3 = make_pfft3_mesh(r=4, c=1, hosts=2)
assert mesh_host_shape(m3, "fft_r") == (2, 2)
z = pfft3_pencil(x3, mesh=m3, config=PlanConfig(exchange="hier"))
gz = multihost_utils.process_allgather(z, tiled=True)
np.testing.assert_allclose(np.asarray(gz), np.fft.fftn(np.asarray(x3)),
                           atol=1e-2)

# Measured tuning: pin top_k=1 so the deterministic estimate ranking
# fixes the finalist and every process races — and picks — the same
# program (divergent winners would diverge the SPMD program).
import repro.plan.tune as tune_mod
_tune_orig = tune_mod.tune_dist_config
def _tune_one(*args, **kw):
    kw["top_k"] = 1
    return _tune_orig(*args, **kw)
tune_mod.tune_dist_config = _tune_one

W = "WISDOM_PATH"
y1 = pfft2_distributed(x, mesh=mesh, tune="measure", wisdom=W)
store = json.load(open(W))
key = [k for k in store["entries"] if "|topo=2hx4xfft" in k]
assert key, list(store["entries"])
entry = store["entries"][key[0]]
assert entry["hosts"] == 2
assert {s["tier"] for s in entry["comm_samples"]} == {"intra", "inter"}

# Warm serve: zero re-measurement on the same topology.
def boom(*a, **k):
    raise AssertionError("re-measured a wisdom-served topology")
tune_mod.measure_dist_configs = boom
tune_mod._measure_local_phase = boom
tune_mod._measure_tier_exchange = boom
y2 = pfft2_distributed(x, mesh=mesh, tune="measure", wisdom=W)
g1 = multihost_utils.process_allgather(y1, tiled=True)
g2 = multihost_utils.process_allgather(y2, tiled=True)
assert np.allclose(np.asarray(g1), np.asarray(g2))

# The persisted samples calibrate a two-tier CostParams without error.
fitted = fit_cost_params(W, backend="cpu")
assert fitted.inter_bytes_per_s > 0 and fitted.interconnect_bytes_per_s > 0

if pid == 0:
    print("MULTIHOST_ACCEPT_OK")
"""


def test_multihost_acceptance_two_process_rig(multihost_subprocess,
                                              tmp_path):
    script = _MH_ACCEPT_SCRIPT.replace("WISDOM_PATH",
                                       str(tmp_path / "wisdom.json"))
    multihost_subprocess(script, procs=2, devices=2,
                         sentinel="MULTIHOST_ACCEPT_OK")
