"""Data-pipeline determinism + shape contracts + input_specs consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES
from repro.data.pipeline import SyntheticTokenPipeline, make_batch
from repro.data.specs import input_specs
from repro.models.registry import ARCH_IDS, get_config, get_smoke_config


def test_determinism_same_seed_step():
    cfg = get_smoke_config("qwen2_5_3b")
    a = make_batch(cfg, 4, 16, seed=3, step=7)
    b = make_batch(cfg, 4, 16, seed=3, step=7)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = make_batch(cfg, 4, 16, seed=3, step=8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_host_sharding_disjoint_and_complete():
    cfg = get_smoke_config("internlm2_1_8b")
    full = make_batch(cfg, 8, 16, seed=0, step=0, host_shard=0, n_hosts=1)
    parts = [make_batch(cfg, 8, 16, seed=0, step=0, host_shard=h, n_hosts=2)
             for h in range(2)]
    assert parts[0]["tokens"].shape[0] == 4
    # different hosts draw from different streams
    assert not np.array_equal(np.asarray(parts[0]["tokens"]),
                              np.asarray(parts[1]["tokens"]))


def test_pipeline_cursor_roundtrip():
    cfg = get_smoke_config("internlm2_1_8b")
    p1 = SyntheticTokenPipeline(cfg, 4, 16, seed=1)
    _ = p1.next(); _ = p1.next()
    saved = p1.state_dict()
    b3 = p1.next()
    p2 = SyntheticTokenPipeline(cfg, 4, 16, seed=1)
    p2.load_state_dict(saved)
    b3b = p2.next()
    np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                  np.asarray(b3b["tokens"]))


def test_targets_are_next_token():
    cfg = get_smoke_config("qwen2_5_3b")
    b = make_batch(cfg, 2, 16, seed=0, step=0)
    # markov: target token at t == input token at t+1
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_indivisible_hosts_raises():
    cfg = get_smoke_config("qwen2_5_3b")
    with pytest.raises(ValueError):
        make_batch(cfg, 5, 8, seed=0, step=0, n_hosts=2)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_match_real_batches(arch, shape_name):
    """input_specs() (used by the dry-run) must agree with the concrete
    batches the pipeline emits — same keys, trailing dims, dtype kinds."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and not cfg.supports_decode():
        pytest.skip("encoder-only")
    specs = input_specs(cfg, shape)
    if shape.kind == "decode":
        assert specs["tokens"].shape == (shape.global_batch,)
        assert specs["pos"].shape == ()
        return
    small = make_batch(cfg, 2, 64 if cfg.modality != "vision" else
                       cfg.n_prefix_embeds + 8, seed=0, step=0)
    if shape.kind == "prefill":
        small.pop("targets", None)
    assert set(specs) == set(small), (set(specs), set(small))
    for k in specs:
        assert specs[k].dtype.kind == np.asarray(small[k]).dtype.kind or \
            (specs[k].dtype == jnp.bfloat16 and
             np.asarray(small[k]).dtype.kind == "f"), k
