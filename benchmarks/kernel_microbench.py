"""Kernel-layer microbenchmarks -> BENCH_kernels.json (and wisdom).

    PYTHONPATH=src python -m benchmarks.kernel_microbench \\
        [--quick] [--out F] [--wisdom W]

Four comparisons, one JSON record each (plus structural facts the
acceptance checks assert on):

  radix        radix-2 vs radix-4 Stockham (same op, half the passes);
               records stage counts from ``stockham_stage_count``.
  fused        unfused (fft_rows_op + transpose_op, intermediate matrix)
               vs fused ``fft_rows_transpose_op`` (one dispatch).
  segments     looped per-segment ``segment_row_ffts`` vs the batched
               one-dispatch-per-distinct-pad-length path; records the
               dispatch counts from ``plan_segment_batches``.
  planner      the full ``PlanConfig`` sweep (every variant the tuner can
               pick) vs the estimate-planned config — records whether the
               cost model's pick lands within the measured envelope
               (``within_best_pct`` / ``not_worst``).
  schedule     heterogeneous per-segment planning (one slow + p-1 fast
               FPMs): the per-segment ``tune_schedule`` pick vs the best
               homogeneous config — records the distinct config count,
               the makespan-estimate delta, and the measured limb times
               of both (hetero schedule wisdom is recorded under the
               same key ``plan_pfft`` would look up).
  dist         distributed measure tuning on a mesh over every visible
               device: ``tune_dist_config`` races finalists through the
               full ``pfft2_distributed`` pipeline and the record carries
               the *measured-vs-estimated comm delta* (the number the
               cost model's interconnect constants are judged — and
               calibrated — by).  On a 1-device host the sweep records
               the estimate-fallback facts; run under
               ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
               (the CI dist job does) for a real comm sample.
  hetero-dist  grouped-vs-homogeneous device-group programs: a synthetic
               mixed-pad fleet drives ``grouped_dist_schedule`` and both
               programs race end-to-end through ``pfft2_distributed``;
               the record carries the grouped-vs-homogeneous makespan
               delta and the measured winner warms the same v3 topology
               key ``plan_pfft(mesh=..., method="fpm-pad")`` consults.
  rfft         the real-input half-spectrum pipeline vs the upcast-and-
               crop complex fallback: interleaved wall-time race of both
               limbs, the structural p=4 comm-bytes delta (half-spectrum
               panels vs full panels), and the measure-tuned family pick
               (wisdom-warmed under the ``rfft-lb`` keys ``plan_pfft``
               looks up).  On a multi-device host an ``rfft-dist`` record
               races both families end to end through the distributed
               pipelines and carries the measured comm sample.
  pfft3        pencil-vs-slab 3-D decomposition on an r x c mesh over
               every visible device: ``tune_pfft3(mode="measure")`` races
               config x panel x *orientation* finalists through the
               two-exchange pencil pipeline, then the winner races the
               one-axis slab program (three exchanges) end to end on the
               same devices — the record carries the pencil-vs-slab
               delta and the measured comm sample, and the winner
               (orientation included) warms the same v3 2-D-topology key
               ``plan_pfft3(mesh=...)`` looks up.  A 1-device host
               records the estimate-fallback facts.
  multihost    hierarchical-vs-flat exchange on an emulated hosts x local
               host-major mesh (``make_fft_mesh(hosts=...)`` over the
               forced CPU devices): ``tune_dist_config(mode="measure")``
               races both exchange forms end to end, the record carries
               the explicit hier-vs-flat delta, the per-tier comm
               samples (one grouped all_to_all per tier), and — when a
               wisdom store is being warmed — the two interconnect
               tiers ``fit_cost_params`` recovers from those samples.
               The winner lands under the host-count topology digest
               (``2hx4x...``), so a warmed store serves later multi-host
               plans with zero re-measurement (CI asserts it).  A
               sub-4-device host records the structural two-tier
               byte-accounting facts instead.

Every record is labeled with the backend it was measured on and whether
the Pallas kernels ran in interpret mode.  A ``--sweeps`` subset merges:
records of benches not being rerun are carried into the new file intact,
and a cpu (interpret-mode) run refuses to replace accelerator-tagged
records of the benches it *is* rerunning (``--force`` overrides —
interpreter numbers say nothing about hardware and must not masquerade
as it).

``--wisdom W`` writes each benched size's best *measured* config into the
wisdom store ``W`` (keyed exactly as ``plan_pfft`` keys its lookups), so a
measured benchmark run warms every later planning session — FFTW's
wisdom lifecycle; CI asserts the round trip.

On this CPU container the Pallas kernels run in interpret mode, so the
absolute times are not TPU times — the JSON exists to start the perf
trajectory and to pin the structural wins (pass counts, dispatch counts)
that carry to hardware.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np
import jax.numpy as jnp

from benchmarks.common import signal, time_fn
from repro.core.fpm import FPMSet, SpeedFunction
from repro.core.pfft import _pfft_limb, plan_segment_batches, segment_row_ffts
from repro.core.partition import lb_partition
from repro.kernels.fft.kernel import stockham_stage_count
from repro.kernels.fft.ops import fft_rows_op
from repro.kernels.fused.ops import fft_rows_transpose_op
from repro.kernels.transpose.ops import transpose_op
from repro.plan import (CostParams, PlanConfig, SegmentSchedule,
                        candidate_configs, dist_comm_bytes, dist_panel_space,
                        estimate_cost, estimate_grouped_cost,
                        estimate_schedule_cost, exchange_time,
                        grouped_dist_schedule,
                        measure_configs, measure_dist_configs,
                        partition_digest, record_wisdom, topology_digest,
                        tune_config, tune_dist_config, tune_schedule,
                        wisdom_key)

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")


def _rows_signal(rows: int, n: int, seed: int = 1) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal((rows, n))
                        + 1j * rng.standard_normal((rows, n))
                        ).astype(np.complex64))


def bench_radix(sizes, rows: int) -> list[dict]:
    recs = []
    for n in sizes:
        x = _rows_signal(rows, n)
        for radix in (2, 4):
            t = time_fn(lambda x=x, r=radix: fft_rows_op(x, radix=r))
            recs.append({
                "bench": "radix",
                "n": int(n),
                "rows": int(rows),
                "radix": radix,
                "stages": stockham_stage_count(n, radix),
                "time_s": t,
            })
    return recs


def bench_fused(sizes) -> list[dict]:
    recs = []
    for n in sizes:
        m = signal(n, seed=2)

        def unfused(m):
            return transpose_op(fft_rows_op(m))

        for name, fn in (("unfused", unfused), ("fused", fft_rows_transpose_op)):
            t = time_fn(fn, m)
            recs.append({
                "bench": "fused",
                "n": int(n),
                "variant": name,
                "dispatches_per_phase": 2 if name == "unfused" else 1,
                "time_s": t,
            })
    return recs


def bench_segments(n: int, p: int, pad_to: int) -> list[dict]:
    m = signal(n, seed=3)
    d = lb_partition(n, p).d
    pads = np.array([pad_to if i % 2 else n for i in range(p)], dtype=np.int64)
    plan = plan_segment_batches(d, pads, n)
    recs = []
    for name, batched in (("looped", False), ("batched", True)):
        cfg = PlanConfig(batched=batched, pad="fpm")
        t = time_fn(lambda m=m, c=cfg: segment_row_ffts(
            m, d, pad_lengths=pads, config=c))
        recs.append({
            "bench": "segments",
            "n": int(n),
            "p": int(p),
            "distinct_pad_lengths": len(plan),
            "dispatches": len(plan) if batched else int((np.asarray(d) > 0).sum()),
            "variant": name,
            "time_s": t,
        })
    return recs


def bench_planner(sizes, p: int, wisdom_path: str | None = None) -> list[dict]:
    """Time the full PlanConfig sweep, compare the estimate-planned pick
    against the measured envelope, and (optionally) warm the wisdom store
    with each size's best measured config."""
    import jax
    backend = jax.default_backend()
    recs = []
    for n in sizes:
        d = lb_partition(n, p).d
        # measure_configs is the tuner's own interleaved-min harness (a
        # per-config timing block would rank this host's jitter instead);
        # 40 rounds so per-config mins converge below the few-percent gap
        # the acceptance comparison cares about.
        times = measure_configs(candidate_configs(n, d=d), n, d=d, rounds=40)
        for cfg, t in times.items():
            recs.append({"bench": "planner", "n": int(n), "p": int(p),
                         "role": "sweep", "config": cfg.describe(),
                         "time_s": t})
        est_cfg, _ = tune_config(n, d=d, mode="estimate")
        t_est = times[est_cfg]
        best_cfg = min(times, key=times.get)
        t_best, t_worst = times[best_cfg], max(times.values())
        recs.append({
            "bench": "planner", "n": int(n), "p": int(p),
            "role": "estimate-planned", "config": est_cfg.describe(),
            "time_s": t_est,
            "best_config": best_cfg.describe(), "best_s": t_best,
            "worst_s": t_worst,
            "within_best_pct": 100.0 * (t_est / t_best - 1.0),
            "not_worst": bool(t_est <= t_worst),
        })
        if wisdom_path:
            key = wisdom_key(n=n, dtype="complex64", p=p, method="lb",
                             backend=backend)
            record_wisdom(wisdom_path, key, best_cfg, mode="measure",
                          time_s=t_best,
                          extra={"origin": "kernel_microbench"})
    return recs


def bench_schedule(n: int, p: int, wisdom_path: str | None = None
                   ) -> list[dict]:
    """Heterogeneous per-segment planning vs the best homogeneous config.

    A synthetic one-slow/(p-1)-fast FPM set — the ISSUE-3 acceptance
    scenario — whose partition *and* pad lengths are derived exactly the
    way ``plan_pfft(method="fpm-pad")`` derives them (``partition_rows``
    + ``fpm_pad_lengths``), so the recorded wisdom key is the one a
    ``plan_pfft`` call with the same FPMSet looks up.  The fast
    processors' speed peaks at the next pow2 (padding wins for them);
    the slow processor's is flat (padding only adds flops), yielding
    mixed effective lengths.  Estimates use the accelerator cost
    constants (the per-segment choice is about *which* variants differ,
    which interpret-mode CPU constants collapse); measured limb times
    use this host.  The makespan-estimate delta and the distinct-config
    count are the structural facts CI pins.
    """
    from repro.core.partition import partition_rows
    from repro.plan.pads import fpm_pad_lengths

    npow2 = 1 << int(np.ceil(np.log2(n + 1)))
    xs = np.array(sorted({1, max(n // 2, 1), n}))
    ys = np.array(sorted({n, npow2, 2 * npow2}))
    fast = np.tile([1e9, 4e9, 1e9], (len(xs), 1))
    slow = np.full((len(xs), len(ys)), 2.5e8)
    fpms = FPMSet([SpeedFunction(xs, ys, slow if i == 0 else fast,
                                 name=f"P{i}") for i in range(p)])
    part = partition_rows(n, fpms, 0.05)
    d = part.d
    pads = fpm_pad_lengths(fpms, d, n)
    params = CostParams.for_backend("tpu")

    sched, info = tune_schedule(n, d=d, pad_lengths=pads, fpms=fpms,
                                mode="estimate", pad="fpm", params=params)
    # The *assembled* heterogeneous estimate, not the winner's (the winner
    # is already the argmin of this very comparison — recording it would
    # make hetero_not_worse_est tautologically true).
    est_hetero = (info["heterogeneous"]["est_s"] if "heterogeneous" in info
                  else estimate_schedule_cost(sched, fpms=fpms, params=params))
    homo_cfg, est_homo = min(
        ((c, estimate_cost(c, n=n, d=d, pad_lengths=pads, fpms=fpms,
                           params=params))
         for c in candidate_configs(n, pad="fpm", d=d)),
        key=lambda kv: kv[1])

    m = signal(n, seed=4)
    t_hetero = time_fn(lambda m=m: _pfft_limb(m, d, schedule=sched))
    t_homo = time_fn(lambda m=m, c=homo_cfg: _pfft_limb(
        m, d, pad_lengths=pads, config=c))
    rec = {
        "bench": "schedule", "n": int(n), "p": int(p),
        "schedule": sched.describe(),
        "distinct_configs": len(sched.configs),
        "dispatch_groups": len(sched.batch_groups()),
        "homogeneous_config": homo_cfg.describe(),
        "makespan_est_hetero_s": float(est_hetero),
        "makespan_est_homo_s": float(est_homo),
        "makespan_est_delta_s": float(est_homo - est_hetero),
        "hetero_not_worse_est": bool(est_hetero <= est_homo),
        "time_hetero_s": t_hetero,
        "time_homo_s": t_homo,
        "chosen": info["chosen"],
    }
    if wisdom_path:
        # Record what this host actually measured fastest — the estimate
        # deliberately used accelerator constants, so on CPU the
        # homogeneous library config can beat the kernel-bearing
        # schedule; wisdom must never serve a measured-slower plan.
        import jax
        from repro.plan import SegmentSchedule
        winner, t_best = ((sched, t_hetero) if t_hetero <= t_homo else
                          (SegmentSchedule.homogeneous(homo_cfg, n, d, pads),
                           t_homo))
        key = wisdom_key(n=n, dtype="complex64", p=p, method="fpm-pad",
                         backend=jax.default_backend(),
                         detail=partition_digest(d, pads))
        record_wisdom(wisdom_path, key, winner, mode="measure",
                      time_s=t_best, extra={"origin": "kernel_microbench"})
    return [rec]


def bench_dist(sizes, wisdom_path: str | None = None) -> list[dict]:
    """Distributed measure tuning over every visible device.

    For each size, ``tune_dist_config(mode="measure")`` races the top
    finalists through the full ``pfft2_distributed`` pipeline (both
    all_to_all phases) on a 1-D mesh over all local devices, and the
    record pins the measured-vs-estimated comm delta — the evidence the
    interconnect constants are calibrated from.  Wisdom entries land
    under the same per-topology v3 key ``plan_pfft(mesh=...)`` looks up,
    comm sample included, so a benchmark run warms distributed planning
    exactly like it warms the single-host kinds.
    """
    import jax
    from repro.launch.mesh import make_fft_mesh

    p = jax.device_count()
    mesh = make_fft_mesh(p)
    backend = jax.default_backend()
    recs = []
    for n in sizes:
        if n % p:
            continue
        panels = dist_panel_space(n, p)
        cfg, info = tune_dist_config(n, mesh, "fft", mode="measure",
                                     panels=panels)
        dist = info["dist"]
        measured = "measure_fallback" not in info
        rec = {
            "bench": "dist", "n": int(n), "devices": p,
            "topology": topology_digest(mesh, "fft", panels=panels),
            "config": cfg.describe(),
            "comm_bytes": dist["comm_bytes"],
            "comm_time_est_s": dist["comm_time_est_s"],
            "measured": measured,
        }
        if measured:
            rec.update({
                "time_s": info["time_s"],
                "local_phase_s": dist["local_phase_s"],
                "comm_time_meas_s": dist["comm_time_meas_s"],
                "comm_delta_s": dist["comm_time_meas_s"]
                - dist["comm_time_est_s"],
            })
        else:
            rec["fallback"] = info["measure_fallback"]
        recs.append(rec)
        if wisdom_path and measured:
            key = wisdom_key(n=n, dtype="complex64", p=p, method="lb",
                             backend=backend, topology=rec["topology"])
            record_wisdom(wisdom_path, key, cfg, mode="measure",
                          time_s=info["time_s"],
                          extra={"origin": "kernel_microbench",
                                 "topology": rec["topology"],
                                 "comm_bytes": dist["comm_bytes"],
                                 "comm_time_s": dist["comm_time_meas_s"]})
    return recs


def bench_hetero_dist(sizes, wisdom_path: str | None = None) -> list[dict]:
    """Grouped-vs-homogeneous distributed makespan (device-group programs).

    Synthetic per-device pad lengths — half the devices pow2-padded, the
    rest unpadded — make ``grouped_dist_schedule``'s per-device argmin
    genuinely mixed, and the cost constants favor the *pure-jnp* radix-2
    kernel on pow2 lengths so the raced branches stay cheap on this CPU
    container (the point is the grouped-vs-homogeneous structure and the
    makespan delta, not which backend wins interpret mode).  On a
    multi-device host both programs run end to end through
    ``pfft2_distributed`` (the grouped one through its ``lax.switch``
    lowering) and the record carries the measured delta; the measured
    winner lands in wisdom under the same per-topology v3 key
    ``plan_pfft(mesh=..., method="fpm-pad")`` looks up.
    """
    import dataclasses

    import jax
    from repro.launch.mesh import make_fft_mesh

    p = jax.device_count()
    mesh = make_fft_mesh(p)
    backend = jax.default_backend()
    params = dataclasses.replace(
        CostParams.for_backend("cpu"),
        backend_factor={"xla": 1.0, "stockham": 0.5, "pallas": 300.0})
    recs = []
    for n in sizes:
        if n % p:
            continue
        pow2 = 1 << int(np.ceil(np.log2(n + 1)))
        pads = np.array([pow2 if i >= p // 2 else n for i in range(p)],
                        dtype=np.int64)
        d = np.full(p, n // p, dtype=np.int64)
        grouped = grouped_dist_schedule(n, p, pad_lengths=pads, pad="fpm",
                                        params=params)
        homo = SegmentSchedule.homogeneous(PlanConfig(pad="fpm"), n, d, pads)
        comm = dist_comm_bytes(n, p)
        est_g = (estimate_grouped_cost(grouped, params=params,
                                       comm_bytes=comm)
                 if grouped is not None else None)
        est_h = estimate_grouped_cost(homo, params=params, comm_bytes=comm)
        rec = {
            "bench": "hetero-dist", "n": int(n), "devices": p,
            "grouped": grouped.describe() if grouped is not None else None,
            "distinct_configs": (len(grouped.configs)
                                 if grouped is not None else 1),
            "makespan_est_grouped_s": est_g,
            "makespan_est_homo_s": float(est_h),
            "measured": bool(p > 1 and grouped is not None),
        }
        if rec["measured"]:
            times = measure_dist_configs([homo, grouped], n, mesh, "fft",
                                         rounds=3)
            t_h, t_g = times[homo], times[grouped]
            rec.update({
                "time_grouped_s": float(t_g),
                "time_homo_s": float(t_h),
                "grouped_vs_homo_delta_s": float(t_h - t_g),
            })
            if wisdom_path:
                winner, t_best = ((grouped, t_g) if t_g <= t_h
                                  else (homo, t_h))
                topo = topology_digest(mesh, "fft",
                                       panels=dist_panel_space(n, p))
                key = wisdom_key(n=n, dtype="complex64", p=p,
                                 method="fpm-pad", backend=backend,
                                 detail=partition_digest(d, pads),
                                 topology=topo)
                record_wisdom(wisdom_path, key, winner, mode="measure",
                              time_s=float(t_best),
                              extra={"origin": "kernel_microbench",
                                     "topology": topo})
        recs.append(rec)
    return recs


def bench_rfft(sizes, wisdom_path: str | None = None) -> list[dict]:
    """Real-input pipeline vs the upcast-and-crop complex fallback.

    Both limbs deliver the same (N, N//2+1) half spectrum, so the race is
    apples-to-apples: ``measure_rfft_configs`` interleaves them through
    the tuner's own min-of-rounds harness.  The comm-bytes columns are
    structural (``dist_comm_bytes`` at p=4 — the half-spectrum panel is
    ~half the full panel regardless of host), so the record pins the
    comm win even on a 1-device container; on a multi-device host an
    ``rfft-dist`` record adds the *measured* end-to-end race and comm
    sample.  The measure-tuned pick warms wisdom under the same
    ``method="rfft-lb"`` keys ``plan_pfft`` consults.
    """
    import jax
    from repro.plan import measure_rfft_configs, tune_rfft

    backend = jax.default_backend()
    recs = []
    for n in sizes:
        real_cfg = PlanConfig(real=True)
        cplx_cfg = PlanConfig()
        times = measure_rfft_configs([real_cfg, cplx_cfg], n, rounds=20)
        t_real, t_cplx = times[real_cfg], times[cplx_cfg]
        cb_c = dist_comm_bytes(n, 4)
        cb_r = dist_comm_bytes(n, 4, real=True)
        sched, info = tune_rfft(n, mode="measure", top_k=2, reps=5)
        recs.append({
            "bench": "rfft", "n": int(n),
            "time_real_s": float(t_real),
            "time_complex_s": float(t_cplx),
            "speedup_real": float(t_cplx / t_real),
            "comm_bytes_real_p4": float(cb_r),
            "comm_bytes_complex_p4": float(cb_c),
            "comm_ratio_p4": float(cb_r / cb_c),
            "tuned_path": info["chosen_path"],
            "tuned_time_s": float(info["time_s"]),
        })
        if wisdom_path:
            key = wisdom_key(n=n, dtype="float32", p=1, method="rfft-lb",
                             backend=backend)
            record_wisdom(wisdom_path, key, sched, mode="measure",
                          time_s=float(info["time_s"]),
                          extra={"origin": "kernel_microbench"})

    p = jax.device_count()
    if p > 1:
        from repro.launch.mesh import make_fft_mesh
        from repro.plan import tune_rfft_dist

        mesh = make_fft_mesh(p)
        for n in sizes:
            if n % p:
                continue
            sched, info = tune_rfft_dist(n, mesh, "fft", mode="measure",
                                         top_k=2, reps=3)
            dist = info["dist"]
            topo = topology_digest(mesh, "fft", panels=dist_panel_space(n, p))
            recs.append({
                "bench": "rfft-dist", "n": int(n), "devices": p,
                "topology": topo,
                "tuned_path": info["chosen_path"],
                "comm_bytes_real": dist["comm_bytes_real"],
                "comm_bytes_complex": dist["comm_bytes_complex"],
                "comm_ratio_real": dist["comm_ratio_real"],
                "comm_time_meas_s": dist.get("comm_time_meas_s"),
                "time_s": float(info["time_s"]),
            })
            if wisdom_path:
                key = wisdom_key(n=n, dtype="float32", p=p,
                                 method="rfft-lb", backend=backend,
                                 topology=topo)
                record_wisdom(wisdom_path, key, sched, mode="measure",
                              time_s=float(info["time_s"]),
                              extra={"origin": "kernel_microbench",
                                     "topology": topo,
                                     "comm_bytes": dist["comm_bytes"],
                                     "comm_time_s":
                                         dist.get("comm_time_meas_s")})
    return recs


def bench_pfft3(sizes, wisdom_path: str | None = None) -> list[dict]:
    """Pencil-vs-slab 3-D decomposition race on this host's devices.

    The mesh is the squarest r x c factorization of the visible device
    count (rectangular when p is not a perfect square — exactly the case
    where ``tune_pfft3``'s orientation racing matters, since swapping
    which axis plays row changes which exchange round moves more data).
    ``tune_pfft3(mode="measure")`` races config x panel x orientation
    finalists through the full two-exchange pencil pipeline, then the
    winning program races the one-axis *slab* pipeline (three exchange
    rounds) end to end over the same devices: the record carries the
    pencil-vs-slab delta — the decomposition's headline claim — plus the
    measured-vs-estimated comm delta the 3-D makespan constants are
    calibrated by.  The measured winner lands in wisdom, orientation
    included, under the same v3 2-D-topology key ``plan_pfft3(mesh=...)``
    looks up, so a benchmark run warms 3-D planning like every other
    sweep warms its family.  On a 1-device host the sweep records the
    estimate-fallback facts.
    """
    import functools

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.pfft3d import pfft3_slab
    from repro.launch.mesh import make_fft_mesh, make_pfft3_mesh
    from repro.plan import pfft3_panel_space, tune_pfft3

    p = jax.device_count()
    backend = jax.default_backend()
    c = max(k for k in range(1, int(p ** 0.5) + 1) if p % k == 0)
    r = p // c
    recs = []
    for n in sizes:
        if n % r or n % c or n % p:
            continue
        mesh = make_pfft3_mesh(r, c)
        panels = pfft3_panel_space(n, r, c)
        topo = topology_digest(mesh, ("fft_r", "fft_c"), panels=panels)
        cfg, waxes, info = tune_pfft3(n, mesh, mode="measure",
                                      panels=panels)
        stats = info["pfft3"]
        measured = "measure_fallback" not in info
        rec = {
            "bench": "pfft3", "n": int(n), "devices": p,
            "mesh": f"{r}x{c}",
            "topology": topo,
            "config": cfg.describe(),
            "orientation": info.get("orientation"),
            "comm_bytes": stats["comm_bytes"],
            "comm_time_est_s": stats["comm_time_est_s"],
            "measured": measured,
        }
        if measured:
            # Slab baseline: same cube, same local config, one mesh axis,
            # three exchange rounds instead of the pencil's two.
            slab_mesh = make_fft_mesh(p)
            rng = np.random.default_rng(0)
            x = jnp.asarray((rng.standard_normal((n, n, n))
                             + 1j * rng.standard_normal((n, n, n))
                             ).astype(np.complex64))
            x = jax.device_put(x, NamedSharding(slab_mesh,
                                                P("fft", None, None)))
            t_slab = time_fn(jax.jit(functools.partial(
                pfft3_slab, mesh=slab_mesh, axis_name="fft", config=cfg)), x)
            rec.update({
                "time_pencil_s": float(info["time_s"]),
                "time_slab_s": float(t_slab),
                "pencil_vs_slab_delta_s": float(t_slab - info["time_s"]),
                "local_pass_s": stats.get("local_pass_s"),
                "comm_time_meas_s": stats.get("comm_time_meas_s"),
            })
            if stats.get("comm_time_meas_s") is not None:
                rec["comm_delta_s"] = float(
                    stats["comm_time_meas_s"] - stats["comm_time_est_s"])
        else:
            rec["fallback"] = info["measure_fallback"]
        recs.append(rec)
        if wisdom_path and measured:
            key = wisdom_key(n=n, dtype="complex64", p=p, method="pfft3-lb",
                             backend=backend, topology=topo)
            extra = {"origin": "kernel_microbench", "topology": topo}
            if waxes is not None:
                extra["pfft3_orientation"] = list(waxes)
            if stats.get("comm_time_meas_s") is not None:
                extra["comm_bytes"] = stats["comm_bytes"]
                extra["comm_time_s"] = stats["comm_time_meas_s"]
            record_wisdom(wisdom_path, key, cfg, mode="measure",
                          time_s=info.get("time_s"), extra=extra)
    return recs


def bench_multihost(sizes, wisdom_path: str | None = None) -> list[dict]:
    """Hierarchical-vs-flat exchange race on an emulated hosts x local mesh.

    ``make_fft_mesh(hosts=h, local=l)`` splits the forced CPU devices
    into ``h`` host-major groups (the single-process stand-in for real
    ``process_index`` structure), so ``tune_dist_config(mode="measure")``
    races hierarchical-exchange candidates against flat ones through the
    full ``pfft2_distributed`` pipeline.  The record pins three things:

    * the *explicit* hier-vs-flat end-to-end delta (both forms of the
      winner's config, interleaved through ``measure_dist_configs``);
    * the per-tier comm samples the tuner times — one grouped
      all_to_all per tier, byte volumes matching
      ``dist_comm_bytes(hosts=..., exchange="hier")`` exactly;
    * the two interconnect tiers ``fit_cost_params`` recovers from the
      warmed store (fast intra-host vs slow inter-host constants) —
      degenerate on a localhost rig where both tiers are shared memory,
      but the fit *path* is the one real clusters calibrate through.

    The measured winner warms wisdom under the host-count topology
    digest (``{h}hx{p}x...``), the key ``plan_pfft(mesh=...)`` looks up
    when handed the same emulated-host mesh — so a warmed store serves
    the multi-host plan with zero re-measurement.  Sub-4-device hosts
    record the structural two-tier byte split instead (flat keeps
    ``M(l-1)/p`` on the fast tier, hier aggregates to ``M(l-1)/l`` fast
    bytes but only ``h-1`` slow-tier messages).
    """
    import dataclasses

    import jax
    from repro.launch.mesh import make_fft_mesh, mesh_host_shape
    from repro.plan import fit_cost_params

    p = jax.device_count()
    backend = jax.default_backend()
    hosts = 2 if p >= 4 and p % 2 == 0 else 1
    local = p // hosts
    recs = []
    if hosts < 2 or local < 2:
        # Structural fallback: the tier byte accounting at a reference
        # 2-host x 2-device topology, priced by the default constants.
        params = CostParams.for_backend(backend)
        for n in sizes:
            flat = dist_comm_bytes(n, 4, hosts=2, exchange="flat")
            hier = dist_comm_bytes(n, 4, hosts=2, exchange="hier")
            total = dist_comm_bytes(n, 4)
            recs.append({
                "bench": "multihost", "n": int(n), "devices": p,
                "hosts": 2, "local": 2, "measured": False,
                "fallback": "needs >= 4 devices with an even split",
                "flat_intra_bytes": float(flat.intra),
                "flat_inter_bytes": float(flat.inter),
                "hier_intra_bytes": float(hier.intra),
                "hier_inter_bytes": float(hier.inter),
                "inter_msgs_flat": 2, "inter_msgs_hier": 1,
                "exchange_time_flat_s": exchange_time(
                    total, 4, params=params, hosts=2, exchange="flat"),
                "exchange_time_hier_s": exchange_time(
                    total, 4, params=params, hosts=2, exchange="hier"),
            })
        return recs

    mesh = make_fft_mesh(hosts=hosts, local=local)
    assert mesh_host_shape(mesh, "fft") == (hosts, local)
    for n in sizes:
        if n % p:
            continue
        panels = dist_panel_space(n, p)
        topo = topology_digest(mesh, "fft", panels=panels)
        cfg, info = tune_dist_config(n, mesh, "fft", mode="measure",
                                     panels=panels)
        dist = info["dist"]
        tiers = dist_comm_bytes(n, p, hosts=hosts, exchange=cfg.exchange)
        measured = "measure_fallback" not in info
        rec = {
            "bench": "multihost", "n": int(n), "devices": p,
            "hosts": hosts, "local": local,
            "topology": topo,
            "config": cfg.describe(),
            "exchange": cfg.exchange,
            "intra_bytes": float(tiers.intra),
            "inter_bytes": float(tiers.inter),
            "comm_time_est_s": dist["comm_time_est_s"],
            "measured": measured,
        }
        if measured:
            # Explicit hier-vs-flat: the same winning config under both
            # exchange forms, interleaved through the tuner's harness.
            flat_cfg = dataclasses.replace(cfg, exchange="flat")
            hier_cfg = dataclasses.replace(cfg, exchange="hier")
            times = measure_dist_configs([flat_cfg, hier_cfg], n, mesh,
                                         "fft", rounds=3)
            rec.update({
                "time_s": info["time_s"],
                "time_flat_s": float(times[flat_cfg]),
                "time_hier_s": float(times[hier_cfg]),
                "hier_vs_flat_delta_s": float(times[flat_cfg]
                                              - times[hier_cfg]),
                "comm_time_meas_s": dist.get("comm_time_meas_s"),
                "comm_samples": dist.get("comm_samples"),
            })
        else:
            rec["fallback"] = info["measure_fallback"]
        recs.append(rec)
        if wisdom_path and measured:
            key = wisdom_key(n=n, dtype="complex64", p=p, method="lb",
                             backend=backend, topology=topo)
            extra = {"origin": "kernel_microbench", "topology": topo,
                     "hosts": hosts,
                     "comm_bytes": dist["comm_bytes"],
                     "comm_time_s": dist.get("comm_time_meas_s")}
            if dist.get("comm_samples"):
                extra["comm_samples"] = dist["comm_samples"]
            record_wisdom(wisdom_path, key, cfg, mode="measure",
                          time_s=info["time_s"], extra=extra)
    if wisdom_path and any(r.get("measured") for r in recs):
        fitted = fit_cost_params(wisdom_path, backend=backend)
        for r in recs:
            if r.get("measured"):
                r["fit_intra_bytes_per_s"] = fitted.interconnect_bytes_per_s
                r["fit_intra_latency_s"] = fitted.comm_latency_s
                r["fit_inter_bytes_per_s"] = fitted.inter_bytes_per_s
                r["fit_inter_latency_s"] = fitted.inter_latency_s
    return recs


# Which record ``bench`` tags each sweep (re)writes — the unit of the
# overwrite guard and of partial-sweep merging below.
_SWEEP_BENCHES = {
    "radix": ("radix",), "fused": ("fused",), "segments": ("segments",),
    "planner": ("planner",), "schedule": ("schedule",),
    "dist": ("dist",), "hetero-dist": ("hetero-dist",),
    "rfft": ("rfft", "rfft-dist"), "pfft3": ("pfft3",),
    "multihost": ("multihost",),
}


def _merge_existing_records(out: str, rerun_benches: set, backend: str,
                            force: bool) -> list:
    """Record-level overwrite protection + partial-sweep merge.

    Returns the existing records whose bench is *not* being rerun (they
    are carried into the new file unchanged, so a ``--sweeps`` subset
    refreshes only its own rows).  For the benches that *are* rerun: if
    this run is cpu (interpret-mode Pallas) and any record it would
    replace is tagged with an accelerator backend, refuse — interpreter
    timings say nothing about hardware and must never silently replace
    measured numbers.  ``--force`` overrides.  Records predating the
    per-record tags inherit the file's top-level backend.
    """
    if not os.path.exists(out):
        return []
    try:
        with open(out) as f:
            existing = json.load(f)
    except (OSError, ValueError):
        return []  # unreadable/legacy file: nothing trustworthy to protect
    if not isinstance(existing, dict):
        return []
    file_backend = existing.get("backend")
    records = [r for r in existing.get("records", []) if isinstance(r, dict)]
    replaced = [r for r in records if r.get("bench") in rerun_benches]
    if backend == "cpu" and not force:
        accel = sorted({r.get("backend") or file_backend or "?"
                        for r in replaced
                        if (r.get("backend") or file_backend or "cpu")
                        != "cpu"})
        if accel:
            raise SystemExit(
                f"{out} holds {'/'.join(accel)}-measured records for "
                f"benches being rerun; refusing to replace them with cpu "
                f"interpret-mode timings (--force to override)")
    kept = [r for r in records if r.get("bench") not in rerun_benches]
    for r in kept:
        # Tags travel with the record once it outlives its original file
        # header (the merged file's header describes *this* run).
        r.setdefault("backend", file_backend)
        r.setdefault("interpret", bool(existing.get("interpret_mode")))
    return kept


def run(quick: bool = False, out: str = DEFAULT_OUT,
        wisdom: str | None = None, sweeps: str | None = None,
        force: bool = False) -> dict:
    radix_sizes = [64, 256] if quick else [64, 256, 1024]
    fused_sizes = [64, 128] if quick else [64, 128, 256]
    planner_sizes = [128] if quick else [128, 256]
    all_sweeps = {
        "radix": lambda: bench_radix(radix_sizes, rows=32 if quick else 64),
        "fused": lambda: bench_fused(fused_sizes),
        "segments": lambda: bench_segments(n=128 if quick else 256, p=4,
                                           pad_to=160 if quick else 320),
        "planner": lambda: bench_planner(planner_sizes, p=4,
                                         wisdom_path=wisdom),
        "schedule": lambda: bench_schedule(n=48 if quick else 96, p=4,
                                           wisdom_path=wisdom),
        "dist": lambda: bench_dist([64] if quick else [64, 128],
                                   wisdom_path=wisdom),
        "hetero-dist": lambda: bench_hetero_dist(
            [48] if quick else [48, 96], wisdom_path=wisdom),
        "rfft": lambda: bench_rfft([64] if quick else [64, 128],
                                   wisdom_path=wisdom),
        "pfft3": lambda: bench_pfft3([8] if quick else [8, 16],
                                     wisdom_path=wisdom),
        "multihost": lambda: bench_multihost([64] if quick else [64, 128],
                                             wisdom_path=wisdom),
    }
    chosen = (list(all_sweeps) if sweeps is None
              else [s.strip() for s in sweeps.split(",") if s.strip()])
    unknown = set(chosen) - set(all_sweeps)
    if unknown:
        raise SystemExit(f"unknown sweeps {sorted(unknown)}; "
                         f"choose from {sorted(all_sweeps)}")
    import jax
    backend = jax.default_backend()
    interpret = backend == "cpu"
    rerun_benches = {b for s in chosen for b in _SWEEP_BENCHES[s]}
    kept = _merge_existing_records(out, rerun_benches, backend, force)
    records = []
    for name in chosen:
        records += all_sweeps[name]()
    for r in records:
        # Every record says where its numbers came from, so merged or
        # archived files stay interpretable record by record.
        r.setdefault("backend", backend)
        r.setdefault("interpret", interpret)
    payload = {
        "backend": backend,
        "interpret_mode": interpret,
        "records": kept + records,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    for r in records:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {out} ({len(records)} records)")
    if wisdom:
        print(f"warmed wisdom store {wisdom}")
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--wisdom", default=None,
                    help="wisdom store to warm with each size's best "
                         "measured config (plan_pfft-compatible keys)")
    ap.add_argument("--sweeps", default=None,
                    help="comma-separated subset of "
                         "radix,fused,segments,planner,schedule,dist,"
                         "hetero-dist,rfft,pfft3,multihost (default: all)")
    ap.add_argument("--force", action="store_true",
                    help="overwrite an output file holding accelerator-"
                         "tagged records with interpret-mode timings")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out, wisdom=args.wisdom,
        sweeps=args.sweeps, force=args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
