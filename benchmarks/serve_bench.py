"""Serving benchmark: a Zipf-mixed request stream through ``FFTService``.

What the paper's planner optimises offline — pick the cheapest
execution variant per problem — the serving layer must deliver online,
to a stream of many users' mixed-size requests.  This benchmark drives
that stream and reports the serving numbers that matter:

* sustained throughput (req/s) and latency percentiles (p50/p90/p99,
  via the shared ``benchmarks.stats.percentiles``) under a Zipf
  size/dtype mix with complex and real (rfft) transforms interleaved;
* batching efficiency (requests per dispatch) and the largest coalesced
  cohort — how much the tick loop actually merges;
* batched-vs-serial speedup: the same request list dispatched
  one-at-a-time through bare warmed plans (no queueing, no stacking) —
  the null hypothesis continuous batching has to beat;
* the zero-retune audit: a second pass on the same service
  (``reset_stats``) and a *fresh* service against the now-warm wisdom
  store must both report ``plan_cache.retunes == 0``;
* one priced-admission demo record (an oversized outlier rejected with
  the model's prediction attached).

Results land in ``benchmarks/BENCH_serve.json``.  ``--smoke`` is the CI
shape (small sizes, fewer requests); correctness of every response is
asserted against numpy in both modes, so the bench doubles as an
end-to-end integration test.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.stats import percentiles  # noqa: E402
from repro.launch.serve_fft import AdmissionError, FFTService  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")


def zipf_workload(sizes, n_requests, *, rfft_share=0.35, a=1.4, seed=0):
    """(payload, method) stream: Zipf-weighted sizes, rfft interleaved.

    Small transforms dominate (rank-weighted ``1/rank^a``) with a long
    tail of big ones — the shape that makes coalescing pay and admission
    matter.  ``rfft_share`` of requests are real signals served through
    the half-spectrum pipeline.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(sizes) + 1, dtype=float)
    probs = ranks ** -a
    probs /= probs.sum()
    reqs = []
    for _ in range(n_requests):
        n = int(rng.choice(sizes, p=probs))
        if rng.random() < rfft_share:
            reqs.append((rng.standard_normal((n, n)).astype(np.float32),
                         "rfft-lb"))
        else:
            m = rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
            reqs.append((m.astype(np.complex64), "lb"))
    return reqs


def _reference(m, method):
    return np.fft.rfft2(m) if method.startswith("rfft") else np.fft.fft2(m)


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


async def _run_stream(svc, requests, *, check=False):
    """Submit the whole stream concurrently and await every response."""

    async def one(m, method):
        out = await svc.submit(m, method=method)
        if check:
            assert np.allclose(np.asarray(out), _reference(m, method),
                               atol=1e-2), f"mismatch n={m.shape[0]} {method}"
        return out

    t0 = time.perf_counter()
    async with svc:
        outs = await asyncio.gather(*(one(m, meth) for m, meth in requests))
    return time.perf_counter() - t0, outs


def _serial_baseline(requests, *, wisdom, reps=3):
    """One-at-a-time dispatch through bare warmed plans — no queue, no
    stacking, plans and jits prebuilt and excluded from the timing, so
    the comparison isolates what coalescing buys at dispatch time.
    Best-of-``reps``, matching how the tick loop is timed."""
    import jax
    from repro.core.api import plan_pfft

    plans = {}
    for m, method in requests:
        key = (m.shape[0], method, str(m.dtype))
        if key not in plans:
            plans[key] = plan_pfft(key[0], p=1, method=method,
                                   tune="estimate", wisdom=wisdom,
                                   dtype=key[2])
            jax.block_until_ready(plans[key].execute(m))  # warm the jit
    def one_pass():
        for m, method in requests:
            key = (m.shape[0], method, str(m.dtype))
            jax.block_until_ready(plans[key].execute(m))
    return min(_timed(one_pass) for _ in range(reps))


def run(*, smoke=False, out=DEFAULT_OUT, wisdom=None, seed=0):
    if smoke:
        sizes, n_requests, budget = [32, 64], 80, 0.05
    else:
        sizes, n_requests, budget = [32, 48, 64, 96, 128], 400, 0.1

    owned_tmp = None
    if wisdom is None:
        owned_tmp = tempfile.mkdtemp(prefix="serve_bench_")
        wisdom = os.path.join(owned_tmp, "wisdom.json")

    requests = zipf_workload(sizes, n_requests, seed=seed)

    # --- pass 0: cold (plans tune + jit; excluded from the timed run) --
    svc = FFTService(wisdom=wisdom, tune="estimate", tick_budget_s=budget)
    asyncio.run(_run_stream(svc, requests, check=True))
    cold = svc.stats()

    # --- pass 1: warm timed run (same service; caches + jits hot) ------
    svc.reset_stats()
    elapsed, _ = asyncio.run(_run_stream(svc, requests))
    warm = svc.stats()
    assert warm["served"] == n_requests, warm
    lat = warm["latencies_s"]

    # --- batched tick loop vs serial dispatch --------------------------
    # The speedup metric compares the two *dispatch paths* over the
    # identical stream: the sync core (enqueue + tick: coalesce, stack,
    # one program per cohort) against one-at-a-time execution of bare
    # warmed plans.  The async pass above prices the whole service —
    # event loop included — and feeds the latency percentiles.
    def _tick_loop():
        for m, meth in requests:
            svc.enqueue(m, method=meth)
        svc.drain()

    tick_loop_s = min(_timed(_tick_loop) for _ in range(3))
    serial_s = _serial_baseline(requests, wisdom=wisdom)

    # --- fresh service against the warm wisdom store -------------------
    svc2 = FFTService(wisdom=wisdom, tune="estimate", tick_budget_s=budget)
    asyncio.run(_run_stream(svc2, requests[: max(n_requests // 4, 8)]))
    fresh = svc2.stats()

    # --- priced-admission demo -----------------------------------------
    demo_n = 4096 if smoke else 8192
    try:
        svc.enqueue(np.zeros((demo_n, demo_n), np.complex64), method="lb")
        admission = {"rejected": False}
    except AdmissionError as e:
        admission = {"rejected": True, "n": demo_n,
                     "predicted_s": e.predicted_s, "budget_s": e.budget_s}

    record = {
        "mode": "smoke" if smoke else "full",
        "sizes": sizes,
        "n_requests": n_requests,
        "tick_budget_s": budget,
        "elapsed_s": elapsed,
        "req_per_s": n_requests / elapsed,
        **{k: percentiles(lat)[k] for k in ("p50", "p90", "p99")},
        "batching_efficiency": warm["batching_efficiency"],
        "max_coalesced": warm["max_coalesced"],
        "coalesced_dispatches": warm["coalesced_dispatches"],
        "dispatches": warm["dispatches"],
        "ticks": warm["ticks"],
        "splits": warm["splits"],
        "tick_loop_s": tick_loop_s,
        "serial_s": serial_s,
        "speedup_vs_serial": serial_s / tick_loop_s,
        "cold_retunes": cold["plan_cache"]["retunes"],
        "second_run_retunes": warm["plan_cache"]["retunes"],
        "fresh_service_retunes": fresh["plan_cache"]["retunes"],
        "fresh_service_sources": fresh["sources"],
        "plan_cache": warm["plan_cache"],
        "admission_demo": admission,
    }
    assert record["second_run_retunes"] == 0, \
        "warm pass re-tuned: plan cache is not doing its job"
    assert record["fresh_service_retunes"] == 0, \
        "fresh service re-tuned despite warm wisdom: write-back broken"

    payload = {"backend": None, "record": record}
    try:
        import jax
        payload["backend"] = jax.default_backend()
    except Exception:
        pass
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"[serve_bench] {record['req_per_s']:.1f} req/s  "
          f"p50={record['p50'] * 1e3:.2f}ms p99={record['p99'] * 1e3:.2f}ms  "
          f"eff={record['batching_efficiency']:.2f} req/dispatch  "
          f"speedup_vs_serial={record['speedup_vs_serial']:.2f}x")
    print(f"[serve_bench] wrote {out}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small sizes, fewer requests")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--wisdom", default=None,
                    help="wisdom store path (default: a fresh temp store)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(smoke=args.smoke, out=args.out, wisdom=args.wisdom, seed=args.seed)


if __name__ == "__main__":
    main()
