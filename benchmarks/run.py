"""Benchmark harness — one table per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV blocks per table (plus the richer
per-table CSVs each module emits).  Tables:

  speed_functions   paper Figs 1-6, 13-14  (backend performance profiles)
  pfft_speedups     paper Figs 15-24       (PFFT-FPM / -PAD / -CZT vs basic)
  partition_quality paper Figs 9-12        (HPOPTA vs load-balance)
  roofline          EXPERIMENTS.md §Roofline (from dry-run records)
  serve             DESIGN.md §Transform serving (continuous batching
                    of a Zipf request mix -> BENCH_serve.json)

NOTE: this container is one CPU core — the parallel-speedup component of
the paper's results needs >1 physical core; the padding/model components
reproduce directly (see EXPERIMENTS.md for the mapping).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: speed,pfft,partition,roofline,serve")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (partition_quality, pfft_speedup, roofline_report,
                            speed_functions)

    t_all = time.perf_counter()
    if only is None or "speed" in only:
        t0 = time.perf_counter()
        speed_functions.run(quick=args.quick)
        print(f"speed_functions,{(time.perf_counter() - t0) * 1e6:.0f},wall_us\n")
    if only is None or "pfft" in only:
        t0 = time.perf_counter()
        pfft_speedup.run(quick=args.quick)
        print(f"pfft_speedups,{(time.perf_counter() - t0) * 1e6:.0f},wall_us\n")
    if only is None or "partition" in only:
        t0 = time.perf_counter()
        partition_quality.run()
        print(f"partition_quality,{(time.perf_counter() - t0) * 1e6:.0f},wall_us\n")
    if only is None or "roofline" in only:
        t0 = time.perf_counter()
        roofline_report.run()
        print(f"roofline,{(time.perf_counter() - t0) * 1e6:.0f},wall_us\n")
    if only is None or "serve" in only:
        from benchmarks import serve_bench
        t0 = time.perf_counter()
        serve_bench.run(smoke=args.quick)
        print(f"serve,{(time.perf_counter() - t0) * 1e6:.0f},wall_us\n")
    print(f"benchmarks_total,{(time.perf_counter() - t_all) * 1e6:.0f},wall_us")
    return 0


if __name__ == "__main__":
    sys.exit(main())
