"""Paper Figs 15-24: speedups of PFFT-FPM / PFFT-FPM-PAD / PFFT-FPM-CZT
over the basic FFT, per problem size.

Speedup = t_basic / t_method, t_basic = one fft2 call with all resources
(the paper's one-group-of-36-threads baseline), exactly the paper's metric.
The FPMs are measured on this host (partial speed functions — paper §V-B
notes full functions took 96h; partial FPMs give sub-optimal but valid
distributions), then each method is planned once and the jitted plan is
timed.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (N_SWEEP, N_VALLEYS, basic_fft2_time,
                               build_host_fpms, mflops_of, signal, time_fn)
from repro.core.api import plan_pfft

__all__ = ["run"]

P = 4  # abstract processors (paper uses p=4 groups for FFTW)


def fpms_for_n(n: int, p: int = P):
    xs = sorted({max(n // p // 2, 1), max(n // p, 1), max(n // 2, 1), n})
    pow2 = 1 << int(np.ceil(np.log2(max(n, 2))))
    # candidate padded lengths: the FPM measures n itself, the next
    # power of two (the platform's fast sizes) and nearby composites.
    ys = sorted({n, pow2, 2 * pow2, ((n + 63) // 64) * 64, n + 64})
    return build_host_fpms(p, xs, ys)


def run(ns=None, quick: bool = False, methods=("fpm", "fpm-pad", "fpm-czt")):
    # Paper-style composite sizes + this platform's valley (prime) sizes:
    # the paper's speedups concentrate where the backend has performance
    # drops (its §V: 'speedups not significant where variations are not
    # remarkable'), so both categories are reported.
    default_ns = sorted(set(N_SWEEP[::4]) | set(N_VALLEYS) | {256, 512, 1024})
    ns = ns or ([251, 256, 509] if quick else default_ns)
    rows = []
    for n in ns:
        m = signal(n)
        t_basic = basic_fft2_time(n)
        fpms = fpms_for_n(n)
        entry = {"n": n, "basic_mflops": mflops_of(n, t_basic)}
        for method in methods:
            try:
                plan = plan_pfft(n, p=P, fpms=fpms, method=method)
                t = time_fn(plan.execute, m, eps=0.15, max_reps=8, max_t=4.0)
                entry[f"speedup_{method}"] = t_basic / t
                entry[f"d_{method}"] = plan.d.tolist()
            except Exception as e:  # pragma: no cover
                entry[f"speedup_{method}"] = float("nan")
                entry[f"d_{method}"] = repr(e)[:40]
        rows.append(entry)

    print("table=pfft_speedups  (paper Figs 15-24)")
    cols = [f"speedup_{m}" for m in methods]
    print("n,basic_mflops," + ",".join(cols))
    for e in rows:
        print(f"{e['n']},{e['basic_mflops']:.1f}," +
              ",".join(f"{e[c]:.3f}" for c in cols))
    for m in methods:
        sp = np.array([e[f"speedup_{m}"] for e in rows])
        ok = np.isfinite(sp)
        if ok.any():
            print(f"stat,{m},avg_speedup={np.nanmean(sp):.2f},"
                  f"max_speedup={np.nanmax(sp):.2f}")
    return rows


if __name__ == "__main__":
    run()
