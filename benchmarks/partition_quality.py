"""Paper §III-C illustration (Figs 9-12): what the data-partitioning layer
does with heterogeneous speed functions — HPOPTA's (possibly imbalanced)
distribution vs the load-balanced one, on synthetic profiles with the
paper's characteristic performance drops."""

from __future__ import annotations

import numpy as np

from repro.core.fpm import FPMSet, SpeedFunction
from repro.core.partition import hpopta, lb_partition, partition_rows

__all__ = ["run"]


def paper_like_profiles(n: int, p: int, seed: int = 0):
    """Speed functions with cliffs at 'unlucky' sizes (the paper's observed
    shape for MKL/FFTW) and one slower group (NUMA-asymmetric)."""
    rng = np.random.default_rng(seed)
    xs = np.arange(1, n + 1)
    ys = np.array([n])
    fns = []
    for i in range(p):
        base = 1000.0 * (1.0 - 0.4 * (i % 2))          # alternate-socket speed
        sp = base * (1 + 0.3 * np.sin(xs / 7.0))       # oscillation
        cliff = rng.choice(n, size=n // 8, replace=False)
        sp[cliff] *= 0.25                              # severe drops
        fns.append(SpeedFunction(xs, ys, sp[:, None], name=f"G{i}"))
    return FPMSet(fns)


def run(n: int = 512, p: int = 4, seed: int = 0):
    fpms = paper_like_profiles(n, p, seed)
    curves = [f.time_curve(n, n) for f in fpms]

    lb = lb_partition(n, p)
    t_lb = max(curves[i][lb.d[i]] for i in range(p))
    opt = hpopta(curves, n)

    print("table=partition_quality  (paper Figs 9-12)")
    print(f"n={n},p={p}")
    print(f"lb_distribution,{lb.d.tolist()},makespan,{t_lb:.4f}")
    print(f"hpopta_distribution,{opt.d.tolist()},makespan,{opt.tau:.4f}")
    print(f"stat,hpopta_vs_lb_speedup,{t_lb / opt.tau:.3f}")
    imbalance = float(opt.d.max() - opt.d.min())
    print(f"stat,optimal_imbalance_rows,{imbalance:.0f}  "
          f"(paper: optimal solutions may not load-balance)")

    disp = partition_rows(n, fpms, eps=0.05, y=n)
    print(f"dispatch_method,{disp.method}")
    return {"lb_makespan": t_lb, "hpopta_makespan": opt.tau,
            "speedup": t_lb / opt.tau}


if __name__ == "__main__":
    run()
