"""Paper Figs 1-6 & 13-14: performance profiles of FFT backends vs N.

The paper compares FFTW-2.1.5 / FFTW-3.3.7 / Intel MKL FFT; the JAX-native
analogues here are three 2-D DFT implementations with genuinely different
size-sensitivity:

  * xla_fft   — jnp.fft.fft2 (XLA's PocketFFT path; Bluestein on non-smooth N)
  * stockham  — our radix-2 row-column pipeline (pow2 only; NaN elsewhere)
  * czt_pow2  — chirp-Z through pow2 FFTs (smooth cost at every N)

Reports the paper's comparison stats: average speed, peak speed (+argmax),
width-of-variation (Eq. 1), and #sizes where each backend beats another.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import N_SWEEP, N_VALLEYS, mflops_of, signal, time_fn
from repro.core.pfft import czt_dft
from repro.fft.fft2d import fft2d_rowcol

__all__ = ["run"]


def _czt2(m):
    return czt_dft(czt_dft(m).T).T


BACKENDS = {
    "xla_fft": jax.jit(jnp.fft.fft2),
    "stockham": jax.jit(lambda m: fft2d_rowcol(m, use_stockham=True)),
    "czt_pow2": jax.jit(_czt2),
}


def variation_width(speeds: np.ndarray) -> float:
    """Paper Eq. 1: max |s1-s2|/min(s1,s2) over subsequent local extrema."""
    s = speeds[np.isfinite(speeds)]
    if len(s) < 2:
        return 0.0
    return float(np.max(np.abs(np.diff(s)) / np.minimum(s[:-1], s[1:])) * 100)


def run(ns=None, quick: bool = False):
    ns = ns or (N_SWEEP[:8] if quick else sorted(set(N_SWEEP) | set(N_VALLEYS)))
    rows = []
    for n in ns:
        m = signal(n)
        entry = {"n": n}
        for name, fn in BACKENDS.items():
            if name == "stockham" and (n & (n - 1)):
                entry[name] = float("nan")
                continue
            try:
                t = time_fn(fn, m, eps=0.15, max_reps=8, max_t=3.0)
                entry[name] = mflops_of(n, t)
            except Exception:
                entry[name] = float("nan")
        rows.append(entry)

    print("table=speed_functions  (paper Figs 1-6, 13-14)")
    print("n," + ",".join(BACKENDS))
    for e in rows:
        print(f"{e['n']}," + ",".join(f"{e[b]:.1f}" for b in BACKENDS))

    stats = {}
    for b in BACKENDS:
        sp = np.array([e[b] for e in rows])
        ok = np.isfinite(sp)
        stats[b] = {
            "avg_mflops": float(np.nanmean(sp)),
            "peak_mflops": float(np.nanmax(sp)),
            "peak_n": int(np.array(ns)[ok][np.nanargmax(sp[ok])]),
            "variation_width_pct": variation_width(sp),
        }
    a, c = stats["xla_fft"], stats["czt_pow2"]
    wins = sum(1 for e in rows
               if np.isfinite(e["czt_pow2"]) and e["czt_pow2"] > e["xla_fft"])
    for b, s in stats.items():
        print(f"stat,{b},avg={s['avg_mflops']:.0f},peak={s['peak_mflops']:.0f}"
              f"@N={s['peak_n']},variation={s['variation_width_pct']:.0f}%")
    print(f"stat,czt_beats_xla_on,{wins},of,{len(rows)}")
    return rows, stats


if __name__ == "__main__":
    run()
