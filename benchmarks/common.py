"""Shared benchmark plumbing: signal generation, timed 2-D FFT backends,
FPM construction on the benchmark host."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.stats import mean_using_ttest
from repro.core.fpm import FPMSet, SpeedFunction, build_fpm, fft_flops

__all__ = ["signal", "time_fn", "basic_fft2_time", "build_host_fpms",
           "N_SWEEP", "N_VALLEYS", "mflops_of"]

# CPU-budget slice of the paper's sweep {128, 192, ..., 64000}.
N_SWEEP = list(range(128, 1153, 64))
# This platform's performance valleys: XLA/pocketfft falls off a cliff at
# sizes with large prime factors (Bluestein), the analogue of the paper's
# MKL-unfriendly sizes.  The paper's step-64 sweep is all-composite, so the
# benchmark adds these to exhibit (and then remove) the variation.
N_VALLEYS = [251, 379, 509, 761, 1021]


def signal(n: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal((n, n))
                        + 1j * rng.standard_normal((n, n))).astype(np.complex64))


def time_fn(fn, *args, eps: float = 0.1, max_reps: int = 10,
            max_t: float = 5.0) -> float:
    """Compile once, then Alg.-8-style timed repetitions."""
    out = fn(*args)
    jax.block_until_ready(out)
    res = mean_using_ttest(lambda: jax.block_until_ready(fn(*args)),
                           min_reps=3, max_reps=max_reps, max_t=max_t, eps=eps)
    return res["mean"]


def basic_fft2_time(n: int, seed: int = 0) -> float:
    """The 'basic package' baseline: one full 2-D FFT call."""
    m = signal(n, seed)
    f = jax.jit(jnp.fft.fft2)
    return time_fn(f, m)


def mflops_of(n: int, t: float) -> float:
    """Paper speed metric for an N x N 2-D DFT: 2 * (2.5 N^2 log2 N) / t."""
    return float(2 * fft_flops(n, n) / t / 1e6)


def build_host_fpms(p: int, xs, ys, *, eps: float = 0.15) -> FPMSet:
    """Measure speed functions for p abstract processors on this host.

    Each abstract processor executes the same row-FFT batches (they are
    identical host groups); measurement noise supplies small variations,
    exactly the situation the paper's epsilon-tolerance test classifies."""
    def timer(x: int, y: int) -> float:
        m = jnp.ones((x, y), jnp.complex64)
        f = jax.jit(lambda a: jnp.fft.fft(a, axis=-1))
        try:
            return time_fn(f, m, eps=eps, max_reps=6, max_t=2.0)
        except Exception:
            return float("nan")

    return FPMSet([build_fpm(xs, ys, timer, name=f"P{i}") for i in range(p)])
