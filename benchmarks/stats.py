"""Statistical methodology (paper Alg. 8): repeat until the sample mean
lies in the 95% confidence interval with the requested precision, via
Student's t-test."""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

try:
    from scipy import stats as _sstats
except Exception:  # pragma: no cover
    _sstats = None

__all__ = ["mean_using_ttest", "percentiles"]


def percentiles(samples, qs=(50, 90, 99)) -> dict:
    """Tail-latency summary: {"p50": ..., "p90": ..., "p99": ...}.

    The shared helper behind the serving and resilience benchmarks —
    one definition of "p99" (linear interpolation over the sample) so
    their numbers compare.  Empty input yields NaNs rather than raising
    so a smoke run with a shed-everything policy still writes a record.
    """
    arr = np.asarray(list(samples), dtype=float)
    if arr.size == 0:
        return {f"p{int(q)}": float("nan") for q in qs}
    return {f"p{int(q)}": float(np.percentile(arr, q)) for q in qs}


def mean_using_ttest(app: Callable[[], None], *, min_reps: int = 3,
                     max_reps: int = 30, max_t: float = 60.0,
                     cl: float = 0.95, eps: float = 0.05) -> dict:
    """Run ``app`` repeatedly; stop when CI/mean < eps (or rep/time caps).

    Returns {mean, reps, eps_achieved, elapsed} — the paper's MeanUsingTtest
    with the same three stop conditions."""
    obs: list[float] = []
    elapsed = 0.0
    eps_out = float("inf")
    while len(obs) < max_reps:
        t0 = time.perf_counter()
        app()
        dt = time.perf_counter() - t0
        obs.append(dt)
        elapsed += dt
        if len(obs) >= min_reps:
            arr = np.asarray(obs)
            sd = arr.std(ddof=1)
            if _sstats is not None and sd > 0:
                half = float(_sstats.t.ppf(cl, len(obs) - 1)) * sd / np.sqrt(len(obs))
            else:
                half = 2.0 * sd / np.sqrt(len(obs))
            eps_out = half / arr.mean()
            if eps_out < eps:
                break
            if elapsed > max_t:
                break
    return {"mean": float(np.mean(obs)), "reps": len(obs),
            "eps_achieved": float(eps_out), "elapsed": elapsed}
