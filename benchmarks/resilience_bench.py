"""Resilience microbench -> BENCH_resilience.json.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python -m benchmarks.resilience_bench [--quick] \\
        [--out F] [--wisdom W] [--sweeps straggler,loss]

Drives the self-healing runtime (``repro.runtime.resilient``) through
the two injected-fault recoveries and records the numbers the
acceptance criteria are judged by:

  straggler  a 3x slowdown of one device group under an estimate-tuned
             plan: time-to-detect (wall seconds and execute calls from
             injection to the detection event), re-plan seconds, the
             hot-swap call boundary, and the post-recovery steady-state
             step time vs an *oracle* plan tuned from scratch against
             the same degraded FPMs (``post_vs_oracle`` — the <= 1.25
             acceptance bound).  The rig is the engineered-FPM fleet of
             tests/test_resilient.py: the drift genuinely flips the
             grouped-vs-homogeneous makespan race, so the recovery is a
             heterogeneous device-group program.
  loss       a ``DeviceLostError`` mid-stream under a measure-tuned
             plan: time-to-recover (mesh rebuild + serve-or-retune +
             re-shard), the 4->3 topology digests, and whether a second
             runtime on the reduced topology is served from wisdom with
             zero re-measurement.

On a 1-device host both sweeps emit a skip record (the JSON is always
written, so CI assertions never chase a missing file).  Absolute times
are CPU-container times; the structural facts (detection fired, the
swap happened, wisdom served) are what carry.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
import time

import numpy as np

DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "BENCH_resilience.json")


def _engineered_rig(n: int = 48):
    """The causal-flip fleet: three slow-ish pow2-peaked devices (pad to
    64, kernel-eligible) + one fast flat device (stays at 48).  Constants
    sized so the healthy race picks homogeneous and the drifted race
    picks the grouped program — see tests/test_resilient.py."""
    from repro.core.fpm import FPMSet, SpeedFunction
    from repro.plan.cost import CostParams

    xs = np.array(sorted({1, n // 4, n}))
    ys = np.array(sorted({48, 64, 128}))
    peaked = np.tile([2e8, 8e8, 2e8], (len(xs), 1))
    flat = np.full((len(xs), len(ys)), 4e9)
    fpms = FPMSet([SpeedFunction(xs, ys, peaked.copy(), name=f"d{i}")
                   for i in range(3)]
                  + [SpeedFunction(xs, ys, flat, name="d3")])
    params = dataclasses.replace(
        CostParams.for_backend("cpu"),
        backend_factor={"xla": 1.0, "stockham": 0.25, "pallas": 300.0},
        dispatch_overhead_s=1e-5)
    return fpms, params


def _step_stats(plan, x, reps: int) -> dict:
    """{"mean", "p50", "p90", "p99"} step seconds — percentiles via the
    shared ``benchmarks.stats.percentiles`` (same tail definition as
    the serving bench)."""
    import jax
    from benchmarks.stats import percentiles
    jax.block_until_ready(plan.execute(x))   # compile outside the timing
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.execute(x))
        ts.append(time.perf_counter() - t0)
    return {"mean": float(np.mean(ts)), **percentiles(ts)}


def _mean_plan_step(plan, x, reps: int) -> float:
    return _step_stats(plan, x, reps)["mean"]


def bench_straggler(quick: bool = False) -> list[dict]:
    import jax
    from repro.plan.tune import tune_dist_schedule
    from repro.runtime.faults import inject
    from repro.runtime.resilient import ResilientPlan

    p = jax.device_count()
    if p < 2:
        return [{"bench": "straggler", "skipped":
                 f"needs a multi-device topology (have {p}); run under "
                 "XLA_FLAGS=--xla_force_host_platform_device_count=4"}]
    if p != 4:
        return [{"bench": "straggler", "skipped":
                 f"rig is engineered for 4 devices (have {p})"}]

    n = 48
    reps = 3 if quick else 10
    fpms, params = _engineered_rig(n)
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((n, n))
         + 1j * rng.standard_normal((n, n))).astype("complex64")

    with inject() as inj:
        rp = ResilientPlan(n, method="fpm-pad", fpms=fpms, tune="estimate",
                           retune_params=params, alpha=0.6,
                           drift_threshold=1.3, cooldown=2)
        pre_sched = rp.schedule.describe()
        rp.execute(x)
        baseline = _step_stats(rp.plan, x, reps)
        baseline_s = baseline["mean"]

        inject_wall = time.perf_counter()
        inject_call = rp.calls
        inj.slow_group(0, 3)
        swap = None
        for _ in range(40):
            rp.execute(x)
            swaps = [e for e in rp.events
                     if e["kind"] == "replan"
                     and e.get("swap_call") is not None]
            if swaps and swaps[-1].get("chosen") == "heterogeneous":
                swap = swaps[-1]
                break
        rec = {
            "bench": "straggler", "n": n, "devices": p,
            "slow_device": 0, "slow_factor": 3,
            "baseline_step_s": baseline_s,
            "baseline_step_p50_s": baseline["p50"],
            "baseline_step_p99_s": baseline["p99"],
            "pre_schedule": pre_sched,
            "recovered": swap is not None,
            "events": rp.events,
        }
        if swap is None:
            return [rec]

        post = _step_stats(rp.plan, x, reps)
        post_s = post["mean"]
        degraded = rp.last_degraded_fpms
        t0 = time.perf_counter()
        oracle_sched, _ = tune_dist_schedule(
            n, rp.mesh, "fft",
            pad_lengths=rp._pad_lengths(degraded), mode="estimate",
            pad="fpm", fpms=degraded, params=params)
        oracle_tune_s = time.perf_counter() - t0
        oracle_plan = rp.plan.with_schedule(oracle_sched)
        oracle_s = _mean_plan_step(oracle_plan, x, reps)
        rec.update({
            "detect_s": swap["detect_wall"] - inject_wall,
            "detect_calls": swap["call"] - inject_call,
            "replan_s": swap["replan_s"],
            "swap_call": swap["swap_call"],
            "relative_speeds_at_detect": swap["relative_speeds"],
            "post_schedule": rp.schedule.describe(),
            "post_step_s": post_s,
            "post_step_p50_s": post["p50"],
            "post_step_p99_s": post["p99"],
            "oracle_schedule": oracle_sched.describe(),
            "oracle_step_s": oracle_s,
            "oracle_tune_s": oracle_tune_s,
            "post_vs_oracle": post_s / oracle_s,
            "schedule_matches_oracle": oracle_sched == rp.schedule,
        })
        return [rec]


def bench_loss(quick: bool = False, wisdom: str | None = None) -> list[dict]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_fft_mesh
    from repro.runtime.faults import inject
    from repro.runtime.resilient import ResilientPlan

    p = jax.device_count()
    if p < 2:
        return [{"bench": "loss", "skipped":
                 f"needs a multi-device topology (have {p}); run under "
                 "XLA_FLAGS=--xla_force_host_platform_device_count=4"}]

    n = 48
    if wisdom is None:
        wisdom = os.path.join(tempfile.mkdtemp(prefix="resilience_bench_"),
                              "wisdom.json")
    rng = np.random.default_rng(1)
    x = (rng.standard_normal((n, n))
         + 1j * rng.standard_normal((n, n))).astype("complex64")

    with inject() as inj:
        rp = ResilientPlan(n, method="lb", tune="measure", wisdom=wisdom)
        topo_before = rp.plan.tuning.get("topology")
        rp.execute(x)
        rp.register_state({"acc": jnp.zeros((n, n), "complex64")},
                          {"acc": P("fft", None)})
        lost = rp.p - 1
        inj.fail_execute(rp.calls, lost=(lost,))
        t0 = time.perf_counter()
        out = rp.execute(x)   # recovers and retries inside
        recover_total_s = time.perf_counter() - t0
        ev = [e for e in rp.events if e["kind"] == "device_loss"][-1]
        correct = bool(np.allclose(np.asarray(out), np.fft.fft2(x),
                                   atol=1e-2))

    # a fresh runtime on the reduced topology: wisdom must serve
    rp2 = ResilientPlan(n, method="lb", tune="measure", wisdom=wisdom,
                        mesh=make_fft_mesh(ev["devices"]))
    return [{
        "bench": "loss", "n": n, "devices_before": p,
        "devices_after": ev["devices"], "lost": ev["lost"],
        "dropped": ev["dropped"],
        "topology_before": topo_before, "topology_after": ev["topology"],
        "recover_s": ev["recover_s"],
        "recover_total_s": recover_total_s,
        "post_recovery_correct": correct,
        "replan_source_after_loss": ev["plan_source"],
        "second_run_source": rp2.plan.tuning.get("source"),
        "served_without_remeasure":
            rp2.plan.tuning.get("source") == "wisdom",
        "events": [ev],
    }]


def run(quick: bool = False, out: str = DEFAULT_OUT,
        wisdom: str | None = None, sweeps: str | None = None) -> dict:
    all_sweeps = {
        "straggler": lambda: bench_straggler(quick),
        "loss": lambda: bench_loss(quick, wisdom=wisdom),
    }
    chosen = (list(all_sweeps) if sweeps is None
              else [s.strip() for s in sweeps.split(",") if s.strip()])
    unknown = set(chosen) - set(all_sweeps)
    if unknown:
        raise SystemExit(f"unknown sweeps {sorted(unknown)}; "
                         f"choose from {sorted(all_sweeps)}")
    records = []
    for name in chosen:
        records += all_sweeps[name]()
    import jax
    payload = {
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "records": records,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    for r in records:
        keys = ("bench", "skipped", "recovered", "detect_s", "replan_s",
                "post_vs_oracle", "recover_s", "served_without_remeasure")
        print(",".join(f"{k}={r[k]}" for k in keys if k in r))
    print(f"wrote {out} ({len(records)} records)")
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--wisdom", default=None,
                    help="wisdom store the loss sweep records/serves "
                         "reduced-topology plans through (default: tmp)")
    ap.add_argument("--sweeps", default=None,
                    help="comma-separated subset of straggler,loss "
                         "(default: both)")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out, wisdom=args.wisdom,
        sweeps=args.sweeps)
    return 0


if __name__ == "__main__":
    sys.exit(main())
