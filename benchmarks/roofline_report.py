"""§Roofline report: read the dry-run JSON records and print the per-
(arch x shape x mesh) three-term roofline table with dominant bottleneck,
useful-compute ratio, and roofline fraction."""

from __future__ import annotations

import glob
import json
import os

__all__ = ["run", "load_records"]

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(dirname: str = DRYRUN_DIR, tag: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as fh:
            r = json.load(fh)
        if tag is None or r.get("tag") == tag:
            recs.append(r)
    return recs


def run(dirname: str = DRYRUN_DIR, tag: str | None = None):
    recs = load_records(dirname, tag)
    if not recs:
        print(f"table=roofline  (no dry-run records in {dirname} — run "
              f"`python -m repro.launch.dryrun --all` first)")
        return []
    print("table=roofline  (per arch x shape x mesh; seconds per step)")
    print("arch,shape,mesh,tag,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_fraction")
    for r in recs:
        t = r["roofline"]
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        print(f"{r['arch']},{r['shape']},{mesh},{r.get('tag','')},"
              f"{t['compute_s']:.4g},{t['memory_s']:.4g},"
              f"{t['collective_s']:.4g},{t['dominant']},"
              f"{t['useful_ratio']:.3f},{t['roofline_fraction']:.4f}")
    return recs


if __name__ == "__main__":
    run()
