"""Pure-jnp radix-2 1-D FFT (decimation-in-time, bit-reversal reorder).

This is the algorithmic basis of the Pallas kernel in ``repro.kernels.fft``:
identical stage structure, so the kernel can be validated stage-by-stage
against this implementation, which in turn is validated against the naive
DFT oracle and ``jnp.fft.fft``.

Power-of-two lengths only; ``repro.fft.fft2d`` dispatches to ``jnp.fft`` for
general lengths (XLA will pick Bluestein — exactly the "slow sizes" the
paper's padding method routes around).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["fft1d_stockham", "bit_reverse_indices"]


def bit_reverse_indices(n: int) -> np.ndarray:
    """Bit-reversal permutation for length n (n a power of two)."""
    if n & (n - 1) or n < 1:
        raise ValueError(f"n must be a power of two, got {n}")
    bits = int(np.log2(n))
    idx = np.arange(n, dtype=np.int64)
    rev = np.zeros_like(idx)
    for _ in range(bits):
        rev = (rev << 1) | (idx & 1)
        idx >>= 1
    return rev


def fft1d_stockham(x: jnp.ndarray, *, inverse: bool = False) -> jnp.ndarray:
    """Radix-2 FFT along the last axis. x: (..., n) complex, n = 2**k.

    The stage loop is unrolled at trace time (log2 n stages), matching the
    Pallas kernel's structure one-to-one.
    """
    x = jnp.asarray(x)
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length {n} is not a power of two")
    ctype = jnp.result_type(x, jnp.complex64)
    x = x.astype(ctype)
    if n == 1:
        return x

    x = x[..., bit_reverse_indices(n)]
    sign = 1.0 if inverse else -1.0
    size = 2
    while size <= n:
        half = size // 2
        tw = jnp.exp(sign * 2j * jnp.pi * jnp.arange(half) / size).astype(ctype)
        xs = x.reshape(x.shape[:-1] + (n // size, size))
        even = xs[..., :half]
        odd = xs[..., half:] * tw
        x = jnp.concatenate([even + odd, even - odd], axis=-1).reshape(x.shape)
        size *= 2
    if inverse:
        x = x / n
    return x
