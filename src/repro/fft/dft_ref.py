"""Naive O(N^2) DFT — the testing oracle for everything FFT in this repo.

Direct implementation of the paper's definition:

    M[k][l] = sum_i sum_j M[i][j] * w^{ki} * w^{lj},   w = exp(-2*pi*i/N)
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["dft1d_naive", "dft2d_naive"]


def _dft_matrix(n: int, dtype=jnp.complex64) -> jnp.ndarray:
    k = jnp.arange(n)
    w = jnp.exp(-2j * jnp.pi * jnp.outer(k, k) / n)
    return w.astype(dtype)


def dft1d_naive(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """O(N^2) DFT along ``axis``."""
    x = jnp.asarray(x)
    n = x.shape[axis]
    w = _dft_matrix(n, jnp.result_type(x, jnp.complex64))
    return jnp.moveaxis(jnp.tensordot(jnp.moveaxis(x, axis, -1), w, axes=[[-1], [1]]), -1, axis)


def dft2d_naive(m: jnp.ndarray) -> jnp.ndarray:
    """O(N^4-equivalent) 2-D DFT of a square (or rectangular) matrix."""
    return dft1d_naive(dft1d_naive(m, axis=-1), axis=-2)
