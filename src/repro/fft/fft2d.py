"""Row-column 2-D DFT (paper §III-A) built from 1-D FFTs.

``fft2d_rowcol`` is the sequential algorithm the parallel methods decompose:
row FFTs -> transpose -> row FFTs -> transpose.  It reduces the O(N^4)
direct 2-D DFT to O(N^2 log N).

``fused=True`` collapses each (row FFT, transpose) pair into one Pallas
dispatch (``repro.kernels.fused``): the transformed row block is written
straight to its transposed tile, so the intermediate HBM matrix between
steps 1-2 and 3-4 never exists.  See DESIGN.md §Fused pipeline.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.fft.fft1d import fft1d_stockham

__all__ = ["fft2d_rowcol", "fft_rows", "fft_rows_then_transpose"]


def fft_rows(m: jnp.ndarray, *, use_stockham: bool = False,
             backend: str | None = None,
             radix: int | None = None) -> jnp.ndarray:
    """1-D FFT along the last axis.

    backend: None/'xla' -> jnp.fft; 'stockham' -> pure-jnp radix-2;
    'pallas' -> the Pallas TPU kernel (interpret-mode on CPU).  Power-of-two
    lengths required for stockham/pallas; XLA otherwise.  ``radix`` feeds
    the Pallas kernel's Stockham radix (None auto-selects; the planner's
    ``PlanConfig.radix`` lands here).
    """
    n = m.shape[-1]
    if backend is None:
        backend = "stockham" if use_stockham else "xla"
    if backend == "pallas" and not (n & (n - 1)):
        from repro.kernels.fft.ops import fft_rows_op
        return fft_rows_op(m, radix=radix)
    if backend == "stockham" and not (n & (n - 1)):
        return fft1d_stockham(m)
    return jnp.fft.fft(m, axis=-1)


def fft_rows_then_transpose(m: jnp.ndarray, *,
                            backend: str | None = None,
                            radix: int | None = None) -> jnp.ndarray:
    """One fused phase: ``FFT_rows(m).T`` without the intermediate matrix.

    Dispatches to the fused Pallas kernel when it applies (2-D input,
    power-of-two row length, single-precision data — the kernel computes
    in f32 planes, so wider dtypes keep the full-precision path);
    otherwise computes the same value as ``fft_rows`` + ``swapaxes`` so
    callers can use it unconditionally.
    """
    n = m.shape[-1]
    eligible = (m.ndim == 2 and n > 1 and not (n & (n - 1))
                and jnp.result_type(m, jnp.complex64) == jnp.complex64)
    if eligible and backend in (None, "pallas", "fused"):
        from repro.kernels.fused.ops import fft_rows_transpose_op
        return fft_rows_transpose_op(m, radix=radix)
    return fft_rows(m, backend=backend).swapaxes(-1, -2)


def fft2d_rowcol(m: jnp.ndarray, *, use_stockham: bool = False,
                 fused: bool = False) -> jnp.ndarray:
    """2-D DFT via row-column decomposition, mirroring the paper's 4 steps:

      1. 1-D FFTs on rows
      2. transpose
      3. 1-D FFTs on rows (i.e. the original columns)
      4. transpose

    ``fused=True`` runs steps 1+2 and 3+4 as single fused dispatches
    (numerically equivalent; no intermediate HBM matrix).
    """
    if fused:
        m = fft_rows_then_transpose(m)              # steps 1+2
        m = fft_rows_then_transpose(m)              # steps 3+4
        return m
    m = fft_rows(m, use_stockham=use_stockham)      # step 1
    m = m.swapaxes(-1, -2)                          # step 2
    m = fft_rows(m, use_stockham=use_stockham)      # step 3
    m = m.swapaxes(-1, -2)                          # step 4
    return m
