"""Row-column 2-D DFT (paper §III-A) built from 1-D FFTs.

``fft2d_rowcol`` is the sequential algorithm the parallel methods decompose:
row FFTs -> transpose -> row FFTs -> transpose.  It reduces the O(N^4)
direct 2-D DFT to O(N^2 log N).

``fused=True`` collapses each (row FFT, transpose) pair into one Pallas
dispatch (``repro.kernels.fused``): the transformed row block is written
straight to its transposed tile, so the intermediate HBM matrix between
steps 1-2 and 3-4 never exists.  See DESIGN.md §Fused pipeline.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.fft.fft1d import fft1d_stockham

__all__ = ["fft2d_rowcol", "fft_rows", "fft_rows_then_transpose",
           "irfft2", "rfft2", "rfft_rows", "rfft_rows_then_transpose"]


def fft_rows(m: jnp.ndarray, *, use_stockham: bool = False,
             backend: str | None = None,
             radix: int | None = None) -> jnp.ndarray:
    """1-D FFT along the last axis.

    backend: None/'xla' -> jnp.fft; 'stockham' -> pure-jnp radix-2;
    'pallas' -> the Pallas TPU kernel (interpret-mode on CPU).  Power-of-two
    lengths required for stockham/pallas; XLA otherwise.  ``radix`` feeds
    the Pallas kernel's Stockham radix (None auto-selects; the planner's
    ``PlanConfig.radix`` lands here).
    """
    n = m.shape[-1]
    if backend is None:
        backend = "stockham" if use_stockham else "xla"
    if backend == "pallas" and not (n & (n - 1)):
        from repro.kernels.fft.ops import fft_rows_op
        return fft_rows_op(m, radix=radix)
    if backend == "stockham" and not (n & (n - 1)):
        return fft1d_stockham(m)
    return jnp.fft.fft(m, axis=-1)


def fft_rows_then_transpose(m: jnp.ndarray, *,
                            backend: str | None = None,
                            radix: int | None = None) -> jnp.ndarray:
    """One fused phase: ``FFT_rows(m).T`` without the intermediate matrix.

    Dispatches to the fused Pallas kernel when it applies (2-D input,
    power-of-two row length, single-precision data — the kernel computes
    in f32 planes, so wider dtypes keep the full-precision path);
    otherwise computes the same value as ``fft_rows`` + ``swapaxes`` so
    callers can use it unconditionally.
    """
    n = m.shape[-1]
    eligible = (m.ndim == 2 and n > 1 and not (n & (n - 1))
                and jnp.result_type(m, jnp.complex64) == jnp.complex64)
    if eligible and backend in (None, "pallas", "fused"):
        from repro.kernels.fused.ops import fft_rows_transpose_op
        return fft_rows_transpose_op(m, radix=radix)
    return fft_rows(m, backend=backend).swapaxes(-1, -2)


def _packed_rfft(m: jnp.ndarray, fft_fn) -> jnp.ndarray:
    """Real row FFT by packing two real rows per complex transform.

    ``fft_fn`` runs a complex FFT along the last axis; the conjugate
    split recovers both spectra (kernels.fft.real runs the plane form of
    the same identity inside Pallas).  Returns the (..., rows, n//2+1)
    half spectrum.
    """
    rows, n = m.shape[-2], m.shape[-1]
    nh = n // 2 + 1
    if rows % 2:
        pad = [(0, 0)] * (m.ndim - 2) + [(0, 1), (0, 0)]
        m = jnp.pad(m, pad)
    z = m[..., 0::2, :] + 1j * m[..., 1::2, :]
    zf = fft_fn(z)
    zrev = jnp.concatenate([zf[..., :1], jnp.flip(zf[..., 1:], axis=-1)],
                           axis=-1)
    spec_a = 0.5 * (zf + jnp.conj(zrev))
    spec_b = -0.5j * (zf - jnp.conj(zrev))
    out = jnp.stack([spec_a, spec_b], axis=-2)
    out = out.reshape(out.shape[:-3] + (-1, n))
    return out[..., :rows, :nh]


def rfft_rows(m: jnp.ndarray, *, backend: str | None = None,
              radix: int | None = None) -> jnp.ndarray:
    """1-D *real* FFT along the last axis -> (..., n//2+1) half spectrum.

    Same backend vocabulary as ``fft_rows``: 'pallas' runs the packed
    two-rows-per-FFT Pallas kernel, 'stockham' packs through the pure-jnp
    radix-2 Stockham, None/'xla' is the library rfft.  Power-of-two
    lengths required for the kernel backends, XLA otherwise.
    """
    n = m.shape[-1]
    if backend == "pallas" and m.ndim >= 2 and not (n & (n - 1)):
        from repro.kernels.fft.real import rfft_rows_op
        return rfft_rows_op(m, radix=radix)
    if backend == "stockham" and m.ndim >= 2 and not (n & (n - 1)):
        return _packed_rfft(m, fft1d_stockham)
    return jnp.fft.rfft(m, axis=-1)


def rfft_rows_then_transpose(m: jnp.ndarray, *,
                             backend: str | None = None,
                             radix: int | None = None) -> jnp.ndarray:
    """One fused real phase: ``rfft_rows(m).T`` without the intermediate.

    Eligibility mirrors ``fft_rows_then_transpose`` (2-D input,
    power-of-two row length, f32-representable data); otherwise the
    unfused value, so callers can use it unconditionally.
    """
    n = m.shape[-1]
    eligible = (m.ndim == 2 and n > 1 and not (n & (n - 1))
                and jnp.result_type(m, jnp.complex64) == jnp.complex64)
    if eligible and backend in (None, "pallas", "fused"):
        from repro.kernels.fused.real import rfft_rows_transpose_op
        return rfft_rows_transpose_op(m, radix=radix)
    return rfft_rows(m, backend=backend).swapaxes(-1, -2)


def rfft2(m: jnp.ndarray, *, backend: str | None = None,
          radix: int | None = None) -> jnp.ndarray:
    """Real-input 2-D DFT -> the (..., n_rows, n//2+1) half spectrum.

    Matches ``jnp.fft.rfft2``: real row FFTs (half the transforms via row
    packing), then full complex FFTs down the surviving half-spectrum
    columns.  Phase 2 is a plain complex ``fft_rows`` on the transposed
    half spectrum — the conjugate-symmetric half never materialises.
    """
    h = rfft_rows(m, backend=backend, radix=radix).swapaxes(-1, -2)
    h = fft_rows(h, backend=backend, radix=radix)
    return h.swapaxes(-1, -2)


def irfft2(h: jnp.ndarray, *, n: int | None = None) -> jnp.ndarray:
    """Inverse of ``rfft2``: (..., rows, nh) half spectrum -> real matrix.

    ``n`` is the last-axis length of the original signal; the default
    ``2 * (nh - 1)`` assumes it was even (pass ``n`` explicitly for odd).
    """
    if n is None:
        n = 2 * (h.shape[-1] - 1)
    g = jnp.fft.ifft(h, axis=-2)
    return jnp.fft.irfft(g, n=n, axis=-1)


def fft2d_rowcol(m: jnp.ndarray, *, use_stockham: bool = False,
                 fused: bool = False) -> jnp.ndarray:
    """2-D DFT via row-column decomposition, mirroring the paper's 4 steps:

      1. 1-D FFTs on rows
      2. transpose
      3. 1-D FFTs on rows (i.e. the original columns)
      4. transpose

    ``fused=True`` runs steps 1+2 and 3+4 as single fused dispatches
    (numerically equivalent; no intermediate HBM matrix).
    """
    if fused:
        m = fft_rows_then_transpose(m)              # steps 1+2
        m = fft_rows_then_transpose(m)              # steps 3+4
        return m
    m = fft_rows(m, use_stockham=use_stockham)      # step 1
    m = m.swapaxes(-1, -2)                          # step 2
    m = fft_rows(m, use_stockham=use_stockham)      # step 3
    m = m.swapaxes(-1, -2)                          # step 4
    return m
