from repro.fft.fft1d import fft1d_stockham, bit_reverse_indices
from repro.fft.fft2d import (fft2d_rowcol, fft_rows_then_transpose, irfft2,
                             rfft2, rfft_rows, rfft_rows_then_transpose)
from repro.fft.dft_ref import dft1d_naive, dft2d_naive

__all__ = [
    "fft1d_stockham",
    "bit_reverse_indices",
    "fft2d_rowcol",
    "fft_rows_then_transpose",
    "irfft2",
    "rfft2",
    "rfft_rows",
    "rfft_rows_then_transpose",
    "dft1d_naive",
    "dft2d_naive",
]
