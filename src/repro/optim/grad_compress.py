"""Gradient compression for bandwidth-bound cross-pod reduction.

Two codecs and an error-feedback wrapper:

* int8: per-tensor absmax-scaled symmetric quantisation (8x over f32);
* topk: magnitude top-k sparsification (values + indices);
* error feedback: the residual (g - decompress(compress(g))) is carried to
  the next step, which is what keeps compressed SGD/Adam convergent.

``compressed_psum`` is the collective: inside shard_map over the 'pod' axis
it quantises, psums the int8 payload (accumulated in int32), and rescales —
cutting cross-pod gradient bytes 4x vs f32 / 2x vs bf16.  Used by
train.step when TrainCfg.grad_compress != 'none'.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["int8_compress", "int8_decompress", "topk_compress",
           "topk_decompress", "error_feedback_update", "compressed_psum"]


def int8_compress(g: jnp.ndarray):
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_compress(g: jnp.ndarray, k_frac: float = 0.05):
    flat = g.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.size * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    picked = flat[idx]
    return picked, idx, g.shape


def topk_decompress(vals, idx, shape) -> jnp.ndarray:
    out = jnp.zeros((int(jnp.prod(jnp.array(shape))),), jnp.float32)
    return out.at[idx].set(vals).reshape(shape)


def error_feedback_update(g: jnp.ndarray, residual: jnp.ndarray,
                          codec: str = "int8", **kw):
    """Compress (g + residual); return (decompressed, new_residual)."""
    total = g.astype(jnp.float32) + residual
    if codec == "int8":
        q, s = int8_compress(total)
        dec = int8_decompress(q, s)
    elif codec == "topk":
        v, i, shp = topk_compress(total, **kw)
        dec = topk_decompress(v, i, shp)
    else:
        raise ValueError(codec)
    return dec.astype(g.dtype), total - dec


def compressed_psum(grads: Any, axis_name: str):
    """int8-quantised psum over ``axis_name`` (call inside shard_map).

    Each participant quantises with its own scale; scales are maxed across
    the axis first so the int8 payloads share a codebook and can be summed
    in int32 exactly (no per-participant decompression traffic).
    """
    def one(g):
        local_scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0 + 1e-12
        scale = jax.lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * scale).astype(g.dtype)
    return jax.tree.map(one, grads)
