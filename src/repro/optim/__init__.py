from repro.optim.adamw import adamw_init, adamw_update, OptState
from repro.optim.schedule import cosine_warmup
from repro.optim.grad_compress import (int8_compress, int8_decompress,
                                       topk_compress, topk_decompress,
                                       compressed_psum)

__all__ = ["adamw_init", "adamw_update", "OptState", "cosine_warmup",
           "int8_compress", "int8_decompress", "topk_compress",
           "topk_decompress", "compressed_psum"]
