"""AdamW with decoupled weight decay and global-norm clipping.

Moments are f32 regardless of param dtype (bf16 params, f32 state — the
standard mixed-precision recipe); the optimizer state pytree mirrors the
param pytree so the same PartitionSpecs shard it (ZeRO-style: FSDP'd params
get FSDP'd moments for free).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainCfg

__all__ = ["OptState", "adamw_init", "adamw_update"]


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt: OptState, params, cfg: TrainCfg, lr: jnp.ndarray):
    """Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat = jax.tree.map(upd, params, grads, opt.m, opt.v,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_m, new_v), {"grad_norm": gnorm}
