"""Distributed PFFT on a jax device mesh (the TPU-pod adaptation).

The paper's 4-step pipeline maps onto a 1-D pencil decomposition over a mesh
axis: each device holds a contiguous block of rows; the paper's explicit
transpose steps become ``all_to_all`` collectives (this is the dominant
roofline term at pod scale — see DESIGN.md §Distributed pipeline).

``pipeline_panels=k`` chunks each local phase into ``k`` row panels and
software-pipelines them: panel ``i``'s ``all_to_all`` is issued before panel
``i+1``'s local FFT, so the dataflow lets the compiler overlap the
distributed transpose with compute instead of serializing the full-block
FFT against the full-block exchange (see DESIGN.md §Compute/communication
overlap).

    rows sharded (N/p, N) --local row FFT-->
    --all_to_all (split cols, concat rows) + local transpose-->
    cols sharded (N/p, N) --local row FFT (== column FFT)-->
    --all_to_all back + local transpose--> rows sharded, transformed.

Padding adaptation on TPU: the *local FFT length* is padded to an FPM-chosen
fast size (smooth / lane-aligned).  Two variants:

  * ``padded='crop'``  — the paper's PFFT-FPM-PAD semantics (padded-signal
    DFT cropped to N bins; spectral interpolation);
  * ``padded='czt'``   — exact N-point DFT via Bluestein at the padded
    length (beyond-paper, exactness preserved).

Uneven (HPOPTA) distributions across *heterogeneous device groups* are
realised block-ragged: the row axis is split into ``p`` equal SPMD shards,
but the FPM distribution decides how many of each shard's rows are real
work vs. masked padding; see ``ragged_row_layout``.

Heterogeneous *execution variants* are realised as device-group programs
(``repro.plan.groups``): a schedule whose entries pick different row-FFT
variants lowers to one SPMD program whose local phase branches per shard
via ``jax.lax.switch(jax.lax.axis_index(axis_name), ...)`` — one traced
branch per distinct config, every device meeting the others at the same
collectives, with the effective FFT length made uniform at the
schedule's max entry length (see DESIGN.md §Device-group programs).
"""

from __future__ import annotations

import functools
import warnings
from typing import Literal

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.padding import pad_to_smooth
from repro.core.pfft import czt_dft
from repro.fft.fft2d import fft_rows, fft_rows_then_transpose, rfft_rows
from repro.plan.config import PlanConfig
from repro.plan.groups import (DeviceGroupProgram, device_group_program,
                               spmd_program_config)
from repro.plan.schedule import SegmentSchedule

__all__ = ["pfft2_distributed", "rpfft2_distributed", "irpfft2_distributed",
           "make_pfft2_fn", "ragged_row_layout", "hier_all_to_all",
           "validate_spmd_schedule", "default_dist_pad_len",
           "require_mesh_divisible"]

# Inverse of PlanConfig.dist_padded: the ``padded`` vocabulary of this
# module mapped back onto the planner's pad strategies.
_PAD_FROM_PADDED = {"crop": "fpm", "czt": "czt", None: "none"}


def default_dist_pad_len(n: int, padded: str | None) -> int:
    """Default local FFT length under each padding semantics: the
    model-free smooth size for 'crop', the next pow2 >= 2N-1 for 'czt'
    (Bluestein's linear-convolution length), N otherwise.  The single
    home of the rule — ``pfft2_distributed`` applies it and the dist
    tuner's local-phase probe (``plan.tune``) must time the very same
    program the end-to-end race ran."""
    if padded == "crop":
        return pad_to_smooth(n)
    if padded == "czt":
        return 1 << int(np.ceil(np.log2(2 * n - 1)))
    return n


def require_mesh_divisible(n: int, p: int, axis_name: str) -> None:
    """The shared divisibility check of every distributed entry point: the
    mesh axis size must divide N (SPMD shards are equal-sized).  One home
    for the rule — and for the message, whose wording once drifted into
    the inverted "N must divide the mesh axis" in the 3-D path."""
    if int(p) > 0 and n % int(p):
        raise ValueError(
            f"N={n} must be divisible by mesh axis {axis_name}={int(p)}")


def _hier_groups(hosts: int, local: int) -> tuple[list, list]:
    """``axis_index_groups`` of the two hierarchical-exchange stages on a
    host-major axis: intra groups are each host's contiguous run of
    ``local`` positions, inter groups collect local rank ``L`` of every
    host."""
    intra = [[H * local + L for L in range(local)] for H in range(hosts)]
    inter = [[H * local + L for H in range(hosts)] for L in range(local)]
    return intra, inter


def hier_all_to_all(x: jnp.ndarray, *, axis_name: str, hosts: int,
                    local: int, split_axis: int,
                    concat_axis: int) -> jnp.ndarray:
    """Hierarchical tiled ``all_to_all`` over a host-major mesh axis —
    bit-identical output to the flat collective, different traffic shape.

    The flat tiled all_to_all sends one split-axis panel to each of the
    ``p - 1`` peers, ``p - local`` of which cross the slow inter-host
    tier.  This form runs two grouped stages instead: a local permutation
    reorders the ``p = hosts * local`` panels host-major -> local-major,
    an *intra-host* all_to_all (each host's contiguous group of ``local``
    devices) aggregates, per device, the panels bound for local rank L of
    every host, and an *inter-host* all_to_all (the ``local`` groups
    collecting rank L across hosts) delivers them in ``hosts - 1``
    slow-tier messages per device.  Panel algebra: after the grouped
    stages the received blocks concatenate in (host, local) lexicographic
    order — exactly the flat collective's peer order — and block (H, L)
    is that sender's panel for this device, so the result matches the
    flat exchange element for element (pinned by tests on the monolithic,
    pipelined, fused-transposed, and pencil layouts).

    Works for any (split_axis, concat_axis) pair with
    ``x.shape[split_axis] % p == 0``; the fused path's transposed
    exchange and the 3-D pencil rounds reuse it unchanged.
    """
    p = hosts * local
    shape = x.shape
    w = shape[split_axis] // p
    xs = x.reshape(shape[:split_axis] + (hosts, local, w)
                   + shape[split_axis + 1:])
    xs = xs.swapaxes(split_axis, split_axis + 1)
    x = xs.reshape(shape)
    intra, inter = _hier_groups(hosts, local)
    x = jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                           concat_axis=concat_axis, tiled=True,
                           axis_index_groups=intra)
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True,
                              axis_index_groups=inter)


def _exchange_fns(axis_name: str, host_shape: tuple[int, int] | None):
    """(a2a, a2a_t) for one phase: the flat collectives, or the
    hierarchical pair when the phase runs on a host-major axis with a
    non-degenerate (hosts > 1, local > 1) shape — degenerate hierarchies
    are the flat program with extra steps."""
    if host_shape is not None and host_shape[0] > 1 and host_shape[1] > 1:
        hosts, local = host_shape
        a2a = functools.partial(hier_all_to_all, axis_name=axis_name,
                                hosts=hosts, local=local,
                                split_axis=1, concat_axis=0)
        a2a_t = functools.partial(hier_all_to_all, axis_name=axis_name,
                                  hosts=hosts, local=local,
                                  split_axis=0, concat_axis=1)
        return a2a, a2a_t
    a2a = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                            split_axis=1, concat_axis=0, tiled=True)
    a2a_t = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                              split_axis=0, concat_axis=1, tiled=True)
    return a2a, a2a_t


def _local_fft(block: jnp.ndarray, n: int, *, padded: str | None,
               pad_len: int, config: PlanConfig,
               backend: str | None) -> jnp.ndarray:
    """Row FFTs on a local block under the selected padding semantics."""
    if padded == "czt":
        return czt_dft(block, pad_len)
    kw = config.row_fft_kwargs(backend)
    if padded == "crop" and pad_len > n:
        block = jnp.pad(block, ((0, 0), (0, pad_len - n)))
        return fft_rows(block, **kw)[:, :n]
    return fft_rows(block, **kw)


def _faulted_fft(fft, axis_name: str, axis_size: int | None):
    """Apply the fault layer's per-device slowdown to a local row-FFT.

    When the process-global ``FaultInjector`` has an active slowdown, the
    FFT is wrapped per mesh position: a ``lax.switch`` on
    ``axis_index(axis_name)`` routes each device to a ``repeated``
    variant that genuinely runs its FFT ``factor`` times (bit-identical
    output via exact power-of-two rescaling — work XLA can neither CSE
    nor DCE), so an injected straggler costs real wall time exactly
    where a thermally-throttled device would.  With no active fault the
    function is returned untouched — zero overhead — and callers that
    don't thread ``axis_size`` (single-host paths) are never wrapped.

    Injection is read at *trace* time: executors that cache jitted
    programs re-trace on the injector's ``epoch`` (``ResilientPlan``
    does; a plain jitted ``pfft2_distributed`` traced before the fault
    keeps running the healthy program, exactly like real hardware drift
    under an already-compiled binary).
    """
    if axis_size is None:
        return fft
    from repro.runtime.faults import get_injector, repeated  # lazy: no cycle
    reps = get_injector().local_repeats(int(axis_size))
    if reps is None:
        return fft
    distinct = sorted(set(reps))
    branch_of = jnp.asarray([distinct.index(r) for r in reps],
                            dtype=jnp.int32)
    branches = [repeated(fft, r) for r in distinct]

    def slowed(block: jnp.ndarray) -> jnp.ndarray:
        b = branch_of[jax.lax.axis_index(axis_name)]
        return jax.lax.switch(b, branches, block)

    return slowed


def _grouped_local_fft(axis_name: str, n: int, *, padded: str | None,
                       pad_len: int, program: DeviceGroupProgram,
                       backend: str | None):
    """Per-shard branching row-FFT: one ``lax.switch`` branch per distinct
    config, selected by this device's position along ``axis_name``.

    Every device traces every branch (it is still one SPMD program) and
    executes its own; collectives stay *outside* the switch, so devices
    on different branches still meet at the same ``all_to_all``.  All
    branches transform at the uniform ``pad_len`` and crop back to N
    bins, so their output shapes — and the exchanged bin semantics —
    agree (the uniform-length rule of ``repro.plan.groups``).
    """
    branches = [
        functools.partial(_local_fft, n=n, padded=padded, pad_len=pad_len,
                          config=cfg, backend=backend)
        for cfg in program.configs]
    groups = jnp.asarray(np.asarray(program.group_of_device, dtype=np.int32))

    def fft(block: jnp.ndarray) -> jnp.ndarray:
        gid = groups[jax.lax.axis_index(axis_name)]
        return jax.lax.switch(gid, branches, block)

    return fft


def _local_phase(block: jnp.ndarray, axis_name: str, n: int, *,
                 padded: str | None, pad_len: int, config: PlanConfig,
                 backend: str | None = None,
                 pipeline_panels: int = 1,
                 program: DeviceGroupProgram | None = None,
                 axis_size: int | None = None,
                 host_shape: tuple[int, int] | None = None) -> jnp.ndarray:
    """One (row FFT -> distributed transpose) phase on a local block.

    block: (n_loc, N) — this device's rows.  Returns (n_loc, N): this
    device's block of the *transposed, row-transformed* matrix.

    The phase executes its schedule entry's config.  ``config.fused``
    runs the local (row FFT, transpose) as one fused Pallas dispatch
    (``fft_rows_then_transpose``) and swaps the ``all_to_all`` axes to
    match — since ``a2a(X, split=1, concat=0).T == a2a(X.T, split=0,
    concat=1)``, the exchange consumes the transposed block directly and
    the intermediate row-major matrix never exists.  This is what routes
    the planner's fused pick to pods; unfused configs keep FFT →
    exchange → local transpose.

    With ``pipeline_panels=1`` the phase is monolithic: transform the
    whole block, then one tiled ``all_to_all`` (split the column axis
    into p panels, keep panel j from every peer, concat along rows).

    With ``pipeline_panels=k > 1`` the block's rows are chunked into ``k``
    panels and software-pipelined: panel ``i``'s all_to_all is issued
    *before* panel ``i+1``'s FFT, so the two have no data dependence and
    the exchange of one panel hides behind the compute of the next (the
    paper's overlap lever, restated for collectives).  Panel results are
    re-interleaved so the output is bit-identical in layout to the
    monolithic phase.

    ``program`` (a ``DeviceGroupProgram``) makes the local row-FFT branch
    per shard — ``_grouped_local_fft``'s ``lax.switch`` over one traced
    branch per distinct config — while the collective structure stays
    uniform; heterogeneous schedules never take the fused path (the
    grouped lowering rejects fused mixes eagerly).

    ``host_shape`` (hosts, local) routes the exchange through the
    hierarchical two-stage collective (``hier_all_to_all``) — same
    output, but the slow inter-host tier carries ``hosts - 1`` aggregated
    messages per device instead of one per remote peer; the panel
    pipeline then overlaps those inter-host rounds against the next
    panel's FFT exactly as it overlaps flat exchanges.  ``None`` (or a
    degenerate shape) is the flat collective.
    """
    fused = config.fused and padded is None and program is None
    a2a, a2a_t = _exchange_fns(axis_name, host_shape)
    if fused:
        # radix=2 means the pure-jnp Stockham elsewhere, not a kernel
        # radix: only an explicit radix-4 reaches the fused kernel.
        fused_radix = config.radix if config.radix == 4 else None
        fft_t = functools.partial(fft_rows_then_transpose,
                                  backend=backend, radix=fused_radix)
    if program is not None:
        fft = _grouped_local_fft(axis_name, n, padded=padded,
                                 pad_len=pad_len, program=program,
                                 backend=backend)
    else:
        fft = functools.partial(_local_fft, n=n, padded=padded,
                                pad_len=pad_len, config=config,
                                backend=backend)
    fft = _faulted_fft(fft, axis_name, axis_size)
    if fused:
        fft_t = _faulted_fft(fft_t, axis_name, axis_size)
    n_loc = block.shape[0]
    k = pipeline_panels
    if k > 1 and n_loc % k:
        # Refuse the silent monolithic fallback: a direct caller (or
        # tuner drift) would time/run a different program than the one
        # requested.  pfft2_distributed validates divisibility before
        # building the phase, so reaching this is a caller bug.
        raise ValueError(
            f"_local_phase: pipeline_panels={k} must divide local rows "
            f"{n_loc}; refusing to silently run the monolithic phase "
            "instead of the requested pipelined one")
    if k <= 1:
        if fused:
            return a2a_t(fft_t(block))  # (N/p, N): a row-block of M^T
        return a2a(fft(block)).T

    c = n_loc // k  # rows per panel
    # Software pipeline: FFT panel 0; then alternate (issue all_to_all of
    # panel i, FFT panel i+1) so each exchange overlaps the next FFT.
    # Fused panels exchange transposed (see above); their gathered tiles
    # arrive already column-major, saving the per-panel transpose below.
    gathered = []
    current = fft_t(block[:c]) if fused else fft(block[:c])
    exchange = a2a_t if fused else a2a
    for i in range(1, k):
        in_flight = exchange(current)      # exchange panel i-1 ...
        nxt = block[i * c:(i + 1) * c]     # ... while transforming i
        current = fft_t(nxt) if fused else fft(nxt)
        gathered.append(in_flight)
    gathered.append(exchange(current))

    # Unfused: each g_i is (N/k, N/p): peer-major stack of that peer's
    # panel-i rows, column slice j.  Transposed, its columns are global
    # rows q*n_loc + i*c + r (q peer-major, r in-panel).  Fused tiles are
    # already transposed, (N/p, N/k).  Interleave panels so output
    # columns are in global row order, matching the monolithic path.
    tiles = [g if fused else g.T for g in gathered]   # (rows_out, n_loc/k)
    rows_out = tiles[0].shape[0]
    p = tiles[0].shape[1] * k // n_loc if n_loc else 1
    panels_t = [t.reshape(rows_out, p, c) for t in tiles]
    out = jnp.stack(panels_t, axis=2)      # (rows_out, p, k, c)
    return out.reshape(rows_out, p * k * c)


def validate_spmd_schedule(schedule: SegmentSchedule,
                           pad_len: int | None = None) -> PlanConfig:
    """Eagerly reject schedules that genuinely cannot lower to one SPMD
    program; return the schedule's *program config*.

    Heterogeneous schedules are no longer refused wholesale: per-device
    row-FFT variants lower as a device-group program (one ``lax.switch``
    branch per distinct config — ``repro.plan.groups``), and mixed
    effective lengths lower under the uniform-length rule (every branch
    transforms at the schedule's max entry length; an explicit
    ``pad_len`` overrides it).  What still raises — before any device
    work, at plan-build time in ``make_pfft2_fn`` and at the top of
    ``pfft2_distributed``, with the schedule's own ``describe()`` in the
    message — are mixes of the *program-level* knobs that shape the
    collective structure: pad strategy, ``fused``, ``pipeline_panels``
    (see ``repro.plan.groups.spmd_program_config``).  The returned
    config is the common one, or the anchor of a groupable mix (its
    program-level knobs are shared by every entry).
    """
    del pad_len  # mixed lengths always lower now; kept for API compat
    return spmd_program_config(schedule)


def _coerce_dist_config(config: PlanConfig | None,
                        schedule: SegmentSchedule | None,
                        padded: str | None,
                        use_stockham: bool | None,
                        pipeline_panels: int | None,
                        pad_len: int | None = None) -> PlanConfig:
    """Fold the legacy loose kwargs into a ``PlanConfig`` (deprecated shims).

    A ``schedule`` resolves to its *program config* (the common config,
    or the anchor of a heterogeneous-but-groupable mix — its shared
    program-level knobs drive ``padded``/``pipeline_panels`` below);
    ``validate_spmd_schedule`` raises eagerly for the mixes the grouped
    lowering genuinely cannot express.  ``pfft2_distributed`` builds the
    per-shard branching program itself (it knows the mesh size).
    """
    if schedule is not None:
        if config is not None:
            raise ValueError("pass either schedule= or config=, not both")
        config = validate_spmd_schedule(schedule, pad_len)
    if config is not None:
        if use_stockham is not None or pipeline_panels is not None:
            raise ValueError(
                f"pass either {'schedule=' if schedule is not None else 'config='}"
                " or the legacy kwargs (use_stockham/pipeline_panels), not both")
        if padded is not None and config.dist_padded != padded:
            raise ValueError(
                f"config.pad={config.pad!r} conflicts with padded={padded!r}")
        return config
    if use_stockham is not None or pipeline_panels is not None:
        warnings.warn(
            "pfft2_distributed: use_stockham=/pipeline_panels= are "
            "deprecated; pass config=PlanConfig(...) (see repro.plan)",
            DeprecationWarning, stacklevel=3)
    return PlanConfig(
        radix=2 if use_stockham else None,
        pad=_PAD_FROM_PADDED[padded],
        pipeline_panels=int(pipeline_panels) if pipeline_panels else 1)


def _resolve_dist_config(n: int, mesh: Mesh, axis_name: str, *, pad: str,
                         dtype, tune: str, wisdom: str | None,
                         pad_len: int | None
                         ) -> tuple[PlanConfig | SegmentSchedule, dict]:
    """Plan a raw ``pfft2_distributed`` call the way ``plan_pfft`` plans.

    Resolution order mirrors ``core.api._resolve_schedule``: wisdom hit
    (per-topology v3 key) > tuner > default.  A measured pick is recorded
    back — with its comm sample — so the next process on the same mesh is
    served from disk with zero re-measurement.  Keys use the method the
    pad strategy implies, so a ``plan_pfft(mesh=...)`` entry and a raw
    ``pfft2_distributed(tune=...)`` entry for the same problem coincide.
    A wisdom hit that persisted a full ``SegmentSchedule`` (a grouped
    pick included) is returned as the schedule, provided it still lowers
    to this mesh; anything that doesn't is a miss, never an error.
    """
    from repro.plan.calibrate import fit_cost_params
    from repro.plan.tune import dist_panel_space, tune_dist_config
    from repro.plan.wisdom import (lookup_wisdom, record_wisdom,
                                   topology_digest, wisdom_key)

    if tune not in ("off", "estimate", "measure"):
        raise ValueError(f"tune must be 'off'|'estimate'|'measure', got {tune!r}")
    p = int(mesh.shape[axis_name])
    panels = dist_panel_space(n, p)
    topo = topology_digest(mesh, axis_name, panels=panels)
    method = {"none": "lb", "fpm": "fpm-pad", "czt": "fpm-czt"}[pad]
    key = wisdom_key(n=n, dtype=np.dtype(dtype).name, p=p, method=method,
                     backend=jax.default_backend(), topology=topo)
    tuning: dict = {"mode": tune, "wisdom_key": key, "topology": topo}
    if wisdom is not None:
        hit = lookup_wisdom(wisdom, key)
        if hit is not None:
            plan, entry = hit
            if isinstance(plan, SegmentSchedule):
                # Served only when it still lowers to *this* mesh (a
                # hand-edited or drifted entry that cannot is a miss)
                # and its pad semantics match the requested strategy.
                try:
                    device_group_program(plan, p, pad_len=pad_len)
                except ValueError:
                    plan = None
                if plan is not None and plan.n == n \
                        and all(e.config.pad == pad for e in plan):
                    tuning["source"] = "wisdom"
                    tuning["wisdom_entry"] = entry
                    return plan, tuning
            elif plan.pad == pad:
                tuning["source"] = "wisdom"
                tuning["wisdom_entry"] = entry
                return plan, tuning
    if tune == "off":
        tuning["source"] = "off"
        return PlanConfig(pad=pad), tuning
    params = fit_cost_params(wisdom) if wisdom is not None else None
    cfg, info = tune_dist_config(n, mesh, axis_name, mode=tune, pad=pad,
                                 pad_len=pad_len, params=params,
                                 panels=panels, dtype=np.dtype(dtype))
    tuning.update(info)
    tuning["source"] = tune
    if wisdom is not None and tune == "measure" and "time_s" in info:
        extra = {"topology": topo}
        dist = info.get("dist", {})
        if dist.get("comm_time_meas_s") is not None:
            extra["comm_bytes"] = dist["comm_bytes"]
            extra["comm_time_s"] = dist["comm_time_meas_s"]
        if dist.get("comm_samples"):
            # Tier-tagged per-exchange samples (intra-/inter-host): what
            # ``fit_cost_params`` fits the two comm tiers from.
            extra["comm_samples"] = dist["comm_samples"]
        if int(dist.get("hosts", 1)) > 1:
            extra["hosts"] = int(dist["hosts"])
        record_wisdom(wisdom, key, cfg, mode="measure",
                      time_s=info["time_s"], extra=extra)
    return cfg, tuning


def _resolve_dist_plan_kw(n: int, mesh: Mesh, axis_name: str, *,
                          padded: str | None, dtype, tune: str,
                          wisdom: str | None,
                          pad_len: int | None) -> dict:
    """``_resolve_dist_config`` shaped as executor kwargs: ``{"config":
    cfg}`` or ``{"schedule": sched}`` — the one home of the
    pad-vocabulary mapping and the plan/schedule dispatch shared by
    ``pfft2_distributed`` and ``make_pfft2_fn``."""
    plan, _ = _resolve_dist_config(
        n, mesh, axis_name, pad=_PAD_FROM_PADDED[padded], dtype=dtype,
        tune=tune, wisdom=wisdom, pad_len=pad_len)
    key = "schedule" if isinstance(plan, SegmentSchedule) else "config"
    return {key: plan}


def pfft2_distributed(
    m: jnp.ndarray,
    mesh: Mesh,
    axis_name: str = "fft",
    *,
    config: PlanConfig | None = None,
    schedule: SegmentSchedule | None = None,
    padded: Literal["crop", "czt", None] = None,
    pad_len: int | None = None,
    use_stockham: bool | None = None,
    backend: str | None = None,
    pipeline_panels: int | None = None,
    tune: str = "off",
    wisdom: str | None = None,
) -> jnp.ndarray:
    """Distributed 2-D DFT of a square matrix sharded by rows over ``axis_name``.

    ``config`` selects the execution variant (``PlanConfig``): its ``pad``
    strategy maps to the ``padded`` semantics ('fpm' -> 'crop',
    'czt' -> 'czt'), ``radix`` picks the local row-FFT backend,
    ``fused`` collapses each local (row FFT, transpose) into one fused
    dispatch feeding a transposed ``all_to_all`` (the planner's fused
    pick carries to pods), and ``pipeline_panels=k`` overlaps each
    phase's all_to_all with compute by chunking the local rows into k
    software-pipelined panels (k must divide N/p; k=1 is the monolithic
    phase).  ``schedule`` routes a planner ``SegmentSchedule`` here: a
    homogeneous schedule executes its common config; a heterogeneous one
    lowers to a *device-group program* — the local phase branches per
    shard via ``lax.switch``, one traced branch per distinct config, at
    the schedule's max effective length (``repro.plan.groups``; mixes of
    pad/fused/pipeline_panels still raise the named SPMD error).  The
    loose ``use_stockham=``/``pipeline_panels=`` kwargs are deprecated
    shims.

    ``tune=``/``wisdom=`` plan the call when no explicit config/schedule
    is given: consult the per-topology wisdom store, tune on a miss
    (``tune="measure"`` times finalists end-to-end on *this* mesh), and
    record the measured pick — the same lifecycle ``plan_pfft(mesh=...)``
    runs, usable straight from the distributed entry point.

    ``pad_len``: FPM-chosen local FFT length (defaults to the model-free
    smooth size for 'crop', next pow2 >= 2N-1 for 'czt').
    """
    if (tune != "off" or wisdom is not None) and config is None \
            and schedule is None:
        resolved = _resolve_dist_plan_kw(
            m.shape[0], mesh, axis_name, padded=padded, dtype=m.dtype,
            tune=tune, wisdom=wisdom, pad_len=pad_len)
        config = resolved.get("config")
        schedule = resolved.get("schedule")
    config = _coerce_dist_config(config, schedule, padded, use_stockham,
                                 pipeline_panels, pad_len)
    if schedule is not None and pad_len is None:
        # The schedule's entries carry the FPM-chosen effective lengths —
        # the very thing the planner picked; honor them rather than the
        # model-free smooth default.  Mixed lengths lower under the
        # uniform-length rule: every device transforms at the max (the
        # program-level analog of ragged_row_layout — see plan.groups).
        pad_len = max(e.length for e in schedule)
    padded = config.dist_padded
    panels = config.pipeline_panels
    n = m.shape[0]
    p = mesh.shape[axis_name]
    require_mesh_divisible(n, p, axis_name)
    if panels > 1 and (n // p) % panels:
        raise ValueError(
            f"pipeline_panels={panels} must divide local rows {n // p}")
    if pad_len is None:
        pad_len = default_dist_pad_len(n, padded)
    program = None
    if schedule is not None and schedule.common_config is None:
        # Heterogeneous-but-groupable: lower to the device-group program
        # (one lax.switch branch per distinct config).  Raises the named
        # SPMD error when the entries cannot tile this mesh's shards.
        program = device_group_program(schedule, int(p), pad_len=pad_len)
        pad_len = program.pad_len  # the lowering owns the uniform length

    host_shape = None
    if config.exchange == "hier":
        # Hierarchy comes from the mesh, not the config: on a mesh with
        # no host-major structure the hier pick degrades to the flat
        # program (mesh_host_shape returns (1, p)) rather than raising —
        # a wisdom entry replayed onto a reshaped mesh stays correct.
        from repro.launch.mesh import mesh_host_shape
        host_shape = mesh_host_shape(mesh, axis_name)

    spec_rows = P(axis_name, None)
    phase = functools.partial(
        _local_phase, axis_name=axis_name, n=n, padded=padded,
        pad_len=pad_len, config=config, backend=backend,
        pipeline_panels=panels, program=program, axis_size=int(p),
        host_shape=host_shape)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec_rows,), out_specs=spec_rows,
        check_rep=False,
    )
    def _run(block):
        # Phase 1: row FFTs + distributed transpose.
        # Phase 2: (original-)column FFTs + distributed transpose back.
        return phase(phase(block))

    return _run(m)


# ---------------------------------------------------------------------------
# Real-input distributed pipeline: the all_to_all moves only half-spectrum
# panels — ~half the bytes per phase of the complex path.
# ---------------------------------------------------------------------------

def _validate_real_dist(config: PlanConfig | None,
                        schedule: SegmentSchedule | None) -> PlanConfig:
    """The real distributed path's program config, validated.

    The half-spectrum exchange reshapes both collectives, so the path
    supports the homogeneous, unfused, monolithic program shape (the one
    the real tuner races); panel pipelining / fused exchange / per-shard
    branching stay complex-path features for now and are refused eagerly
    with the schedule's own description.
    """
    if schedule is not None:
        if config is not None:
            raise ValueError("pass either schedule= or config=, not both")
        config = validate_spmd_schedule(schedule)
        if schedule.common_config is None:
            raise ValueError(
                "rpfft2_distributed runs homogeneous schedules only; "
                f"got {schedule.describe()}")
    if config is None:
        config = PlanConfig(real=True)
    if not config.real:
        raise ValueError(
            f"rpfft2_distributed needs a real config, got {config.describe()}")
    if config.fused or config.pipeline_panels > 1:
        raise ValueError(
            "the real distributed path is unfused and monolithic "
            f"(fused/panels are complex-path features), got {config.describe()}")
    if config.exchange != "flat":
        raise ValueError(
            "the real distributed path exchanges padded half-spectrum "
            "panels over the flat collective only (hier is a complex-path "
            f"feature for now), got {config.describe()}")
    return config


def rpfft2_distributed(
    m: jnp.ndarray,
    mesh: Mesh,
    axis_name: str = "fft",
    *,
    config: PlanConfig | None = None,
    schedule: SegmentSchedule | None = None,
    pad_len: int | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """Distributed real-input 2-D DFT -> the (N, N//2+1) half spectrum.

    ``m`` is a real square matrix sharded by rows over ``axis_name``.
    Phase 1 rffts each device's rows (two real rows per complex FFT) and
    exchanges only the ``halfspec_cols(n, p)`` surviving spectral columns
    — the panel crossing the interconnect is ~half the complex path's
    bytes; phase 2 runs complex FFTs over the sharded spectral rows and
    exchanges the same half-width panel back.  ``config.pad='fpm'`` pads
    the local FFT length to ``pad_len`` with the padded-signal crop
    semantics, exactly like ``pfft2_distributed``; a homogeneous
    ``schedule`` is validated through ``validate_spmd_schedule`` and its
    max entry length becomes ``pad_len``.
    """
    from repro.plan.cost import halfspec_cols  # lazy: plan imports core

    config = _validate_real_dist(config, schedule)
    if schedule is not None and pad_len is None:
        pad_len = max(e.length for e in schedule)
    padded = config.dist_padded
    n = m.shape[0]
    if m.ndim != 2 or m.shape[1] != n:
        raise ValueError("PFFT operates on square N x N signal matrices")
    if not jnp.issubdtype(m.dtype, jnp.floating):
        raise ValueError(
            f"the real pipeline takes a real-valued matrix, got {m.dtype}")
    p = int(mesh.shape[axis_name])
    require_mesh_divisible(n, p, axis_name)
    if pad_len is None:
        pad_len = default_dist_pad_len(n, padded)
    nh = n // 2 + 1
    hc = halfspec_cols(n, p)
    kw = config.row_fft_kwargs(backend)
    a2a = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                            split_axis=1, concat_axis=0, tiled=True)

    def local_rfft(block: jnp.ndarray) -> jnp.ndarray:
        if padded == "crop" and pad_len > n:
            block = jnp.pad(block, ((0, 0), (0, pad_len - n)))
            return rfft_rows(block, **kw)[:, :nh]
        return rfft_rows(block, **kw)

    def local_fft(block: jnp.ndarray) -> jnp.ndarray:
        if padded == "crop" and pad_len > n:
            block = jnp.pad(block, ((0, 0), (0, pad_len - n)))
            return fft_rows(block, **kw)[:, :n]
        return fft_rows(block, **kw)

    spec_rows = P(axis_name, None)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec_rows,), out_specs=spec_rows,
        check_rep=False,
    )
    def _run(block):
        # Phase 1: local rffts, pad the half spectrum to the p-divisible
        # panel width, exchange + transpose -> spectral rows sharded.
        h = local_rfft(block)                       # (n/p, nh)
        h = jnp.pad(h, ((0, 0), (0, hc - nh)))      # (n/p, hc)
        h = a2a(h).T                                # (hc/p, n)
        # Phase 2: complex FFTs down the (original) columns, exchange the
        # half-width panel back -> row-sharded (n/p, hc).
        f = local_fft(h)                            # (hc/p, n)
        return a2a(f).T                             # (n/p, hc)

    return _run(m)[:, :nh]


def irpfft2_distributed(
    h: jnp.ndarray,
    mesh: Mesh,
    axis_name: str = "fft",
    *,
    n: int | None = None,
) -> jnp.ndarray:
    """Distributed inverse of ``rpfft2_distributed``.

    ``h`` is the (N, N//2+1) half spectrum sharded by rows; the result is
    the real (N, N) signal matrix, same sharding.  ``n`` is the original
    last-axis length (default assumes it was even).  Both collectives
    move the same half-width panel as the forward transform.
    """
    from repro.plan.cost import halfspec_cols  # lazy: plan imports core

    nh = h.shape[-1]
    if n is None:
        n = 2 * (nh - 1)
    if h.ndim != 2 or h.shape[0] != n:
        raise ValueError(
            f"expected the ({n}, {nh}) half spectrum, got {h.shape}")
    p = int(mesh.shape[axis_name])
    require_mesh_divisible(n, p, axis_name)
    hc = halfspec_cols(n, p)
    a2a = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                            split_axis=1, concat_axis=0, tiled=True)

    spec_rows = P(axis_name, None)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec_rows,), out_specs=spec_rows,
        check_rep=False,
    )
    def _run(block):
        # Inverse column FFTs first (on the transposed, sharded spectral
        # rows), then the real inverse along rows.
        g = jnp.pad(block, ((0, 0), (0, hc - nh)))  # (n/p, hc)
        g = a2a(g).T                                # (hc/p, n)
        g = jnp.fft.ifft(g, axis=-1)
        g = a2a(g).T[:, :nh]                        # (n/p, nh)
        return jnp.fft.irfft(g, n=n, axis=-1)       # (n/p, n) real

    return _run(h)


def make_pfft2_fn(mesh: Mesh, n: int, axis_name: str = "fft", **kw):
    """jit-compiled distributed 2-D DFT closed over a mesh (sharded in/out).

    Planning happens *now*, not at first call: a ``schedule=`` is
    SPMD-validated eagerly — a heterogeneous one is lowered against this
    mesh's device count, so an ungroupable schedule is a build-time error
    with the schedule's ``describe()`` — and ``tune=``/``wisdom=``
    resolve to a concrete config before jit so measurement never runs
    inside a trace (the plan is keyed for complex64 signals, the
    pipeline's working dtype).
    """
    if kw.get("schedule") is not None:
        sched = kw["schedule"]
        validate_spmd_schedule(sched, kw.get("pad_len"))
        if sched.common_config is None:
            device_group_program(sched, int(mesh.shape[axis_name]),
                                 pad_len=kw.get("pad_len"))
    tune = kw.pop("tune", "off")
    wisdom = kw.pop("wisdom", None)
    if (tune != "off" or wisdom is not None) \
            and kw.get("config") is None and kw.get("schedule") is None:
        kw.update(_resolve_dist_plan_kw(
            n, mesh, axis_name, padded=kw.pop("padded", None),
            dtype=np.complex64, tune=tune, wisdom=wisdom,
            pad_len=kw.get("pad_len")))
    sharding = NamedSharding(mesh, P(axis_name, None))
    fn = functools.partial(pfft2_distributed, mesh=mesh, axis_name=axis_name, **kw)
    return jax.jit(fn, in_shardings=(sharding,), out_shardings=sharding)


def ragged_row_layout(d: np.ndarray, p: int) -> tuple[int, np.ndarray]:
    """Block-ragged realisation of an uneven HPOPTA distribution under SPMD.

    SPMD shards must be equal-sized, so each of the ``p`` groups gets a
    buffer of ``max(d)`` rows; group i's valid-row count is d[i] and the
    remainder is masked padding.  Returns (rows_per_shard, valid_counts).
    The waste max(d)*p - sum(d) is the price of SPMD on *homogeneous* pods —
    on heterogeneous fleets (where d is uneven because speeds genuinely
    differ) the time saved dominates; see DESIGN.md §Ragged layouts.
    """
    d = np.asarray(d, dtype=np.int64)
    if len(d) != p:
        raise ValueError("distribution length must equal group count")
    return int(d.max()), d.copy()
