"""Distributed PFFT on a jax device mesh (the TPU-pod adaptation).

The paper's 4-step pipeline maps onto a 1-D pencil decomposition over a mesh
axis: each device holds a contiguous block of rows; the paper's explicit
transpose steps become ``all_to_all`` collectives (this is the dominant
roofline term at pod scale — see EXPERIMENTS.md §Roofline).

    rows sharded (N/p, N) --local row FFT-->
    --all_to_all (split cols, concat rows) + local transpose-->
    cols sharded (N/p, N) --local row FFT (== column FFT)-->
    --all_to_all back + local transpose--> rows sharded, transformed.

Padding adaptation on TPU: the *local FFT length* is padded to an FPM-chosen
fast size (smooth / lane-aligned).  Two variants:

  * ``padded='crop'``  — the paper's PFFT-FPM-PAD semantics (padded-signal
    DFT cropped to N bins; spectral interpolation);
  * ``padded='czt'``   — exact N-point DFT via Bluestein at the padded
    length (beyond-paper, exactness preserved).

Uneven (HPOPTA) distributions across *heterogeneous device groups* are
realised block-ragged: the row axis is split into ``p`` equal SPMD shards,
but the FPM distribution decides how many of each shard's rows are real
work vs. masked padding; see ``ragged_row_layout``.
"""

from __future__ import annotations

import functools
from typing import Literal

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.padding import pad_to_smooth
from repro.core.pfft import czt_dft
from repro.fft.fft2d import fft_rows

__all__ = ["pfft2_distributed", "make_pfft2_fn", "ragged_row_layout"]


def _local_phase(block: jnp.ndarray, axis_name: str, n: int, *,
                 padded: str | None, pad_len: int, use_stockham: bool,
                 backend: str | None = None) -> jnp.ndarray:
    """One (row FFT -> distributed transpose) phase on a local block.

    block: (n_loc, N) — this device's rows.  Returns (n_loc, N): this
    device's block of the *transposed, row-transformed* matrix.
    """
    if padded == "czt":
        block = czt_dft(block, pad_len)
    elif padded == "crop" and pad_len > n:
        block = jnp.pad(block, ((0, 0), (0, pad_len - n)))
        block = fft_rows(block, use_stockham=use_stockham,
                         backend=backend)[:, :n]
    else:
        block = fft_rows(block, use_stockham=use_stockham, backend=backend)
    # Distributed transpose: exchange column panels between devices, then
    # transpose locally.  tiled all_to_all: split axis 1 into p panels, each
    # device keeps panel j from every peer, concatenated along axis 0.
    gathered = jax.lax.all_to_all(block, axis_name, split_axis=1, concat_axis=0,
                                  tiled=True)  # (N, N/p)
    return gathered.T  # (N/p, N): a row-block of M^T


def pfft2_distributed(
    m: jnp.ndarray,
    mesh: Mesh,
    axis_name: str = "fft",
    *,
    padded: Literal["crop", "czt", None] = None,
    pad_len: int | None = None,
    use_stockham: bool = False,
    backend: str | None = None,
) -> jnp.ndarray:
    """Distributed 2-D DFT of a square matrix sharded by rows over ``axis_name``.

    ``pad_len``: FPM-chosen local FFT length (defaults to the model-free
    smooth size for 'crop', next pow2 >= 2N-1 for 'czt').
    """
    n = m.shape[0]
    p = mesh.shape[axis_name]
    if n % p:
        raise ValueError(f"N={n} must be divisible by mesh axis {axis_name}={p}")
    if pad_len is None:
        if padded == "crop":
            pad_len = pad_to_smooth(n)
        elif padded == "czt":
            pad_len = 1 << int(np.ceil(np.log2(2 * n - 1)))
        else:
            pad_len = n

    spec_rows = P(axis_name, None)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec_rows,), out_specs=spec_rows,
        check_rep=False,
    )
    def _run(block):
        # Phase 1: row FFTs + distributed transpose.
        block = _local_phase(block, axis_name, n, padded=padded,
                             pad_len=pad_len, use_stockham=use_stockham,
                             backend=backend)
        # Phase 2: (original-)column FFTs + distributed transpose back.
        block = _local_phase(block, axis_name, n, padded=padded,
                             pad_len=pad_len, use_stockham=use_stockham,
                             backend=backend)
        return block

    return _run(m)


def make_pfft2_fn(mesh: Mesh, n: int, axis_name: str = "fft", **kw):
    """jit-compiled distributed 2-D DFT closed over a mesh (sharded in/out)."""
    sharding = NamedSharding(mesh, P(axis_name, None))
    fn = functools.partial(pfft2_distributed, mesh=mesh, axis_name=axis_name, **kw)
    return jax.jit(fn, in_shardings=(sharding,), out_shardings=sharding)


def ragged_row_layout(d: np.ndarray, p: int) -> tuple[int, np.ndarray]:
    """Block-ragged realisation of an uneven HPOPTA distribution under SPMD.

    SPMD shards must be equal-sized, so each of the ``p`` groups gets a
    buffer of ``max(d)`` rows; group i's valid-row count is d[i] and the
    remainder is masked padding.  Returns (rows_per_shard, valid_counts).
    The waste max(d)*p - sum(d) is the price of SPMD on *homogeneous* pods —
    on heterogeneous fleets (where d is uneven because speeds genuinely
    differ) the time saved dominates; see DESIGN.md §2.
    """
    d = np.asarray(d, dtype=np.int64)
    if len(d) != p:
        raise ValueError("distribution length must equal group count")
    return int(d.max()), d.copy()
