"""POPTA / HPOPTA data-partitioning algorithms (paper Step 1, Alg. 2).

Given discrete speed functions of ``p`` abstract processors and a workload of
``n`` rows, find an integer distribution ``d`` (sum = n) minimising the
parallel execution time ``max_i t_i(d_i)``.  Because the time functions are
arbitrary discrete profiles (non-monotonic, non-convex — that is the whole
point of the paper), the optimum may be *load-imbalanced*.

Algorithmic contract follows Lastovetsky & Reddy (POPTA, homogeneous —
identical speed functions) and Khaleghzadeh et al. (HPOPTA, heterogeneous).
We implement the min-max partition exactly:

  * candidate makespans tau are the values of the time curves;
  * binary search for the smallest feasible tau;
  * feasibility of tau = subset-sum reachability over the per-processor
    allowed sets {x : t_i(x) <= tau}, computed with FFT convolutions of 0/1
    indicator vectors (O(p * n log n) per check);
  * backtracking recovers a witness distribution, preferring assignments with
    smaller predicted time (secondary objective).

This is exact on the per-row-granularity time curves produced by
``SpeedFunction.time_curve`` (linear interpolation between FPM sample points,
which is also what the original works assume between measured points).
"""

from __future__ import annotations

import dataclasses

import numpy as np

try:  # scipy is optional; np.convolve fallback below is fine for small n
    from scipy.signal import fftconvolve as _fftconvolve
except Exception:  # pragma: no cover
    _fftconvolve = None

from repro.core.fpm import FPMSet, SpeedFunction

__all__ = [
    "PartitionResult",
    "popta",
    "hpopta",
    "lb_partition",
    "partition_rows",
]


@dataclasses.dataclass
class PartitionResult:
    d: np.ndarray  # (p,) int64 distribution, sum == n
    tau: float  # predicted makespan max_i t_i(d_i)
    method: str  # "POPTA" | "HPOPTA" | "LB"
    predicted_times: np.ndarray  # (p,) per-processor predicted times

    def __post_init__(self) -> None:
        self.d = np.asarray(self.d, dtype=np.int64)
        self.predicted_times = np.asarray(self.predicted_times, dtype=np.float64)


def _conv01(a: np.ndarray, b: np.ndarray, n: int) -> np.ndarray:
    """Boolean 'sum reachability' convolution, truncated to length n+1."""
    if _fftconvolve is not None and len(a) * len(b) > 1 << 16:
        c = _fftconvolve(a.astype(np.float64), b.astype(np.float64))[: n + 1]
        return c > 0.5
    c = np.convolve(a.astype(np.float64), b.astype(np.float64))[: n + 1]
    return c > 0.5


def _feasible(time_curves: list[np.ndarray], n: int, tau: float, keep: bool = False):
    """Is there d (sum=n) with t_i(d_i) <= tau for all i?  Optionally keep
    the per-prefix reach arrays for backtracking."""
    reach = np.zeros(n + 1, dtype=bool)
    reach[0] = True
    prefixes = [reach.copy()] if keep else None
    for t in time_curves:
        allowed = (t <= tau).astype(np.float64)
        if not allowed.any():
            return (False, None) if keep else False
        reach = _conv01(reach, allowed, n)
        if keep:
            prefixes.append(reach.copy())
        if not reach.any():
            return (False, None) if keep else False
    ok = bool(reach[n])
    return (ok, prefixes) if keep else ok


def hpopta(time_curves: list[np.ndarray], n: int) -> PartitionResult:
    """Exact heterogeneous min-max partition of n rows over p processors.

    ``time_curves[i]`` has length n+1; entry x is the predicted time of
    assigning x rows to processor i (entry 0 must be 0; inf = infeasible).
    """
    p = len(time_curves)
    curves = [np.asarray(t, dtype=np.float64) for t in time_curves]
    for t in curves:
        if len(t) != n + 1:
            raise ValueError("each time curve must have length n+1")
        if t[0] != 0.0:
            raise ValueError("t(0) must be 0")

    cand = np.unique(np.concatenate([t[np.isfinite(t)] for t in curves]))
    cand = cand[cand >= 0.0]
    if len(cand) == 0:
        raise ValueError("no finite time values — cannot partition")

    # Binary search the smallest feasible candidate makespan.
    lo, hi = 0, len(cand) - 1
    if not _feasible(curves, n, float(cand[hi])):
        raise ValueError("workload infeasible even at max tau (all-inf curves?)")
    while lo < hi:
        mid = (lo + hi) // 2
        if _feasible(curves, n, float(cand[mid])):
            hi = mid
        else:
            lo = mid + 1
    tau = float(cand[lo])

    _, prefixes = _feasible(curves, n, tau, keep=True)
    # Backtrack: walk processors in reverse, picking for each an allowed x
    # such that the remaining sum stays reachable by the prefix before it.
    d = np.zeros(p, dtype=np.int64)
    rem = n
    for i in range(p - 1, -1, -1):
        t = curves[i]
        xs = np.arange(rem + 1)
        ok = (t[: rem + 1] <= tau) & prefixes[i][rem - xs]
        if not ok.any():  # pragma: no cover — cannot happen if feasible
            raise RuntimeError("backtracking failed")
        ok_xs = xs[ok]
        # Secondary objective: among feasible choices, smallest predicted time.
        d[i] = int(ok_xs[np.argmin(t[ok_xs])])
        rem -= int(d[i])
    assert rem == 0
    times = np.array([curves[i][d[i]] for i in range(p)])
    return PartitionResult(d=d, tau=tau, method="HPOPTA", predicted_times=times)


def popta(time_curve: np.ndarray, p: int, n: int) -> PartitionResult:
    """Homogeneous case: one (averaged) time curve shared by all p processors."""
    res = hpopta([time_curve] * p, n)
    return PartitionResult(d=res.d, tau=res.tau, method="POPTA",
                           predicted_times=res.predicted_times)


def lb_partition(n: int, p: int) -> PartitionResult:
    """PFFT-LB distribution: rows split as evenly as possible."""
    base, extra = divmod(n, p)
    d = np.full(p, base, dtype=np.int64)
    d[:extra] += 1
    return PartitionResult(d=d, tau=float("nan"), method="LB",
                           predicted_times=np.full(p, np.nan))


def partition_rows(n: int, fpms: FPMSet, eps: float, y: int | None = None) -> PartitionResult:
    """Paper Algorithm 2 (PARTITION).

    Sections the speed functions by the plane y = N; if the max pointwise
    variation exceeds ``eps`` the functions are heterogeneous -> HPOPTA, else
    the harmonic-average function is built and POPTA is used.
    """
    y = n if y is None else y
    variation = fpms.max_variation_at_plane(y)
    if variation > eps:
        curves = [f.time_curve(n, y) for f in fpms]
        return hpopta(curves, n)
    avg: SpeedFunction = fpms.averaged()
    return popta(avg.time_curve(n, y), fpms.p, n)
