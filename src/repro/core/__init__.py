"""The paper's primary contribution: FPMs, POPTA/HPOPTA partitioning,
padding selection, and the PFFT-LB / PFFT-FPM / PFFT-FPM-PAD algorithms."""

from repro.core.fpm import SpeedFunction, FPMSet, build_fpm, save_fpms, load_fpms, fft_flops
from repro.core.partition import PartitionResult, popta, hpopta, lb_partition, partition_rows
from repro.core.padding import determine_pad_length, smooth_candidates, pad_to_smooth, is_smooth
from repro.core.pfft import (pfft_lb, pfft_fpm, pfft_fpm_pad, pfft_fpm_czt,
                             czt_dft, segment_row_ffts, plan_segment_batches,
                             rpfft_lb, rpfft_fpm, rpfft_fpm_pad,
                             halfspec_distribution, segment_row_rffts)
from repro.core.api import (plan_pfft, PfftPlan, rfft2, irfft2,
                            plan_pfft3, Pfft3Plan,
                            plan_pfft1_large, Pfft1LargePlan, pfft1_large)
from repro.core.pfft3d import (pfft3_lb, pfft3_fpm, pfft3_fpm_pad,
                               pfft3_distributed, pfft3_pencil, pfft3_slab)
from repro.core.pfft_large import four_step_factors, pfft1_large_apply
from repro.plan.config import PlanConfig

__all__ = [
    "SpeedFunction", "FPMSet", "build_fpm", "save_fpms", "load_fpms", "fft_flops",
    "PartitionResult", "popta", "hpopta", "lb_partition", "partition_rows",
    "determine_pad_length", "smooth_candidates", "pad_to_smooth", "is_smooth",
    "pfft_lb", "pfft_fpm", "pfft_fpm_pad", "pfft_fpm_czt", "czt_dft",
    "segment_row_ffts", "plan_segment_batches",
    "rpfft_lb", "rpfft_fpm", "rpfft_fpm_pad",
    "halfspec_distribution", "segment_row_rffts",
    "plan_pfft", "PfftPlan", "rfft2", "irfft2", "PlanConfig",
    "plan_pfft3", "Pfft3Plan",
    "plan_pfft1_large", "Pfft1LargePlan", "pfft1_large",
    "pfft3_lb", "pfft3_fpm", "pfft3_fpm_pad", "pfft3_distributed",
    "pfft3_pencil", "pfft3_slab",
    "four_step_factors", "pfft1_large_apply",
]
