"""Plan-style user API (mirrors fftw's plan/execute/wisdom lifecycle).

    plan = plan_pfft(n=4096, fpms=fpms, method="fpm-pad", tune="estimate")
    out  = plan.execute(signal)     # jit-compiled, reusable

The plan captures everything host-side once — the partition ``d``, the pad
lengths, *and* the execution schedule (``SegmentSchedule``: one
``PlanConfig`` per segment, so a slow processor can keep the library FFT
while pow2-padded fast ones take the kernel) — so ``execute`` is a pure
jitted function: the analogue of building an fftw plan once and calling
``fftw_execute`` repeatedly (the only thread-safe op, as the paper notes
in §IV).  A single explicit ``config=`` becomes the degenerate
one-entry-per-segment schedule, keeping the PR-2 API a thin shim.

``tune`` selects how the variant is chosen (fftw's ESTIMATE/MEASURE):

* ``"off"`` — the default config (library FFT, batched dispatch), or an
  explicit ``config=``/legacy flags.
* ``"estimate"`` — rank the candidate space with the cost model
  (``repro.plan.cost``), per distinct effective FFT length
  (``tune_schedule``); no device work.
* ``"measure"`` — additionally time the Pareto top-k candidates per
  length group on device.

``wisdom=path`` consults/feeds the persistent store (``repro.plan.wisdom``)
keyed by (n, dtype, p, method, backend): a hit skips tuning entirely, and
a measured choice is recorded so fresh processes are served from disk.
When the store holds enough measured entries, the estimate cost model is
re-calibrated from them (``repro.plan.calibrate``) before ranking.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, Literal

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fpm import FPMSet
from repro.core.partition import PartitionResult, lb_partition, partition_rows
from repro.core.pfft import _pfft_limb
from repro.plan.calibrate import fit_cost_params
from repro.plan.config import PlanConfig, normalize_pad
from repro.plan.schedule import SegmentSchedule
from repro.plan.tune import dist_panel_space, tune_dist_schedule, tune_schedule
from repro.plan.wisdom import (lookup_wisdom, partition_digest, record_wisdom,
                               topology_digest, wisdom_key)

Method = Literal["lb", "fpm", "fpm-pad", "fpm-czt",
                 "rfft-lb", "rfft-fpm", "rfft-fpm-pad"]
TuneMode = Literal["off", "estimate", "measure"]

_PAD_STRATEGY = {"lb": "none", "fpm": "none", "fpm-pad": "fpm",
                 "fpm-czt": "czt",
                 "rfft-lb": "none", "rfft-fpm": "none", "rfft-fpm-pad": "fpm"}

# The real-input half-spectrum pipeline: same partition/pad machinery as
# the base method (the name after the ``rfft-`` prefix), but the plan
# transforms a real (N, N) signal into its (N, N//2+1) half spectrum and
# the tuner races the real pipeline against the upcast-and-crop complex
# fallback — the winning family is recorded in the schedule's ``real``
# flags and the executor routes on them.  No ``rfft-fpm-czt``: the real
# pipeline has no Bluestein form.
_REAL_METHODS = frozenset({"rfft-lb", "rfft-fpm", "rfft-fpm-pad"})

__all__ = ["PfftPlan", "plan_pfft", "rfft2", "irfft2",
           "Pfft3Plan", "plan_pfft3",
           "Pfft1LargePlan", "plan_pfft1_large", "pfft1_large"]


def _base_method(method: Method) -> str:
    """The partitioning family a method uses: ``rfft-fpm-pad`` pads and
    partitions exactly like ``fpm-pad``; the prefix only changes what the
    transform delivers."""
    return method[5:] if method in _REAL_METHODS else method


def _ctype_for(dtype: str) -> str:
    return "complex128" if np.dtype(dtype) == np.dtype(np.float64) \
        else "complex64"


def _build_raw(n: int, method: Method, d: np.ndarray,
               schedule: SegmentSchedule, mesh, axis_name: str,
               dtype: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """The un-jitted executor for a resolved schedule.

    Shared by ``plan_pfft`` and ``PfftPlan.with_schedule`` so a hot-swap
    routes identically to the original plan.  Real methods route on the
    *winning family*: a ``real``-flagged schedule runs the half-spectrum
    pipeline, a complex-family winner upcasts and crops to the same
    (N, N//2+1) deliverable.
    """
    if method in _REAL_METHODS:
        nh = n // 2 + 1
        ctype = _ctype_for(dtype)
        if mesh is not None:
            if schedule.anchor_config.real:
                from repro.core.pfft_dist import rpfft2_distributed

                def raw(m):
                    return rpfft2_distributed(m, mesh, axis_name,
                                              schedule=schedule)
            else:
                from repro.core.pfft_dist import pfft2_distributed

                def raw(m):
                    return pfft2_distributed(m.astype(ctype), mesh,
                                             axis_name,
                                             schedule=schedule)[:, :nh]
        elif schedule.anchor_config.real:
            from repro.core.pfft import _rpfft_limb

            def raw(m):
                return _rpfft_limb(m, d, schedule=schedule)
        else:
            def raw(m):
                return _pfft_limb(m.astype(ctype), d,
                                  schedule=schedule)[:, :nh]
        return raw
    if mesh is not None:
        from repro.core.pfft_dist import pfft2_distributed

        def raw(m):
            # The full schedule, not just its anchor config: this is what
            # routes heterogeneous picks to the device-group program (and
            # per-device FPM pad lengths to the uniform-length rule).
            return pfft2_distributed(m, mesh, axis_name, schedule=schedule)
    else:
        def raw(m):
            return _pfft_limb(m, d, schedule=schedule)
    return raw


@dataclasses.dataclass
class PfftPlan:
    n: int
    method: Method
    partition: PartitionResult
    pad_lengths: np.ndarray | None
    config: PlanConfig
    schedule: SegmentSchedule
    tuning: dict[str, Any]
    _fn: Callable[[jnp.ndarray], jnp.ndarray]

    # Distributed plans carry their mesh so the plan can be *rebuilt*
    # against the same topology (the self-healing hot-swap path).
    mesh: Any = None
    axis_name: str = "fft"
    # The planned input dtype; real methods need it to rebuild the
    # upcast-and-crop fallback executor on a hot-swap.
    dtype: str = "complex64"
    _batched_fns: dict[int, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def execute(self, m: jnp.ndarray) -> jnp.ndarray:
        """Run the planned transform; leading batch dims are vmapped.

        ``m``: ``(..., n, n)``.  Batched wrappers are built (and jitted)
        once per batch rank and cached — execute stays the
        plan-once/run-many hot path.  Every method vmaps, czt included
        (its phases are ordinary jnp programs since the schedule
        executor took over the per-segment slicing).
        """
        if m.ndim < 2 or m.shape[-2:] != (self.n, self.n):
            raise ValueError(
                f"plan is for ({self.n}, {self.n}) signals "
                f"(optionally with leading batch dims), got {m.shape}")
        if m.ndim == 2:
            return self._fn(m)
        fn = self._batched_fns.get(m.ndim)
        if fn is None:
            fn = self._fn
            for _ in range(m.ndim - 2):
                fn = jax.vmap(fn)
            fn = jax.jit(fn)
            self._batched_fns[m.ndim] = fn
        return fn(m)

    def execute_many(self, ms, *, pad_to: int | None = None) -> list:
        """Serve a cohort: stack same-size signals into ONE batched dispatch.

        The serving layer's execution surface — ``ms`` is a sequence of
        ``(n, n)`` signals (many users' concurrent requests for the same
        transform), stacked onto a leading batch axis and run through
        ``execute``'s vmapped program, so the whole cohort costs one
        dispatch instead of ``len(ms)``.  Returns the per-request
        results in order.

        ``pad_to`` rounds the stacked batch up with zero signals before
        dispatch (the extras are computed and dropped): a serving loop
        that buckets its batch sizes to powers of two compiles one
        program per (plan, bucket) instead of one per distinct cohort
        size — jit specialises on shapes, and an unbucketed mixed
        stream would otherwise retrace on nearly every tick.

        Stacking, padding, and unstacking happen on the host (numpy),
        so the device sees exactly one transfer in and one out; the
        returned results are numpy views into the fetched batch.
        Per-item device slicing would cost a dispatch per request —
        the very overhead coalescing exists to amortise.
        """
        return _execute_many(self, ms, (self.n, self.n), pad_to)

    @property
    def d(self) -> np.ndarray:
        return self.partition.d

    def with_schedule(self, schedule: SegmentSchedule,
                      tuning: dict[str, Any] | None = None) -> "PfftPlan":
        """Same problem, new execution schedule: rebuild the jitted
        executor around ``schedule`` and return a fresh plan.

        This is the hot-swap primitive of the self-healing runtime
        (``repro.runtime.resilient``): an online re-plan produces a new
        ``SegmentSchedule`` (typically a device-group program that gives
        a degraded device different work) and the wrapper swaps it in at
        the next call boundary.  The swapped program lowers exactly like
        ``plan_pfft`` lowers — distributed plans re-enter
        ``pfft2_distributed`` on the captured mesh, single-host plans
        re-enter the limb on the captured partition (and real-method
        plans re-route on the swapped schedule's winning family).
        """
        raw = _build_raw(self.n, self.method, self.partition.d, schedule,
                         self.mesh, self.axis_name, self.dtype)
        return dataclasses.replace(
            self, schedule=schedule, config=schedule.anchor_config,
            tuning=dict(tuning) if tuning is not None else dict(self.tuning),
            _fn=jax.jit(raw), _batched_fns={})


def _resolve_schedule(n: int, method: Method, part: PartitionResult,
                      pads: np.ndarray | None, fpms: FPMSet | None,
                      tune: TuneMode, wisdom: str | None,
                      config: PlanConfig | None, dtype: str,
                      mesh=None, axis_name: str = "fft"
                      ) -> tuple[SegmentSchedule, dict[str, Any]]:
    """Pick the plan's execution schedule and say where it came from.

    Resolution order: explicit config > wisdom hit > tuner > default.
    A wisdom hit applies even at ``tune="off"`` — passing ``wisdom=path``
    *is* the request to use stored plans (FFTW reads wisdom regardless of
    planner rigor) — but only when the stored schedule still describes
    the current partition (a stale structure is a miss, never an error).
    ``tuning["source"]`` records which branch won — the CI smoke test
    asserts a warm wisdom file yields ``"wisdom"`` (no re-measure).

    With a ``mesh``, the plan is for ``pfft2_distributed``: the wisdom
    key gains the mesh's ``topology_digest`` (schema v3 — a plan measured
    on one topology is never served to another), the tuner is the
    distributed one (``tune_dist_schedule``: measure races finalists
    through the full all_to_all pipeline end to end on this mesh), and a
    measured pick is recorded with its comm sample so calibration can fit
    the interconnect constants.
    """
    pad_strategy = _PAD_STRATEGY[method]
    real = method in _REAL_METHODS

    def normalize(cfg: PlanConfig) -> PlanConfig:
        """The method owns the pad semantics: ``plan.config.normalize_pad``
        (shared with the algorithm entry points in ``core.pfft``, so an
        explicit ``PlanConfig()`` on fpm-czt still runs Bluestein and a
        drifted ``pad="czt"`` on fpm-pad still runs the paper's crop).
        Real methods also own the transform: an explicit config is
        real-flagged so the executor runs the half-spectrum pipeline
        (a tuner-chosen complex fallback keeps its own flag — that flag
        *is* the race's verdict)."""
        cfg = normalize_pad(cfg, pad_strategy)
        if real and not cfg.real:
            cfg = dataclasses.replace(cfg, real=True)
        return cfg

    tuning: dict[str, Any] = {"mode": tune}
    if config is not None:
        tuning["source"] = "explicit"
        return SegmentSchedule.homogeneous(normalize(config), n, part.d,
                                           pads), tuning

    # The lb partition is a function of (n, p); the FPM partitions (and
    # pad lengths) depend on the FPMSet and eps, so they digest into the
    # key — a different model must not be served another model's plan.
    # A mesh additionally digests its topology: a measured distributed
    # plan is a property of the pod it was timed on.
    detail = (partition_digest(part.d, pads)
              if _base_method(method) != "lb" else None)
    topo = panels = None
    if mesh is not None:
        panels = dist_panel_space(n, int(mesh.shape[axis_name]))
        topo = topology_digest(mesh, axis_name, panels=panels)
        tuning["topology"] = topo
    key = wisdom_key(n=n, dtype=dtype, p=len(part.d), method=method,
                     backend=jax.default_backend(), detail=detail,
                     topology=topo)
    tuning["wisdom_key"] = key
    if wisdom is not None:
        hit = lookup_wisdom(wisdom, key)
        if hit is not None:
            plan, entry = hit
            if isinstance(plan, SegmentSchedule):
                # Structure AND pad semantics must match: an entry whose
                # config pad drifted from the method's strategy would
                # execute the wrong transform (czt vs pad-and-crop), so
                # it is a miss like every other kind of drift.
                ok = (plan.matches(part.d, pads)
                      and all(e.config.pad == pad_strategy for e in plan))
                schedule = plan if ok else None
            else:
                schedule = SegmentSchedule.homogeneous(normalize(plan), n,
                                                       part.d, pads)
            if schedule is not None and mesh is not None:
                # A distributed plan must lower to one SPMD program —
                # heterogeneous mixes of the row-FFT variant group fine
                # (device-group programs), but a hand-edited or drifted
                # entry mixing program-level knobs is a miss.  The rows
                # mapping is already guaranteed by matches() above
                # (the even N/p split tiles every mesh).  A real-family
                # hit must additionally satisfy the real dist program's
                # shape (homogeneous, unfused, monolithic) — anything
                # ``rpfft2_distributed`` would refuse is a miss too.
                try:
                    if schedule.anchor_config.real:
                        from repro.core.pfft_dist import _validate_real_dist
                        _validate_real_dist(None, schedule)
                    else:
                        from repro.core.pfft_dist import \
                            validate_spmd_schedule
                        validate_spmd_schedule(schedule)
                except ValueError:
                    schedule = None
            if schedule is not None:
                tuning["source"] = "wisdom"
                tuning["wisdom_entry"] = entry
                return schedule, tuning

    if tune == "off":
        tuning["source"] = "off"
        return SegmentSchedule.homogeneous(
            PlanConfig(pad=pad_strategy, real=real), n, part.d,
            pads), tuning

    params = None
    if wisdom is not None:
        # Enough measured entries on this host re-fit the cost constants
        # (falls back to the hard-coded ones below the sample threshold).
        from repro.plan.cost import CostParams
        params = fit_cost_params(wisdom)
        tuning["calibrated"] = params != CostParams.for_backend()
    if real and mesh is not None:
        from repro.plan.tune import tune_rfft_dist
        schedule, info = tune_rfft_dist(
            n, mesh, axis_name, mode=tune, pad=pad_strategy, fpms=fpms,
            params=params, panels=panels, dtype=np.dtype(dtype))
    elif real:
        from repro.plan.tune import tune_rfft
        schedule, info = tune_rfft(n, d=part.d, pad_lengths=pads,
                                   fpms=fpms, mode=tune, pad=pad_strategy,
                                   params=params, dtype=np.dtype(dtype))
    elif mesh is not None:
        schedule, info = tune_dist_schedule(
            n, mesh, axis_name, pad_lengths=pads, mode=tune,
            pad=pad_strategy, fpms=fpms, params=params, panels=panels,
            dtype=np.dtype(dtype))
    else:
        schedule, info = tune_schedule(n, d=part.d, pad_lengths=pads,
                                       fpms=fpms, mode=tune,
                                       pad=pad_strategy, params=params,
                                       dtype=np.dtype(dtype))
    tuning.update(info)
    tuning["source"] = tune
    if wisdom is not None and tune == "measure":
        extra = None
        if mesh is not None:
            extra = {"topology": topo}
            dist = info.get("dist", {})
            if dist.get("comm_time_meas_s") is not None:
                extra["comm_bytes"] = dist["comm_bytes"]
                extra["comm_time_s"] = dist["comm_time_meas_s"]
            if dist.get("comm_samples"):
                extra["comm_samples"] = dist["comm_samples"]
            if int(dist.get("hosts", 1)) > 1:
                extra["hosts"] = int(dist["hosts"])
        record_wisdom(wisdom, key, schedule, mode="measure",
                      time_s=info.get("time_s"), extra=extra)
    return schedule, tuning


def plan_pfft(n: int, *, p: int | None = None, fpms: FPMSet | None = None,
              method: Method = "fpm", eps: float = 0.05,
              tune: TuneMode = "off", wisdom: str | None = None,
              config: PlanConfig | None = None, dtype: str = "complex64",
              mesh=None, axis_name: str = "fft",
              use_stockham: bool | None = None,
              fused: bool | None = None) -> PfftPlan:
    """Build a reusable plan; see the module docstring for the lifecycle.

    ``mesh=`` plans for ``pfft2_distributed`` over the given ``Mesh``
    instead of the single-host limb: the wisdom key gains the mesh's
    ``topology_digest``, ``tune="measure"`` times finalists through the
    full all_to_all pipeline end to end on that mesh, and ``execute``
    runs the distributed transform.  N must divide by the mesh axis
    size.  The padded FPM methods are planned too: SPMD shards rows
    evenly (one abstract processor per device, N/p rows each), so the
    FPMs drive *per-device pad lengths and execution variants* instead
    of row counts — plain ``method="fpm"`` is rejected (on an even
    split it would be byte-identical to ``"lb"``); heterogeneous picks
    lower as device-group programs
    (``repro.plan.groups``: per-shard ``lax.switch`` branches at the
    schedule's max effective length, the program-level analog of the
    ragged row layout) and persist under the same v3 topology keys.
    ``method="fpm-pad"``/``"fpm-czt"`` require ``fpms`` covering
    exactly the mesh axis (``fpms.p == p``).

    The ``rfft-*`` methods plan the *real-input* transform: ``execute``
    takes a real (N, N) signal (``dtype='float32'|'float64'`` required)
    and returns the (N, N//2+1) half spectrum — half the row FFTs (two
    real rows packed per complex transform) and, distributed, roughly
    half the all_to_all bytes.  The tuner races the real pipeline
    against the upcast-and-crop complex fallback and the plan routes on
    the winner; ``plan.tuning["chosen_path"]`` says which side won.

    ``use_stockham=``/``fused=`` are deprecated shims for the pre-planner
    flag API (they build an explicit config, so tuning is skipped).
    """
    if tune not in ("off", "estimate", "measure"):
        raise ValueError(f"tune must be 'off'|'estimate'|'measure', got {tune!r}")
    if method not in _PAD_STRATEGY:
        raise ValueError(f"unknown method {method!r}")
    real = method in _REAL_METHODS
    base = _base_method(method)
    kind = np.dtype(dtype).kind
    if real and kind != "f":
        raise ValueError(
            f"method={method!r} transforms real input; pass dtype='float32' "
            f"or 'float64' (got {dtype!r})")
    if not real and kind == "f":
        raise ValueError(
            f"method={method!r} transforms complex input (got dtype="
            f"{dtype!r}); use an 'rfft-*' method for real signals")
    if real and mesh is not None and base == "fpm-pad":
        raise ValueError(
            "the distributed real path runs the homogeneous unpadded "
            "program; use method='rfft-lb' with mesh=, or plan "
            "'rfft-fpm-pad' single-host")
    if mesh is not None:
        mesh_p = int(mesh.shape[axis_name])
        if p is None:
            p = mesh_p
        elif p != mesh_p:
            raise ValueError(f"p={p} conflicts with mesh axis "
                             f"{axis_name!r} size {mesh_p}")
        if n % p:
            raise ValueError(f"N={n} must be divisible by mesh axis "
                             f"{axis_name}={p}")
        if base == "fpm":
            raise ValueError(
                "plan_pfft(mesh=...) shards rows evenly, so plain "
                f"method={method!r} would run byte-identically to the 'lb' "
                "variant (its FPMs can only influence the *row* split, "
                "which SPMD fixes) — use the 'lb' variant, or "
                "'fpm-pad'/'fpm-czt' for FPM-driven per-device pads and "
                "execution variants")
        if base != "lb" and fpms is not None and fpms.p != p:
            raise ValueError(
                f"plan_pfft(mesh=...) assigns one abstract processor per "
                f"device: fpms covers {fpms.p} processors but the mesh "
                f"axis {axis_name!r} has {p} devices")
    if use_stockham is not None or fused is not None:
        if config is not None:
            raise ValueError("pass either config= or the legacy flags "
                             "(use_stockham/fused), not both")
        warnings.warn(
            "plan_pfft: use_stockham=/fused= are deprecated; pass "
            "config=PlanConfig(...) or let tune='estimate'|'measure' choose",
            DeprecationWarning, stacklevel=2)
        pad_strategy = _PAD_STRATEGY[method]
        # The pre-refactor API silently ignored fused= on the padded
        # methods (pad semantics are per-processor); the shim must too.
        config = PlanConfig.from_flags(
            use_stockham=bool(use_stockham),
            fused=bool(fused) and pad_strategy == "none",
            pad=pad_strategy)

    if base == "lb":
        if p is None:
            raise ValueError(f"method={method!r} requires p")
        part = lb_partition(n, p)
        pads = None
    else:
        if fpms is None:
            raise ValueError(f"method={method!r} requires fpms")
        if mesh is not None:
            # SPMD shards rows evenly — the FPMs drive per-device pad
            # lengths and execution variants, not row counts (the
            # device-group lowering's realisation of heterogeneity).
            part = lb_partition(n, p)
        else:
            part = partition_rows(n, fpms, eps)
        if base == "fpm-pad" and real:
            # Even pads only: the packed real row FFT transforms two rows
            # per complex FFT, and the half-spectrum crop identity holds
            # for any length >= n, so the model picks among even
            # beneficial lengths.
            from repro.plan.pads import rfft_pad_lengths
            pads = rfft_pad_lengths(fpms, part.d, n)
        elif base == "fpm-pad":
            from repro.plan.pads import fpm_pad_lengths
            pads = fpm_pad_lengths(fpms, part.d, n)
        elif base == "fpm-czt":
            from repro.plan.pads import czt_fft_lengths
            pads = czt_fft_lengths(fpms, part.d, n, limit_ratio=2.0)
        else:
            pads = None

    schedule, tuning = _resolve_schedule(n, method, part, pads, fpms, tune,
                                         wisdom, config, dtype,
                                         mesh=mesh, axis_name=axis_name)
    raw = _build_raw(n, method, part.d, schedule, mesh, axis_name, dtype)
    return PfftPlan(n=n, method=method, partition=part, pad_lengths=pads,
                    config=schedule.anchor_config, schedule=schedule,
                    tuning=tuning, _fn=jax.jit(raw), mesh=mesh,
                    axis_name=axis_name, dtype=dtype)


def rfft2(m: jnp.ndarray, *, p: int = 1, tune: TuneMode = "off",
          wisdom: str | None = None, mesh=None,
          axis_name: str = "fft") -> jnp.ndarray:
    """One-shot planned real-input 2-D DFT -> (N, N//2+1) half spectrum.

    Convenience wrapper: builds an ``rfft-lb`` plan for ``m``'s size and
    dtype and executes it once.  For the plan-once/run-many lifecycle
    (or the FPM methods) use ``plan_pfft(method='rfft-...')`` directly.
    """
    if m.ndim < 2 or m.shape[-1] != m.shape[-2]:
        raise ValueError(f"rfft2 plans square (N, N) signals, got {m.shape}")
    plan = plan_pfft(m.shape[-1], p=p, method="rfft-lb", tune=tune,
                     wisdom=wisdom, dtype=str(jnp.asarray(m).dtype),
                     mesh=mesh, axis_name=axis_name)
    return plan.execute(m)


def irfft2(h: jnp.ndarray, *, n: int | None = None) -> jnp.ndarray:
    """Inverse of ``rfft2``: half spectrum back to the real signal
    (``repro.fft.irfft2``; pass ``n`` for odd original lengths)."""
    from repro.fft.fft2d import irfft2 as _irfft2
    return _irfft2(h, n=n)


# ---------------------------------------------------------------------- 3-D

def _execute_many(plan, ms, shape: tuple[int, ...],
                  pad_to: int | None) -> list:
    """The shared cohort-stacking core of every plan's ``execute_many``:
    host-side stack (+ zero-pad to the bucket), one batched ``execute``,
    host-side unstack.  See ``PfftPlan.execute_many`` for why."""
    if not ms:
        return []
    arrs = [np.asarray(m) for m in ms]
    for m in arrs:
        if m.shape != shape:
            raise ValueError(
                f"execute_many stacks {shape} signals, got {m.shape}")
    batch = np.stack(arrs)
    b = len(arrs)
    if pad_to is not None and pad_to > b:
        batch = np.concatenate(
            [batch, np.zeros((pad_to - b,) + batch.shape[1:], batch.dtype)])
    out = np.asarray(plan.execute(batch))
    return [out[i] for i in range(b)]


@dataclasses.dataclass
class Pfft3Plan:
    """A planned 3-D transform — same plan/execute/wisdom lifecycle as
    ``PfftPlan``, for cubic N^3 signals.

    Distributed plans run the pencil pipeline (``pfft3_pencil``) on the
    captured 2-D mesh in the *tuned orientation* (which mesh axis plays
    row is a degree of freedom on rectangular meshes — see
    ``tune_pfft3``); single-host plans run ``pfft3_lb``'s axis passes.
    """
    n: int
    method: str
    config: PlanConfig
    tuning: dict[str, Any]
    _fn: Callable[[jnp.ndarray], jnp.ndarray]
    mesh: Any = None
    axis_names: tuple[str, str] | None = None
    dtype: str = "complex64"
    _batched_fns: dict[int, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def execute(self, m: jnp.ndarray) -> jnp.ndarray:
        """Run the planned transform; leading batch dims are vmapped
        (single-host plans only — the pencil program is already SPMD)."""
        if m.ndim < 3 or m.shape[-3:] != (self.n,) * 3:
            raise ValueError(
                f"plan is for ({self.n}, {self.n}, {self.n}) signals "
                f"(optionally with leading batch dims), got {m.shape}")
        if m.ndim == 3:
            return self._fn(m)
        if self.mesh is not None:
            raise ValueError(
                "distributed pfft3 plans transform one cube per call "
                "(vmapping over shard_map is not supported); loop instead")
        fn = self._batched_fns.get(m.ndim)
        if fn is None:
            fn = self._fn
            for _ in range(m.ndim - 3):
                fn = jax.vmap(fn)
            fn = jax.jit(fn)
            self._batched_fns[m.ndim] = fn
        return fn(m)

    def execute_many(self, ms, *, pad_to: int | None = None) -> list:
        """Serve a cohort of cubes in ONE batched dispatch — the 3-D
        sibling of ``PfftPlan.execute_many`` (same host-side stacking,
        zero-pad bucketing, and unstacking discipline)."""
        return _execute_many(self, ms, (self.n,) * 3, pad_to)


def plan_pfft3(n: int, *, p: int | None = None, mesh=None,
               axis_names: tuple[str, str] = ("fft_r", "fft_c"),
               tune: TuneMode = "off", wisdom: str | None = None,
               config: PlanConfig | None = None,
               dtype: str = "complex64") -> Pfft3Plan:
    """Plan the 3-D transform; see ``plan_pfft`` for the lifecycle.

    ``mesh=`` plans the pencil-parallel pipeline over a 2-D r x c mesh
    (both ``axis_names`` must exist on it; N must divide by both sizes):
    the wisdom key gains the mesh's 2-D ``topology_digest`` (schema v3 —
    '+'-joined per-axis terms, injective against 1-D and transposed
    meshes), ``tune="measure"`` races config x panel x *orientation*
    finalists through the full two-exchange pipeline end to end, and a
    measured winner persists with its orientation
    (``extra["pfft3_orientation"]``) so a second plan on the same mesh
    is served from disk with zero re-measurement.  Without a mesh the
    plan runs the single-host axis passes over an lb row partition of
    ``p`` segments (default 1).
    """
    if tune not in ("off", "estimate", "measure"):
        raise ValueError(f"tune must be 'off'|'estimate'|'measure', got {tune!r}")
    if np.dtype(dtype).kind != "c":
        raise ValueError(
            f"plan_pfft3 transforms complex input, got dtype={dtype!r}")
    from repro.core.pfft_dist import require_mesh_divisible
    from repro.plan.tune import pfft3_panel_space, tune_pfft3

    method = "pfft3-lb"
    axes0 = tuple(axis_names)
    if mesh is not None:
        if len(axes0) != 2:
            raise ValueError(
                f"plan_pfft3(mesh=...) needs two axis names, got {axes0!r}")
        r = int(mesh.shape[axes0[0]])
        c = int(mesh.shape[axes0[1]])
        require_mesh_divisible(n, r, axes0[0])
        require_mesh_divisible(n, c, axes0[1])
        q = r * c
        if p is not None and p != q:
            raise ValueError(f"p={p} conflicts with mesh {axes0[0]}x"
                             f"{axes0[1]} = {r}x{c} = {q} devices")
    else:
        # Single host: lb row partitions split unevenly by design, so any
        # 1 <= p <= n works (only the SPMD mesh path needs divisibility).
        r = c = 1
        q = int(p) if p is not None else 1
        if not 1 <= q <= n:
            raise ValueError(f"need 1 <= p <= N, got p={q} for N={n}")

    tuning: dict[str, Any] = {"mode": tune}
    axes: tuple[str, str] | None = axes0 if mesh is not None else None

    def build(cfg: PlanConfig, waxes) -> Pfft3Plan:
        if mesh is not None:
            from repro.core.pfft3d import pfft3_pencil
            raw = functools.partial(pfft3_pencil, mesh=mesh,
                                    axis_names=waxes, config=cfg)
        else:
            from repro.core.pfft3d import pfft3_lb
            raw = functools.partial(pfft3_lb, p=q, config=cfg)
        return Pfft3Plan(n=n, method=method, config=cfg, tuning=tuning,
                         _fn=jax.jit(raw), mesh=mesh, axis_names=waxes,
                         dtype=dtype)

    if config is not None:
        tuning["source"] = "explicit"
        return build(normalize_pad(config, "none"), axes)

    panels = pfft3_panel_space(n, r, c) if mesh is not None else (1,)
    topo = None
    if mesh is not None:
        topo = topology_digest(mesh, axes0, panels=panels)
        tuning["topology"] = topo
    key = wisdom_key(n=n, dtype=dtype, p=q, method=method,
                     backend=jax.default_backend(), topology=topo)
    tuning["wisdom_key"] = key
    if wisdom is not None:
        hit = lookup_wisdom(wisdom, key)
        if hit is not None:
            plan, entry = hit
            ok = isinstance(plan, PlanConfig)  # pencil plans are configs
            waxes = axes
            if ok and mesh is not None:
                stored = entry.get("pfft3_orientation")
                if stored is not None:
                    waxes = tuple(stored)
                    # Drifted orientation names are a miss, not an error.
                    ok = sorted(waxes) == sorted(axes0)
            if ok:
                tuning["source"] = "wisdom"
                tuning["wisdom_entry"] = entry
                return build(normalize_pad(plan, "none"), waxes)

    if tune == "off":
        tuning["source"] = "off"
        return build(PlanConfig(), axes)

    cfg, waxes, info = tune_pfft3(
        n, mesh, axes0 if mesh is not None else ("fft_r", "fft_c"),
        mode=tune, panels=panels if mesh is not None else None,
        dtype=np.dtype(dtype))
    tuning.update(info)
    tuning["source"] = tune
    if wisdom is not None and tune == "measure":
        extra: dict[str, Any] = {}
        if topo is not None:
            extra["topology"] = topo
        if waxes is not None:
            extra["pfft3_orientation"] = list(waxes)
        stats = info.get("pfft3", {})
        if stats.get("comm_time_meas_s") is not None:
            extra["comm_bytes"] = stats["comm_bytes"]
            extra["comm_time_s"] = stats["comm_time_meas_s"]
        if int(stats.get("hosts", 1)) > 1:
            extra["hosts"] = int(stats["hosts"])
        record_wisdom(wisdom, key, cfg, mode="measure",
                      time_s=info.get("time_s"), extra=extra or None)
    return build(cfg, waxes if mesh is not None else None)


# ------------------------------------------------------------------ huge 1-D

@dataclasses.dataclass
class Pfft1LargePlan:
    """A planned four-step huge-1-D transform (``core.pfft_large``)."""
    n: int
    n1: int
    n2: int
    method: str
    config: PlanConfig
    tuning: dict[str, Any]
    _fn: Callable[[jnp.ndarray], jnp.ndarray]
    dtype: str = "complex64"
    _batched_fns: dict[int, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def execute(self, x: jnp.ndarray) -> jnp.ndarray:
        """Run the planned transform; leading batch dims are vmapped."""
        if x.ndim < 1 or int(x.shape[-1]) != self.n:
            raise ValueError(
                f"plan is for length-{self.n} 1-D signals "
                f"(optionally with leading batch dims), got {x.shape}")
        if x.ndim == 1:
            return self._fn(x)
        fn = self._batched_fns.get(x.ndim)
        if fn is None:
            fn = self._fn
            for _ in range(x.ndim - 1):
                fn = jax.vmap(fn)
            fn = jax.jit(fn)
            self._batched_fns[x.ndim] = fn
        return fn(x)

    def execute_many(self, xs, *, pad_to: int | None = None) -> list:
        """Serve a cohort of lines in ONE batched dispatch — the 1-D
        sibling of ``PfftPlan.execute_many``."""
        return _execute_many(self, xs, (self.n,), pad_to)


def plan_pfft1_large(n: int, *, tune: TuneMode = "off",
                     wisdom: str | None = None,
                     config: PlanConfig | None = None,
                     dtype: str = "complex64", n1: int | None = None,
                     n2: int | None = None) -> Pfft1LargePlan:
    """Plan one huge 1-D line through the EFFT four-step pipeline.

    ``n1``/``n2`` pin the factorization (default: most-square split —
    ``four_step_factors``); a non-default split enters the wisdom key as
    a ``part=`` detail, since the best row-FFT variant depends on which
    lengths the two phases actually run at.
    """
    if tune not in ("off", "estimate", "measure"):
        raise ValueError(f"tune must be 'off'|'estimate'|'measure', got {tune!r}")
    if np.dtype(dtype).kind != "c":
        raise ValueError(
            f"plan_pfft1_large transforms complex input, got dtype={dtype!r}")
    from repro.core.pfft_large import four_step_factors, pfft1_large_apply
    from repro.plan.tune import tune_pfft1_large

    method = "pfft1-large"
    f1, f2 = four_step_factors(n, n1=n1, n2=n2)
    default = four_step_factors(n)
    detail = f"{f1}x{f2}" if (f1, f2) != default else None

    tuning: dict[str, Any] = {"mode": tune, "n1": f1, "n2": f2}

    def build(cfg: PlanConfig) -> Pfft1LargePlan:
        raw = functools.partial(pfft1_large_apply, config=cfg, n1=f1, n2=f2)
        return Pfft1LargePlan(n=n, n1=f1, n2=f2, method=method, config=cfg,
                              tuning=tuning, _fn=jax.jit(raw), dtype=dtype)

    if config is not None:
        tuning["source"] = "explicit"
        return build(normalize_pad(config, "none"))

    key = wisdom_key(n=n, dtype=dtype, p=1, method=method,
                     backend=jax.default_backend(), detail=detail)
    tuning["wisdom_key"] = key
    if wisdom is not None:
        hit = lookup_wisdom(wisdom, key)
        if hit is not None:
            plan, entry = hit
            if isinstance(plan, PlanConfig):
                tuning["source"] = "wisdom"
                tuning["wisdom_entry"] = entry
                return build(normalize_pad(plan, "none"))

    if tune == "off":
        tuning["source"] = "off"
        return build(PlanConfig())

    cfg, info = tune_pfft1_large(n, n1=f1, n2=f2, mode=tune,
                                 dtype=np.dtype(dtype))
    tuning.update(info)
    tuning["source"] = tune
    if wisdom is not None and tune == "measure":
        record_wisdom(wisdom, key, cfg, mode="measure",
                      time_s=info.get("time_s"))
    return build(cfg)


def pfft1_large(x: jnp.ndarray, *, tune: TuneMode = "off",
                wisdom: str | None = None, n1: int | None = None,
                n2: int | None = None) -> jnp.ndarray:
    """One-shot planned four-step 1-D DFT of a long line.

    Convenience wrapper over ``plan_pfft1_large`` for ``x``'s length and
    dtype; use the plan directly for the plan-once/run-many lifecycle.
    """
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(
            f"pfft1_large transforms one 1-D line, got shape {x.shape}")
    dt = x.dtype if jnp.issubdtype(x.dtype, jnp.complexfloating) \
        else jnp.complex64
    plan = plan_pfft1_large(int(x.shape[0]), tune=tune, wisdom=wisdom,
                            dtype=str(np.dtype(dt)), n1=n1, n2=n2)
    return plan.execute(x.astype(dt))
