"""Plan-style user API (mirrors fftw's plan/execute/wisdom lifecycle).

    plan = plan_pfft(n=4096, fpms=fpms, method="fpm-pad", tune="estimate")
    out  = plan.execute(signal)     # jit-compiled, reusable

The plan captures everything host-side once — the partition ``d``, the pad
lengths, *and* the execution variant (``PlanConfig``: radix, fused,
batched, pad strategy) — so ``execute`` is a pure jitted function: the
analogue of building an fftw plan once and calling ``fftw_execute``
repeatedly (the only thread-safe op, as the paper notes in §IV).

``tune`` selects how the variant is chosen (fftw's ESTIMATE/MEASURE):

* ``"off"`` — the default config (library FFT, batched dispatch), or an
  explicit ``config=``/legacy flags.
* ``"estimate"`` — rank the candidate space with the cost model
  (``repro.plan.cost``); no device work.
* ``"measure"`` — additionally time the top-k candidates on device.

``wisdom=path`` consults/feeds the persistent store (``repro.plan.wisdom``)
keyed by (n, dtype, p, method, backend): a hit skips tuning entirely, and
a measured choice is recorded so fresh processes are served from disk.
"""

from __future__ import annotations

import dataclasses
import warnings
import zlib
from typing import Any, Callable, Literal

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fpm import FPMSet
from repro.core.partition import PartitionResult, lb_partition, partition_rows
from repro.core.pfft import _pfft_limb, czt_dft, _segments
from repro.plan.config import PlanConfig
from repro.plan.tune import tune_config
from repro.plan.wisdom import lookup_wisdom, record_wisdom, wisdom_key

Method = Literal["lb", "fpm", "fpm-pad", "fpm-czt"]
TuneMode = Literal["off", "estimate", "measure"]

_PAD_STRATEGY = {"lb": "none", "fpm": "none", "fpm-pad": "fpm", "fpm-czt": "czt"}

__all__ = ["PfftPlan", "plan_pfft"]


@dataclasses.dataclass
class PfftPlan:
    n: int
    method: Method
    partition: PartitionResult
    pad_lengths: np.ndarray | None
    config: PlanConfig
    tuning: dict[str, Any]
    _fn: Callable[[jnp.ndarray], jnp.ndarray]

    _batched_fns: dict[int, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def execute(self, m: jnp.ndarray) -> jnp.ndarray:
        """Run the planned transform; leading batch dims are vmapped.

        ``m``: ``(..., n, n)``.  The czt method builds its phases around
        axis-0 segment slicing, so it stays 2-D-only for now.  Batched
        wrappers are built (and jitted) once per batch rank and cached —
        execute stays the plan-once/run-many hot path.
        """
        if m.ndim < 2 or m.shape[-2:] != (self.n, self.n):
            raise ValueError(
                f"plan is for ({self.n}, {self.n}) signals "
                f"(optionally with leading batch dims), got {m.shape}")
        if m.ndim == 2:
            return self._fn(m)
        if self.method == "fpm-czt":
            raise ValueError(
                f"method='fpm-czt' plans execute one ({self.n}, {self.n}) "
                f"matrix at a time; got batched shape {m.shape}")
        fn = self._batched_fns.get(m.ndim)
        if fn is None:
            fn = self._fn
            for _ in range(m.ndim - 2):
                fn = jax.vmap(fn)
            fn = jax.jit(fn)
            self._batched_fns[m.ndim] = fn
        return fn(m)

    @property
    def d(self) -> np.ndarray:
        return self.partition.d


def _resolve_config(n: int, method: Method, part: PartitionResult,
                    pads: np.ndarray | None, fpms: FPMSet | None,
                    tune: TuneMode, wisdom: str | None,
                    config: PlanConfig | None, dtype: str
                    ) -> tuple[PlanConfig, dict[str, Any]]:
    """Pick the plan's execution variant and say where it came from.

    Resolution order: explicit config > wisdom hit > tuner > default.
    A wisdom hit applies even at ``tune="off"`` — passing ``wisdom=path``
    *is* the request to use stored plans (FFTW reads wisdom regardless of
    planner rigor).  ``tuning["source"]`` records which branch won — the
    CI smoke test asserts a warm wisdom file yields ``"wisdom"`` (no
    re-measure).
    """
    pad_strategy = _PAD_STRATEGY[method]
    tuning: dict[str, Any] = {"mode": tune}
    if config is not None:
        tuning["source"] = "explicit"
        return config, tuning
    if method == "fpm-czt":
        # The czt pipeline has a single execution shape today; its real
        # tunable (the per-processor FFT length) is already FPM-chosen.
        tuning["source"] = "fixed"
        return PlanConfig(pad="czt"), tuning

    # The lb partition is a function of (n, p); the FPM partitions (and
    # pad lengths) depend on the FPMSet and eps, so they digest into the
    # key — a different model must not be served another model's config.
    detail = None
    if method != "lb":
        raw = np.asarray(part.d, dtype=np.int64).tobytes()
        if pads is not None:
            raw += np.asarray(pads, dtype=np.int64).tobytes()
        detail = format(zlib.crc32(raw), "08x")
    key = wisdom_key(n=n, dtype=dtype, p=len(part.d), method=method,
                     backend=jax.default_backend(), detail=detail)
    tuning["wisdom_key"] = key
    if wisdom is not None:
        hit = lookup_wisdom(wisdom, key)
        if hit is not None:
            cfg, entry = hit
            tuning["source"] = "wisdom"
            tuning["wisdom_entry"] = entry
            return cfg, tuning

    if tune == "off":
        tuning["source"] = "off"
        return PlanConfig(pad=pad_strategy), tuning

    cfg, info = tune_config(n, d=part.d, pad_lengths=pads, fpms=fpms,
                            mode=tune, pad=pad_strategy,
                            dtype=np.dtype(dtype))
    tuning.update(info)
    tuning["source"] = tune
    if wisdom is not None and tune == "measure":
        record_wisdom(wisdom, key, cfg, mode="measure",
                      time_s=info.get("time_s"))
    return cfg, tuning


def plan_pfft(n: int, *, p: int | None = None, fpms: FPMSet | None = None,
              method: Method = "fpm", eps: float = 0.05,
              tune: TuneMode = "off", wisdom: str | None = None,
              config: PlanConfig | None = None, dtype: str = "complex64",
              use_stockham: bool | None = None,
              fused: bool | None = None) -> PfftPlan:
    """Build a reusable plan; see the module docstring for the lifecycle.

    ``use_stockham=``/``fused=`` are deprecated shims for the pre-planner
    flag API (they build an explicit config, so tuning is skipped).
    """
    if tune not in ("off", "estimate", "measure"):
        raise ValueError(f"tune must be 'off'|'estimate'|'measure', got {tune!r}")
    if use_stockham is not None or fused is not None:
        if config is not None:
            raise ValueError("pass either config= or the legacy flags "
                             "(use_stockham/fused), not both")
        warnings.warn(
            "plan_pfft: use_stockham=/fused= are deprecated; pass "
            "config=PlanConfig(...) or let tune='estimate'|'measure' choose",
            DeprecationWarning, stacklevel=2)
        pad_strategy = _PAD_STRATEGY[method]
        # The pre-refactor API silently ignored fused= on the padded
        # methods (pad semantics are per-processor); the shim must too.
        config = PlanConfig.from_flags(
            use_stockham=bool(use_stockham),
            fused=bool(fused) and pad_strategy == "none",
            pad=pad_strategy)

    if method == "lb":
        if p is None:
            raise ValueError("method='lb' requires p")
        part = lb_partition(n, p)
        pads = None
    else:
        if fpms is None:
            raise ValueError(f"method={method!r} requires fpms")
        part = partition_rows(n, fpms, eps)
        if method == "fpm-pad":
            from repro.plan.pads import fpm_pad_lengths
            pads = fpm_pad_lengths(fpms, part.d, n)
        elif method == "fpm-czt":
            from repro.plan.pads import czt_fft_lengths
            pads = czt_fft_lengths(fpms, part.d, n, limit_ratio=2.0)
        else:
            pads = None

    cfg, tuning = _resolve_config(n, method, part, pads, fpms, tune, wisdom,
                                  config, dtype)

    if method == "fpm-czt":
        segs = _segments(part.d)
        lens = pads

        def raw(m):
            def phase(mat):
                outs = [czt_dft(mat[lo:hi], int(lens[i]))
                        for i, (lo, hi) in enumerate(segs) if hi > lo]
                return jnp.concatenate(outs, axis=0)
            return phase(phase(m).T).T
    else:
        d = part.d
        pl = pads

        def raw(m):
            return _pfft_limb(m, d, pad_lengths=pl, config=cfg)

    return PfftPlan(n=n, method=method, partition=part, pad_lengths=pads,
                    config=cfg, tuning=tuning, _fn=jax.jit(raw))
