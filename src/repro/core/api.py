"""Plan-style user API (mirrors fftw's plan/execute lifecycle).

    plan = plan_pfft(n=4096, fpms=fpms, method="fpm-pad", eps=0.05)
    out  = plan.execute(signal)     # jit-compiled, reusable

The plan captures everything host-side (partition d, pad lengths) once, so
``execute`` is a pure jitted function — the analogue of building an fftw
plan once and calling ``fftw_execute`` repeatedly (the only thread-safe op,
as the paper notes in §IV).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fpm import FPMSet
from repro.core.padding import determine_pad_length
from repro.core.partition import PartitionResult, lb_partition, partition_rows
from repro.core.pfft import _pfft_limb, czt_dft, _segments
from repro.core.padding import smooth_candidates

Method = Literal["lb", "fpm", "fpm-pad", "fpm-czt"]

__all__ = ["PfftPlan", "plan_pfft"]


@dataclasses.dataclass
class PfftPlan:
    n: int
    method: Method
    partition: PartitionResult
    pad_lengths: np.ndarray | None
    _fn: Callable[[jnp.ndarray], jnp.ndarray]

    def execute(self, m: jnp.ndarray) -> jnp.ndarray:
        if m.shape != (self.n, self.n):
            raise ValueError(f"plan is for {self.n}x{self.n}, got {m.shape}")
        return self._fn(m)

    @property
    def d(self) -> np.ndarray:
        return self.partition.d


def plan_pfft(n: int, *, p: int | None = None, fpms: FPMSet | None = None,
              method: Method = "fpm", eps: float = 0.05,
              use_stockham: bool = False, fused: bool = False) -> PfftPlan:
    """``fused=True`` routes the unpadded limb phases through the fused
    FFT->transpose Pallas dispatch (see DESIGN.md §Fused pipeline)."""
    if method == "lb":
        if p is None:
            raise ValueError("method='lb' requires p")
        part = lb_partition(n, p)
        pads = None
    else:
        if fpms is None:
            raise ValueError(f"method={method!r} requires fpms")
        part = partition_rows(n, fpms, eps)
        if method == "fpm-pad":
            pads = np.array([determine_pad_length(fpms[i], int(part.d[i]), n)
                             for i in range(fpms.p)], dtype=np.int64)
        elif method == "fpm-czt":
            cands = smooth_candidates(2 * n - 1, limit_ratio=2.0)
            pads = np.array(
                [int(cands[int(np.argmin([fpms[i].time_at(max(int(part.d[i]), 1), int(c))
                                          for c in cands]))])
                 for i in range(fpms.p)], dtype=np.int64)
        else:
            pads = None

    if method == "fpm-czt":
        segs = _segments(part.d)
        lens = pads

        def raw(m):
            def phase(mat):
                outs = [czt_dft(mat[lo:hi], int(lens[i]))
                        for i, (lo, hi) in enumerate(segs) if hi > lo]
                return jnp.concatenate(outs, axis=0)
            return phase(phase(m).T).T
    else:
        d = part.d
        pl = pads

        def raw(m):
            return _pfft_limb(m, d, pad_lengths=pl, use_stockham=use_stockham,
                              fused=fused)

    return PfftPlan(n=n, method=method, partition=part, pad_lengths=pads,
                    _fn=jax.jit(raw))
