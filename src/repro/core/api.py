"""Plan-style user API (mirrors fftw's plan/execute/wisdom lifecycle).

    plan = plan_pfft(n=4096, fpms=fpms, method="fpm-pad", tune="estimate")
    out  = plan.execute(signal)     # jit-compiled, reusable

The plan captures everything host-side once — the partition ``d``, the pad
lengths, *and* the execution schedule (``SegmentSchedule``: one
``PlanConfig`` per segment, so a slow processor can keep the library FFT
while pow2-padded fast ones take the kernel) — so ``execute`` is a pure
jitted function: the analogue of building an fftw plan once and calling
``fftw_execute`` repeatedly (the only thread-safe op, as the paper notes
in §IV).  A single explicit ``config=`` becomes the degenerate
one-entry-per-segment schedule, keeping the PR-2 API a thin shim.

``tune`` selects how the variant is chosen (fftw's ESTIMATE/MEASURE):

* ``"off"`` — the default config (library FFT, batched dispatch), or an
  explicit ``config=``/legacy flags.
* ``"estimate"`` — rank the candidate space with the cost model
  (``repro.plan.cost``), per distinct effective FFT length
  (``tune_schedule``); no device work.
* ``"measure"`` — additionally time the Pareto top-k candidates per
  length group on device.

``wisdom=path`` consults/feeds the persistent store (``repro.plan.wisdom``)
keyed by (n, dtype, p, method, backend): a hit skips tuning entirely, and
a measured choice is recorded so fresh processes are served from disk.
When the store holds enough measured entries, the estimate cost model is
re-calibrated from them (``repro.plan.calibrate``) before ranking.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Literal

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fpm import FPMSet
from repro.core.partition import PartitionResult, lb_partition, partition_rows
from repro.core.pfft import _pfft_limb
from repro.plan.calibrate import fit_cost_params
from repro.plan.config import PlanConfig, normalize_pad
from repro.plan.schedule import SegmentSchedule
from repro.plan.tune import dist_panel_space, tune_dist_schedule, tune_schedule
from repro.plan.wisdom import (lookup_wisdom, partition_digest, record_wisdom,
                               topology_digest, wisdom_key)

Method = Literal["lb", "fpm", "fpm-pad", "fpm-czt"]
TuneMode = Literal["off", "estimate", "measure"]

_PAD_STRATEGY = {"lb": "none", "fpm": "none", "fpm-pad": "fpm", "fpm-czt": "czt"}

__all__ = ["PfftPlan", "plan_pfft"]


@dataclasses.dataclass
class PfftPlan:
    n: int
    method: Method
    partition: PartitionResult
    pad_lengths: np.ndarray | None
    config: PlanConfig
    schedule: SegmentSchedule
    tuning: dict[str, Any]
    _fn: Callable[[jnp.ndarray], jnp.ndarray]

    # Distributed plans carry their mesh so the plan can be *rebuilt*
    # against the same topology (the self-healing hot-swap path).
    mesh: Any = None
    axis_name: str = "fft"
    _batched_fns: dict[int, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def execute(self, m: jnp.ndarray) -> jnp.ndarray:
        """Run the planned transform; leading batch dims are vmapped.

        ``m``: ``(..., n, n)``.  Batched wrappers are built (and jitted)
        once per batch rank and cached — execute stays the
        plan-once/run-many hot path.  Every method vmaps, czt included
        (its phases are ordinary jnp programs since the schedule
        executor took over the per-segment slicing).
        """
        if m.ndim < 2 or m.shape[-2:] != (self.n, self.n):
            raise ValueError(
                f"plan is for ({self.n}, {self.n}) signals "
                f"(optionally with leading batch dims), got {m.shape}")
        if m.ndim == 2:
            return self._fn(m)
        fn = self._batched_fns.get(m.ndim)
        if fn is None:
            fn = self._fn
            for _ in range(m.ndim - 2):
                fn = jax.vmap(fn)
            fn = jax.jit(fn)
            self._batched_fns[m.ndim] = fn
        return fn(m)

    @property
    def d(self) -> np.ndarray:
        return self.partition.d

    def with_schedule(self, schedule: SegmentSchedule,
                      tuning: dict[str, Any] | None = None) -> "PfftPlan":
        """Same problem, new execution schedule: rebuild the jitted
        executor around ``schedule`` and return a fresh plan.

        This is the hot-swap primitive of the self-healing runtime
        (``repro.runtime.resilient``): an online re-plan produces a new
        ``SegmentSchedule`` (typically a device-group program that gives
        a degraded device different work) and the wrapper swaps it in at
        the next call boundary.  The swapped program lowers exactly like
        ``plan_pfft`` lowers — distributed plans re-enter
        ``pfft2_distributed`` on the captured mesh, single-host plans
        re-enter the limb on the captured partition.
        """
        if self.mesh is not None:
            from repro.core.pfft_dist import pfft2_distributed
            mesh, axis_name = self.mesh, self.axis_name

            def raw(m):
                return pfft2_distributed(m, mesh, axis_name,
                                         schedule=schedule)
        else:
            d = self.partition.d

            def raw(m):
                return _pfft_limb(m, d, schedule=schedule)

        return dataclasses.replace(
            self, schedule=schedule, config=schedule.anchor_config,
            tuning=dict(tuning) if tuning is not None else dict(self.tuning),
            _fn=jax.jit(raw), _batched_fns={})


def _resolve_schedule(n: int, method: Method, part: PartitionResult,
                      pads: np.ndarray | None, fpms: FPMSet | None,
                      tune: TuneMode, wisdom: str | None,
                      config: PlanConfig | None, dtype: str,
                      mesh=None, axis_name: str = "fft"
                      ) -> tuple[SegmentSchedule, dict[str, Any]]:
    """Pick the plan's execution schedule and say where it came from.

    Resolution order: explicit config > wisdom hit > tuner > default.
    A wisdom hit applies even at ``tune="off"`` — passing ``wisdom=path``
    *is* the request to use stored plans (FFTW reads wisdom regardless of
    planner rigor) — but only when the stored schedule still describes
    the current partition (a stale structure is a miss, never an error).
    ``tuning["source"]`` records which branch won — the CI smoke test
    asserts a warm wisdom file yields ``"wisdom"`` (no re-measure).

    With a ``mesh``, the plan is for ``pfft2_distributed``: the wisdom
    key gains the mesh's ``topology_digest`` (schema v3 — a plan measured
    on one topology is never served to another), the tuner is the
    distributed one (``tune_dist_schedule``: measure races finalists
    through the full all_to_all pipeline end to end on this mesh), and a
    measured pick is recorded with its comm sample so calibration can fit
    the interconnect constants.
    """
    pad_strategy = _PAD_STRATEGY[method]

    def normalize(cfg: PlanConfig) -> PlanConfig:
        """The method owns the pad semantics: ``plan.config.normalize_pad``
        (shared with the algorithm entry points in ``core.pfft``, so an
        explicit ``PlanConfig()`` on fpm-czt still runs Bluestein and a
        drifted ``pad="czt"`` on fpm-pad still runs the paper's crop)."""
        return normalize_pad(cfg, pad_strategy)

    tuning: dict[str, Any] = {"mode": tune}
    if config is not None:
        tuning["source"] = "explicit"
        return SegmentSchedule.homogeneous(normalize(config), n, part.d,
                                           pads), tuning

    # The lb partition is a function of (n, p); the FPM partitions (and
    # pad lengths) depend on the FPMSet and eps, so they digest into the
    # key — a different model must not be served another model's plan.
    # A mesh additionally digests its topology: a measured distributed
    # plan is a property of the pod it was timed on.
    detail = partition_digest(part.d, pads) if method != "lb" else None
    topo = panels = None
    if mesh is not None:
        panels = dist_panel_space(n, int(mesh.shape[axis_name]))
        topo = topology_digest(mesh, axis_name, panels=panels)
        tuning["topology"] = topo
    key = wisdom_key(n=n, dtype=dtype, p=len(part.d), method=method,
                     backend=jax.default_backend(), detail=detail,
                     topology=topo)
    tuning["wisdom_key"] = key
    if wisdom is not None:
        hit = lookup_wisdom(wisdom, key)
        if hit is not None:
            plan, entry = hit
            if isinstance(plan, SegmentSchedule):
                # Structure AND pad semantics must match: an entry whose
                # config pad drifted from the method's strategy would
                # execute the wrong transform (czt vs pad-and-crop), so
                # it is a miss like every other kind of drift.
                ok = (plan.matches(part.d, pads)
                      and all(e.config.pad == pad_strategy for e in plan))
                schedule = plan if ok else None
            else:
                schedule = SegmentSchedule.homogeneous(normalize(plan), n,
                                                       part.d, pads)
            if schedule is not None and mesh is not None:
                # A distributed plan must lower to one SPMD program —
                # heterogeneous mixes of the row-FFT variant group fine
                # (device-group programs), but a hand-edited or drifted
                # entry mixing program-level knobs is a miss.  The rows
                # mapping is already guaranteed by matches() above
                # (the even N/p split tiles every mesh).
                from repro.core.pfft_dist import validate_spmd_schedule
                try:
                    validate_spmd_schedule(schedule)
                except ValueError:
                    schedule = None
            if schedule is not None:
                tuning["source"] = "wisdom"
                tuning["wisdom_entry"] = entry
                return schedule, tuning

    if tune == "off":
        tuning["source"] = "off"
        return SegmentSchedule.homogeneous(
            PlanConfig(pad=pad_strategy), n, part.d, pads), tuning

    params = None
    if wisdom is not None:
        # Enough measured entries on this host re-fit the cost constants
        # (falls back to the hard-coded ones below the sample threshold).
        from repro.plan.cost import CostParams
        params = fit_cost_params(wisdom)
        tuning["calibrated"] = params != CostParams.for_backend()
    if mesh is not None:
        schedule, info = tune_dist_schedule(
            n, mesh, axis_name, pad_lengths=pads, mode=tune,
            pad=pad_strategy, fpms=fpms, params=params, panels=panels,
            dtype=np.dtype(dtype))
    else:
        schedule, info = tune_schedule(n, d=part.d, pad_lengths=pads,
                                       fpms=fpms, mode=tune,
                                       pad=pad_strategy, params=params,
                                       dtype=np.dtype(dtype))
    tuning.update(info)
    tuning["source"] = tune
    if wisdom is not None and tune == "measure":
        extra = None
        if mesh is not None:
            extra = {"topology": topo}
            dist = info.get("dist", {})
            if dist.get("comm_time_meas_s") is not None:
                extra["comm_bytes"] = dist["comm_bytes"]
                extra["comm_time_s"] = dist["comm_time_meas_s"]
        record_wisdom(wisdom, key, schedule, mode="measure",
                      time_s=info.get("time_s"), extra=extra)
    return schedule, tuning


def plan_pfft(n: int, *, p: int | None = None, fpms: FPMSet | None = None,
              method: Method = "fpm", eps: float = 0.05,
              tune: TuneMode = "off", wisdom: str | None = None,
              config: PlanConfig | None = None, dtype: str = "complex64",
              mesh=None, axis_name: str = "fft",
              use_stockham: bool | None = None,
              fused: bool | None = None) -> PfftPlan:
    """Build a reusable plan; see the module docstring for the lifecycle.

    ``mesh=`` plans for ``pfft2_distributed`` over the given ``Mesh``
    instead of the single-host limb: the wisdom key gains the mesh's
    ``topology_digest``, ``tune="measure"`` times finalists through the
    full all_to_all pipeline end to end on that mesh, and ``execute``
    runs the distributed transform.  N must divide by the mesh axis
    size.  The padded FPM methods are planned too: SPMD shards rows
    evenly (one abstract processor per device, N/p rows each), so the
    FPMs drive *per-device pad lengths and execution variants* instead
    of row counts — plain ``method="fpm"`` is rejected (on an even
    split it would be byte-identical to ``"lb"``); heterogeneous picks
    lower as device-group programs
    (``repro.plan.groups``: per-shard ``lax.switch`` branches at the
    schedule's max effective length, the program-level analog of the
    ragged row layout) and persist under the same v3 topology keys.
    ``method="fpm-pad"``/``"fpm-czt"`` require ``fpms`` covering
    exactly the mesh axis (``fpms.p == p``).

    ``use_stockham=``/``fused=`` are deprecated shims for the pre-planner
    flag API (they build an explicit config, so tuning is skipped).
    """
    if tune not in ("off", "estimate", "measure"):
        raise ValueError(f"tune must be 'off'|'estimate'|'measure', got {tune!r}")
    if mesh is not None:
        mesh_p = int(mesh.shape[axis_name])
        if p is None:
            p = mesh_p
        elif p != mesh_p:
            raise ValueError(f"p={p} conflicts with mesh axis "
                             f"{axis_name!r} size {mesh_p}")
        if n % p:
            raise ValueError(f"N={n} must be divisible by mesh axis "
                             f"{axis_name}={p}")
        if method == "fpm":
            raise ValueError(
                "plan_pfft(mesh=...) shards rows evenly, so plain "
                "method='fpm' would run byte-identically to method='lb' "
                "(its FPMs can only influence the *row* split, which SPMD "
                "fixes) — use method='lb', or 'fpm-pad'/'fpm-czt' for "
                "FPM-driven per-device pads and execution variants")
        if method != "lb" and fpms is not None and fpms.p != p:
            raise ValueError(
                f"plan_pfft(mesh=...) assigns one abstract processor per "
                f"device: fpms covers {fpms.p} processors but the mesh "
                f"axis {axis_name!r} has {p} devices")
    if use_stockham is not None or fused is not None:
        if config is not None:
            raise ValueError("pass either config= or the legacy flags "
                             "(use_stockham/fused), not both")
        warnings.warn(
            "plan_pfft: use_stockham=/fused= are deprecated; pass "
            "config=PlanConfig(...) or let tune='estimate'|'measure' choose",
            DeprecationWarning, stacklevel=2)
        pad_strategy = _PAD_STRATEGY[method]
        # The pre-refactor API silently ignored fused= on the padded
        # methods (pad semantics are per-processor); the shim must too.
        config = PlanConfig.from_flags(
            use_stockham=bool(use_stockham),
            fused=bool(fused) and pad_strategy == "none",
            pad=pad_strategy)

    if method == "lb":
        if p is None:
            raise ValueError("method='lb' requires p")
        part = lb_partition(n, p)
        pads = None
    else:
        if fpms is None:
            raise ValueError(f"method={method!r} requires fpms")
        if mesh is not None:
            # SPMD shards rows evenly — the FPMs drive per-device pad
            # lengths and execution variants, not row counts (the
            # device-group lowering's realisation of heterogeneity).
            part = lb_partition(n, p)
        else:
            part = partition_rows(n, fpms, eps)
        if method == "fpm-pad":
            from repro.plan.pads import fpm_pad_lengths
            pads = fpm_pad_lengths(fpms, part.d, n)
        elif method == "fpm-czt":
            from repro.plan.pads import czt_fft_lengths
            pads = czt_fft_lengths(fpms, part.d, n, limit_ratio=2.0)
        else:
            pads = None

    schedule, tuning = _resolve_schedule(n, method, part, pads, fpms, tune,
                                         wisdom, config, dtype,
                                         mesh=mesh, axis_name=axis_name)
    d = part.d

    if mesh is not None:
        from repro.core.pfft_dist import pfft2_distributed

        def raw(m):
            # The full schedule, not just its anchor config: this is what
            # routes heterogeneous picks to the device-group program (and
            # per-device FPM pad lengths to the uniform-length rule).
            return pfft2_distributed(m, mesh, axis_name, schedule=schedule)
    else:
        def raw(m):
            return _pfft_limb(m, d, schedule=schedule)

    return PfftPlan(n=n, method=method, partition=part, pad_lengths=pads,
                    config=schedule.anchor_config, schedule=schedule,
                    tuning=tuning, _fn=jax.jit(raw), mesh=mesh,
                    axis_name=axis_name)
