"""PFFT-LB / PFFT-FPM / PFFT-FPM-PAD — the paper's parallel 2-D DFT methods.

Three layers:

1. **Abstract-processor (single-host) versions** — faithful to the paper's
   Algorithms 1/3/6/7: the N rows are split into ``p`` segments per the
   distribution ``d``; each segment's row FFTs run as a *separate* FFT call
   (on the CPU benchmark backend this is what makes the distribution
   performance-relevant, exactly like the paper's per-group
   ``fftw_plan_many_dft`` calls), then transpose, row FFTs again, transpose.

2. **PFFT-FPM-PAD** — each segment's row length is padded ``N -> N_padded_i``
   chosen from that processor's FPM (paper Alg. 7).  NOTE on semantics: like
   the paper (and its fftw implementation, which sets the transform size to
   N_padded), the padded method computes the DFT *of the zero-padded signal*
   cropped back to N bins — a spectral interpolation, not the exact N-point
   DFT.  Tests validate it against exactly that oracle.

3. **PFFT-FPM-CZT (beyond paper)** — exact N-point DFT with full padding
   freedom via the Bluestein/chirp-Z identity: the N-point DFT is computed
   with FFTs of any model-chosen length m >= 2N-1.  This keeps the paper's
   "run a faster larger FFT" win while preserving exactness.

The distributed (mesh / shard_map) versions live in ``repro.core.pfft_dist``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.fpm import FPMSet
from repro.core.padding import determine_pad_length, smooth_candidates
from repro.core.partition import PartitionResult, lb_partition, partition_rows
from repro.fft.fft2d import fft_rows

__all__ = [
    "pfft_lb",
    "pfft_fpm",
    "pfft_fpm_pad",
    "pfft_fpm_czt",
    "czt_dft",
    "segment_row_ffts",
]


def _segments(d: np.ndarray) -> list[tuple[int, int]]:
    offs = np.concatenate([[0], np.cumsum(np.asarray(d))])
    return [(int(offs[i]), int(offs[i + 1])) for i in range(len(d))]


def segment_row_ffts(m: jnp.ndarray, d: np.ndarray, *, pad_lengths=None,
                     use_stockham: bool = False,
                     backend: str | None = None) -> jnp.ndarray:
    """Step 2/4 of PFFT-FPM: processor i runs row FFTs on its d_i rows.

    ``pad_lengths[i]`` (optional) is N_padded for processor i; rows are
    zero-padded to that length, transformed, and cropped back to N bins.
    """
    n = m.shape[-1]
    outs = []
    for i, (lo, hi) in enumerate(_segments(d)):
        if hi == lo:
            continue
        seg = m[lo:hi]
        if pad_lengths is not None and int(pad_lengths[i]) > n:
            npad = int(pad_lengths[i])
            seg = jnp.pad(seg, ((0, 0), (0, npad - n)))
            outs.append(fft_rows(seg, use_stockham=use_stockham,
                                 backend=backend)[:, :n])
        else:
            outs.append(fft_rows(seg, use_stockham=use_stockham,
                                 backend=backend))
    return jnp.concatenate(outs, axis=0)


def _pfft_limb(m: jnp.ndarray, d: np.ndarray, *, pad_lengths=None,
               use_stockham: bool = False) -> jnp.ndarray:
    """Paper Algorithm 3 (PFFT_LIMB): rows -> T -> rows -> T."""
    if m.ndim != 2 or m.shape[0] != m.shape[1]:
        raise ValueError("PFFT operates on square N x N signal matrices")
    m = segment_row_ffts(m, d, pad_lengths=pad_lengths, use_stockham=use_stockham)
    m = m.T
    m = segment_row_ffts(m, d, pad_lengths=pad_lengths, use_stockham=use_stockham)
    m = m.T
    return m


def pfft_lb(m: jnp.ndarray, p: int, *, use_stockham: bool = False) -> jnp.ndarray:
    """PFFT-LB (paper §III-B): even row distribution over p processors."""
    d = lb_partition(m.shape[0], p).d
    return _pfft_limb(m, d, use_stockham=use_stockham)


def pfft_fpm(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
             use_stockham: bool = False,
             return_partition: bool = False):
    """PFFT-FPM (paper §III-C / Alg. 1): FPM-optimal (possibly imbalanced)
    row distribution, then the 4-step row-column pipeline."""
    n = m.shape[0]
    part: PartitionResult = partition_rows(n, fpms, eps)
    out = _pfft_limb(m, part.d, use_stockham=use_stockham)
    return (out, part) if return_partition else out


def pfft_fpm_pad(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
                 use_stockham: bool = False,
                 return_partition: bool = False):
    """PFFT-FPM-PAD (paper §III-D): PFFT-FPM + per-processor row padding
    N -> N_padded_i determined from the FPMs (padded-signal DFT semantics)."""
    n = m.shape[0]
    part = partition_rows(n, fpms, eps)
    pads = np.array(
        [determine_pad_length(fpms[i], int(part.d[i]), n) for i in range(fpms.p)],
        dtype=np.int64,
    )
    out = _pfft_limb(m, part.d, pad_lengths=pads, use_stockham=use_stockham)
    return (out, part, pads) if return_partition else out


# ---------------------------------------------------------------------------
# Beyond paper: exact N-point DFT at arbitrary (model-chosen) FFT length.
# ---------------------------------------------------------------------------

def czt_dft(x: jnp.ndarray, m_fft: int | None = None) -> jnp.ndarray:
    """Exact N-point DFT along the last axis via Bluestein's chirp-Z trick.

    DFT_N(x)[k] = conj(c_k) * IFFT_m( FFT_m(x*conj(c)) * FFT_m(c') )[k]
    with chirp c_j = exp(i*pi*j^2/N) and any FFT length m >= 2N-1.  ``m_fft``
    is the model-chosen fast length (defaults to next power of two).
    """
    n = x.shape[-1]
    if m_fft is None:
        m_fft = 1 << int(np.ceil(np.log2(2 * n - 1)))
    if m_fft < 2 * n - 1:
        raise ValueError(f"m_fft={m_fft} < 2N-1={2 * n - 1}")
    ctype = jnp.result_type(x, jnp.complex64)
    j = jnp.arange(n)
    # exp(-i*pi*j^2/N); j^2 mod 2N keeps the argument small (exactness).
    chirp = jnp.exp(-1j * jnp.pi * ((j * j) % (2 * n)) / n).astype(ctype)
    a = jnp.zeros(x.shape[:-1] + (m_fft,), ctype).at[..., :n].set(x * chirp)
    # Kernel b_j = conj(chirp)_{|j|}, wrapped for circular convolution.
    b = jnp.zeros(m_fft, ctype)
    b = b.at[:n].set(jnp.conj(chirp))
    b = b.at[m_fft - n + 1:].set(jnp.conj(chirp)[1:n][::-1])
    conv = jnp.fft.ifft(jnp.fft.fft(a, axis=-1) * jnp.fft.fft(b), axis=-1)
    return (conv[..., :n] * chirp).astype(ctype)


def pfft_fpm_czt(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
                 return_partition: bool = False):
    """PFFT-FPM with exact padded transforms: each processor runs its row
    DFTs through the chirp-Z identity at an FPM-chosen smooth FFT length.
    Output equals the exact 2-D DFT (unlike PFFT-FPM-PAD's interpolation)."""
    n = m.shape[0]
    part = partition_rows(n, fpms, eps)
    min_m = 2 * n - 1
    cands = smooth_candidates(min_m, limit_ratio=2.0)

    def best_len(i: int) -> int:
        d_i = int(part.d[i])
        if d_i == 0:
            return int(cands[0])
        times = [fpms[i].time_at(d_i, int(c)) for c in cands]
        return int(cands[int(np.argmin(times))])

    lens = [best_len(i) for i in range(fpms.p)]

    def phase(mat: jnp.ndarray) -> jnp.ndarray:
        outs = []
        for i, (lo, hi) in enumerate(_segments(part.d)):
            if hi > lo:
                outs.append(czt_dft(mat[lo:hi], lens[i]))
        return jnp.concatenate(outs, axis=0)

    out = phase(m).T
    out = phase(out).T
    return (out, part, np.array(lens)) if return_partition else out
