"""PFFT-LB / PFFT-FPM / PFFT-FPM-PAD — the paper's parallel 2-D DFT methods.

Three layers:

1. **Abstract-processor (single-host) versions** — faithful to the paper's
   Algorithms 1/3/6/7: the N rows are split into ``p`` segments per the
   distribution ``d``; each segment's row FFTs run as a *separate* FFT call
   (on the CPU benchmark backend this is what makes the distribution
   performance-relevant, exactly like the paper's per-group
   ``fftw_plan_many_dft`` calls), then transpose, row FFTs again, transpose.

2. **PFFT-FPM-PAD** — each segment's row length is padded ``N -> N_padded_i``
   chosen from that processor's FPM (paper Alg. 7).  NOTE on semantics: like
   the paper (and its fftw implementation, which sets the transform size to
   N_padded), the padded method computes the DFT *of the zero-padded signal*
   cropped back to N bins — a spectral interpolation, not the exact N-point
   DFT.  Tests validate it against exactly that oracle.

3. **PFFT-FPM-CZT (beyond paper)** — exact N-point DFT with full padding
   freedom via the Bluestein/chirp-Z identity: the N-point DFT is computed
   with FFTs of any model-chosen length m >= 2N-1.  This keeps the paper's
   "run a faster larger FFT" win while preserving exactness.

The distributed (mesh / shard_map) versions live in ``repro.core.pfft_dist``.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np
import jax.numpy as jnp

from repro.core.fpm import FPMSet
from repro.core.partition import PartitionResult, lb_partition, partition_rows
from repro.fft.fft2d import fft_rows, rfft_rows
from repro.plan.config import PlanConfig, normalize_pad
from repro.plan.schedule import SegmentPlan, SegmentSchedule

__all__ = [
    "pfft_lb",
    "pfft_fpm",
    "pfft_fpm_pad",
    "pfft_fpm_czt",
    "rpfft_lb",
    "rpfft_fpm",
    "rpfft_fpm_pad",
    "czt_dft",
    "halfspec_distribution",
    "segment_row_ffts",
    "segment_row_rffts",
    "plan_segment_batches",
]


def _coerce_config(config: PlanConfig | None, caller: str, **flags) -> PlanConfig:
    """Fold the PR-1 loose booleans into a ``PlanConfig``.

    ``flags`` values of ``None`` mean "not passed"; any explicit value
    triggers a deprecation warning — the planner (``repro.plan``) owns
    variant selection now, and one config object is the only way every
    variant stays choosable from a single point.
    """
    passed = {k: v for k, v in flags.items() if v is not None}
    if config is not None:
        if passed:
            raise ValueError(
                f"{caller}: pass either config= or the legacy flags "
                f"({', '.join(sorted(passed))}), not both")
        return config
    if passed:
        warnings.warn(
            f"{caller}: the {', '.join(sorted(passed))} kwarg(s) are "
            "deprecated; pass config=PlanConfig(...) (see repro.plan)",
            DeprecationWarning, stacklevel=3)
    return PlanConfig.from_flags(**passed)


def _segments(d: np.ndarray) -> list[tuple[int, int]]:
    offs = np.concatenate([[0], np.cumsum(np.asarray(d))])
    return [(int(offs[i]), int(offs[i + 1])) for i in range(len(d))]


def plan_segment_batches(d: np.ndarray, pad_lengths, n: int, configs=None):
    """Group the segments of distribution ``d`` into dispatch batches.

    Without ``configs``, groups by effective FFT length alone and returns
    ``{fft_length: row_indices}``: all rows transformed at the same
    length form one batch — one FFT dispatch per distinct *plan*, the
    moral equivalent of the paper sharing an ``fftw_plan_many_dft`` across
    same-shaped groups.  len(result) is the dispatch count of the batched
    ``segment_row_ffts``.

    With ``configs`` (one ``PlanConfig`` per processor — a heterogeneous
    schedule's assignment), groups by ``(effective_length, config)`` and
    returns ``{(length, config): row_indices}``: same-length segments on
    *different* execution variants get different dispatches, so a slow
    segment can keep the library FFT while a fast one takes the kernel
    in the same phase (see ``repro.plan.schedule``).  A ``batched=False``
    config opts its segment out of sharing — those entries keep their
    per-segment key ``(length, config, index)`` so ``len(result)`` stays
    the executor's true dispatch count.
    """
    if configs is not None:
        sched = SegmentSchedule.from_parts(n, d, pad_lengths, list(configs))
        out: dict[tuple, np.ndarray] = {}
        for length, cfg, idx in sched.batch_groups():
            key = ((length, cfg) if cfg.batched
                   else (length, cfg, int(idx[0])))
            out[key] = idx
        return out
    groups: dict[int, list[np.ndarray]] = {}
    for i, (lo, hi) in enumerate(_segments(d)):
        if hi == lo:
            continue
        length = n
        if pad_lengths is not None and int(pad_lengths[i]) > n:
            length = int(pad_lengths[i])
        groups.setdefault(length, []).append(np.arange(lo, hi, dtype=np.int64))
    return {length: np.concatenate(idx) for length, idx in groups.items()}


def _row_fft(rows: jnp.ndarray, config: PlanConfig,
             backend: str | None) -> jnp.ndarray:
    """Row FFTs under ``config``'s backend (``backend`` is an explicit
    override, e.g. the test suite forcing the Pallas kernel)."""
    return fft_rows(rows, **config.row_fft_kwargs(backend))


def _group_row_ffts(rows: jnp.ndarray, length: int, n: int,
                    config: PlanConfig, backend: str | None) -> jnp.ndarray:
    """One dispatch group's program: transform ``rows`` at effective
    ``length`` under ``config``, cropped back to N bins.

    ``pad='czt'`` entries run the exact Bluestein transform at the
    entry's length (``czt_dft``); pad-and-crop entries zero-pad, FFT,
    and crop (the paper's padded-signal semantics); unpadded entries
    FFT in place.
    """
    if config.pad == "czt" and length > n:
        return czt_dft(rows, length)
    if length > n:
        rows = jnp.pad(rows, ((0, 0), (0, length - n)))
        return _row_fft(rows, config, backend)[:, :n]
    return _row_fft(rows, config, backend)


def segment_row_ffts(m: jnp.ndarray, d: np.ndarray, *, pad_lengths=None,
                     config: PlanConfig | None = None,
                     schedule: SegmentSchedule | None = None,
                     use_stockham: bool | None = None,
                     backend: str | None = None,
                     batched: bool | None = None) -> jnp.ndarray:
    """Step 2/4 of PFFT-FPM: processor i runs row FFTs on its d_i rows.

    ``pad_lengths[i]`` (optional) is N_padded for processor i; rows are
    zero-padded to that length, transformed, and cropped back to N bins
    (or chirp-Z-transformed at it when the config says ``pad='czt'``).

    ``schedule`` (a ``repro.plan.SegmentSchedule``) is the general form:
    each segment executes its own entry's config, and dispatch groups are
    ``(effective_length, config)`` — same-length segments on the same
    variant share one FFT dispatch, segments on different variants get
    their own.  ``config`` is the homogeneous shim: it becomes the
    degenerate every-segment-alike schedule, whose grouping (by length,
    ``batched=True``) or per-segment loop (``batched=False``) reproduces
    the PR-2 behavior exactly.  The loose ``use_stockham=``/``batched=``
    kwargs are deprecated shims for the pre-planner API.
    """
    n = m.shape[-1]
    if schedule is not None:
        if (config is not None or pad_lengths is not None
                or use_stockham is not None or batched is not None):
            raise ValueError(
                "segment_row_ffts: pass either schedule= (which carries its "
                "own lengths) or config=/pad_lengths=/legacy flags, not both")
    else:
        config = _coerce_config(config, "segment_row_ffts",
                                use_stockham=use_stockham, batched=batched)
        schedule = SegmentSchedule.homogeneous(config, n, d, pad_lengths)
    if int(np.sum(np.asarray(d))) != m.shape[0]:
        raise ValueError(
            f"distribution sums to {int(np.sum(np.asarray(d)))} rows, "
            f"matrix has {m.shape[0]}")
    if schedule.total_rows != m.shape[0]:
        raise ValueError(
            f"schedule covers {schedule.total_rows} rows, "
            f"matrix has {m.shape[0]}")

    groups = schedule.batch_groups()
    if len(groups) == 1:
        # Single plan covering every row in order: one dispatch, no
        # gather/scatter at all.
        length, cfg, idx = groups[0]
        if len(idx) == m.shape[0] and np.array_equal(idx, np.arange(len(idx))):
            return _group_row_ffts(m, length, n, cfg, backend)
    out = jnp.zeros(m.shape, jnp.result_type(m, jnp.complex64))
    for length, cfg, idx in groups:
        res = _group_row_ffts(m[idx], length, n, cfg, backend)
        out = out.at[idx].set(res)
    return out


def _pfft_limb(m: jnp.ndarray, d: np.ndarray, *, pad_lengths=None,
               config: PlanConfig | None = None,
               schedule: SegmentSchedule | None = None,
               use_stockham: bool | None = None,
               fused: bool | None = None) -> jnp.ndarray:
    """Paper Algorithm 3 (PFFT_LIMB): rows -> T -> rows -> T.

    ``schedule`` runs each segment under its own entry's config (the
    heterogeneous executor); ``config`` is the homogeneous shim (it
    becomes the degenerate schedule).  A homogeneous ``fused=True``
    schedule with no per-segment padding runs each (row FFTs, transpose)
    phase as one fused Pallas dispatch — segmentation is then purely a
    scheduling notion, so the fused whole-matrix transform computes the
    identical value with no intermediate HBM matrix.  Padded
    distributions keep the segment path (the pad semantics are
    per-processor).  The loose ``use_stockham=``/``fused=`` kwargs are
    deprecated shims.
    """
    if schedule is not None:
        if (config is not None or pad_lengths is not None
                or use_stockham is not None or fused is not None):
            raise ValueError(
                "_pfft_limb: pass either schedule= (which carries its own "
                "lengths) or config=/pad_lengths=/legacy flags, not both")
    else:
        config = _coerce_config(config, "_pfft_limb",
                                use_stockham=use_stockham, fused=fused)
        schedule = SegmentSchedule.homogeneous(config, m.shape[-1], d,
                                               pad_lengths)
    if m.ndim != 2 or m.shape[0] != m.shape[1]:
        raise ValueError("PFFT operates on square N x N signal matrices")
    common = schedule.common_config
    if (common is not None and common.fused
            and all(e.length == schedule.n for e in schedule)):
        # Segmentation without padding is purely a scheduling notion, so
        # the whole-matrix fused phase computes the identical value.
        # fft_rows_then_transpose itself falls back to the unfused
        # computation when the kernel doesn't apply (non-pow2 N,
        # dtypes wider than the f32 planes).
        from repro.fft.fft2d import fft_rows_then_transpose
        # radix=2 means the pure-jnp Stockham backend elsewhere, not a
        # kernel radix: only an explicit radix-4 reaches the fused kernel
        # (None lets it auto-pick 4, the pre-refactor behavior).
        fused_radix = common.radix if common.radix == 4 else None
        m = fft_rows_then_transpose(m, radix=fused_radix)
        m = fft_rows_then_transpose(m, radix=fused_radix)
        return m
    m = segment_row_ffts(m, d, schedule=schedule)
    m = m.T
    m = segment_row_ffts(m, d, schedule=schedule)
    m = m.T
    return m


# ---------------------------------------------------------------------------
# Real-input (half-spectrum) variants: rows are real, phase 1 runs rffts
# (two rows per complex FFT), phase 2 transforms only the N//2+1
# Hermitian-unique spectral columns.
# ---------------------------------------------------------------------------

def halfspec_distribution(d: np.ndarray, nh: int) -> np.ndarray:
    """Clip a row distribution to the first ``nh`` half-spectrum rows.

    Phase 2 of the real pipeline transforms the ``nh = N//2+1`` surviving
    spectral rows; prefix-clipping keeps spectral row ``j < nh`` on the
    *same* processor that owns row ``j`` in the complex path, so a padded
    real transform computes exactly ``complex_result[:, :nh]`` (identical
    per-row pad lengths) — the property that keeps the tuner's
    real-vs-complex race apples-to-apples.
    """
    d = np.asarray(d)
    offs = np.concatenate([[0], np.cumsum(d)])
    lo = np.minimum(offs[:-1], nh)
    hi = np.minimum(offs[1:], nh)
    return (hi - lo).astype(np.int64)


def _clip_schedule(schedule: SegmentSchedule, d: np.ndarray,
                   nh: int) -> tuple[np.ndarray, SegmentSchedule]:
    """(clipped distribution, clipped schedule) covering ``nh`` rows.

    Entries keep their index/length/config; rows shrink per
    ``halfspec_distribution`` and emptied segments drop out.
    """
    d2 = halfspec_distribution(d, nh)
    entries = []
    for e in schedule.entries:
        rows = int(d2[e.index])
        if rows <= 0:
            continue
        entries.append(SegmentPlan(index=e.index, rows=rows,
                                   length=e.length, config=e.config))
    return d2, SegmentSchedule(n=schedule.n, entries=tuple(entries))


def _group_row_rffts(rows: jnp.ndarray, length: int, n: int,
                     config: PlanConfig, backend: str | None) -> jnp.ndarray:
    """One dispatch group's real phase-1 program: rfft ``rows`` at
    effective ``length``, cropped to the N//2+1 half spectrum.

    The crop identity: for any pad length L >= N, bins 0..N//2 of the
    length-L transform are exactly the first N//2+1 bins the complex
    pad-and-crop path keeps — so the padded real phase equals the padded
    complex phase's half spectrum, column for column.
    """
    nh = n // 2 + 1
    if config.pad == "czt":
        raise ValueError("the real pipeline has no Bluestein form "
                         "(PlanConfig rejects real+czt)")
    kwargs = config.row_fft_kwargs(backend)
    if length > n:
        rows = jnp.pad(rows, ((0, 0), (0, length - n)))
        return rfft_rows(rows, **kwargs)[:, :nh]
    return rfft_rows(rows, **kwargs)


def segment_row_rffts(m: jnp.ndarray, d: np.ndarray, *, pad_lengths=None,
                      config: PlanConfig | None = None,
                      schedule: SegmentSchedule | None = None,
                      backend: str | None = None) -> jnp.ndarray:
    """Real phase 1: processor i runs row rffts on its d_i real rows.

    The (rows, N) real matrix comes back as the (rows, N//2+1) complex
    half spectrum; grouping/dispatch semantics are exactly
    ``segment_row_ffts``'s (same ``SegmentSchedule.batch_groups``).
    """
    n = m.shape[-1]
    nh = n // 2 + 1
    if schedule is not None:
        if config is not None or pad_lengths is not None:
            raise ValueError(
                "segment_row_rffts: pass either schedule= (which carries "
                "its own lengths) or config=/pad_lengths=, not both")
    else:
        if config is None:
            config = PlanConfig(real=True)
        schedule = SegmentSchedule.homogeneous(config, n, d, pad_lengths)
    if int(np.sum(np.asarray(d))) != m.shape[0]:
        raise ValueError(
            f"distribution sums to {int(np.sum(np.asarray(d)))} rows, "
            f"matrix has {m.shape[0]}")
    if schedule.total_rows != m.shape[0]:
        raise ValueError(
            f"schedule covers {schedule.total_rows} rows, "
            f"matrix has {m.shape[0]}")

    groups = schedule.batch_groups()
    if len(groups) == 1:
        length, cfg, idx = groups[0]
        if len(idx) == m.shape[0] and np.array_equal(idx, np.arange(len(idx))):
            return _group_row_rffts(m, length, n, cfg, backend)
    ctype = jnp.result_type(m, jnp.complex64)
    out = jnp.zeros((m.shape[0], nh), ctype)
    for length, cfg, idx in groups:
        res = _group_row_rffts(m[idx], length, n, cfg, backend)
        out = out.at[idx].set(res)
    return out


def _rpfft_limb(m: jnp.ndarray, d: np.ndarray, *, pad_lengths=None,
                config: PlanConfig | None = None,
                schedule: SegmentSchedule | None = None) -> jnp.ndarray:
    """Real PFFT_LIMB: real rows -> T -> complex rows on the half spectrum.

    Returns the (N, N//2+1) half spectrum of the 2-D DFT (``rfft2``
    layout).  Phase 1 rffts each segment (half the complex FFTs via row
    packing); phase 2 runs *complex* row FFTs over the nh surviving
    spectral rows under the prefix-clipped schedule
    (``halfspec_distribution``), so per-processor pad lengths apply to
    exactly the rows the complex path would pad.  A homogeneous
    ``fused=True`` schedule with no padding runs both phases as fused
    Pallas dispatches, like ``_pfft_limb``.
    """
    if schedule is not None:
        if config is not None or pad_lengths is not None:
            raise ValueError(
                "_rpfft_limb: pass either schedule= (which carries its own "
                "lengths) or config=/pad_lengths=, not both")
    else:
        if config is None:
            config = PlanConfig(real=True)
        schedule = SegmentSchedule.homogeneous(config, m.shape[-1], d,
                                               pad_lengths)
    if m.ndim != 2 or m.shape[0] != m.shape[1]:
        raise ValueError("PFFT operates on square N x N signal matrices")
    if not jnp.issubdtype(m.dtype, jnp.floating):
        raise ValueError(
            f"the real pipeline takes a real-valued matrix, got {m.dtype}")
    n = m.shape[-1]
    nh = n // 2 + 1
    common = schedule.common_config
    if (common is not None and common.fused
            and all(e.length == schedule.n for e in schedule)):
        from repro.fft.fft2d import (fft_rows_then_transpose,
                                     rfft_rows_then_transpose)
        fused_radix = common.radix if common.radix == 4 else None
        h = rfft_rows_then_transpose(m, radix=fused_radix)    # (nh, n)
        return fft_rows_then_transpose(h, radix=fused_radix)  # (n, nh)
    h = segment_row_rffts(m, d, schedule=schedule).T          # (nh, n)
    d2, sched2 = _clip_schedule(schedule, np.asarray(d), nh)
    return segment_row_ffts(h, d2, schedule=sched2).T         # (n, nh)


def pfft_lb(m: jnp.ndarray, p: int, *, use_stockham: bool | None = None,
            fused: bool | None = None,
            config: PlanConfig | None = None) -> jnp.ndarray:
    """PFFT-LB (paper §III-B): even row distribution over p processors."""
    cfg = _coerce_config(config, "pfft_lb",
                         use_stockham=use_stockham, fused=fused)
    d = lb_partition(m.shape[0], p).d
    return _pfft_limb(m, d, config=cfg)


def pfft_fpm(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
             use_stockham: bool | None = None, fused: bool | None = None,
             config: PlanConfig | None = None,
             return_partition: bool = False):
    """PFFT-FPM (paper §III-C / Alg. 1): FPM-optimal (possibly imbalanced)
    row distribution, then the 4-step row-column pipeline."""
    n = m.shape[0]
    cfg = _coerce_config(config, "pfft_fpm",
                         use_stockham=use_stockham, fused=fused)
    part: PartitionResult = partition_rows(n, fpms, eps)
    out = _pfft_limb(m, part.d, config=cfg)
    return (out, part) if return_partition else out


def pfft_fpm_pad(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
                 use_stockham: bool | None = None,
                 config: PlanConfig | None = None,
                 return_partition: bool = False):
    """PFFT-FPM-PAD (paper §III-D): PFFT-FPM + per-processor row padding
    N -> N_padded_i determined from the FPMs (padded-signal DFT semantics).

    The method owns the pad strategy: any explicit ``config=`` is
    normalized to ``pad="fpm"`` (``normalize_pad``, shared with
    ``core.api``), so a drifted ``PlanConfig(pad="czt")`` still runs the
    paper's padded-signal crop rather than Bluestein."""
    from repro.plan.pads import fpm_pad_lengths  # lazy: plan imports core
    n = m.shape[0]
    cfg = _coerce_config(config, "pfft_fpm_pad", use_stockham=use_stockham)
    cfg = normalize_pad(cfg, "fpm")
    part = partition_rows(n, fpms, eps)
    pads = fpm_pad_lengths(fpms, part.d, n)
    out = _pfft_limb(m, part.d, pad_lengths=pads, config=cfg)
    return (out, part, pads) if return_partition else out


def _real_config(config: PlanConfig | None) -> PlanConfig:
    """Default/force the ``real`` flag for the rpfft entry points."""
    if config is None:
        return PlanConfig(real=True)
    return config if config.real else dataclasses.replace(config, real=True)


def rpfft_lb(m: jnp.ndarray, p: int, *,
             config: PlanConfig | None = None) -> jnp.ndarray:
    """Real-input PFFT-LB: even row distribution, half-spectrum output."""
    cfg = _real_config(config)
    d = lb_partition(m.shape[0], p).d
    return _rpfft_limb(m, d, config=cfg)


def rpfft_fpm(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
              config: PlanConfig | None = None,
              return_partition: bool = False):
    """Real-input PFFT-FPM: FPM-optimal row distribution, half-spectrum
    output.  The partition is computed for the full N rows (phase 1 sees
    all of them); phase 2 prefix-clips it to the half spectrum."""
    n = m.shape[0]
    cfg = _real_config(config)
    part: PartitionResult = partition_rows(n, fpms, eps)
    out = _rpfft_limb(m, part.d, config=cfg)
    return (out, part) if return_partition else out


def rpfft_fpm_pad(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
                  config: PlanConfig | None = None,
                  return_partition: bool = False):
    """Real-input PFFT-FPM-PAD: per-processor row padding chosen by
    ``rfft_pad_lengths`` (even lengths only), padded-signal DFT semantics
    — the output equals the complex ``pfft_fpm_pad`` result's first
    N//2+1 columns, bin for bin."""
    from repro.plan.pads import rfft_pad_lengths  # lazy: plan imports core
    n = m.shape[0]
    cfg = normalize_pad(_real_config(config), "fpm")
    part = partition_rows(n, fpms, eps)
    pads = rfft_pad_lengths(fpms, part.d, n)
    out = _rpfft_limb(m, part.d, pad_lengths=pads, config=cfg)
    return (out, part, pads) if return_partition else out


# ---------------------------------------------------------------------------
# Beyond paper: exact N-point DFT at arbitrary (model-chosen) FFT length.
# ---------------------------------------------------------------------------

def _czt_chirp(n: int) -> np.ndarray:
    """Bluestein chirp c_j = exp(-i*pi*(j^2 mod 2N)/N), j = 0..N-1.

    Computed host-side (N is static): ``jnp.arange(n)`` is int32 under
    the default x64-off config, so a traced ``j*j`` wraps for
    j >= 46341 and the chirp — hence the "exact" transform — would be
    silently wrong for every N > 46340.  ``np.int64`` squares stay exact
    to N ~ 2^31, and the reduced residue (< 2N) keeps the float64 angle
    small, which is the whole point of the mod-2N identity.
    """
    j = np.arange(n, dtype=np.int64)
    return np.exp(-1j * np.pi * ((j * j) % (2 * n)) / n)


def czt_dft(x: jnp.ndarray, m_fft: int | None = None) -> jnp.ndarray:
    """Exact N-point DFT along the last axis via Bluestein's chirp-Z trick.

    DFT_N(x)[k] = conj(c_k) * IFFT_m( FFT_m(x*conj(c)) * FFT_m(c') )[k]
    with chirp c_j = exp(i*pi*j^2/N) and any FFT length m >= 2N-1.  ``m_fft``
    is the model-chosen fast length (defaults to next power of two).
    """
    n = x.shape[-1]
    if m_fft is None:
        m_fft = 1 << int(np.ceil(np.log2(2 * n - 1)))
    if m_fft < 2 * n - 1:
        raise ValueError(f"m_fft={m_fft} < 2N-1={2 * n - 1}")
    ctype = jnp.result_type(x, jnp.complex64)
    chirp = jnp.asarray(_czt_chirp(n).astype(ctype))
    a = jnp.zeros(x.shape[:-1] + (m_fft,), ctype).at[..., :n].set(x * chirp)
    # Kernel b_j = conj(chirp)_{|j|}, wrapped for circular convolution.
    b = jnp.zeros(m_fft, ctype)
    b = b.at[:n].set(jnp.conj(chirp))
    b = b.at[m_fft - n + 1:].set(jnp.conj(chirp)[1:n][::-1])
    conv = jnp.fft.ifft(jnp.fft.fft(a, axis=-1) * jnp.fft.fft(b), axis=-1)
    return (conv[..., :n] * chirp).astype(ctype)


def pfft_fpm_czt(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
                 return_partition: bool = False):
    """PFFT-FPM with exact padded transforms: each processor runs its row
    DFTs through the chirp-Z identity at an FPM-chosen smooth FFT length.
    Output equals the exact 2-D DFT (unlike PFFT-FPM-PAD's interpolation).

    Executes through the schedule path, so same-length czt segments share
    one Bluestein dispatch (``plan_segment_batches`` semantics)."""
    from repro.plan.pads import czt_fft_lengths  # lazy: plan imports core
    n = m.shape[0]
    part = partition_rows(n, fpms, eps)
    lens = czt_fft_lengths(fpms, part.d, n, limit_ratio=2.0)
    out = _pfft_limb(m, part.d, pad_lengths=lens,
                     config=PlanConfig(pad="czt"))
    return (out, part, lens) if return_partition else out
