"""Four-step huge-1-D FFT — the EFFT decomposition of one length-N line.

A 1-D transform too long for one row-FFT dispatch (or one cache) is
computed as a tiny 2-D problem: with N = n1 * n2,

    X[k2 + n2*k1] = sum_{j1, j2} x[j1 + n1*j2]
                    * W_N^{j1*k2} * W_{n1}^{j1*k1} * W_{n2}^{j2*k2}

which is exactly (1) n1 row FFTs of length n2 over the reshaped input,
(2) a pointwise twiddle by W_N^{j1*k2}, (3) n2 row FFTs of length n1,
(4) a transpose-reshape back to one line.  Both row-FFT phases run
through the planner's standard ``_group_row_ffts`` machinery, so the
whole thing is tunable/persistable like every other method in the repo
(wisdom method string ``"pfft1-large"``).

The twiddle table is built host-side in ``int64`` modular arithmetic
(``(j1*k2) mod N`` before the complex exponential): at N in the tens of
millions the raw product overflows float32's integer range and the
phase error would swamp the transform.
"""

from __future__ import annotations

import numpy as np

from repro.plan.config import PlanConfig

__all__ = ["four_step_factors", "pfft1_large_apply"]


def four_step_factors(n: int, *, n1: int | None = None,
                      n2: int | None = None) -> tuple[int, int]:
    """The (n1, n2) factorization the four-step pipeline runs at.

    Defaults to the most-square split (n1 = largest divisor <= sqrt(N)),
    which balances the two row-FFT phases; callers may pin either factor
    (the other is derived) — e.g. to land one phase on a power of two the
    radix kernels accept.  A prime N degenerates to n1 = 1: phase 1 is N
    length-1 FFTs (identity) and phase 3 is one length-N library FFT —
    still correct, just not faster.
    """
    n = int(n)
    if n <= 0:
        raise ValueError(f"pfft1_large needs a positive length, got N={n}")
    if n1 is not None and n2 is not None:
        n1, n2 = int(n1), int(n2)
        if n1 * n2 != n:
            raise ValueError(
                f"four-step factors must multiply to N: {n1}*{n2} != {n}")
        return n1, n2
    if n1 is not None:
        n1 = int(n1)
        if n1 <= 0 or n % n1:
            raise ValueError(f"n1={n1} must divide N={n}")
        return n1, n // n1
    if n2 is not None:
        n2 = int(n2)
        if n2 <= 0 or n % n2:
            raise ValueError(f"n2={n2} must divide N={n}")
        return n // n2, n2
    best = 1
    for f in range(int(n ** 0.5), 0, -1):
        if n % f == 0:
            best = f
            break
    return best, n // best


def _twiddle(n1: int, n2: int) -> np.ndarray:
    """W_N^{j1*k2} table, shape (n1, n2), complex64.

    Host-side numpy with the exponent reduced mod N in int64 *before*
    the complex exponential — see module docstring.
    """
    n = n1 * n2
    j1 = np.arange(n1, dtype=np.int64)[:, None]
    k2 = np.arange(n2, dtype=np.int64)[None, :]
    return np.exp(-2j * np.pi * ((j1 * k2) % n) / n).astype(np.complex64)


def pfft1_large_apply(x, *, config: PlanConfig | None = None,
                      n1: int | None = None, n2: int | None = None,
                      backend: str | None = None):
    """One length-N line through the four-step pipeline; returns X[k].

    ``x`` must be 1-D; complex input is transformed as-is, real input is
    upcast.  The two row-FFT phases honor ``config``'s row-FFT knobs
    (radix kernels fall back to XLA per phase when that phase's length is
    not a power of two — the standard ``fft_rows`` rule).
    """
    import jax.numpy as jnp

    from repro.core.pfft import _group_row_ffts  # lazy: sibling module

    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(
            f"pfft1_large transforms one 1-D line, got shape {x.shape}")
    n = int(x.shape[0])
    n1, n2 = four_step_factors(n, n1=n1, n2=n2)
    cfg = config if config is not None else PlanConfig()
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)

    # Step 1: n1 rows of length n2.  x[j1 + n1*j2] reshapes to (n2, n1)
    # with j2 as the row index, so the length-n2 lines are the *columns*
    # — transpose first.
    a = jnp.transpose(x.reshape(n2, n1))
    b = _group_row_ffts(a, n2, n2, cfg, backend)
    # Step 2: pointwise twiddle W_N^{j1*k2}.
    cmat = b * jnp.asarray(_twiddle(n1, n2))
    # Step 3: n2 rows of length n1 (transpose brings k2 to the row index).
    e = _group_row_ffts(jnp.transpose(cmat), n1, n1, cfg, backend)
    # Step 4: E[k2, k1] -> X[k2 + n2*k1] is a transpose-reshape.
    return jnp.transpose(e).reshape(-1)
