"""3-D DFT extension (the paper's stated future work, §VII).

The row-column decomposition generalises: a 3-D DFT is three passes of
batched 1-D FFTs with axis rotations between them.  Both methods carry
over unchanged:

* ``pfft3_fpm``   — FPM/HPOPTA partitioning of the *plane* dimension
  (x-y planes of the cube play the role the rows played in 2-D);
* ``pfft3_fpm_pad`` — per-processor padded transform lengths from the FPMs
  (padded-signal semantics, as in 2-D);
* ``pfft3_distributed`` — 1-D pencil decomposition on a device mesh: the
  z-axis passes are local, the axis rotations are the all_to_all
  transposes (identical collective pattern to the 2-D pipeline, one more
  round).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.fpm import FPMSet
from repro.core.padding import determine_pad_length
from repro.core.partition import lb_partition, partition_rows
from repro.fft.fft2d import fft_rows

__all__ = ["pfft3_lb", "pfft3_fpm", "pfft3_fpm_pad", "pfft3_distributed"]


def _axis_pass(m: jnp.ndarray, d: np.ndarray, pads=None) -> jnp.ndarray:
    """Batched 1-D FFTs along the last axis, planes split per ``d`` over the
    leading axis (each segment is one abstract processor's separate call)."""
    n = m.shape[-1]
    offs = np.concatenate([[0], np.cumsum(d)])
    outs = []
    for i in range(len(d)):
        lo, hi = int(offs[i]), int(offs[i + 1])
        if hi == lo:
            continue
        seg = m[lo:hi]
        if pads is not None and int(pads[i]) > n:
            npad = int(pads[i])
            seg = jnp.pad(seg, [(0, 0)] * (seg.ndim - 1) + [(0, npad - n)])
            outs.append(fft_rows(seg)[..., :n])
        else:
            outs.append(fft_rows(seg))
    return jnp.concatenate(outs, axis=0)


def _pfft3(m: jnp.ndarray, d: np.ndarray, pads=None) -> jnp.ndarray:
    """Three passes with axis rotation: z, then y, then x."""
    if m.ndim != 3 or len(set(m.shape)) != 1:
        raise ValueError("pfft3 operates on cubic N^3 signals")
    for _ in range(3):
        m = _axis_pass(m, d, pads)          # FFT along the last axis
        m = jnp.moveaxis(m, -1, 0)          # rotate axes (z,y,x) -> (x,z,y)
    return m


def pfft3_lb(m: jnp.ndarray, p: int) -> jnp.ndarray:
    return _pfft3(m, lb_partition(m.shape[0], p).d)


def pfft3_fpm(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05,
              return_partition: bool = False):
    n = m.shape[0]
    part = partition_rows(n, fpms, eps)
    out = _pfft3(m, part.d)
    return (out, part) if return_partition else out


def pfft3_fpm_pad(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05,
                  return_partition: bool = False):
    n = m.shape[0]
    part = partition_rows(n, fpms, eps)
    pads = np.array([determine_pad_length(fpms[i], int(part.d[i]), n)
                     for i in range(fpms.p)], dtype=np.int64)
    out = _pfft3(m, part.d, pads)
    return (out, part, pads) if return_partition else out


def pfft3_distributed(m: jnp.ndarray, mesh: Mesh, axis_name: str = "fft"):
    """Distributed 3-D DFT, x-planes sharded over ``axis_name``.

    Each of the three passes FFTs the (local) last axis then performs the
    distributed axis rotation: a tiled all_to_all exchanging last-axis
    panels while concatenating along the sharded plane axis.
    """
    n = m.shape[0]
    p = mesh.shape[axis_name]
    if n % p:
        raise ValueError(f"N={n} must divide the mesh axis ({p})")

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis_name, None, None),),
                       out_specs=P(axis_name, None, None), check_rep=False)
    def _run(block):                        # (n/p, n, n)
        for _ in range(3):
            block = fft_rows(block)
            # distributed rotation: split the transformed axis, concat the
            # sharded plane axis, then rotate locally.
            block = jax.lax.all_to_all(block, axis_name, split_axis=2,
                                       concat_axis=0, tiled=True)  # (n, n, n/p)
            block = jnp.moveaxis(block, -1, 0)                     # (n/p, n, n)
        return block

    return _run(m)
