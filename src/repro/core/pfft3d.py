"""3-D DFT extension (the paper's stated future work, §VII), planner-grade.

The row-column decomposition generalises: a 3-D DFT is three passes of
batched 1-D FFTs with axis rotations between them.  Everything routes
through the same ``PlanConfig`` machinery as the 2-D pipeline:

* ``pfft3_lb`` / ``pfft3_fpm`` — LB / FPM partitioning of the *plane*
  dimension (x-y planes of the cube play the role the rows played in
  2-D), each segment's row FFTs running through the shared dispatch
  program ``core.pfft._group_row_ffts``;
* ``pfft3_fpm_pad`` — per-processor padded transform lengths from the
  FPMs.  The pad strategy is *semantics owned by the method*: any
  explicit config is normalized through ``plan.config.normalize_pad``
  (the PR-5 rule that never reached 3-D), so a drifted
  ``PlanConfig(pad="czt")`` still runs the paper's padded-signal crop;
* ``pfft3_slab`` — the legacy 1-D slab decomposition: three rounds of
  (local FFTs, all_to_all rotation) over one mesh axis;
* ``pfft3_pencil`` — the pencil decomposition on a 2-D ``(r, c)`` device
  mesh: each device owns an ``(N/r, N/c, N)`` pencil, so only *two*
  all_to_all rounds are needed (round 1 over the ``c`` axis, round 2
  over the ``r`` axis) instead of the slab's three, and each round's
  exchange is software-pipelined against the next panel's FFTs exactly
  like ``pfft2_distributed``'s panels.  Heterogeneous schedules lower as
  device-group programs (``repro.plan.groups``) branching on the
  flattened ``(r, c)`` device index.

Dataflow of the pencil (device (i, j), block axes in brackets):

    (N/r, N/c, N) [a0, a1, a2]   --FFT a2->k2--
    --all_to_all over c (split k2, concat a1) + swapaxes-->
    (N/r, N/c, N) [a0, k2, a1]   --FFT a1->k1--
    --all_to_all over r (split k1, concat a0) + moveaxis-->
    (N/c, N/r, N) [k2, k1, a0]   --FFT a0->k0--  => global [k2, k1, k0]

The final global transpose back to ``fftn`` order happens *outside*
``shard_map`` (a GSPMD reshard); ``transpose_back=False`` keeps the raw
[k2, k1, k0] layout for pipelines that consume it directly.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.fpm import FPMSet
from repro.core.partition import lb_partition, partition_rows
from repro.core.pfft import _group_row_ffts
from repro.core.pfft_dist import (_local_fft, default_dist_pad_len,
                                  hier_all_to_all, require_mesh_divisible,
                                  validate_spmd_schedule)
from repro.plan.config import PlanConfig, normalize_pad
from repro.plan.groups import DeviceGroupProgram, device_group_program
from repro.plan.schedule import SegmentSchedule

__all__ = ["pfft3_lb", "pfft3_fpm", "pfft3_fpm_pad", "pfft3_distributed",
           "pfft3_pencil", "pfft3_slab"]


def _require_cube(m: jnp.ndarray) -> int:
    if m.ndim != 3 or len(set(m.shape)) != 1:
        raise ValueError("pfft3 operates on cubic N^3 signals")
    return m.shape[0]


def _axis_pass(m: jnp.ndarray, d: np.ndarray, pads=None,
               config: PlanConfig | None = None,
               backend: str | None = None) -> jnp.ndarray:
    """Batched 1-D FFTs along the last axis, planes split per ``d`` over
    the leading axis.  Each segment's planes flatten to rows and run the
    shared dispatch program (``_group_row_ffts``) at that segment's
    effective length — the same pad-and-crop / czt semantics the 2-D
    segments execute, so 3-D pad handling can never drift again."""
    n = m.shape[-1]
    cfg = config if config is not None else PlanConfig()
    offs = np.concatenate([[0], np.cumsum(d)])
    outs = []
    for i in range(len(d)):
        lo, hi = int(offs[i]), int(offs[i + 1])
        if hi == lo:
            continue
        seg = m[lo:hi]
        length = n
        if pads is not None and int(pads[i]) > n:
            length = int(pads[i])
        rows = _group_row_ffts(seg.reshape(-1, n), length, n, cfg, backend)
        outs.append(rows.reshape(seg.shape[:-1] + (n,)))
    return jnp.concatenate(outs, axis=0)


def _pfft3(m: jnp.ndarray, d: np.ndarray, pads=None,
           config: PlanConfig | None = None,
           backend: str | None = None) -> jnp.ndarray:
    """Three passes with axis rotation: z, then y, then x."""
    _require_cube(m)
    for _ in range(3):
        m = _axis_pass(m, d, pads, config, backend)  # FFT along last axis
        m = jnp.moveaxis(m, -1, 0)           # rotate axes (z,y,x) -> (x,z,y)
    return m


def pfft3_lb(m: jnp.ndarray, p: int, *,
             config: PlanConfig | None = None,
             backend: str | None = None) -> jnp.ndarray:
    cfg = normalize_pad(config if config is not None else PlanConfig(),
                        "none")
    return _pfft3(m, lb_partition(m.shape[0], p).d, config=cfg,
                  backend=backend)


def pfft3_fpm(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
              config: PlanConfig | None = None,
              return_partition: bool = False):
    n = m.shape[0]
    cfg = normalize_pad(config if config is not None else PlanConfig(),
                        "none")
    part = partition_rows(n, fpms, eps)
    out = _pfft3(m, part.d, config=cfg)
    return (out, part) if return_partition else out


def pfft3_fpm_pad(m: jnp.ndarray, fpms: FPMSet, eps: float = 0.05, *,
                  config: PlanConfig | None = None,
                  return_partition: bool = False):
    """PFFT3-FPM-PAD: per-processor padded lengths from the FPMs, the
    paper's padded-signal semantics (DFT of the zero-padded signal
    cropped back to N bins, per pass).

    The method owns the pad strategy: any explicit ``config=`` is
    normalized to ``pad="fpm"`` (``normalize_pad``, shared with the 2-D
    entry points), and pad lengths come from the shared
    ``plan.pads.fpm_pad_lengths`` rather than a private copy of the
    selection loop."""
    from repro.plan.pads import fpm_pad_lengths  # lazy: plan imports core
    n = m.shape[0]
    cfg = normalize_pad(config if config is not None else PlanConfig(),
                        "fpm")
    part = partition_rows(n, fpms, eps)
    pads = fpm_pad_lengths(fpms, part.d, n)
    out = _pfft3(m, part.d, pads, config=cfg)
    return (out, part, pads) if return_partition else out


# ---------------------------------------------------------------- distributed

def _pencil_rows_fft(n: int, *, padded: str | None, pad_len: int,
                     config: PlanConfig, backend: str | None,
                     program: DeviceGroupProgram | None,
                     axis_names: tuple[str, str] | None, c: int):
    """Local row-FFT program on a 3-D block's last axis.

    Flattens the two leading (pencil) axes to rows, runs the 2-D local
    program (``_local_fft`` — crop / czt / plain, same as the 2-D
    pipeline), and reshapes back.  With a ``program``, the row FFT
    branches per device group via ``lax.switch`` on the *flattened*
    (r, c) device index ``idx_r * c + idx_c`` — the 2-D-mesh analog of
    ``_grouped_local_fft`` — while collectives stay outside the switch.
    """
    if program is None:
        fft = functools.partial(_local_fft, n=n, padded=padded,
                                pad_len=pad_len, config=config,
                                backend=backend)
    else:
        branches = [
            functools.partial(_local_fft, n=n, padded=padded,
                              pad_len=pad_len, config=cfg, backend=backend)
            for cfg in program.configs]
        groups = jnp.asarray(
            np.asarray(program.group_of_device, dtype=np.int32))
        ax_r, ax_c = axis_names

        def fft(rows: jnp.ndarray) -> jnp.ndarray:
            flat = jax.lax.axis_index(ax_r) * c + jax.lax.axis_index(ax_c)
            return jax.lax.switch(groups[flat], branches, rows)

    def run(block: jnp.ndarray) -> jnp.ndarray:
        a, b = block.shape[0], block.shape[1]
        return fft(block.reshape(a * b, block.shape[-1])).reshape(a, b, n)

    return run


def _pencil_phase(block: jnp.ndarray, fft3, a2a, rearrange, panels: int,
                  split_dim: int, concat_dim: int) -> jnp.ndarray:
    """One (local FFTs, all_to_all, local rearrange) pencil round.

    ``panels=k > 1`` software-pipelines the round: the block is chunked
    into ``k`` panels along ``split_dim`` — an axis the exchange does not
    touch, so the gathered panels concatenate back in order with no
    re-interleave — and panel ``i``'s all_to_all is issued before panel
    ``i+1``'s FFTs, letting the exchange hide behind the next panel's
    compute (the 2-D pipeline's overlap lever, restated for pencils).
    ``concat_dim`` is where ``split_dim`` lands after ``rearrange``.
    """
    if panels <= 1:
        return rearrange(a2a(fft3(block)))
    chunk = block.shape[split_dim] // panels

    def panel(i: int) -> jnp.ndarray:
        idx = [slice(None)] * 3
        idx[split_dim] = slice(i * chunk, (i + 1) * chunk)
        return block[tuple(idx)]

    gathered = []
    current = fft3(panel(0))
    for i in range(1, panels):
        in_flight = a2a(current)       # exchange panel i-1 ...
        current = fft3(panel(i))       # ... while transforming panel i
        gathered.append(in_flight)
    gathered.append(a2a(current))
    return jnp.concatenate([rearrange(g) for g in gathered], axis=concat_dim)


def pfft3_pencil(
    m: jnp.ndarray,
    mesh: Mesh,
    axis_names: tuple[str, str] = ("fft_r", "fft_c"),
    *,
    config: PlanConfig | None = None,
    schedule: SegmentSchedule | None = None,
    pad_len: int | None = None,
    backend: str | None = None,
    transpose_back: bool = True,
) -> jnp.ndarray:
    """Distributed 3-D DFT on a 2-D device mesh (pencil decomposition).

    ``m`` is the (N, N, N) cube sharded ``P(ax_r, ax_c, None)``; each
    device owns an (N/r, N/c, N) pencil and the transform needs only two
    all_to_all rounds (see the module docstring's dataflow).
    ``config.pipeline_panels=k`` chunks each round into ``k``
    software-pipelined panels (k must divide both N/r and N/c);
    ``config.pad`` selects the local padding semantics exactly as in
    ``pfft2_distributed`` ('fpm' -> pad-and-crop, 'czt' -> Bluestein).
    A heterogeneous ``schedule`` lowers to a device-group program over
    the r*c flattened devices.  ``transpose_back=True`` (default)
    returns ``jnp.fft.fftn`` order; ``False`` keeps the raw
    [k2, k1, k0] layout (the transpose is a global reshard).
    """
    n = _require_cube(m)
    ax_r, ax_c = axis_names
    r = int(mesh.shape[ax_r])
    c = int(mesh.shape[ax_c])
    require_mesh_divisible(n, r, ax_r)
    require_mesh_divisible(n, c, ax_c)
    if schedule is not None:
        if config is not None:
            raise ValueError("pass either schedule= or config=, not both")
        config = validate_spmd_schedule(schedule)
        if pad_len is None:
            pad_len = max(e.length for e in schedule)
    if config is None:
        config = PlanConfig()
    if config.fused:
        raise ValueError(
            "the 3-D pencil pipeline is unfused (the fused kernel's "
            f"transposed exchange is a 2-D layout), got {config.describe()}")
    padded = config.dist_padded
    if pad_len is None:
        pad_len = default_dist_pad_len(n, padded)
    k = config.pipeline_panels
    if k > 1 and ((n // r) % k or (n // c) % k):
        raise ValueError(
            f"pipeline_panels={k} must divide both pencil extents "
            f"N/{ax_r}={n // r} and N/{ax_c}={n // c}")
    program = None
    if schedule is not None and schedule.common_config is None:
        program = device_group_program(schedule, r * c, pad_len=pad_len)
        pad_len = program.pad_len  # the lowering owns the uniform length
    fft3 = _pencil_rows_fft(n, padded=padded, pad_len=pad_len, config=config,
                            backend=backend, program=program,
                            axis_names=(ax_r, ax_c), c=c)
    a2a_c = functools.partial(jax.lax.all_to_all, axis_name=ax_c,
                              split_axis=2, concat_axis=1, tiled=True)
    a2a_r = functools.partial(jax.lax.all_to_all, axis_name=ax_r,
                              split_axis=2, concat_axis=0, tiled=True)
    if config.exchange == "hier":
        # On a host-major pencil mesh only the r axis spans hosts (the
        # c-axis communicators live inside one box — make_pfft3_mesh's
        # layout), so only round 2 takes the hierarchical form; with no
        # exploitable host shape it degrades to the flat round.
        from repro.launch.mesh import mesh_host_shape
        hosts_r, local_r = mesh_host_shape(mesh, ax_r)
        if hosts_r > 1 and local_r > 1:
            a2a_r = functools.partial(hier_all_to_all, axis_name=ax_r,
                                      hosts=hosts_r, local=local_r,
                                      split_axis=2, concat_axis=0)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(ax_r, ax_c, None),),
                       out_specs=P(ax_c, ax_r, None), check_rep=False)
    def _run(block):                       # (N/r, N/c, N)  [a0, a1, a2]
        # Round 1: FFT a2 -> k2, exchange over c (split k2, concat a1),
        # swap back to pencil layout.  Panels split a0 — untouched by the
        # exchange, so gathered panels concatenate in order.
        block = _pencil_phase(block, fft3, a2a_c,
                              lambda g: jnp.swapaxes(g, 1, 2), k,
                              split_dim=0, concat_dim=0)  # [a0, k2, a1]
        # Round 2: FFT a1 -> k1, exchange over r (split k1, concat a0).
        # Panels split a1, which moveaxis lands on axis 0.
        block = _pencil_phase(block, fft3, a2a_r,
                              lambda g: jnp.moveaxis(g, 0, -1), k,
                              split_dim=1, concat_dim=0)  # [k2, k1, a0]
        # Pass 3: FFT a0 -> k0; no exchange left.
        return fft3(block)                 # (N/c, N/r, N)  [k2, k1, k0]

    out = _run(m)
    if not transpose_back:
        return out
    # Outside shard_map: GSPMD reshards, and the result matches
    # jnp.fft.fftn bin for bin.
    return jnp.transpose(out, (2, 1, 0))


def pfft3_slab(m: jnp.ndarray, mesh: Mesh, axis_name: str = "fft", *,
               config: PlanConfig | None = None,
               pad_len: int | None = None,
               backend: str | None = None) -> jnp.ndarray:
    """Distributed 3-D DFT, x-planes sharded over one mesh axis (slab).

    Each of the three passes FFTs the (local) last axis then performs the
    distributed axis rotation: a tiled all_to_all exchanging last-axis
    panels while concatenating along the sharded plane axis — three
    exchange rounds where the pencil needs two (the measured delta is the
    microbench's ``pfft3`` sweep).  Local FFTs run the shared
    ``_local_fft`` program under ``config``.
    """
    n = _require_cube(m)
    p = int(mesh.shape[axis_name])
    require_mesh_divisible(n, p, axis_name)
    cfg = config if config is not None else PlanConfig()
    padded = cfg.dist_padded
    if pad_len is None:
        pad_len = default_dist_pad_len(n, padded)
    fft3 = _pencil_rows_fft(n, padded=padded, pad_len=pad_len, config=cfg,
                            backend=backend, program=None, axis_names=None,
                            c=1)
    rotate = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                               split_axis=2, concat_axis=0, tiled=True)
    if cfg.exchange == "hier":
        from repro.launch.mesh import mesh_host_shape
        hosts, local = mesh_host_shape(mesh, axis_name)
        if hosts > 1 and local > 1:
            rotate = functools.partial(hier_all_to_all, axis_name=axis_name,
                                       hosts=hosts, local=local,
                                       split_axis=2, concat_axis=0)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis_name, None, None),),
                       out_specs=P(axis_name, None, None), check_rep=False)
    def _run(block):                        # (n/p, n, n)
        for _ in range(3):
            block = fft3(block)
            # distributed rotation: split the transformed axis, concat the
            # sharded plane axis, then rotate locally.
            block = rotate(block)                                  # (n, n, n/p)
            block = jnp.moveaxis(block, -1, 0)                     # (n/p, n, n)
        return block

    return _run(m)


def pfft3_distributed(m: jnp.ndarray, mesh: Mesh,
                      axis_name="fft", **kw) -> jnp.ndarray:
    """Distributed 3-D DFT; dispatches on the mesh decomposition.

    A single ``axis_name`` runs the 1-D slab path (``pfft3_slab``); a
    pair of axis names runs the two-exchange pencil path
    (``pfft3_pencil``).  Keyword arguments pass through.
    """
    if isinstance(axis_name, (tuple, list)):
        return pfft3_pencil(m, mesh, tuple(axis_name), **kw)
    return pfft3_slab(m, mesh, axis_name, **kw)
