"""Functional Performance Models (FPMs).

The paper's central data structure: a *discrete 3-D speed function*

    S_i = { ((x, y), s_i(x, y)) }

where ``s_i(x, y)`` is the speed of abstract processor ``i`` executing ``x``
row 1-D FFTs of length ``y``.  Speed follows the paper's normalisation

    s(x, y) = 2.5 * x * y * log2(y) / t

with ``t`` the wall time of the run (so "speed" is FLOP/s under the standard
5/2 * N log2 N complex-FFT flop count).

FPMs are host-side model objects (numpy), built either from real measurements
(``build_fpm`` with a timing callback) or synthetically (tests / dry-runs).
They are the *input* to the partitioning (POPTA/HPOPTA) and padding
algorithms; nothing in here touches jax device state.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "SpeedFunction",
    "FPMSet",
    "fft_flops",
    "build_fpm",
    "save_fpms",
    "load_fpms",
]


def fft_flops(x: np.ndarray | float, y: np.ndarray | float) -> np.ndarray:
    """Paper's flop count for ``x`` complex 1-D FFTs of length ``y``: 2.5·x·y·log2 y."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    return 2.5 * x * y * np.log2(np.maximum(y, 2.0))


@dataclasses.dataclass
class SpeedFunction:
    """Discrete speed function s(x, y) of one abstract processor.

    ``xs``: 1-D int array of row-count sample points (ascending).
    ``ys``: 1-D int array of row-length sample points (ascending).
    ``speed``: float array of shape (len(xs), len(ys)); NaN marks unmeasured
    points (e.g. sizes that exceed memory, paper §V-B).
    """

    xs: np.ndarray
    ys: np.ndarray
    speed: np.ndarray
    name: str = "P"

    def __post_init__(self) -> None:
        self.xs = np.asarray(self.xs, dtype=np.int64)
        self.ys = np.asarray(self.ys, dtype=np.int64)
        self.speed = np.asarray(self.speed, dtype=np.float64)
        if self.speed.shape != (len(self.xs), len(self.ys)):
            raise ValueError(
                f"speed shape {self.speed.shape} != ({len(self.xs)}, {len(self.ys)})"
            )
        if np.any(np.diff(self.xs) <= 0) or np.any(np.diff(self.ys) <= 0):
            raise ValueError("xs / ys sample points must be strictly ascending")
        if np.any(self.speed[np.isfinite(self.speed)] <= 0):
            raise ValueError("speeds must be positive")

    # ---- plane sections (paper Figs 9-12) ----

    def section_y(self, y: int) -> np.ndarray:
        """Intersect with the plane ``y = const``: speed vs x (len(xs),).

        Linear interpolation along y when ``y`` is off-grid (clamped at ends).
        """
        return self._interp_along(self.ys, self.speed, y, axis=1)

    def section_x(self, x: int) -> np.ndarray:
        """Intersect with the plane ``x = const``: speed vs y (len(ys),)."""
        return self._interp_along(self.xs, self.speed, x, axis=0)

    @staticmethod
    def _interp_along(grid: np.ndarray, table: np.ndarray, v: float, axis: int) -> np.ndarray:
        v = float(np.clip(v, grid[0], grid[-1]))
        j = int(np.searchsorted(grid, v, side="right") - 1)
        j = min(max(j, 0), len(grid) - 2) if len(grid) > 1 else 0
        if len(grid) == 1:
            return np.take(table, 0, axis=axis)
        g0, g1 = float(grid[j]), float(grid[j + 1])
        w = 0.0 if g1 == g0 else (v - g0) / (g1 - g0)
        lo = np.take(table, j, axis=axis)
        hi = np.take(table, j + 1, axis=axis)
        # NaN-safe: if one endpoint unmeasured, fall back to the other.
        out = (1.0 - w) * lo + w * hi
        out = np.where(np.isnan(out), np.where(np.isnan(lo), hi, lo), out)
        return out

    # ---- time queries ----

    def speed_at(self, x: float, y: float) -> float:
        """Bilinear interpolation of speed at (x, y)."""
        col = self._interp_along(self.ys, self.speed, y, axis=1)  # (len(xs),)
        return float(self._interp_along(self.xs, col[:, None], x, axis=0)[0])

    def time_at(self, x: float, y: float) -> float:
        """Predicted execution time of x row-FFTs of length y (x=0 -> 0)."""
        if x <= 0:
            return 0.0
        s = self.speed_at(x, y)
        if not np.isfinite(s) or s <= 0:
            return float("inf")
        return float(fft_flops(x, y) / s)

    def time_curve(self, n_rows: int, y: float) -> np.ndarray:
        """Time of assigning 0..n_rows rows of length y: array (n_rows+1,).

        This is the per-row-granularity time function handed to POPTA/HPOPTA;
        speed is linearly interpolated between the x sample points.
        """
        xs_f = np.arange(n_rows + 1, dtype=np.float64)
        sec = self.section_y(int(round(y)))  # speed vs xs grid at this y
        valid = np.isfinite(sec)
        if not np.any(valid):
            t = np.full(n_rows + 1, np.inf)
            t[0] = 0.0
            return t
        sp = np.interp(xs_f, self.xs[valid].astype(np.float64), sec[valid])
        t = fft_flops(xs_f, y) / np.maximum(sp, 1e-30)
        t[0] = 0.0
        return t


@dataclasses.dataclass
class FPMSet:
    """The full model input S = {S_1, ..., S_p} of PFFT-FPM."""

    functions: list[SpeedFunction]

    @property
    def p(self) -> int:
        return len(self.functions)

    def __iter__(self):
        return iter(self.functions)

    def __getitem__(self, i: int) -> SpeedFunction:
        return self.functions[i]

    def max_variation_at_plane(self, y: int) -> float:
        """max over x-grid of (max_i s_i - min_i s_i) / min_i s_i  (paper Step 1b)."""
        curves = np.stack([f.section_y(y) for f in self.functions])  # (p, m)
        ok = np.all(np.isfinite(curves), axis=0)
        if not np.any(ok):
            return 0.0
        hi = curves[:, ok].max(axis=0)
        lo = curves[:, ok].min(axis=0)
        return float(np.max((hi - lo) / np.maximum(lo, 1e-30)))

    def averaged(self) -> SpeedFunction:
        """S_avg with s_avg = p / sum_j 1/s_j  (harmonic mean, paper Step 1c)."""
        f0 = self.functions[0]
        inv = np.zeros_like(f0.speed)
        for f in self.functions:
            if f.speed.shape != f0.speed.shape:
                raise ValueError("averaging requires a common (xs, ys) grid")
            inv = inv + 1.0 / f.speed
        return SpeedFunction(f0.xs, f0.ys, self.p / inv, name="S_avg")


def build_fpm(
    xs: Sequence[int],
    ys: Sequence[int],
    timer: Callable[[int, int], float],
    name: str = "P",
) -> SpeedFunction:
    """Build a speed function by timing ``timer(x, y) -> seconds`` on a grid.

    ``timer`` returning NaN/inf marks the point unmeasured (paper: memory cap).
    """
    xs = np.asarray(list(xs), dtype=np.int64)
    ys = np.asarray(list(ys), dtype=np.int64)
    sp = np.full((len(xs), len(ys)), np.nan)
    for i, x in enumerate(xs):
        for j, y in enumerate(ys):
            t = float(timer(int(x), int(y)))
            if np.isfinite(t) and t > 0:
                sp[i, j] = fft_flops(x, y) / t
    return SpeedFunction(xs, ys, sp, name=name)


def save_fpms(path: str, fpms: FPMSet) -> None:
    arrs: dict[str, np.ndarray] = {}
    meta = []
    for i, f in enumerate(fpms):
        arrs[f"xs_{i}"] = f.xs
        arrs[f"ys_{i}"] = f.ys
        arrs[f"speed_{i}"] = f.speed
        meta.append(f.name)
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, p=np.int64(fpms.p), **arrs)
    os.replace(tmp, path)
    with open(path + ".json", "w") as fh:
        json.dump({"names": meta}, fh)


def load_fpms(path: str) -> FPMSet:
    data = np.load(path)
    p = int(data["p"])
    names = ["P"] * p
    if os.path.exists(path + ".json"):
        with open(path + ".json") as fh:
            names = json.load(fh)["names"]
    fns = [
        SpeedFunction(data[f"xs_{i}"], data[f"ys_{i}"], data[f"speed_{i}"], name=names[i])
        for i in range(p)
    ]
    return FPMSet(fns)
