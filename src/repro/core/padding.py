"""Padding-length selection (paper §III-D, PFFT-FPM-PAD Step 2).

    N_padded = argmin_{y in (N, y_m]}  d_i * y / s_i(d_i, y)
               subject to  t(d_i, y) < t(d_i, N)

i.e. pick the row length > N with the minimal predicted execution time for
this processor's assigned row count d_i, provided it beats the unpadded time;
otherwise pad length is 0 (N_padded = N).  The decision is *local to each
abstract processor* — different processors may pad differently.

TPU adaptation: on the TPU target the fast sizes are (a) FFT lengths that
avoid XLA's Bluestein fallback (smooth sizes, ideally powers of two) and
(b) lane-aligned minor dims (multiples of 128).  ``smooth_candidates``
generates that candidate set so synthetic FPMs for the dry-run can be
evaluated only at plausible-fast sizes, and so callers without a measured FPM
can still pad principally (``pad_to_smooth``).
"""

from __future__ import annotations

import numpy as np

from repro.core.fpm import SpeedFunction, fft_flops

__all__ = ["determine_pad_length", "smooth_candidates", "pad_to_smooth", "is_smooth"]


def determine_pad_length(fpm: SpeedFunction, d_i: int, n: int) -> int:
    """Return N_padded (== n when no beneficial padding exists)."""
    if d_i <= 0:
        return n
    t_base = fpm.time_at(d_i, n)
    ys = fpm.ys[fpm.ys > n]
    best_y, best_t = n, t_base
    for y in ys:
        t = fpm.time_at(d_i, int(y))
        if t < best_t:
            best_t, best_y = t, int(y)
    return best_y


def is_smooth(n: int, primes=(2, 3, 5)) -> bool:
    """True if n factors entirely over ``primes`` (XLA-fast FFT length)."""
    if n < 1:
        return False
    for p in primes:
        while n % p == 0:
            n //= p
    return n == 1


def smooth_candidates(n: int, *, lane: int = 128, limit_ratio: float = 2.0) -> np.ndarray:
    """Ascending candidate padded sizes >= n: lane-aligned *and* smooth,
    capped at ``limit_ratio * n``.  Always contains the next power of two."""
    cap = int(limit_ratio * n) + 1
    out = set()
    npow2 = 1 << int(np.ceil(np.log2(max(n, 1))))
    out.add(max(npow2, lane))
    k = ((n + lane - 1) // lane) * lane
    while k <= cap:
        if is_smooth(k // np.gcd(k, lane) * (lane // np.gcd(k, lane))) or is_smooth(k):
            out.add(k)
        k += lane
    return np.array(sorted(v for v in out if v >= n), dtype=np.int64)


def pad_to_smooth(n: int, *, lane: int = 128) -> int:
    """Model-free fallback: smallest lane-aligned smooth size >= n."""
    cands = smooth_candidates(n, lane=lane)
    return int(cands[0]) if len(cands) else n


def predicted_time(fpm: SpeedFunction, d_i: int, y: int) -> float:
    """Predicted execution time of d_i rows of length y under this FPM."""
    if d_i <= 0:
        return 0.0
    s = fpm.speed_at(d_i, y)
    return float(fft_flops(d_i, y) / s) if np.isfinite(s) and s > 0 else float("inf")
