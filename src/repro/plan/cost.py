"""Cost model: price a ``PlanConfig`` from the FPMs + structural counts.

The paper's thesis is that *measured* speed functions, not fixed
heuristics, should drive execution decisions.  This module is the
"estimate" half of the FFTW-style planner: it predicts the wall time of a
candidate config from

* the FPM-predicted per-processor segment times (``time_at``) — or a
  nominal flop rate when no FPM is supplied,
* per-backend compute multipliers (XLA library FFT vs pure-jnp Stockham
  vs the Pallas kernel, whose radix sets the pass count via
  ``stockham_stage_count``),
* the HBM round-trip of the intermediate matrix that ``fused`` removes,
* kernel dispatch counts (``plan_segment_batches`` for the batched path),
* and the all_to_all term that ``pipeline_panels`` overlaps.

Absolute seconds are not the point — *ranking* is.  ``CostParams``
carries the platform constants; ``CostParams.for_backend("cpu")`` knows
that on this container the Pallas kernels run in interpret mode (orders
of magnitude slower) and the pure-jnp Stockham loses to pocketfft, so
estimate-mode planning picks the library path there, exactly what
measurement confirms.  ``mode="measure"`` exists for when the constants
are wrong.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, NamedTuple

import numpy as np

from repro.core.fpm import FPMSet, fft_flops
from repro.plan.config import PlanConfig
from repro.plan.schedule import SegmentSchedule

__all__ = ["CommTiers", "CostParams", "comm_phase_time", "dist_comm_bytes",
           "dist_comm_time", "estimate_cost", "estimate_grouped_cost",
           "estimate_schedule_cost", "estimate_pfft3_cost", "exchange_time",
           "halfspec_cols", "phase_dispatch_count", "pfft3_comm_bytes"]

_COMPLEX64_BYTES = 8
# Bluestein computes one N-point DFT as ~3 length-m FFTs (forward, kernel
# forward is precomputable but the conv needs fwd+inv) + pointwise chirps.
_CZT_FFT_FACTOR = 3.0
# Real rows pack pairwise into one complex FFT, so the row phase does
# ~half the complex-path flops; the column phase still runs full-length
# FFTs but over ~half the columns.  Pack/unpack lane work eats part of
# the ideal 0.5, hence 0.55.
_REAL_COMPUTE_FACTOR = 0.55


def _is_pow2(n: int) -> bool:
    return n > 0 and not (n & (n - 1))


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Platform constants of the estimate cost model (see module docstring)."""

    nominal_flops: float            # assumed flop/s when no FPM is given
    dispatch_overhead_s: float      # fixed cost per kernel dispatch
    hbm_bytes_per_s: float          # effective bandwidth, intermediate matrix
    backend_factor: Mapping[str, float]  # compute multiplier per fft backend
    fused_factor: float             # multiplier for the fused kernel's compute
    panel_overlap: float = 0.6      # fraction of comm hidden per extra panel
    # Two-tier interconnect: the legacy names price the *intra-host* tier
    # (device-to-device inside one box — the only tier that exists on a
    # single-host mesh, so every pre-multi-host call site keeps its
    # meaning); the ``inter_*`` pair prices the slower host-to-host tier
    # the hierarchical exchange aggregates traffic onto.
    interconnect_bytes_per_s: float = 2e10  # intra-host all_to_all bandwidth
    comm_latency_s: float = 0.0     # intra-host per-collective launch cost
    inter_bytes_per_s: float = 2.5e9   # inter-host (network) bandwidth
    inter_latency_s: float = 2e-5      # inter-host per-message latency

    @classmethod
    def for_backend(cls, backend: str | None = None) -> "CostParams":
        if backend is None:
            import jax
            backend = jax.default_backend()
        if backend == "cpu":
            # Interpret-mode Pallas re-traces every lane op in Python; the
            # pure-jnp Stockham is an unrolled stage loop vs pocketfft.
            # Forced-host "devices" exchange through shared memory, so the
            # interconnect is loopback bandwidth plus a collective-launch
            # latency of XLA's CPU all_to_all; the inter tier models the
            # gloo/TCP hop of multi-process launches (loopback sockets in
            # the emulation rig, NICs on a real cluster).
            return cls(
                nominal_flops=2e9,
                dispatch_overhead_s=5e-5,
                hbm_bytes_per_s=2e10,
                backend_factor={"xla": 1.0, "stockham": 8.0, "pallas": 300.0},
                fused_factor=300.0,
                panel_overlap=0.0,
                interconnect_bytes_per_s=1e10,
                comm_latency_s=5e-5,
                inter_bytes_per_s=2e9,
                inter_latency_s=2e-4,
            )
        # Accelerator defaults (v5e-class): the radix-4 kernel beats the
        # library FFT (half the passes, twiddles from iota), fused wins by
        # skipping the HBM round trip; ICI all_to_all runs near link rate
        # and DCN (the inter-host tier) at roughly a quarter of it with
        # much higher per-message latency.
        return cls(
            nominal_flops=2e11,
            dispatch_overhead_s=3e-6,
            hbm_bytes_per_s=8e11,
            backend_factor={"xla": 1.0, "stockham": 1.6, "pallas": 0.8},
            fused_factor=0.8,
            panel_overlap=0.6,
            interconnect_bytes_per_s=9e10,
            comm_latency_s=1e-6,
            inter_bytes_per_s=2.5e10,
            inter_latency_s=1e-5,
        )


def halfspec_cols(n: int, p: int = 1) -> int:
    """Spectral columns the real half-spectrum pipeline carries.

    ``N//2+1`` Hermitian-unique bins, rounded up to a multiple of ``p``
    when distributed so the all_to_all splits evenly across devices
    (``rpfft2_distributed`` pads the panel to this width and crops after).
    """
    nh = n // 2 + 1
    if p <= 1:
        return nh
    return -(-nh // p) * p


class CommTiers(NamedTuple):
    """Per-tier byte volume of one exchange round (see ``dist_comm_bytes``)."""

    intra: float  # bytes crossing the fast intra-host tier
    inter: float  # bytes crossing the slow inter-host tier

    @property
    def total(self) -> float:
        return self.intra + self.inter


def comm_phase_time(bytes_: float, bytes_per_s: float,
                    latency_s: float) -> float:
    """Seconds of one comm phase: ``bytes/bandwidth + latency``, with the
    launch latency charged only when bytes actually move.

    The single home of the guarded form — a degenerate phase (1-wide
    axis, empty tier) costs nothing, it never issues a collective.  Both
    distributed tuners and both estimate models price phases through
    this, so the guard can never drift between them again.
    """
    if not bytes_:
        return 0.0
    return float(bytes_) / bytes_per_s + latency_s


def dist_comm_bytes(n: int, p: int, *, itemsize: int = _COMPLEX64_BYTES,
                    real: bool = False, hosts: int | None = None,
                    exchange: str = "flat") -> float | CommTiers:
    """Cross-device bytes of one phase's ``all_to_all`` over ``p`` devices.

    Each device holds an (N/p, N) block and keeps its own diagonal tile,
    so (p-1)/p of the matrix crosses the interconnect per phase (0 on a
    1-device mesh — the degenerate exchange is a local reshuffle).
    ``real=True`` prices the half-spectrum panel: ``halfspec_cols(n, p)``
    columns instead of ``n`` — the ~2x comm saving the rfft2 pipeline is
    for.

    ``hosts=None`` (every pre-multi-host call site) returns the legacy
    flat total as a float.  ``hosts=h`` returns the per-tier ``CommTiers``
    breakdown on an ``h``-host host-major axis (``l = p/h`` devices per
    host), for ``exchange`` = ``"flat"`` or ``"hier"``:

    * flat — of the ``M(p-1)/p`` exchanged bytes (M = whole-matrix
      bytes), the fraction with a same-host peer stays on the fast tier:
      intra ``M(l-1)/p``, inter ``M(p-l)/p``.
    * hier — the intra-host stage is a full-width all_to_all within each
      host, ``M(l-1)/l`` (more fast-tier volume: that is the aggregation
      cost), and the inter stage still moves ``M(h-1)/h = M(p-l)/p``; the
      win is slow-tier *message count*, priced in ``exchange_time``.
    """
    if p <= 1:
        return 0.0 if hosts is None else CommTiers(0.0, 0.0)
    cols = halfspec_cols(n, p) if real else n
    matrix = float(n) * float(cols) * itemsize
    total = matrix * (p - 1) / p
    if hosts is None:
        return total
    h = max(int(hosts), 1)
    if h <= 1 or p % h:
        return CommTiers(total, 0.0)
    l = p // h
    inter = matrix * (p - l) / p
    if exchange == "hier" and l > 1:
        return CommTiers(matrix * (l - 1) / l, inter)
    return CommTiers(matrix * (l - 1) / p, inter)


def exchange_time(total_bytes: float, p: int, *, params: "CostParams",
                  hosts: int = 1, exchange: str = "flat") -> float:
    """Seconds of one exchange round whose flat total volume is
    ``total_bytes`` over a ``p``-wide host-major axis.

    Single-host (or non-host-major) axes reduce to the legacy one-tier
    ``comm_phase_time``.  With ``hosts=h`` the volume splits across tiers
    per ``dist_comm_bytes`` and the slow tier pays a *per-message*
    latency: a flat all_to_all sends ``p - l`` inter-host messages per
    device, the hierarchical form aggregates them into ``h - 1`` — the
    latency saving that can buy back hier's extra intra-host volume.
    """
    if total_bytes <= 0 or p <= 1:
        return 0.0
    h = max(int(hosts), 1)
    if h <= 1 or p % h:
        return comm_phase_time(total_bytes, params.interconnect_bytes_per_s,
                               params.comm_latency_s)
    l = p // h
    matrix = float(total_bytes) * p / (p - 1)
    if exchange == "hier" and l > 1:
        intra, inter = matrix * (l - 1) / l, matrix * (p - l) / p
        inter_msgs = h - 1
    else:
        intra, inter = matrix * (l - 1) / p, matrix * (p - l) / p
        inter_msgs = p - l
    t = comm_phase_time(intra, params.interconnect_bytes_per_s,
                        params.comm_latency_s)
    if inter:
        t += inter / params.inter_bytes_per_s \
            + inter_msgs * params.inter_latency_s
    return t


def dist_comm_time(n: int, p: int, *, params: "CostParams", hosts: int = 1,
                   exchange: str = "flat",
                   itemsize: int = _COMPLEX64_BYTES,
                   real: bool = False) -> float:
    """Seconds of one 2-D phase's distributed transpose under the
    two-tier model (``dist_comm_bytes`` volume through
    ``exchange_time``)."""
    total = dist_comm_bytes(n, p, itemsize=itemsize, real=real)
    return exchange_time(total, p, params=params, hosts=hosts,
                         exchange=exchange)


def pfft3_comm_bytes(n: int, q: int, *,
                     itemsize: int = _COMPLEX64_BYTES) -> float:
    """Cross-device bytes of ONE pencil exchange round over a mesh axis of
    size ``q``.

    In a tiled all_to_all over ``q`` peers each device keeps ``1/q`` of
    its block and sends the rest, and every element of the N^3 cube lives
    on exactly one device, so one round moves ``N^3 * itemsize * (q-1)/q``
    bytes in total (0 on a degenerate 1-wide axis — the exchange is a
    local reshuffle).  The pencil transform prices *two* rounds (over the
    ``c`` axis, then the ``r`` axis) where the slab pays three — the
    saving ``estimate_pfft3_cost`` makes visible to the tuner.
    """
    if q <= 1:
        return 0.0
    return float(n) ** 3 * itemsize * (q - 1) / q


def estimate_pfft3_cost(config: PlanConfig, *, n: int, r: int = 1,
                        c: int = 1, params: CostParams | None = None,
                        pad_len: int | None = None,
                        itemsize: int = _COMPLEX64_BYTES,
                        hosts: int = 1) -> float:
    """Predicted seconds of the pencil-parallel 3-D PFFT under ``config``.

    Three local passes — each device transforms its ``N^2/(r*c)`` pencil
    rows at the effective length, paying the block's HBM round trip and a
    dispatch (plus one extra dispatch per extra pipeline panel) — and two
    priced exchange rounds: ``pfft3_comm_bytes`` over the ``c`` axis then
    the ``r`` axis, each overlapped by the panel factor exactly like the
    2-D model's comm term.  ``r = c = 1`` prices the single-host
    transform (no comm).  On a host-major pencil mesh (``hosts > 1``) the
    ``r`` axis is the one spanning hosts — its round goes through the
    two-tier ``exchange_time`` under ``config.exchange``, while ``c``-axis
    communicators live inside one host and stay on the fast tier.  Like
    the rest of the model, *ranking* is the point, not absolute seconds.
    """
    if params is None:
        params = CostParams.for_backend()
    q = max(int(r), 1) * max(int(c), 1)
    rows = max(n * n // q, 1)
    length = int(pad_len) if pad_len else n
    mult = _compute_multiplier(config, length, params)
    compute = float(fft_flops(rows, length)) / params.nominal_flops * mult
    traffic = 2.0 * rows * n * itemsize / params.hbm_bytes_per_s
    k = config.pipeline_panels
    phase = compute + traffic + k * params.dispatch_overhead_s
    comm = 0.0
    for q_ax, ax_hosts in ((int(c), 1), (int(r), max(int(hosts), 1))):
        bytes_ax = pfft3_comm_bytes(n, q_ax, itemsize=itemsize)
        t = exchange_time(bytes_ax, q_ax, params=params, hosts=ax_hosts,
                          exchange=config.exchange)
        if t and k > 1:
            t *= 1.0 - params.panel_overlap * (k - 1) / k
        comm += t
    return 3.0 * phase + comm


def _segment_work(n: int, d, pad_lengths) -> list[tuple[int, int]]:
    """(rows, effective FFT length) of each non-empty segment."""
    if d is None:
        return [(n, n)]
    d = np.asarray(d)
    out = []
    for i, rows in enumerate(d):
        if rows <= 0:
            continue
        length = n
        if pad_lengths is not None and int(pad_lengths[i]) > n:
            length = int(pad_lengths[i])
        out.append((int(rows), length))
    return out


def phase_dispatch_count(config: PlanConfig, n: int, d, pad_lengths) -> int:
    """Kernel dispatches of one (row FFT, transpose) phase under ``config``."""
    if config.fused:
        return 1
    if d is None:
        return 1
    if config.batched:
        from repro.core.pfft import plan_segment_batches  # lazy: avoids cycle
        return max(len(plan_segment_batches(np.asarray(d), pad_lengths, n)), 1)
    return max(int((np.asarray(d) > 0).sum()), 1)


def _factor_term(config: PlanConfig, length: int) -> tuple[str, float]:
    """(factor name, scale) with the backend factor left symbolic: the
    modelled multiplier is ``scale * factor[name]`` (``fused_factor`` for
    name 'fused').  The one home of the fallback/branch logic — both the
    estimate model and the calibration fit (``plan/calibrate.py``) build
    on it, so they can never drift apart."""
    if config.fused:
        return "fused", 1.0
    if config.pad == "czt":
        # The exact Bluestein path runs ~3 library FFTs at the padded
        # length per transform (czt_dft), whatever the radix says.
        return "xla", _CZT_FFT_FACTOR
    backend = config.fft_backend
    if backend != "xla" and not _is_pow2(length):
        # Kernel backends need pow2 lengths (fft_rows falls back to XLA
        # otherwise, and the model mirrors that).
        return "xla", 1.0
    if backend == "pallas":
        # Radix sets the Stockham pass count: radix 4 makes ceil(log2 n / 2)
        # trips over the data instead of log2 n.
        from repro.kernels.fft.kernel import stockham_stage_count
        log2n = max(int(np.log2(length)), 1)
        return "pallas", stockham_stage_count(length, config.radix or 4) \
            / log2n * 2.0
    return backend, 1.0


def _compute_multiplier(config: PlanConfig, length: int,
                        params: CostParams) -> float:
    """Per-segment compute multiplier under ``params`` (see _factor_term)."""
    name, scale = _factor_term(config, length)
    factor = (params.fused_factor if name == "fused"
              else params.backend_factor[name])
    return factor * scale


def estimate_cost(config: PlanConfig, *, n: int, d=None, pad_lengths=None,
                  fpms: FPMSet | None = None,
                  params: CostParams | None = None,
                  comm_bytes: float = 0.0, batch: int = 1,
                  comm_time_s: float | None = None) -> float:
    """Predicted seconds for a full 2-D PFFT (two limb phases) under ``config``.

    ``d``/``pad_lengths`` describe the partition (None: single whole-matrix
    segment); ``fpms`` supplies measured per-processor times when available;
    ``comm_bytes`` is the per-phase all_to_all volume of the distributed
    pipeline (0 single-host); ``batch`` prices a cohort of stacked
    signals riding one vmapped dispatch (see ``estimate_schedule_cost``).

    Delegates to ``estimate_schedule_cost`` of the degenerate
    every-segment-alike schedule — one copy of the phase formula, so the
    tuner's hetero-vs-homo comparison is unbiased by construction.
    """
    schedule = SegmentSchedule.homogeneous(
        config, n, d, pad_lengths if d is not None else None)
    return estimate_schedule_cost(schedule, fpms=fpms, params=params,
                                  comm_bytes=comm_bytes, batch=batch,
                                  comm_time_s=comm_time_s)


def estimate_schedule_cost(schedule: SegmentSchedule, *,
                           fpms: FPMSet | None = None,
                           params: CostParams | None = None,
                           comm_bytes: float = 0.0, batch: int = 1,
                           comm_time_s: float | None = None) -> float:
    """Predicted seconds for a full 2-D PFFT under a (possibly
    heterogeneous) schedule: two limb phases, each costing

        makespan + HBM traffic + dispatches * overhead  (+ overlapped comm)

    Each segment is priced with *its own* entry's config (its FPM
    ``time_at`` times that config's backend multiplier via
    ``_factor_term``); the makespan is their max (abstract processors run
    concurrently — paper semantics); the dispatch count is the number of
    ``(length, config)`` groups; fused schedules never materialise the
    intermediate matrix; ``pipeline_panels=k`` overlaps the comm term at
    (k-1) extra dispatches.  ``estimate_cost`` is the degenerate
    homogeneous view of this same formula.

    ``batch`` prices a *cohort*: ``batch`` same-(n, dtype, method)
    signals stacked on a leading axis and run through one vmapped
    dispatch (``PfftPlan.execute``'s batch dims).  Compute, HBM traffic,
    and comm volume scale with the batch while the per-dispatch
    overheads and the per-phase collective launch latency are paid once
    — the amortisation the serving layer's coalescing tick is priced by
    (predicted cohort cost is affine in the batch, so the tick assembler
    can solve for the largest admissible batch in closed form).
    """
    if params is None:
        params = CostParams.for_backend()
    n = schedule.n
    batch = max(int(batch), 1)

    def seg_time(e) -> float:
        if fpms is not None:
            t = fpms[e.index].time_at(e.rows, e.length)
        else:
            t = float(fft_flops(e.rows, e.length)) / params.nominal_flops
        t *= _compute_multiplier(e.config, e.length, params)
        if e.config.real:
            # Two real rows ride one complex FFT in phase 1 and phase 2
            # only touches the half spectrum; see _REAL_COMPUTE_FACTOR.
            t *= _REAL_COMPUTE_FACTOR
        return t

    makespan = batch * max((seg_time(e) for e in schedule.entries),
                           default=0.0)

    common = schedule.common_config
    fused = common is not None and common.fused
    all_real = all(e.config.real for e in schedule.entries) \
        and bool(schedule.entries)
    traffic = 0.0 if fused else (
        2.0 * batch * n * n * _COMPLEX64_BYTES / params.hbm_bytes_per_s)
    if all_real:
        # The intermediate matrix is the (n, n//2+1) half spectrum.
        traffic *= halfspec_cols(n) / n
    dispatches = 1 if fused else max(len(schedule.batch_groups()), 1)
    phase = makespan + traffic + dispatches * params.dispatch_overhead_s

    k = max(e.config.pipeline_panels for e in schedule.entries)
    comm = 0.0
    if comm_time_s is not None:
        # Tier-aware override: the caller already priced this phase's
        # exchange (``exchange_time`` on a host-major mesh) at batch=1.
        comm = float(comm_time_s) if comm_bytes else 0.0
    elif comm_bytes:
        # The all_to_all crosses the interconnect, not HBM; the fixed
        # collective-launch latency is paid once per phase (panels reuse
        # the issued collective stream).
        comm = comm_phase_time(batch * comm_bytes,
                               params.interconnect_bytes_per_s,
                               params.comm_latency_s)
    if k > 1:
        comm *= 1.0 - params.panel_overlap * (k - 1) / k
        phase += (k - 1) * params.dispatch_overhead_s

    return 2.0 * (phase + comm)


def estimate_grouped_cost(schedule: SegmentSchedule, *,
                          fpms: FPMSet | None = None,
                          params: CostParams | None = None,
                          comm_bytes: float = 0.0, batch: int = 1) -> float:
    """Predicted seconds for a schedule lowered as a *device-group program*
    (``repro.plan.groups``): the per-group makespan of
    ``estimate_schedule_cost`` plus the switch-dispatch overhead.

    The grouped SPMD program traces one ``lax.switch`` branch per
    distinct config, so each phase carries the branch bodies of every
    group through compilation and dispatch — modelled as one extra
    dispatch overhead per extra branch per phase.  The makespan itself is
    the shared per-entry formula (each segment priced with its own FPM
    ``time_at`` and its own entry's backend multiplier), so the
    grouped-vs-homogeneous comparison in ``tune_dist_schedule`` differs
    from the single-host one only by this term.
    """
    if params is None:
        params = CostParams.for_backend()
    base = estimate_schedule_cost(schedule, fpms=fpms, params=params,
                                  comm_bytes=comm_bytes, batch=batch)
    branches = len(schedule.configs)
    if branches > 1:
        base += 2.0 * (branches - 1) * params.dispatch_overhead_s
    return base
