"""Estimate/measure tuner — FFTW's planner loop over ``PlanConfig`` space.

``candidate_configs`` enumerates the valid variant space for a problem
(radix x fused x batched x pipeline_panels, pruned by structural
constraints); ``tune_config`` ranks it:

* ``mode="estimate"`` — cost model only (``plan.cost``), no device work.
  FFTW's ESTIMATE: instant, right whenever the model's ranking is.
* ``mode="measure"`` — time the ``top_k`` cheapest candidates on device
  (``measure_configs``: interleaved round-robin, per-config min) and take
  the winner.  FFTW's MEASURE: pays seconds once so every later execute
  is served by the best plan.

The caller (``plan_pfft`` / the microbenchmark) persists the result via
``plan.wisdom`` so measurement happens once per (n, dtype, p, method,
backend) per machine.
"""

from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from repro.core.fpm import FPMSet
from repro.plan.config import PlanConfig
from repro.plan.cost import (CostParams, _compute_multiplier, _segment_work,
                             estimate_cost, estimate_schedule_cost)
from repro.plan.schedule import SegmentSchedule

__all__ = ["candidate_configs", "segment_candidate_configs",
           "measure_configs", "tune_config", "tune_schedule"]


def _is_pow2(n: int) -> bool:
    return n > 0 and not (n & (n - 1))


def candidate_configs(n: int, *, pad: str = "none", d=None,
                      panels: Sequence[int] = (1,)) -> list[PlanConfig]:
    """Valid ``PlanConfig`` candidates for an n x n problem.

    ``pad`` is fixed by the method (it is semantics, not a tunable);
    ``fused`` requires a power-of-two N and no per-segment padding;
    the kernel radices require a power-of-two N (and the czt path runs
    library FFTs inside ``czt_dft`` whatever the radix says, so czt
    enumerates only the dispatch structure); ``batched`` only matters
    when the partition has more than one non-empty segment.
    """
    radices: list[int | None] = [None]
    if pad != "czt" and _is_pow2(n):
        radices += [2, 4]
    multi_segment = d is None or int((np.asarray(d) > 0).sum()) > 1
    batch_opts = (True, False) if multi_segment else (True,)

    out: list[PlanConfig] = []
    for k in panels:
        for radix in radices:
            for batched in batch_opts:
                out.append(PlanConfig(radix=radix, batched=batched, pad=pad,
                                      pipeline_panels=k))
        if pad == "none" and _is_pow2(n):
            # Fused collapses each phase to one dispatch; segmentation (and
            # therefore batched) is moot, and the kernel is radix-4.
            out.append(PlanConfig(radix=4, fused=True, pipeline_panels=k))
    return out


def segment_candidate_configs(length: int, *, pad: str = "none"
                              ) -> list[PlanConfig]:
    """Per-segment variants for one effective FFT length.

    A segment entry tunes only what is segment-local: the row-FFT backend
    (``radix``).  Phase-global knobs stay out of the per-segment space —
    ``fused`` collapses the whole matrix into one dispatch, ``batched``
    and ``pipeline_panels`` shape the phase, and they are all covered by
    the homogeneous envelope ``tune_schedule`` compares against.  The czt
    path has a single per-segment shape (``czt_dft`` at the entry's
    length), so it contributes exactly one candidate.
    """
    if pad == "czt":
        return [PlanConfig(pad="czt")]
    radices: list[int | None] = [None]
    if _is_pow2(length):
        radices += [2, 4]
    return [PlanConfig(radix=r, pad=pad) for r in radices]


def _length_backend(cfg: PlanConfig, length: int) -> tuple[str, int | None]:
    """Effective (backend, radix) for one length: kernel backends fall
    back to XLA on non-pow2 lengths (``fft_rows``); the one home of that
    rule for behavior keys and Pareto dedup."""
    kw = cfg.row_fft_kwargs()
    if kw["backend"] != "xla" and not _is_pow2(length):
        return "xla", None
    return kw["backend"], kw["radix"]


def _timed_min(pairs, x, rounds: int) -> dict:
    """{item: best seconds} over ``rounds`` shuffled-interleaved episodes.

    The shared timing discipline of every measure harness here: an
    untimed same-fn warm run before each timed one (evict the shuffled
    neighbour's allocator/cache state), per-item min across rounds.
    ``pairs``: [(item, compiled fn)].
    """
    import jax

    rng = np.random.default_rng(1)
    times = {item: float("inf") for item, _ in pairs}
    for _ in range(max(rounds, 1)):
        for i in rng.permutation(len(pairs)):
            item, fn = pairs[int(i)]
            jax.block_until_ready(fn(x))  # warm: evict neighbour's state
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            times[item] = min(times[item], time.perf_counter() - t0)
    return times


def measure_configs(configs: Sequence[PlanConfig | SegmentSchedule], n: int,
                    *, d=None, pad_lengths=None, dtype=np.complex64,
                    rounds: int = 3
                    ) -> dict[PlanConfig | SegmentSchedule, float]:
    """On-device seconds of the jitted limb per config: {config: best_s}.

    Interleaved in a per-round *shuffled* order, per-config min over
    ``rounds``, with an untimed same-config warm run before every timed
    one: close variants (batched vs looped) differ by far less than the
    episode-to-episode jitter, and a fixed visiting order would tax each
    config by whatever allocator/cache state its fixed neighbour leaves
    behind (one warm run does not fully neutralise an interpret-mode
    Pallas predecessor).  Shuffling varies the predecessor; min keeps
    each config's best-context episode.  This is the shared harness of
    measure-mode tuning and the planner microbenchmark.

    ``d=None`` means one whole-matrix segment (the cost model's
    convention).  Items may be ``PlanConfig``s *or* ``SegmentSchedule``s
    (both hashable) — ``tune_schedule``'s measure mode races assembled
    heterogeneous schedules against homogeneous configs in one pot.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.pfft import _pfft_limb  # lazy: core imports plan.config

    d_eff = np.asarray(d) if d is not None else np.array([n], dtype=np.int64)
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(dtype))
    pairs = []
    for item in configs:
        if isinstance(item, SegmentSchedule):
            kw = {"schedule": item}
        else:
            kw = {"pad_lengths": pad_lengths, "config": item}
        fn = jax.jit(lambda m, kw=kw: _pfft_limb(m, d_eff, **kw))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((item, fn))
    return _timed_min(pairs, x, rounds)


def _behavior_key(cfg: PlanConfig, n: int, d, pad_lengths) -> tuple:
    """What program actually runs under ``cfg`` for this problem.

    Kernel backends fall back to XLA for non-power-of-two effective
    lengths (``fft_rows``), so e.g. radix=None/2/4 are one and the same
    program when every padded length is non-pow2 — measuring more than
    one of them wastes the measure budget on rubber-stamping.
    """
    lengths = sorted({length for _, length in _segment_work(n, d, pad_lengths)})
    if cfg.fused:
        return ("fused", tuple(lengths))
    per_len = [(length,) + _length_backend(cfg, length) for length in lengths]
    return (cfg.batched, cfg.pipeline_panels, tuple(per_len))


def tune_config(n: int, *, d=None, pad_lengths=None, fpms: FPMSet | None = None,
                mode: str = "estimate", pad: str = "none",
                params: CostParams | None = None, top_k: int = 3,
                panels: Sequence[int] = (1,), comm_bytes: float = 0.0,
                dtype=np.complex64, reps: int = 3
                ) -> tuple[PlanConfig, dict]:
    """Pick the best ``PlanConfig`` for the problem; returns (config, info).

    ``info`` carries the full ranking (``"ranked"``: (config dict, predicted
    seconds), cheapest first) and, in measure mode, the on-device times of
    the ``top_k`` finalists (``"measured"``) — the planner's audit trail,
    also persisted into wisdom entries.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    if d is not None:
        d = np.asarray(d)

    cands = candidate_configs(n, pad=pad, d=d, panels=panels)
    if params is None:
        params = CostParams.for_backend()
    ranked = sorted(
        ((cfg, estimate_cost(cfg, n=n, d=d, pad_lengths=pad_lengths,
                             fpms=fpms, params=params, comm_bytes=comm_bytes))
         for cfg in cands),
        key=lambda kv: kv[1])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(), float(c)) for cfg, c in ranked],
    }

    if mode == "estimate":
        return ranked[0][0], info

    if comm_bytes:
        raise NotImplementedError(
            "measure mode times the single-host limb; distributed configs "
            "are estimate-only for now (ROADMAP open item)")
    # One finalist per distinct *program*: ties in the ranking are often
    # configs whose differences are erased by runtime fallbacks.
    finalists, seen = [], set()
    for cfg, _ in ranked:
        key = _behavior_key(cfg, n, d, pad_lengths)
        if key not in seen:
            seen.add(key)
            finalists.append(cfg)
        if len(finalists) >= max(top_k, 1):
            break
    measured = measure_configs(finalists, n, d=d, pad_lengths=pad_lengths,
                               dtype=dtype, rounds=reps)
    winner = min(measured, key=measured.get)
    info["measured"] = [(cfg.to_dict(), float(t)) for cfg, t in measured.items()]
    info["time_s"] = float(measured[winner])
    return winner, info


def _measure_length_group(configs: Sequence[PlanConfig], rows: int,
                          length: int, n: int, dtype, rounds: int
                          ) -> dict[PlanConfig, float]:
    """On-device seconds of one dispatch group's row-FFT program per config.

    The program is exactly what the schedule executor runs for a
    ``(length, config)`` group: gather ``rows`` rows of the N-wide
    matrix, pad to ``length`` (or chirp-Z at it), transform, crop.  Same
    shuffled-interleaved-min discipline as ``measure_configs``.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((rows, n))
                     + 1j * rng.standard_normal((rows, n))).astype(dtype))

    def group_fn(cfg: PlanConfig):
        if cfg.pad == "czt":
            from repro.core.pfft import czt_dft
            return lambda m: czt_dft(m, length)
        from repro.fft.fft2d import fft_rows
        kw = cfg.row_fft_kwargs()
        if length > n:
            return lambda m: fft_rows(
                jnp.pad(m, ((0, 0), (0, length - n))), **kw)[:, :n]
        return lambda m: fft_rows(m, **kw)

    pairs = []
    for cfg in configs:
        fn = jax.jit(group_fn(cfg))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((cfg, fn))
    return _timed_min(pairs, x, rounds)


def tune_schedule(n: int, *, d=None, pad_lengths=None,
                  fpms: FPMSet | None = None, mode: str = "estimate",
                  pad: str = "none", params: CostParams | None = None,
                  top_k: int = 3, panels: Sequence[int] = (1,),
                  comm_bytes: float = 0.0, dtype=np.complex64, reps: int = 3
                  ) -> tuple[SegmentSchedule, dict]:
    """Pick the best per-segment execution schedule; returns (schedule, info).

    The heterogeneous generalisation of ``tune_config``: candidate
    configs are priced *per distinct effective FFT length*, each segment
    with its own FPM ``time_at``, so a slow processor can keep the
    library FFT while pow2-padded fast processors take the kernel in the
    same phase.

    * Single-length problems are exactly the PR-2 homogeneous problem and
      delegate to ``tune_config`` (whose candidate space also covers
      ``fused``/``batched=False``/``pipeline_panels``).
    * Otherwise, estimate mode picks the per-group argmin under the
      makespan objective, then keeps the heterogeneous schedule only if
      it beats the best *homogeneous* config's estimate (dispatch counts
      included) — the makespan can only improve, but extra dispatch
      groups are not free.
    * Measure mode times only the Pareto top-``top_k`` candidates per
      length group (distinct behaviors, cheapest-estimate first), then
      races the assembled schedule against the homogeneous winner end to
      end; ``info["time_s"]`` is the winner's limb time.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    if d is not None:
        d = np.asarray(d)
    if params is None:
        params = CostParams.for_backend()

    # (processor index, rows, effective length) of each non-empty segment.
    idx = [i for i, rows in enumerate(np.asarray(d))
           if rows > 0] if d is not None else [0]
    segments = [(i, rows, length) for i, (rows, length)
                in zip(idx, _segment_work(n, d, pad_lengths))]
    groups: dict[int, list[tuple[int, int]]] = {}
    for i, rows, length in segments:
        groups.setdefault(length, []).append((i, rows))

    if len(groups) <= 1:
        cfg, info = tune_config(n, d=d, pad_lengths=pad_lengths, fpms=fpms,
                                mode=mode, pad=pad, params=params,
                                top_k=top_k, panels=panels,
                                comm_bytes=comm_bytes, dtype=dtype, reps=reps)
        schedule = SegmentSchedule.homogeneous(cfg, n, d, pad_lengths)
        info["chosen"] = "homogeneous"
        info["schedule"] = schedule.to_dict()
        return schedule, info

    if mode == "measure" and comm_bytes:
        raise NotImplementedError(
            "measure mode times the single-host limb; distributed configs "
            "are estimate-only for now (ROADMAP open item)")

    def group_time(cfg: PlanConfig, members, length: int) -> float:
        """Estimated makespan contribution of one length group under cfg."""
        def seg_t(i: int, rows: int) -> float:
            if fpms is not None:
                t = fpms[i].time_at(rows, length)
            else:
                from repro.core.fpm import fft_flops
                t = float(fft_flops(rows, length)) / params.nominal_flops
            return t * _compute_multiplier(cfg, length, params)
        return max(seg_t(i, rows) for i, rows in members)

    info: dict = {"mode": mode, "groups": {}}
    picks: dict[int, PlanConfig] = {}
    for length, members in groups.items():
        cands = segment_candidate_configs(length, pad=pad)
        ranked = sorted(((cfg, group_time(cfg, members, length))
                         for cfg in cands), key=lambda kv: kv[1])
        info["groups"][str(length)] = [(c.to_dict(), float(t))
                                       for c, t in ranked]
        if mode == "estimate":
            picks[length] = ranked[0][0]
            continue
        # Pareto finalists: one per distinct program (pow2 fallbacks erase
        # radix differences), cheapest-estimate first, at most top_k.
        finalists, seen = [], set()
        for cfg, _ in ranked:
            key = (cfg.pad,) + _length_backend(cfg, length)
            if key not in seen:
                seen.add(key)
                finalists.append(cfg)
            if len(finalists) >= max(top_k, 1):
                break
        measured = _measure_length_group(
            finalists, rows=sum(r for _, r in members), length=length,
            n=n, dtype=dtype, rounds=reps)
        picks[length] = min(measured, key=measured.get)
        info.setdefault("group_measured", {})[str(length)] = [
            (c.to_dict(), float(t)) for c, t in measured.items()]

    p = len(d) if d is not None else 1
    default = PlanConfig(pad=pad)
    # Per-processor config: its length group's pick (idle processors get
    # the default; they have no schedule entry anyway).
    eff = {i: length for i, _, length in segments}
    cfg_list = [picks.get(eff.get(i, n), default) for i in range(p)]
    hetero = SegmentSchedule.from_parts(n, d, pad_lengths, cfg_list)
    est_hetero = estimate_schedule_cost(hetero, fpms=fpms, params=params,
                                        comm_bytes=comm_bytes)

    # Homogeneous envelope: the full PR-2 candidate space under one config.
    homo_ranked = sorted(
        ((cfg, estimate_cost(cfg, n=n, d=d, pad_lengths=pad_lengths,
                             fpms=fpms, params=params, comm_bytes=comm_bytes))
         for cfg in candidate_configs(n, pad=pad, d=d, panels=panels)),
        key=lambda kv: kv[1])
    homo_cfg, est_homo = homo_ranked[0]
    homo = SegmentSchedule.homogeneous(homo_cfg, n, d, pad_lengths)
    info["ranked"] = [(c.to_dict(), float(t)) for c, t in homo_ranked]
    info["heterogeneous"] = {"schedule": hetero.to_dict(),
                             "est_s": float(est_hetero)}
    info["homogeneous"] = {"config": homo_cfg.to_dict(),
                           "est_s": float(est_homo)}

    if mode == "estimate":
        winner = homo if est_homo < est_hetero else hetero
    else:
        raced = measure_configs([hetero, homo], n, d=d,
                                pad_lengths=pad_lengths, dtype=dtype,
                                rounds=reps)
        winner = min(raced, key=raced.get)
        info["measured"] = [(s.describe(), float(t)) for s, t in raced.items()]
        info["time_s"] = float(raced[winner])
    info["chosen"] = ("heterogeneous" if len(winner.configs) > 1
                      else "homogeneous")
    info["schedule"] = winner.to_dict()
    return winner, info
