"""Estimate/measure tuner — FFTW's planner loop over ``PlanConfig`` space.

``candidate_configs`` enumerates the valid variant space for a problem
(radix x fused x batched x pipeline_panels, pruned by structural
constraints); ``tune_config`` ranks it:

* ``mode="estimate"`` — cost model only (``plan.cost``), no device work.
  FFTW's ESTIMATE: instant, right whenever the model's ranking is.
* ``mode="measure"`` — time the ``top_k`` cheapest candidates on device
  (``measure_configs``: interleaved round-robin, per-config min) and take
  the winner.  FFTW's MEASURE: pays seconds once so every later execute
  is served by the best plan.

The caller (``plan_pfft`` / the microbenchmark) persists the result via
``plan.wisdom`` so measurement happens once per (n, dtype, p, method,
backend) per machine.
"""

from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from repro.core.fpm import FPMSet
from repro.plan.config import PlanConfig
from repro.plan.cost import CostParams, _segment_work, estimate_cost

__all__ = ["candidate_configs", "measure_configs", "tune_config"]


def _is_pow2(n: int) -> bool:
    return n > 0 and not (n & (n - 1))


def candidate_configs(n: int, *, pad: str = "none", d=None,
                      panels: Sequence[int] = (1,)) -> list[PlanConfig]:
    """Valid ``PlanConfig`` candidates for an n x n problem.

    ``pad`` is fixed by the method (it is semantics, not a tunable);
    ``fused`` requires a power-of-two N and no per-segment padding;
    the kernel radices require a power-of-two N; ``batched`` only
    matters when the partition has more than one non-empty segment.
    """
    radices: list[int | None] = [None]
    if _is_pow2(n):
        radices += [2, 4]
    multi_segment = d is None or int((np.asarray(d) > 0).sum()) > 1
    batch_opts = (True, False) if multi_segment else (True,)

    out: list[PlanConfig] = []
    for k in panels:
        for radix in radices:
            for batched in batch_opts:
                out.append(PlanConfig(radix=radix, batched=batched, pad=pad,
                                      pipeline_panels=k))
        if pad == "none" and _is_pow2(n):
            # Fused collapses each phase to one dispatch; segmentation (and
            # therefore batched) is moot, and the kernel is radix-4.
            out.append(PlanConfig(radix=4, fused=True, pipeline_panels=k))
    return out


def measure_configs(configs: Sequence[PlanConfig], n: int, *, d=None,
                    pad_lengths=None, dtype=np.complex64,
                    rounds: int = 3) -> dict[PlanConfig, float]:
    """On-device seconds of the jitted limb per config: {config: best_s}.

    Interleaved in a per-round *shuffled* order, per-config min over
    ``rounds``, with an untimed same-config warm run before every timed
    one: close variants (batched vs looped) differ by far less than the
    episode-to-episode jitter, and a fixed visiting order would tax each
    config by whatever allocator/cache state its fixed neighbour leaves
    behind (one warm run does not fully neutralise an interpret-mode
    Pallas predecessor).  Shuffling varies the predecessor; min keeps
    each config's best-context episode.  This is the shared harness of
    measure-mode tuning and the planner microbenchmark.

    ``d=None`` means one whole-matrix segment (the cost model's
    convention).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.pfft import _pfft_limb  # lazy: core imports plan.config

    d_eff = np.asarray(d) if d is not None else np.array([n], dtype=np.int64)
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(dtype))
    pairs = []
    for cfg in configs:
        fn = jax.jit(lambda m, c=cfg: _pfft_limb(m, d_eff,
                                                 pad_lengths=pad_lengths,
                                                 config=c))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((cfg, fn))
    times = {cfg: float("inf") for cfg, _ in pairs}
    for _ in range(max(rounds, 1)):
        for i in rng.permutation(len(pairs)):
            cfg, fn = pairs[int(i)]
            jax.block_until_ready(fn(x))  # warm: evict neighbour's state
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            times[cfg] = min(times[cfg], time.perf_counter() - t0)
    return times


def _behavior_key(cfg: PlanConfig, n: int, d, pad_lengths) -> tuple:
    """What program actually runs under ``cfg`` for this problem.

    Kernel backends fall back to XLA for non-power-of-two effective
    lengths (``fft_rows``), so e.g. radix=None/2/4 are one and the same
    program when every padded length is non-pow2 — measuring more than
    one of them wastes the measure budget on rubber-stamping.
    """
    lengths = sorted({length for _, length in _segment_work(n, d, pad_lengths)})
    if cfg.fused:
        return ("fused", tuple(lengths))
    per_len = []
    for length in lengths:
        kw = cfg.row_fft_kwargs()
        if kw["backend"] != "xla" and (length & (length - 1)):
            kw = {"backend": "xla", "radix": None}
        per_len.append((length, kw["backend"], kw["radix"]))
    return (cfg.batched, cfg.pipeline_panels, tuple(per_len))


def tune_config(n: int, *, d=None, pad_lengths=None, fpms: FPMSet | None = None,
                mode: str = "estimate", pad: str = "none",
                params: CostParams | None = None, top_k: int = 3,
                panels: Sequence[int] = (1,), comm_bytes: float = 0.0,
                dtype=np.complex64, reps: int = 3
                ) -> tuple[PlanConfig, dict]:
    """Pick the best ``PlanConfig`` for the problem; returns (config, info).

    ``info`` carries the full ranking (``"ranked"``: (config dict, predicted
    seconds), cheapest first) and, in measure mode, the on-device times of
    the ``top_k`` finalists (``"measured"``) — the planner's audit trail,
    also persisted into wisdom entries.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    if d is not None:
        d = np.asarray(d)

    cands = candidate_configs(n, pad=pad, d=d, panels=panels)
    if params is None:
        params = CostParams.for_backend()
    ranked = sorted(
        ((cfg, estimate_cost(cfg, n=n, d=d, pad_lengths=pad_lengths,
                             fpms=fpms, params=params, comm_bytes=comm_bytes))
         for cfg in cands),
        key=lambda kv: kv[1])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(), float(c)) for cfg, c in ranked],
    }

    if mode == "estimate":
        return ranked[0][0], info

    if comm_bytes:
        raise NotImplementedError(
            "measure mode times the single-host limb; distributed configs "
            "are estimate-only for now (ROADMAP open item)")
    # One finalist per distinct *program*: ties in the ranking are often
    # configs whose differences are erased by runtime fallbacks.
    finalists, seen = [], set()
    for cfg, _ in ranked:
        key = _behavior_key(cfg, n, d, pad_lengths)
        if key not in seen:
            seen.add(key)
            finalists.append(cfg)
        if len(finalists) >= max(top_k, 1):
            break
    measured = measure_configs(finalists, n, d=d, pad_lengths=pad_lengths,
                               dtype=dtype, rounds=reps)
    winner = min(measured, key=measured.get)
    info["measured"] = [(cfg.to_dict(), float(t)) for cfg, t in measured.items()]
    info["time_s"] = float(measured[winner])
    return winner, info
