"""Estimate/measure tuner — FFTW's planner loop over ``PlanConfig`` space.

``candidate_configs`` enumerates the valid variant space for a problem
(radix x fused x batched x pipeline_panels, pruned by structural
constraints); ``tune_config`` ranks it:

* ``mode="estimate"`` — cost model only (``plan.cost``), no device work.
  FFTW's ESTIMATE: instant, right whenever the model's ranking is.
* ``mode="measure"`` — time the ``top_k`` cheapest candidates on device
  (``measure_configs``: interleaved round-robin, per-config min) and take
  the winner.  FFTW's MEASURE: pays seconds once so every later execute
  is served by the best plan.

The caller (``plan_pfft`` / the microbenchmark) persists the result via
``plan.wisdom`` so measurement happens once per (n, dtype, p, method,
backend) per machine.
"""

from __future__ import annotations

import functools
import time
from typing import Sequence

import numpy as np

from repro.core.fpm import FPMSet
from repro.plan.config import PlanConfig
from repro.plan.cost import (CostParams, _compute_multiplier, _segment_work,
                             comm_phase_time, dist_comm_bytes, dist_comm_time,
                             estimate_cost, estimate_grouped_cost,
                             estimate_pfft3_cost, estimate_schedule_cost,
                             exchange_time, pfft3_comm_bytes)
from repro.plan.schedule import SegmentSchedule

__all__ = ["candidate_configs", "segment_candidate_configs",
           "measure_configs", "measure_dist_configs", "tune_config",
           "tune_schedule", "tune_dist_config", "tune_dist_schedule",
           "grouped_dist_schedule", "dist_panel_space",
           "measure_rfft_configs", "measure_rfft_dist_configs",
           "tune_rfft", "tune_rfft_dist",
           "pfft3_panel_space", "measure_pfft3_configs", "tune_pfft3",
           "tune_pfft1_large"]


def _is_pow2(n: int) -> bool:
    return n > 0 and not (n & (n - 1))


def _measure_with_retry(thunk, retries: int, base_s: float = 0.05):
    """Run a measurement thunk, retrying transient failures with
    exponential backoff; re-raises after the budget is exhausted.

    Device measurement is the tuner's only fallible step (a transiently
    wedged device, an allocator hiccup mid-chaos) — the distributed
    tuners call this when ``measure_retries > 0`` and fall back to the
    estimate ranking (``info["measure_fallback"]``) if even the retries
    fail, so a flaky measurement degrades a *plan choice*, never the
    caller.  ``retries=0`` (the default everywhere) keeps the historical
    raise-through behavior.
    """
    delay = float(base_s)
    for attempt in range(int(retries) + 1):
        try:
            return thunk()
        except Exception:
            if attempt >= retries:
                raise
            time.sleep(delay)
            delay *= 2.0


def candidate_configs(n: int, *, pad: str = "none", d=None,
                      panels: Sequence[int] = (1,)) -> list[PlanConfig]:
    """Valid ``PlanConfig`` candidates for an n x n problem.

    ``pad`` is fixed by the method (it is semantics, not a tunable);
    ``fused`` requires a power-of-two N and no per-segment padding;
    the kernel radices require a power-of-two N (and the czt path runs
    library FFTs inside ``czt_dft`` whatever the radix says, so czt
    enumerates only the dispatch structure); ``batched`` only matters
    when the partition has more than one non-empty segment.
    """
    radices: list[int | None] = [None]
    if pad != "czt" and _is_pow2(n):
        radices += [2, 4]
    multi_segment = d is None or int((np.asarray(d) > 0).sum()) > 1
    batch_opts = (True, False) if multi_segment else (True,)

    out: list[PlanConfig] = []
    for k in panels:
        for radix in radices:
            for batched in batch_opts:
                out.append(PlanConfig(radix=radix, batched=batched, pad=pad,
                                      pipeline_panels=k))
        if pad == "none" and _is_pow2(n):
            # Fused collapses each phase to one dispatch; segmentation (and
            # therefore batched) is moot, and the kernel is radix-4.
            out.append(PlanConfig(radix=4, fused=True, pipeline_panels=k))
    return out


def segment_candidate_configs(length: int, *, pad: str = "none"
                              ) -> list[PlanConfig]:
    """Per-segment variants for one effective FFT length.

    A segment entry tunes only what is segment-local: the row-FFT backend
    (``radix``).  Phase-global knobs stay out of the per-segment space —
    ``fused`` collapses the whole matrix into one dispatch, ``batched``
    and ``pipeline_panels`` shape the phase, and they are all covered by
    the homogeneous envelope ``tune_schedule`` compares against.  The czt
    path has a single per-segment shape (``czt_dft`` at the entry's
    length), so it contributes exactly one candidate.
    """
    if pad == "czt":
        return [PlanConfig(pad="czt")]
    radices: list[int | None] = [None]
    if _is_pow2(length):
        radices += [2, 4]
    return [PlanConfig(radix=r, pad=pad) for r in radices]


def _length_backend(cfg: PlanConfig, length: int) -> tuple[str, int | None]:
    """Effective (backend, radix) for one length: kernel backends fall
    back to XLA on non-pow2 lengths (``fft_rows``); the one home of that
    rule for behavior keys and Pareto dedup."""
    kw = cfg.row_fft_kwargs()
    if kw["backend"] != "xla" and not _is_pow2(length):
        return "xla", None
    return kw["backend"], kw["radix"]


def _timed_min(pairs, x, rounds: int) -> dict:
    """{item: best seconds} over ``rounds`` shuffled-interleaved episodes.

    The shared timing discipline of every measure harness here: an
    untimed same-fn warm run before each timed one (evict the shuffled
    neighbour's allocator/cache state), per-item min across rounds.
    ``pairs``: [(item, compiled fn)].
    """
    import jax

    rng = np.random.default_rng(1)
    times = {item: float("inf") for item, _ in pairs}
    for _ in range(max(rounds, 1)):
        for i in rng.permutation(len(pairs)):
            item, fn = pairs[int(i)]
            jax.block_until_ready(fn(x))  # warm: evict neighbour's state
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            times[item] = min(times[item], time.perf_counter() - t0)
    return times


def measure_configs(configs: Sequence[PlanConfig | SegmentSchedule], n: int,
                    *, d=None, pad_lengths=None, dtype=np.complex64,
                    rounds: int = 3
                    ) -> dict[PlanConfig | SegmentSchedule, float]:
    """On-device seconds of the jitted limb per config: {config: best_s}.

    Interleaved in a per-round *shuffled* order, per-config min over
    ``rounds``, with an untimed same-config warm run before every timed
    one: close variants (batched vs looped) differ by far less than the
    episode-to-episode jitter, and a fixed visiting order would tax each
    config by whatever allocator/cache state its fixed neighbour leaves
    behind (one warm run does not fully neutralise an interpret-mode
    Pallas predecessor).  Shuffling varies the predecessor; min keeps
    each config's best-context episode.  This is the shared harness of
    measure-mode tuning and the planner microbenchmark.

    ``d=None`` means one whole-matrix segment (the cost model's
    convention).  Items may be ``PlanConfig``s *or* ``SegmentSchedule``s
    (both hashable) — ``tune_schedule``'s measure mode races assembled
    heterogeneous schedules against homogeneous configs in one pot.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.pfft import _pfft_limb  # lazy: core imports plan.config

    d_eff = np.asarray(d) if d is not None else np.array([n], dtype=np.int64)
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(dtype))
    pairs = []
    for item in configs:
        if isinstance(item, SegmentSchedule):
            kw = {"schedule": item}
        else:
            kw = {"pad_lengths": pad_lengths, "config": item}
        fn = jax.jit(lambda m, kw=kw: _pfft_limb(m, d_eff, **kw))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((item, fn))
    return _timed_min(pairs, x, rounds)


def _behavior_key(cfg: PlanConfig, n: int, d, pad_lengths) -> tuple:
    """What program actually runs under ``cfg`` for this problem.

    Kernel backends fall back to XLA for non-power-of-two effective
    lengths (``fft_rows``), so e.g. radix=None/2/4 are one and the same
    program when every padded length is non-pow2 — measuring more than
    one of them wastes the measure budget on rubber-stamping.
    """
    lengths = sorted({length for _, length in _segment_work(n, d, pad_lengths)})
    if cfg.fused:
        return ("fused", cfg.real, cfg.exchange, tuple(lengths))
    per_len = [(length,) + _length_backend(cfg, length) for length in lengths]
    return (cfg.batched, cfg.pipeline_panels, cfg.real, cfg.exchange,
            tuple(per_len))


def tune_config(n: int, *, d=None, pad_lengths=None, fpms: FPMSet | None = None,
                mode: str = "estimate", pad: str = "none",
                params: CostParams | None = None, top_k: int = 3,
                panels: Sequence[int] = (1,), comm_bytes: float = 0.0,
                dtype=np.complex64, reps: int = 3
                ) -> tuple[PlanConfig, dict]:
    """Pick the best ``PlanConfig`` for the problem; returns (config, info).

    ``info`` carries the full ranking (``"ranked"``: (config dict, predicted
    seconds), cheapest first) and, in measure mode, the on-device times of
    the ``top_k`` finalists (``"measured"``) — the planner's audit trail,
    also persisted into wisdom entries.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    if d is not None:
        d = np.asarray(d)

    cands = candidate_configs(n, pad=pad, d=d, panels=panels)
    if params is None:
        params = CostParams.for_backend()
    ranked = sorted(
        ((cfg, estimate_cost(cfg, n=n, d=d, pad_lengths=pad_lengths,
                             fpms=fpms, params=params, comm_bytes=comm_bytes))
         for cfg in cands),
        key=lambda kv: kv[1])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(), float(c)) for cfg, c in ranked],
    }

    if mode == "estimate":
        return ranked[0][0], info

    if comm_bytes:
        raise ValueError(
            "measure mode with comm_bytes needs the mesh the bytes cross — "
            "use tune_dist_config(mesh=...) to time the distributed "
            "pipeline end to end")
    # One finalist per distinct *program*: ties in the ranking are often
    # configs whose differences are erased by runtime fallbacks.
    finalists, seen = [], set()
    for cfg, _ in ranked:
        key = _behavior_key(cfg, n, d, pad_lengths)
        if key not in seen:
            seen.add(key)
            finalists.append(cfg)
        if len(finalists) >= max(top_k, 1):
            break
    measured = measure_configs(finalists, n, d=d, pad_lengths=pad_lengths,
                               dtype=dtype, rounds=reps)
    winner = min(measured, key=measured.get)
    info["measured"] = [(cfg.to_dict(), float(t)) for cfg, t in measured.items()]
    info["time_s"] = float(measured[winner])
    return winner, info


def _measure_length_group(configs: Sequence[PlanConfig], rows: int,
                          length: int, n: int, dtype, rounds: int
                          ) -> dict[PlanConfig, float]:
    """On-device seconds of one dispatch group's row-FFT program per config.

    The program is exactly what the schedule executor runs for a
    ``(length, config)`` group: gather ``rows`` rows of the N-wide
    matrix, pad to ``length`` (or chirp-Z at it), transform, crop.  Same
    shuffled-interleaved-min discipline as ``measure_configs``.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((rows, n))
                     + 1j * rng.standard_normal((rows, n))).astype(dtype))

    def group_fn(cfg: PlanConfig):
        if cfg.pad == "czt":
            from repro.core.pfft import czt_dft
            return lambda m: czt_dft(m, length)
        from repro.fft.fft2d import fft_rows
        kw = cfg.row_fft_kwargs()
        if length > n:
            return lambda m: fft_rows(
                jnp.pad(m, ((0, 0), (0, length - n))), **kw)[:, :n]
        return lambda m: fft_rows(m, **kw)

    pairs = []
    for cfg in configs:
        fn = jax.jit(group_fn(cfg))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((cfg, fn))
    return _timed_min(pairs, x, rounds)


def tune_schedule(n: int, *, d=None, pad_lengths=None,
                  fpms: FPMSet | None = None, mode: str = "estimate",
                  pad: str = "none", params: CostParams | None = None,
                  top_k: int = 3, panels: Sequence[int] = (1,),
                  comm_bytes: float = 0.0, dtype=np.complex64, reps: int = 3
                  ) -> tuple[SegmentSchedule, dict]:
    """Pick the best per-segment execution schedule; returns (schedule, info).

    The heterogeneous generalisation of ``tune_config``: candidate
    configs are priced *per distinct effective FFT length*, each segment
    with its own FPM ``time_at``, so a slow processor can keep the
    library FFT while pow2-padded fast processors take the kernel in the
    same phase.

    * Single-length problems are exactly the PR-2 homogeneous problem and
      delegate to ``tune_config`` (whose candidate space also covers
      ``fused``/``batched=False``/``pipeline_panels``).
    * Otherwise, estimate mode picks the per-group argmin under the
      makespan objective, then keeps the heterogeneous schedule only if
      it beats the best *homogeneous* config's estimate (dispatch counts
      included) — the makespan can only improve, but extra dispatch
      groups are not free.
    * Measure mode times only the Pareto top-``top_k`` candidates per
      length group (distinct behaviors, cheapest-estimate first), then
      races the assembled schedule against the homogeneous winner end to
      end; ``info["time_s"]`` is the winner's limb time.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    if d is not None:
        d = np.asarray(d)
    if params is None:
        params = CostParams.for_backend()

    # (processor index, rows, effective length) of each non-empty segment.
    idx = [i for i, rows in enumerate(np.asarray(d))
           if rows > 0] if d is not None else [0]
    segments = [(i, rows, length) for i, (rows, length)
                in zip(idx, _segment_work(n, d, pad_lengths))]
    groups: dict[int, list[tuple[int, int]]] = {}
    for i, rows, length in segments:
        groups.setdefault(length, []).append((i, rows))

    if len(groups) <= 1:
        cfg, info = tune_config(n, d=d, pad_lengths=pad_lengths, fpms=fpms,
                                mode=mode, pad=pad, params=params,
                                top_k=top_k, panels=panels,
                                comm_bytes=comm_bytes, dtype=dtype, reps=reps)
        schedule = SegmentSchedule.homogeneous(cfg, n, d, pad_lengths)
        info["chosen"] = "homogeneous"
        info["schedule"] = schedule.to_dict()
        return schedule, info

    if mode == "measure" and comm_bytes:
        raise ValueError(
            "measure mode with comm_bytes needs the mesh the bytes cross — "
            "use tune_dist_schedule(mesh=...) to time the distributed "
            "pipeline end to end")

    def group_time(cfg: PlanConfig, members, length: int) -> float:
        """Estimated makespan contribution of one length group under cfg."""
        def seg_t(i: int, rows: int) -> float:
            if fpms is not None:
                t = fpms[i].time_at(rows, length)
            else:
                from repro.core.fpm import fft_flops
                t = float(fft_flops(rows, length)) / params.nominal_flops
            return t * _compute_multiplier(cfg, length, params)
        return max(seg_t(i, rows) for i, rows in members)

    info: dict = {"mode": mode, "groups": {}}
    picks: dict[int, PlanConfig] = {}
    for length, members in groups.items():
        cands = segment_candidate_configs(length, pad=pad)
        ranked = sorted(((cfg, group_time(cfg, members, length))
                         for cfg in cands), key=lambda kv: kv[1])
        info["groups"][str(length)] = [(c.to_dict(), float(t))
                                       for c, t in ranked]
        if mode == "estimate":
            picks[length] = ranked[0][0]
            continue
        # Pareto finalists: one per distinct program (pow2 fallbacks erase
        # radix differences), cheapest-estimate first, at most top_k.
        finalists, seen = [], set()
        for cfg, _ in ranked:
            key = (cfg.pad,) + _length_backend(cfg, length)
            if key not in seen:
                seen.add(key)
                finalists.append(cfg)
            if len(finalists) >= max(top_k, 1):
                break
        measured = _measure_length_group(
            finalists, rows=sum(r for _, r in members), length=length,
            n=n, dtype=dtype, rounds=reps)
        picks[length] = min(measured, key=measured.get)
        info.setdefault("group_measured", {})[str(length)] = [
            (c.to_dict(), float(t)) for c, t in measured.items()]

    p = len(d) if d is not None else 1
    default = PlanConfig(pad=pad)
    # Per-processor config: its length group's pick (idle processors get
    # the default; they have no schedule entry anyway).
    eff = {i: length for i, _, length in segments}
    cfg_list = [picks.get(eff.get(i, n), default) for i in range(p)]
    hetero = SegmentSchedule.from_parts(n, d, pad_lengths, cfg_list)
    est_hetero = estimate_schedule_cost(hetero, fpms=fpms, params=params,
                                        comm_bytes=comm_bytes)

    # Homogeneous envelope: the full PR-2 candidate space under one config.
    homo_ranked = sorted(
        ((cfg, estimate_cost(cfg, n=n, d=d, pad_lengths=pad_lengths,
                             fpms=fpms, params=params, comm_bytes=comm_bytes))
         for cfg in candidate_configs(n, pad=pad, d=d, panels=panels)),
        key=lambda kv: kv[1])
    homo_cfg, est_homo = homo_ranked[0]
    homo = SegmentSchedule.homogeneous(homo_cfg, n, d, pad_lengths)
    info["ranked"] = [(c.to_dict(), float(t)) for c, t in homo_ranked]
    info["heterogeneous"] = {"schedule": hetero.to_dict(),
                             "est_s": float(est_hetero)}
    info["homogeneous"] = {"config": homo_cfg.to_dict(),
                           "est_s": float(est_homo)}

    if mode == "estimate":
        winner = homo if est_homo < est_hetero else hetero
    else:
        raced = measure_configs([hetero, homo], n, d=d,
                                pad_lengths=pad_lengths, dtype=dtype,
                                rounds=reps)
        winner = min(raced, key=raced.get)
        info["measured"] = [(s.describe(), float(t)) for s, t in raced.items()]
        info["time_s"] = float(raced[winner])
    info["chosen"] = ("heterogeneous" if len(winner.configs) > 1
                      else "homogeneous")
    info["schedule"] = winner.to_dict()
    return winner, info


# --------------------------------------------------------------- distributed

def dist_panel_space(n: int, p: int, max_panels: int = 8) -> tuple[int, ...]:
    """Candidate ``pipeline_panels`` for an n x n problem on p devices:
    the powers of two up to ``max_panels`` that divide the local row count
    (``pfft2_distributed`` requires k | N/p).  The one home of the rule —
    the tuner, ``plan_pfft(mesh=...)``, and the microbench all enumerate
    (and digest) the same space.

    ``max_panels`` defaults to 8 so the full ``(1, 2, 4, 8)`` literal is
    reachable (it used to be silently capped at 4, making the 8-panel
    candidate dead code).  The panel space is part of the topology
    digest, so stores tuned under the old cap simply re-tune: a
    different candidate space is a different tuning experiment.
    """
    if p <= 0 or n % p:
        return (1,)
    n_loc = n // p
    ks = [k for k in (1, 2, 4, 8) if k <= max_panels and n_loc % k == 0]
    return tuple(ks) or (1,)


def _measure_local_phase(cfg: PlanConfig, n: int, p: int, pad_len: int,
                         dtype, rounds: int) -> float:
    """Seconds of one *local* phase limb of the distributed pipeline: the
    row-FFT program one device runs on its (N/p, N) block, without the
    ``all_to_all``.  Subtracting two of these from the end-to-end time is
    what turns a distributed measurement into a *comm* sample."""
    import jax
    import jax.numpy as jnp
    from repro.core.pfft_dist import _local_fft  # lazy: core imports plan

    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((max(n // p, 1), n))
                     + 1j * rng.standard_normal((max(n // p, 1), n))
                     ).astype(dtype))
    fn = jax.jit(lambda b: _local_fft(b, n, padded=cfg.dist_padded,
                                      pad_len=pad_len, config=cfg,
                                      backend=None))
    jax.block_until_ready(fn(x))  # compile
    return min(_timed_min([(cfg, fn)], x, rounds).values())


def _measure_tier_exchange(mesh, axis_name: str, n: int, hosts: int,
                           local: int, tier: str, dtype,
                           rounds: int) -> float:
    """Seconds of ONE grouped ``all_to_all`` over only ``tier``'s groups.

    Times exactly one stage of the hierarchical exchange on the caller's
    mesh — intra-host groups (the fast tier) or inter-host groups (the
    slow tier) — on the full row-sharded N x N matrix, so the sample's
    byte count is the per-exchange tier volume ``dist_comm_bytes(...,
    hosts=, exchange="hier")`` predicts.  These tier-tagged samples are
    what ``plan/calibrate.py`` fits the two-tier comm params from.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.pfft_dist import _hier_groups  # lazy: core imports plan

    intra, inter = _hier_groups(hosts, local)
    groups = intra if tier == "intra" else inter
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(dtype))
    x = jax.device_put(x, NamedSharding(mesh, P(axis_name, None)))

    @jax.jit
    @functools.partial(shard_map, mesh=mesh, in_specs=(P(axis_name, None),),
                       out_specs=P(axis_name, None), check_rep=False)
    def ex(block):
        return jax.lax.all_to_all(block, axis_name, split_axis=1,
                                  concat_axis=0, tiled=True,
                                  axis_index_groups=groups)

    jax.block_until_ready(ex(x))  # compile
    return min(_timed_min([(tier, ex)], x, rounds).values())


def measure_dist_configs(configs: Sequence[PlanConfig | SegmentSchedule],
                         n: int, mesh, axis_name: str = "fft", *,
                         pad_len: int | None = None, dtype=np.complex64,
                         rounds: int = 3
                         ) -> dict[PlanConfig | SegmentSchedule, float]:
    """End-to-end on-device seconds of ``pfft2_distributed`` per config.

    Unlike ``measure_configs`` (which times the single-host limb and so
    prices ``comm_bytes`` candidates by model alone), this times the full
    pipeline — both all_to_all exchanges, pipelined panels, fused local
    phases — on the caller's actual ``Mesh``.  Same shuffled-interleaved
    per-config-min harness (``_timed_min``); the input is laid out
    row-sharded over ``axis_name`` first so placement cost is not billed
    to whichever config runs first.

    Items may be ``PlanConfig``s *or* ``SegmentSchedule``s —
    ``tune_dist_schedule`` races assembled heterogeneous (device-group)
    schedules against homogeneous finalists in one pot; a schedule runs
    with its own entry lengths (the uniform-length rule), so ``pad_len``
    applies only to bare configs.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.pfft_dist import pfft2_distributed  # lazy

    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((n, n))
                     + 1j * rng.standard_normal((n, n))).astype(dtype))
    x = jax.device_put(x, NamedSharding(mesh, P(axis_name, None)))
    pairs = []
    for item in configs:
        if isinstance(item, SegmentSchedule):
            kw = {"schedule": item}
        else:
            kw = {"config": item, "pad_len": pad_len}
        fn = jax.jit(functools.partial(pfft2_distributed, mesh=mesh,
                                       axis_name=axis_name, **kw))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((item, fn))
    return _timed_min(pairs, x, rounds)


def tune_dist_config(n: int, mesh, axis_name: str = "fft", *,
                     mode: str = "estimate", pad: str = "none",
                     pad_len: int | None = None, fpms: FPMSet | None = None,
                     params: CostParams | None = None, top_k: int = 3,
                     panels: Sequence[int] | None = None,
                     dtype=np.complex64, reps: int = 3,
                     measure_retries: int = 0
                     ) -> tuple[PlanConfig, dict]:
    """Pick the best ``PlanConfig`` for ``pfft2_distributed`` on ``mesh``.

    The distributed sibling of ``tune_config``: candidates are ranked with
    the comm term filled in from the mesh (``dist_comm_bytes``), and
    ``mode="measure"`` races the ``top_k`` distinct finalists through the
    *full* pipeline on the mesh — both all_to_all phases included — via
    ``measure_dist_configs``, instead of pricing comm by model alone.

    On a 1-device mesh measure falls back to estimate (there is no
    interconnect to measure; the degenerate all_to_all is a reshuffle) and
    ``info["measure_fallback"]`` says so.

    ``info["dist"]`` carries the topology facts and, after a measured run,
    the comm sample: ``comm_time_meas_s = total − 2·local_phase``
    (clamped at 0), the number ``plan/calibrate.py`` fits
    ``interconnect_bytes_per_s``/``comm_latency_s`` from.  Both
    ``comm_time_est_s`` and ``comm_time_meas_s`` cover the transform's
    *two* all_to_all phases, so they compare directly.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    p = int(mesh.shape[axis_name])
    if n % p:
        raise ValueError(f"N={n} must be divisible by mesh axis "
                         f"{axis_name}={p}")
    if panels is None:
        panels = dist_panel_space(n, p)
    if params is None:
        params = CostParams.for_backend()
    comm_bytes = dist_comm_bytes(n, p)
    from repro.launch.mesh import mesh_host_shape  # lazy: launch is thin
    hosts, local = mesh_host_shape(mesh, axis_name)

    # ``batched`` shapes the segment dispatch plan; the dist pipeline has
    # one whole-block segment per device, so the knob is meaningless here
    # and would only burn finalist slots on identical programs.
    cands = [c for c in candidate_configs(n, pad=pad, d=None, panels=panels)
             if c.batched]
    if hosts > 1 and local > 1:
        # Host-major axis: the hierarchical exchange is a real program
        # alternative — race it as its own config dimension.  (The real
        # path exchanges padded half-spectrum panels flat-only.)
        import dataclasses
        cands += [dataclasses.replace(c, exchange="hier")
                  for c in cands if not c.real]
    ranked = sorted(
        ((cfg, estimate_cost(
            cfg, n=n, fpms=fpms, params=params, comm_bytes=comm_bytes,
            comm_time_s=dist_comm_time(n, p, params=params, hosts=hosts,
                                       exchange=cfg.exchange)))
         for cfg in cands),
        key=lambda kv: kv[1])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(), float(c)) for cfg, c in ranked],
        "dist": {
            "devices": p,
            "hosts": int(hosts),
            "axis_name": axis_name,
            "comm_bytes": float(comm_bytes),
            # Both phases, like the measured sample it is judged against.
            "comm_time_est_s": float(2.0 * comm_phase_time(
                comm_bytes, params.interconnect_bytes_per_s,
                params.comm_latency_s)),
        },
    }

    if mode == "estimate":
        return ranked[0][0], info
    if p <= 1:
        # Nothing distributed to time: the 1-device all_to_all is a local
        # reshuffle and an end-to-end race would just re-measure the limb.
        info["measure_fallback"] = "1-device mesh: measure == estimate"
        return ranked[0][0], info

    # One finalist per distinct *distributed* program: the single-host
    # behavior key plus the panel count (panels change the collective
    # structure even when the local program is identical).
    finalists, seen = [], set()
    for cfg, _ in ranked:
        key = (_behavior_key(cfg, n, None, None), cfg.pipeline_panels)
        if key not in seen:
            seen.add(key)
            finalists.append(cfg)
        if len(finalists) >= max(top_k, 1):
            break
    try:
        measured = _measure_with_retry(
            lambda: measure_dist_configs(finalists, n, mesh, axis_name,
                                         pad_len=pad_len, dtype=dtype,
                                         rounds=reps),
            measure_retries)
    except Exception as err:
        if measure_retries <= 0:
            raise
        # Retries exhausted: serve the estimate ranking rather than fail
        # the caller (the self-healing re-planner must always get a plan).
        info["measure_fallback"] = (
            f"measurement failed after {measure_retries} retries: {err!r}")
        return ranked[0][0], info
    winner = min(measured, key=measured.get)
    info["measured"] = [(cfg.to_dict(), float(t)) for cfg, t in measured.items()]
    info["time_s"] = float(measured[winner])

    # Comm sample: end-to-end minus the two measured local phases of the
    # winning config.  Clamped at 0 — overlap (pipelined panels) can
    # legitimately hide comm below the subtraction's noise floor.
    eff_len = pad_len
    if eff_len is None:
        # The executor's own default, so the local probe runs the same
        # program the end-to-end measurement ran.
        from repro.core.pfft_dist import default_dist_pad_len
        eff_len = default_dist_pad_len(n, winner.dist_padded)
    try:
        local_s = _measure_with_retry(
            lambda: _measure_local_phase(winner, n, p, eff_len, dtype, reps),
            measure_retries)
    except Exception as err:
        if measure_retries <= 0:
            raise
        # The winner stands; only the comm sample is lost this round.
        info["dist"]["comm_sample_error"] = repr(err)
        return winner, info
    info["dist"]["local_phase_s"] = float(local_s)
    info["dist"]["comm_time_meas_s"] = float(
        max(measured[winner] - 2.0 * local_s, 0.0))
    info["dist"]["exchange"] = winner.exchange
    if hosts > 1 and local > 1:
        # Per-tier samples: one grouped all_to_all per tier, so calibrate
        # can fit the intra- and inter-host comm params separately.  The
        # byte counts are the hierarchical per-exchange tier volumes the
        # same microbench actually moves; ``msgs`` is the slow-tier
        # message count of the timed launch (the latency multiplier).
        tiers = dist_comm_bytes(n, p, hosts=hosts, exchange="hier")
        samples = []
        for tier, tier_bytes, msgs in (("intra", tiers.intra, 1),
                                       ("inter", tiers.inter, hosts - 1)):
            if not tier_bytes:
                continue
            try:
                t = _measure_with_retry(
                    lambda tier=tier: _measure_tier_exchange(
                        mesh, axis_name, n, hosts, local, tier, dtype, reps),
                    measure_retries)
            except Exception as err:
                if measure_retries <= 0:
                    raise
                info["dist"]["tier_sample_error"] = repr(err)
                break
            samples.append({"tier": tier, "bytes": float(tier_bytes),
                            "msgs": int(msgs), "time_s": float(t)})
        if samples:
            info["dist"]["comm_samples"] = samples
    return winner, info


# ------------------------------------------------------------------ pfft3

def pfft3_panel_space(n: int, r: int, c: int, max_panels: int = 8
                      ) -> tuple[int, ...]:
    """Candidate ``pipeline_panels`` for an N^3 problem on an r x c pencil
    mesh: the powers of two up to ``max_panels`` dividing *both* local
    extents (``pfft3_pencil`` splits panels along whichever block axis the
    current exchange leaves alone, so k must divide N/r and N/c alike).
    The one home of the rule — the tuner, ``plan_pfft3(mesh=...)``, and
    the microbench all enumerate (and digest) the same space.
    """
    import math

    r, c = int(r), int(c)
    if r <= 0 or c <= 0 or n % r or n % c:
        return (1,)
    g = math.gcd(n // r, n // c)
    ks = [k for k in (1, 2, 4, 8) if k <= max_panels and g % k == 0]
    return tuple(ks) or (1,)


def _measure_pfft3_local_pass(cfg: PlanConfig, n: int, r: int, c: int,
                              pad_len: int, dtype, rounds: int) -> float:
    """Seconds of one *local* axis pass of the pencil pipeline: the
    row-FFT program one device runs on its (N/r · N/c, N) pencil rows,
    without either ``all_to_all``.  Subtracting three of these from the
    end-to-end time turns a pencil measurement into a *comm* sample
    covering the transform's two exchange rounds."""
    import jax
    import jax.numpy as jnp
    from repro.core.pfft_dist import _local_fft  # lazy: core imports plan

    rows = max((n // max(r, 1)) * (n // max(c, 1)), 1)
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((rows, n))
                     + 1j * rng.standard_normal((rows, n))).astype(dtype))
    fn = jax.jit(lambda b: _local_fft(b, n, padded=cfg.dist_padded,
                                      pad_len=pad_len, config=cfg,
                                      backend=None))
    jax.block_until_ready(fn(x))  # compile
    return min(_timed_min([(cfg, fn)], x, rounds).values())


def measure_pfft3_configs(configs: Sequence[PlanConfig], n: int, mesh,
                          axis_names: Sequence[str] = ("fft_r", "fft_c"), *,
                          pad_len: int | None = None, dtype=np.complex64,
                          rounds: int = 3) -> dict[PlanConfig, float]:
    """End-to-end on-device seconds of ``pfft3_pencil`` per config.

    The 3-D sibling of ``measure_dist_configs``: times the full pencil
    pipeline — three local passes, both all_to_all rounds, pipelined
    panels, the final global transpose — on the caller's actual 2-D
    ``Mesh``.  Same shuffled-interleaved per-config-min harness
    (``_timed_min``); the cube is laid out pencil-sharded over
    ``axis_names`` first so placement cost is not billed to whichever
    config runs first.  One call races one *orientation* — callers
    (``tune_pfft3``) merge per-orientation races themselves.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.pfft3d import pfft3_pencil  # lazy

    axes = tuple(axis_names)
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal((n, n, n))
                     + 1j * rng.standard_normal((n, n, n))).astype(dtype))
    x = jax.device_put(x, NamedSharding(mesh, P(axes[0], axes[1], None)))
    pairs = []
    for cfg in configs:
        fn = jax.jit(functools.partial(pfft3_pencil, mesh=mesh,
                                       axis_names=axes, config=cfg,
                                       pad_len=pad_len))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((cfg, fn))
    return _timed_min(pairs, x, rounds)


def tune_pfft3(n: int, mesh=None,
               axis_names: Sequence[str] = ("fft_r", "fft_c"), *,
               mode: str = "estimate", pad: str = "none",
               pad_len: int | None = None,
               params: CostParams | None = None, top_k: int = 3,
               panels: Sequence[int] | None = None, dtype=np.complex64,
               reps: int = 3, measure_retries: int = 0
               ) -> tuple[PlanConfig, tuple[str, str] | None, dict]:
    """Pick the best (config, pencil orientation) for the 3-D transform.

    Returns ``(config, axes, info)`` where ``axes`` is the winning
    ``(row_axis, col_axis)`` orientation of ``pfft3_pencil`` — the extra
    degree of freedom the 2-D mesh adds over ``tune_dist_config``: on a
    rectangular r x c mesh the first exchange crosses the *column* axis,
    so swapping which mesh axis plays row changes which round moves the
    bigger fraction of the cube.  Both orientations enter the estimate
    ranking (priced via ``estimate_pfft3_cost``), and measure mode races
    the distinct finalists of each through the full pencil pipeline.

    ``mesh=None`` is the single-host problem (r = c = 1, ``axes=None``):
    the ranking degenerates to the compute terms, and measure mode times
    the jitted single-host ``pfft3_lb`` instead of the pencil program.
    ``info["pfft3"]`` carries the topology facts and, after a measured
    run, the comm sample ``comm_time_meas_s = total − 3·local_pass``
    (clamped at 0) covering both exchange rounds.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    axes0 = tuple(axis_names)
    if mesh is not None:
        r = int(mesh.shape[axes0[0]])
        c = int(mesh.shape[axes0[1]])
        if n % r or n % c:
            raise ValueError(f"N={n} must be divisible by both mesh axes "
                             f"({axes0[0]}={r}, {axes0[1]}={c})")
    else:
        r = c = 1
    if panels is None:
        panels = pfft3_panel_space(n, r, c)
    if params is None:
        params = CostParams.for_backend()
    comm_bytes = pfft3_comm_bytes(n, c) + pfft3_comm_bytes(n, r)
    if mesh is not None:
        from repro.launch.mesh import mesh_host_shape  # lazy: launch is thin
        host_shapes = {a: mesh_host_shape(mesh, a) for a in axes0}
    else:
        host_shapes = {}

    # ``batched`` shapes segment dispatch (one whole-pencil segment here)
    # and the pencil pipeline is unfused by construction — both knobs
    # would only burn finalist slots on identical or invalid programs.
    cands = [cfg for cfg in candidate_configs(n, pad=pad, d=None,
                                              panels=panels)
             if cfg.batched and not cfg.fused]
    if any(h > 1 and l > 1 for h, l in host_shapes.values()):
        # Some orientation puts a host-major axis under the row exchange:
        # race the hierarchical form as its own config dimension.
        import dataclasses
        cands += [dataclasses.replace(cfg, exchange="hier")
                  for cfg in cands if not cfg.real]
    # Orientation space: which mesh axis plays "row".  On a square mesh
    # (or single host) the transposed program is identical.
    if mesh is not None and r != c:
        orientations = [axes0, (axes0[1], axes0[0])]
    elif mesh is not None:
        orientations = [axes0]
    else:
        orientations = [None]

    def est(cfg: PlanConfig, waxes) -> float:
        if waxes is None:
            r_o, c_o, h_o = 1, 1, 1
        else:
            r_o = int(mesh.shape[waxes[0]])
            c_o = int(mesh.shape[waxes[1]])
            # Hosts ride the orientation's row axis (the only exchange
            # the hierarchical form applies to); a non-host-major row
            # axis prices — and runs — as flat.
            h_o = host_shapes[waxes[0]][0]
        return estimate_pfft3_cost(cfg, n=n, r=r_o, c=c_o, params=params,
                                   pad_len=pad_len, hosts=h_o)

    ranked = sorted(((cfg, waxes, est(cfg, waxes))
                     for cfg in cands for waxes in orientations),
                    key=lambda kv: kv[2])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(),
                    list(waxes) if waxes is not None else None, float(t))
                   for cfg, waxes, t in ranked],
        "pfft3": {
            "r": r, "c": c,
            "hosts": int(host_shapes.get(axes0[0], (1, 1))[0]),
            "axis_names": list(axes0) if mesh is not None else None,
            "comm_bytes": float(comm_bytes),
            "comm_time_est_s": float(
                sum(comm_phase_time(b, params.interconnect_bytes_per_s,
                                    params.comm_latency_s)
                    for b in (pfft3_comm_bytes(n, c),
                              pfft3_comm_bytes(n, r)))),
        },
    }

    if mode == "estimate":
        cfg, waxes, _ = ranked[0]
        info["orientation"] = list(waxes) if waxes is not None else None
        return cfg, waxes, info
    if r * c <= 1 and mesh is not None:
        info["measure_fallback"] = "1-device mesh: measure == estimate"
        cfg, waxes, _ = ranked[0]
        info["orientation"] = list(waxes) if waxes is not None else None
        return cfg, waxes, info

    # One finalist per distinct *pencil* program: single-host behavior key
    # plus panel count plus orientation (orientation changes which round
    # crosses which communicator even when the local program is the same).
    finalists, seen = [], set()
    for cfg, waxes, _ in ranked:
        key = (_behavior_key(cfg, n, None, None), cfg.pipeline_panels, waxes)
        if key not in seen:
            seen.add(key)
            finalists.append((cfg, waxes))
        if len(finalists) >= max(top_k, 1):
            break

    def run_races() -> dict:
        merged: dict = {}
        if mesh is None:
            # Single host: time the production single-host program.
            import jax
            import jax.numpy as jnp
            from repro.core.pfft3d import pfft3_lb  # lazy

            rng = np.random.default_rng(0)
            x = jnp.asarray((rng.standard_normal((n, n, n))
                             + 1j * rng.standard_normal((n, n, n))
                             ).astype(dtype))
            pairs = []
            for cfg, _ in finalists:
                fn = jax.jit(lambda m, c=cfg: pfft3_lb(m, 1, config=c))
                jax.block_until_ready(fn(x))  # compile
                pairs.append((cfg, fn))
            for cfg, t in _timed_min(pairs, x, reps).items():
                merged[(cfg, None)] = t
            return merged
        for waxes in orientations:
            group = [cfg for cfg, wa in finalists if wa == waxes]
            if not group:
                continue
            times = measure_pfft3_configs(group, n, mesh, waxes,
                                          pad_len=pad_len, dtype=dtype,
                                          rounds=reps)
            for cfg, t in times.items():
                merged[(cfg, waxes)] = t
        return merged

    try:
        measured = _measure_with_retry(run_races, measure_retries)
    except Exception as err:
        if measure_retries <= 0:
            raise
        info["measure_fallback"] = (
            f"measurement failed after {measure_retries} retries: {err!r}")
        cfg, waxes, _ = ranked[0]
        info["orientation"] = list(waxes) if waxes is not None else None
        return cfg, waxes, info
    wcfg, waxes = min(measured, key=measured.get)
    info["measured"] = [(cfg.to_dict(),
                         list(wa) if wa is not None else None, float(t))
                        for (cfg, wa), t in measured.items()]
    info["time_s"] = float(measured[(wcfg, waxes)])
    info["orientation"] = list(waxes) if waxes is not None else None

    # Comm sample: end-to-end minus the three measured local passes of
    # the winning program.  Clamped at 0 — pipelined panels can hide comm
    # below the subtraction's noise floor.
    eff_len = pad_len
    if eff_len is None:
        from repro.core.pfft_dist import default_dist_pad_len
        eff_len = default_dist_pad_len(n, wcfg.dist_padded)
    try:
        local_s = _measure_with_retry(
            lambda: _measure_pfft3_local_pass(wcfg, n, r, c, eff_len, dtype,
                                              reps),
            measure_retries)
    except Exception as err:
        if measure_retries <= 0:
            raise
        info["pfft3"]["comm_sample_error"] = repr(err)
        return wcfg, waxes, info
    info["pfft3"]["local_pass_s"] = float(local_s)
    info["pfft3"]["comm_time_meas_s"] = float(
        max(measured[(wcfg, waxes)] - 3.0 * local_s, 0.0))
    info["pfft3"]["exchange"] = wcfg.exchange
    return wcfg, waxes, info


def tune_pfft1_large(n: int, *, n1: int | None = None, n2: int | None = None,
                     mode: str = "estimate",
                     params: CostParams | None = None, top_k: int = 3,
                     dtype=np.complex64, reps: int = 3
                     ) -> tuple[PlanConfig, dict]:
    """Tune the four-step huge-1-D transform; returns (config, info).

    The four-step decomposition runs two row-FFT phases at lengths n2 and
    n1 (``core.pfft_large``), so the estimate prices each phase at its
    own length with the config's backend multiplier — a radix kernel that
    helps the pow2 side may be a fallback no-op on the other.  Measure
    mode times the jitted production ``pfft1_large_apply`` end to end.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    from repro.core.fpm import fft_flops
    from repro.core.pfft_large import four_step_factors  # lazy

    n1, n2 = four_step_factors(n, n1=n1, n2=n2)
    if params is None:
        params = CostParams.for_backend()

    radices: list[int | None] = [None]
    if _is_pow2(n1) or _is_pow2(n2):
        radices += [2, 4]
    cands = [PlanConfig(radix=rad) for rad in radices]

    def est(cfg: PlanConfig) -> float:
        compute = (
            float(fft_flops(n1, n2)) / params.nominal_flops
            * _compute_multiplier(cfg, n2, params)
            + float(fft_flops(n2, n1)) / params.nominal_flops
            * _compute_multiplier(cfg, n1, params))
        itemsize = np.dtype(dtype).itemsize
        traffic = 4.0 * n * itemsize / params.hbm_bytes_per_s
        return compute + traffic + 2.0 * params.dispatch_overhead_s

    ranked = sorted(((cfg, est(cfg)) for cfg in cands), key=lambda kv: kv[1])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(), float(t)) for cfg, t in ranked],
        "four_step": {"n1": int(n1), "n2": int(n2)},
    }
    if mode == "estimate":
        return ranked[0][0], info

    import jax
    import jax.numpy as jnp
    from repro.core.pfft_large import pfft1_large_apply  # lazy

    finalists, seen = [], set()
    for cfg, _ in ranked:
        key = (_length_backend(cfg, n1), _length_backend(cfg, n2))
        if key not in seen:
            seen.add(key)
            finalists.append(cfg)
        if len(finalists) >= max(top_k, 1):
            break
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal(n)
                     + 1j * rng.standard_normal(n)).astype(dtype))
    pairs = []
    for cfg in finalists:
        fn = jax.jit(lambda v, c=cfg: pfft1_large_apply(v, config=c, n1=n1,
                                                        n2=n2))
        jax.block_until_ready(fn(x))  # compile
        pairs.append((cfg, fn))
    measured = _timed_min(pairs, x, reps)
    winner = min(measured, key=measured.get)
    info["measured"] = [(cfg.to_dict(), float(t))
                        for cfg, t in measured.items()]
    info["time_s"] = float(measured[winner])
    return winner, info


# ------------------------------------------------------------------- real

def _require_real_dtype(dtype) -> np.dtype:
    """Validate a real-pipeline input dtype; returns the np.dtype."""
    dt = np.dtype(dtype)
    if dt not in (np.dtype(np.float32), np.dtype(np.float64)):
        raise ValueError(
            f"the real pipeline tunes float32/float64 inputs, got {dt.name}")
    return dt


def _real_candidates(cands: Sequence[PlanConfig]) -> list[PlanConfig]:
    """The real-flagged twins of a complex candidate list (czt dropped —
    the real pipeline has no Bluestein form)."""
    import dataclasses
    return [dataclasses.replace(c, real=True) for c in cands
            if c.pad != "czt"]


def _family_finalists(ranked, n: int, d, pad_lengths, top_k: int
                      ) -> list[PlanConfig]:
    """Distinct-program finalists that always include the best candidate
    of *each* family (real and complex), so measure mode genuinely races
    real-vs-complex rather than burning every slot on one side."""
    finalists, seen = [], set()
    for cfg, _ in ranked:
        key = _behavior_key(cfg, n, d, pad_lengths)
        if key not in seen:
            seen.add(key)
            finalists.append(cfg)
        if len(finalists) >= max(top_k, 1):
            break
    for want_real in (True, False):
        if not any(c.real == want_real for c in finalists):
            best = next((c for c, _ in ranked if c.real == want_real), None)
            if best is not None:
                finalists.append(best)
    return finalists


def measure_rfft_configs(configs: Sequence[PlanConfig], n: int, *, d=None,
                         pad_lengths=None, dtype=np.float32, rounds: int = 3
                         ) -> dict[PlanConfig, float]:
    """On-device seconds of the half-spectrum limb per config.

    ``real`` configs run ``_rpfft_limb`` on the real input; complex
    fallback configs run ``_pfft_limb`` on the upcast input and crop to
    the half spectrum — the *same* (N, N//2+1) deliverable with the same
    partition and pad lengths, so the race is apples-to-apples (the
    padded real phase equals the padded complex phase's half spectrum
    bin for bin — see ``core.pfft.halfspec_distribution``).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.pfft import _pfft_limb, _rpfft_limb  # lazy

    dt = _require_real_dtype(dtype)
    ctype = np.complex64 if dt == np.dtype(np.float32) else np.complex128
    nh = n // 2 + 1
    d_eff = np.asarray(d) if d is not None else np.array([n], dtype=np.int64)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, n)).astype(dt))
    pairs = []
    for cfg in configs:
        if cfg.real:
            fn = jax.jit(lambda m, c=cfg: _rpfft_limb(
                m, d_eff, pad_lengths=pad_lengths, config=c))
        else:
            fn = jax.jit(lambda m, c=cfg: _pfft_limb(
                m.astype(ctype), d_eff, pad_lengths=pad_lengths,
                config=c)[:, :nh])
        jax.block_until_ready(fn(x))  # compile
        pairs.append((cfg, fn))
    return _timed_min(pairs, x, rounds)


def tune_rfft(n: int, *, d=None, pad_lengths=None, fpms: FPMSet | None = None,
              mode: str = "estimate", pad: str = "none",
              params: CostParams | None = None, top_k: int = 3,
              dtype=np.float32, reps: int = 3
              ) -> tuple[SegmentSchedule, dict]:
    """Tune a real-input half-spectrum problem; returns (schedule, info).

    The candidate pot holds *both families*: real-flagged configs (the
    rfft pipeline) and their complex twins (upcast + crop fallback), so
    the planner picks real-vs-complex per (n, dtype) on the cost model —
    or, in measure mode, on an on-device race whose finalists always
    include the best of each family.  ``info["chosen_path"]`` says which
    side won; the returned schedule's configs carry the ``real`` flag the
    executor routes on.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    _require_real_dtype(dtype)
    if pad == "czt":
        raise ValueError("the real pipeline has no Bluestein form")
    if d is not None:
        d = np.asarray(d)
    if params is None:
        params = CostParams.for_backend()

    complex_cands = candidate_configs(n, pad=pad, d=d)
    cands = _real_candidates(complex_cands) + complex_cands
    ranked = sorted(
        ((cfg, estimate_cost(cfg, n=n, d=d, pad_lengths=pad_lengths,
                             fpms=fpms, params=params))
         for cfg in cands),
        key=lambda kv: kv[1])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(), float(c)) for cfg, c in ranked],
    }

    if mode == "estimate":
        winner = ranked[0][0]
    else:
        finalists = _family_finalists(ranked, n, d, pad_lengths, top_k)
        measured = measure_rfft_configs(finalists, n, d=d,
                                        pad_lengths=pad_lengths, dtype=dtype,
                                        rounds=reps)
        winner = min(measured, key=measured.get)
        info["measured"] = [(cfg.to_dict(), float(t))
                            for cfg, t in measured.items()]
        info["time_s"] = float(measured[winner])
    info["chosen_path"] = "real" if winner.real else "complex"
    schedule = SegmentSchedule.homogeneous(winner, n, d, pad_lengths)
    info["schedule"] = schedule.to_dict()
    return schedule, info


def measure_rfft_dist_configs(configs: Sequence[PlanConfig], n: int, mesh,
                              axis_name: str = "fft", *,
                              pad_len: int | None = None, dtype=np.float32,
                              rounds: int = 3) -> dict[PlanConfig, float]:
    """End-to-end on-device seconds of the distributed half-spectrum
    transform per config: ``real`` configs run ``rpfft2_distributed``
    (half-width all_to_all panels), complex fallbacks run the upcast
    ``pfft2_distributed`` cropped to the half spectrum — same deliverable
    on the same mesh, same sharded-input discipline as
    ``measure_dist_configs``.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.pfft_dist import (pfft2_distributed,  # lazy
                                      rpfft2_distributed)

    dt = _require_real_dtype(dtype)
    ctype = np.complex64 if dt == np.dtype(np.float32) else np.complex128
    nh = n // 2 + 1
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, n)).astype(dt))
    x = jax.device_put(x, NamedSharding(mesh, P(axis_name, None)))
    pairs = []
    for cfg in configs:
        if cfg.real:
            fn = jax.jit(functools.partial(rpfft2_distributed, mesh=mesh,
                                           axis_name=axis_name, config=cfg,
                                           pad_len=pad_len))
        else:
            fn = jax.jit(lambda m, c=cfg: pfft2_distributed(
                m.astype(ctype), mesh=mesh, axis_name=axis_name, config=c,
                pad_len=pad_len)[:, :nh])
        jax.block_until_ready(fn(x))  # compile
        pairs.append((cfg, fn))
    return _timed_min(pairs, x, rounds)


def _measure_local_real_phases(cfg: PlanConfig, n: int, p: int, pad_len: int,
                               dtype, rounds: int) -> float:
    """Combined seconds of the real pipeline's two *local* phase programs
    (rfft on the (N/p, N) row block + complex FFT on the (hc/p, N)
    spectral block) — the subtraction term that turns an end-to-end real
    measurement into a comm sample, mirroring ``_measure_local_phase``.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.pfft import _group_row_rffts, _group_row_ffts  # lazy
    from repro.plan.cost import halfspec_cols

    dt = _require_real_dtype(dtype)
    ctype = np.complex64 if dt == np.dtype(np.float32) else np.complex128
    hc = halfspec_cols(n, p)
    rng = np.random.default_rng(0)
    x1 = jnp.asarray(rng.standard_normal((max(n // p, 1), n)).astype(dt))
    x2 = jnp.asarray((rng.standard_normal((max(hc // p, 1), n))
                      + 1j * rng.standard_normal((max(hc // p, 1), n))
                      ).astype(ctype))
    length = pad_len if cfg.pad == "fpm" else n
    fn1 = jax.jit(lambda b: _group_row_rffts(b, length, n, cfg, None))
    fn2 = jax.jit(lambda b: _group_row_ffts(b, length, n, cfg, None))
    jax.block_until_ready(fn1(x1))  # compile
    jax.block_until_ready(fn2(x2))
    t1 = min(_timed_min([(cfg, fn1)], x1, rounds).values())
    t2 = min(_timed_min([(cfg, fn2)], x2, rounds).values())
    return t1 + t2


def tune_rfft_dist(n: int, mesh, axis_name: str = "fft", *,
                   mode: str = "estimate", pad: str = "none",
                   pad_len: int | None = None, fpms: FPMSet | None = None,
                   params: CostParams | None = None, top_k: int = 3,
                   panels: Sequence[int] | None = None, dtype=np.float32,
                   reps: int = 3, measure_retries: int = 0
                   ) -> tuple[SegmentSchedule, dict]:
    """Tune the distributed real-input transform on ``mesh``.

    Real candidates are priced with the *half-spectrum* comm term
    (``dist_comm_bytes(real=True)`` — ~half the complex bytes) and their
    complex twins with the full-panel term, so estimate mode already sees
    the comm saving; measure mode races both families end to end through
    their actual distributed programs.  The real path's program shape is
    homogeneous/unfused/monolithic (``rpfft2_distributed``), so real
    candidates enumerate only the row-FFT backend; complex fallbacks keep
    the full panel/fused space.  ``info["dist"]`` carries both byte
    counts, their ratio, and (measured) the winner's comm sample.
    """
    if mode not in ("estimate", "measure"):
        raise ValueError(f"mode must be 'estimate' or 'measure', got {mode!r}")
    _require_real_dtype(dtype)
    if pad == "czt":
        raise ValueError("the real pipeline has no Bluestein form")
    p = int(mesh.shape[axis_name])
    if n % p:
        raise ValueError(f"N={n} must be divisible by mesh axis "
                         f"{axis_name}={p}")
    if panels is None:
        panels = dist_panel_space(n, p)
    if params is None:
        params = CostParams.for_backend()
    comm_complex = dist_comm_bytes(n, p)
    comm_real = dist_comm_bytes(n, p, real=True)

    complex_cands = [c for c in candidate_configs(n, pad=pad, d=None,
                                                  panels=panels) if c.batched]
    real_cands = [c for c in _real_candidates(complex_cands)
                  if not c.fused and c.pipeline_panels == 1]
    ranked = sorted(
        ((cfg, estimate_cost(cfg, n=n, fpms=fpms, params=params,
                             comm_bytes=comm_real if cfg.real
                             else comm_complex))
         for cfg in real_cands + complex_cands),
        key=lambda kv: kv[1])
    info: dict = {
        "mode": mode,
        "ranked": [(cfg.to_dict(), float(c)) for cfg, c in ranked],
        "dist": {
            "devices": p,
            "axis_name": axis_name,
            "comm_bytes_complex": float(comm_complex),
            "comm_bytes_real": float(comm_real),
            "comm_ratio_real": (float(comm_real / comm_complex)
                                if comm_complex else 0.0),
        },
    }

    def finish(winner: PlanConfig) -> tuple[SegmentSchedule, dict]:
        info["chosen_path"] = "real" if winner.real else "complex"
        info["dist"]["comm_bytes"] = float(comm_real if winner.real
                                           else comm_complex)
        d = np.full(p, n // p, dtype=np.int64) if p > 0 else None
        schedule = SegmentSchedule.homogeneous(winner, n, d)
        info["schedule"] = schedule.to_dict()
        return schedule, info

    if mode == "estimate":
        return finish(ranked[0][0])
    if p <= 1:
        info["measure_fallback"] = "1-device mesh: measure == estimate"
        return finish(ranked[0][0])

    finalists = _family_finalists(ranked, n, None, None, top_k)
    try:
        measured = _measure_with_retry(
            lambda: measure_rfft_dist_configs(finalists, n, mesh, axis_name,
                                              pad_len=pad_len, dtype=dtype,
                                              rounds=reps),
            measure_retries)
    except Exception as err:
        if measure_retries <= 0:
            raise
        info["measure_fallback"] = (
            f"measurement failed after {measure_retries} retries: {err!r}")
        return finish(ranked[0][0])
    winner = min(measured, key=measured.get)
    info["measured"] = [(cfg.to_dict(), float(t))
                        for cfg, t in measured.items()]
    info["time_s"] = float(measured[winner])

    eff_len = pad_len
    if eff_len is None:
        from repro.core.pfft_dist import default_dist_pad_len
        eff_len = default_dist_pad_len(n, winner.dist_padded)
    try:
        if winner.real:
            local_s = _measure_with_retry(
                lambda: _measure_local_real_phases(winner, n, p, eff_len,
                                                   dtype, reps),
                measure_retries)
        else:
            ctype = (np.complex64 if np.dtype(dtype) == np.dtype(np.float32)
                     else np.complex128)
            local_s = 2.0 * _measure_with_retry(
                lambda: _measure_local_phase(winner, n, p, eff_len, ctype,
                                             reps),
                measure_retries)
    except Exception as err:
        if measure_retries <= 0:
            raise
        info["dist"]["comm_sample_error"] = repr(err)
        return finish(winner)
    info["dist"]["local_phase_s"] = float(local_s)
    info["dist"]["comm_time_meas_s"] = float(
        max(measured[winner] - local_s, 0.0))
    return finish(winner)


def grouped_dist_schedule(n: int, p: int, *, pad_lengths=None,
                          fpms: FPMSet | None = None, pad: str = "none",
                          params: CostParams | None = None
                          ) -> SegmentSchedule | None:
    """The model-driven heterogeneous candidate for a p-device mesh.

    One entry per device (N/p rows — the SPMD shard), each assigned the
    ``segment_candidate_configs`` argmin of *its own* predicted time:
    its FPM's ``time_at`` (or the nominal flop rate) at its own declared
    effective length, times the candidate's backend multiplier.  Mixed
    per-device pad lengths are what make the assignment genuinely mixed
    — a pow2-padded device's kernel candidates survive ``_factor_term``
    while a non-pow2 neighbour falls back to the library FFT — exactly
    how the single-host ``tune_schedule`` grows heterogeneity.  Returns
    ``None`` when the assembly degenerates to a single config (nothing
    to group) or p <= 1; the caller prices the survivor with
    ``estimate_grouped_cost`` (the lowering runs every branch at the max
    length — the declared-length estimate is the model's view of *why*
    each device picked its variant, not of the padded flops).
    """
    if p <= 1 or n % p:
        return None
    if params is None:
        params = CostParams.for_backend()
    if fpms is not None and fpms.p != p:
        fpms = None  # one abstract processor per device or no FPM at all
    n_loc = n // p
    d = np.full(p, n_loc, dtype=np.int64)

    def seg_time(i: int, cfg: PlanConfig, length: int) -> float:
        if fpms is not None:
            t = fpms[i].time_at(n_loc, length)
        else:
            from repro.core.fpm import fft_flops
            t = float(fft_flops(n_loc, length)) / params.nominal_flops
        return t * _compute_multiplier(cfg, length, params)

    cfgs = []
    for i in range(p):
        length = n
        if pad_lengths is not None and int(pad_lengths[i]) > n:
            length = int(pad_lengths[i])
        cands = segment_candidate_configs(length, pad=pad)
        cfgs.append(min(cands, key=lambda c: seg_time(i, c, length)))
    schedule = SegmentSchedule.from_parts(n, d, pad_lengths, cfgs)
    return schedule if len(schedule.configs) > 1 else None


def tune_dist_schedule(n: int, mesh, axis_name: str = "fft", *,
                       pad_lengths=None, mode: str = "estimate",
                       pad: str = "none", pad_len: int | None = None,
                       fpms: FPMSet | None = None,
                       params: CostParams | None = None, top_k: int = 3,
                       panels: Sequence[int] | None = None,
                       dtype=np.complex64, reps: int = 3,
                       measure_retries: int = 0
                       ) -> tuple[SegmentSchedule, dict]:
    """Schedule-shaped distributed tuner; returns (schedule, info).

    The homogeneous candidate space is ``tune_dist_config``'s (comm term
    from the mesh, measure mode racing finalists end to end).  On top of
    it the tuner *grows heterogeneous candidates*: the per-device
    assembly of ``grouped_dist_schedule`` — lowered by the executor as a
    device-group program (``repro.plan.groups``) — priced with
    ``estimate_grouped_cost`` (per-group makespan + switch-dispatch
    overhead) against the homogeneous winner.  ``mode="measure"`` races
    the grouped finalist against the homogeneous winner end to end
    through the *actual* grouped ``pfft2_distributed`` program on the
    caller's mesh (``info["grouped_measured"]``), so a genuinely
    heterogeneous pod's mixed pick is chosen on evidence, not model
    faith.  This is what ``plan_pfft(mesh=...)`` resolves through, so
    grouped picks persist under the same v3 topology keys.
    """
    p = int(mesh.shape[axis_name])
    if pad_len is None and pad_lengths is not None:
        # The returned schedule executes at the uniform max effective
        # length (pfft2_distributed's uniform-length rule), so the
        # homogeneous finalists must be raced — and the comm sample
        # taken — at that very length, not the unpadded/smooth default:
        # a measured time for a program the plan never runs would poison
        # the wisdom entry and the interconnect calibration.
        lengths = [int(x) for x in pad_lengths if int(x) > n]
        if lengths:
            pad_len = max(lengths)
    cfg, info = tune_dist_config(n, mesh, axis_name, mode=mode, pad=pad,
                                 pad_len=pad_len, fpms=fpms, params=params,
                                 top_k=top_k, panels=panels, dtype=dtype,
                                 reps=reps, measure_retries=measure_retries)
    if params is None:
        params = CostParams.for_backend()
    d = np.full(p, n // p, dtype=np.int64) if p > 0 else None
    homo = SegmentSchedule.homogeneous(cfg, n, d, pad_lengths)
    hetero = grouped_dist_schedule(n, p, pad_lengths=pad_lengths, fpms=fpms,
                                   pad=pad, params=params)
    if hetero is None:
        info["chosen"] = "homogeneous"
        info["schedule"] = homo.to_dict()
        return homo, info

    fpms_dev = fpms if fpms is not None and fpms.p == p else None
    comm_bytes = dist_comm_bytes(n, p)
    est_hetero = estimate_grouped_cost(hetero, fpms=fpms_dev, params=params,
                                       comm_bytes=comm_bytes)
    est_homo = estimate_grouped_cost(homo, fpms=fpms_dev, params=params,
                                     comm_bytes=comm_bytes)
    info["heterogeneous"] = {"schedule": hetero.to_dict(),
                             "est_s": float(est_hetero)}
    info["homogeneous"] = {"config": cfg.to_dict(), "est_s": float(est_homo)}

    if mode == "estimate" or "measure_fallback" in info:
        winner = hetero if est_hetero < est_homo else homo
    else:
        try:
            raced = _measure_with_retry(
                lambda: measure_dist_configs([homo, hetero], n, mesh,
                                             axis_name, dtype=dtype,
                                             rounds=reps),
                measure_retries)
        except Exception as err:
            if measure_retries <= 0:
                raise
            info["measure_fallback"] = (
                f"grouped race failed after {measure_retries} retries: "
                f"{err!r}")
            winner = hetero if est_hetero < est_homo else homo
        else:
            winner = min(raced, key=raced.get)
            info["grouped_measured"] = [(s.describe(), float(t))
                                        for s, t in raced.items()]
            info["time_s"] = float(raced[winner])
    info["chosen"] = ("heterogeneous" if len(winner.configs) > 1
                      else "homogeneous")
    info["schedule"] = winner.to_dict()
    return winner, info
