"""PlanConfig — the single description of *how* a PFFT executes.

After PR 1 the repo had four fast execution variants, each behind its own
hand-set boolean (``use_stockham``, ``fused``, ``batched``,
``pipeline_panels``).  ``PlanConfig`` replaces that flag soup with one
hashable value the planner can enumerate, price, measure, and persist:

* ``radix`` selects the row-FFT implementation: ``None`` is the library
  (XLA) FFT, ``2`` the pure-jnp radix-2 Stockham, ``4`` the Pallas
  radix-4 kernel (half the passes; see DESIGN.md §Row-FFT kernel).
* ``fused`` runs each (row FFT, transpose) phase as one fused Pallas
  dispatch — no intermediate HBM matrix.
* ``batched`` groups same-length segments into one FFT dispatch per
  distinct plan (``plan_segment_batches``).
* ``pad`` names the padding strategy: ``"none"``, ``"fpm"`` (FPM-chosen
  pad-and-crop, the paper's PFFT-FPM-PAD / distributed ``'crop'``), or
  ``"czt"`` (exact Bluestein at a model-chosen length).
* ``pipeline_panels`` software-pipelines the distributed all_to_all
  against per-panel FFTs (``pfft2_distributed``).
* ``real`` runs the real-input half-spectrum pipeline: the row phase is
  an rfft (two real rows packed per complex FFT), the column phase works
  on ``N//2+1`` spectral columns, and the distributed transpose moves
  ~half the bytes.  Incompatible with ``pad="czt"`` — Bluestein has no
  half-spectrum form here.
* ``exchange`` names the distributed-transpose collective layout:
  ``"flat"`` is one ``all_to_all`` over the whole mesh axis; ``"hier"``
  is the hierarchical two-stage form on host-major meshes — a local
  pre-permutation plus an intra-host shuffle on the fast tier, then a
  coarser inter-host exchange that aggregates each host's traffic into
  ``hosts - 1`` slow-tier messages instead of ``p - local`` (see
  DESIGN.md §Multi-host topology).  On meshes without host structure
  ``"hier"`` degrades to the flat program.

The dataclass is frozen so configs can key dicts and be deduplicated; the
dict round-trip (``to_dict``/``from_dict``) is the wisdom wire format.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Literal

PadStrategy = Literal["none", "fpm", "czt"]

_VALID_RADIX = (None, 2, 4)
_VALID_PAD = ("none", "fpm", "czt")
_VALID_EXCHANGE = ("flat", "hier")

__all__ = ["PlanConfig", "PadStrategy", "normalize_pad"]


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    radix: int | None = None
    fused: bool = False
    batched: bool = True
    pad: str = "none"
    pipeline_panels: int = 1
    real: bool = False
    exchange: str = "flat"

    def __post_init__(self) -> None:
        if self.radix not in _VALID_RADIX:
            raise ValueError(f"radix must be one of {_VALID_RADIX}, got {self.radix!r}")
        if self.pad not in _VALID_PAD:
            raise ValueError(f"pad must be one of {_VALID_PAD}, got {self.pad!r}")
        if self.exchange not in _VALID_EXCHANGE:
            raise ValueError(
                f"exchange must be one of {_VALID_EXCHANGE}, got {self.exchange!r}")
        if self.pipeline_panels < 1:
            raise ValueError(f"pipeline_panels must be >= 1, got {self.pipeline_panels}")
        if self.fused and self.pad != "none":
            raise ValueError("fused phases have no per-segment padding; pad must be 'none'")
        if self.real and self.pad == "czt":
            raise ValueError("the real half-spectrum pipeline has no Bluestein "
                             "form; real configs cannot use pad='czt'")

    # ---- derived views -------------------------------------------------

    @property
    def fft_backend(self) -> str:
        """Row-FFT backend implied by ``radix`` (see ``repro.fft.fft_rows``)."""
        return {None: "xla", 2: "stockham", 4: "pallas"}[self.radix]

    @property
    def use_stockham(self) -> bool:
        """Back-compat view of the PR-1 ``use_stockham`` boolean."""
        return self.radix == 2

    @property
    def dist_padded(self) -> str | None:
        """``pfft2_distributed``'s ``padded`` vocabulary for this strategy."""
        return {"none": None, "fpm": "crop", "czt": "czt"}[self.pad]

    def row_fft_kwargs(self, backend: str | None = None) -> dict[str, Any]:
        """``fft_rows`` kwargs for this config (the one place the
        backend-override + radix-only-for-pallas gating lives; both the
        single-host and distributed row phases route through it).
        ``backend`` is an explicit override, e.g. tests forcing the kernel.
        """
        eff = backend if backend is not None else self.fft_backend
        return {"backend": eff,
                "radix": self.radix if eff == "pallas" else None}

    # ---- legacy-flag bridge --------------------------------------------

    @classmethod
    def from_flags(cls, *, use_stockham: bool = False, fused: bool = False,
                   batched: bool = True, pad: str = "none",
                   pipeline_panels: int = 1) -> "PlanConfig":
        """Map the PR-1 loose booleans onto a config (deprecation shims)."""
        return cls(radix=2 if use_stockham else None, fused=bool(fused),
                   batched=bool(batched), pad=pad,
                   pipeline_panels=int(pipeline_panels))

    # ---- wisdom wire format --------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "PlanConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown PlanConfig fields: {sorted(unknown)}")
        return cls(**d)

    def describe(self) -> str:
        """Short human-readable tag (benchmark records, log lines)."""
        parts = [f"radix={self.radix or 'xla'}"]
        if self.fused:
            parts.append("fused")
        parts.append("batched" if self.batched else "looped")
        if self.pad != "none":
            parts.append(f"pad={self.pad}")
        if self.pipeline_panels > 1:
            parts.append(f"panels={self.pipeline_panels}")
        if self.real:
            parts.append("real")
        if self.exchange != "flat":
            parts.append(f"exch={self.exchange}")
        return ",".join(parts)


def normalize_pad(config: PlanConfig, pad: str) -> PlanConfig:
    """Force a method's pad semantics onto a config.

    ``pad`` is semantics, not a tunable: the method owns it (the schedule
    executor consults the entry's pad to pick czt-vs-crop, so an explicit
    ``PlanConfig(pad="czt")`` handed to PFFT-FPM-PAD must still run the
    paper's padded-signal crop, not Bluestein — and vice versa).
    ``fused`` drops with it on padded methods: fused phases have no
    per-segment padding.  The single home of the rule — ``core.api`` and
    the algorithm entry points (``core.pfft``) both normalize through it,
    so their pad semantics can never drift apart again.
    """
    if config.pad == pad:
        return config
    return dataclasses.replace(
        config, pad=pad, fused=config.fused and pad == "none")
