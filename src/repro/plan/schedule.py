"""SegmentSchedule — heterogeneous per-segment execution plans.

The paper's whole point is that abstract processors are *not*
interchangeable: PFFT-FPM feeds each processor its own row count and
PFFT-FPM-PAD its own pad length, both read off that processor's speed
function.  Yet until this module the planner forced one global
``PlanConfig`` onto every segment — the exact homogeneity assumption the
FPM technique exists to break.  A ``SegmentSchedule`` is the ordered list
of ``(segment, PlanConfig)`` entries that replaces it:

* ``SegmentPlan`` — one non-empty segment: which processor (``index``),
  how many rows, the *effective FFT length* it transforms at (N, its
  FPM-chosen ``N_padded_i``, or its Bluestein length), and the
  ``PlanConfig`` variant it executes with.
* ``SegmentSchedule`` — the frozen, hashable sequence of those entries
  for one N x N problem.  ``homogeneous(...)`` builds the degenerate
  schedule a single config used to imply (the PR-2 API shim);
  ``batch_groups()`` groups entries by ``(length, config)`` — the
  dispatch plan the executor (``repro.core.pfft``) runs, generalising
  ``plan_segment_batches``'s by-length-only grouping.

Schedules are the wisdom wire format from schema v2 on
(``to_dict``/``from_dict``), so a tuner that once picked "slow segment
keeps the library FFT, pow2-padded fast segments take the Pallas kernel"
serves that exact mix to every later session.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Sequence

import numpy as np

from repro.plan.config import PlanConfig

__all__ = ["SegmentPlan", "SegmentSchedule"]


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """One segment's entry: processor ``index`` runs ``rows`` row-FFTs at
    effective ``length`` under ``config``."""

    index: int
    rows: int
    length: int
    config: PlanConfig

    def __post_init__(self) -> None:
        if self.rows <= 0:
            raise ValueError(f"segment {self.index}: rows must be > 0, got {self.rows}")
        if self.length <= 0:
            raise ValueError(
                f"segment {self.index}: length must be > 0, got {self.length}")
        if not isinstance(self.config, PlanConfig):
            raise TypeError(
                f"segment {self.index}: config must be a PlanConfig, "
                f"got {type(self.config).__name__}")

    def to_dict(self) -> dict[str, Any]:
        return {"index": self.index, "rows": self.rows,
                "length": self.length, "config": self.config.to_dict()}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SegmentPlan":
        known = {"index", "rows", "length", "config"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown SegmentPlan fields: {sorted(unknown)}")
        return cls(index=int(d["index"]), rows=int(d["rows"]),
                   length=int(d["length"]),
                   config=PlanConfig.from_dict(d["config"]))


def _effective_length(n: int, pad_lengths, i: int) -> int:
    """Effective FFT length of segment i: N, or its pad/Bluestein length."""
    if pad_lengths is not None and int(pad_lengths[i]) > n:
        return int(pad_lengths[i])
    return n


@dataclasses.dataclass(frozen=True)
class SegmentSchedule:
    """Ordered per-segment plans for one N x N problem (frozen, hashable)."""

    n: int
    entries: tuple[SegmentPlan, ...]

    def __post_init__(self) -> None:
        if not self.entries:
            raise ValueError("a SegmentSchedule needs at least one entry")
        object.__setattr__(self, "entries", tuple(self.entries))
        idx = [e.index for e in self.entries]
        if any(b <= a for a, b in zip(idx, idx[1:])):
            raise ValueError(
                f"entries must have strictly ascending segment indices, got {idx}")
        if self.total_rows > self.n:
            raise ValueError(
                f"entries cover {self.total_rows} rows, more than N={self.n}")

    # ---- construction ---------------------------------------------------

    @classmethod
    def from_parts(cls, n: int, d, pad_lengths,
                   configs: Sequence[PlanConfig]) -> "SegmentSchedule":
        """Build from a distribution + per-segment pad lengths + configs.

        ``d=None`` means one whole-matrix segment (the cost model's
        convention).  Empty segments (``d[i] == 0``) get no entry, like
        every executor loop in ``repro.core.pfft``.
        """
        if d is None:
            return cls(n=n, entries=(SegmentPlan(
                index=0, rows=n, length=_effective_length(n, pad_lengths, 0),
                config=configs[0]),))
        d = np.asarray(d)
        entries = []
        for i, rows in enumerate(d):
            if rows <= 0:
                continue
            entries.append(SegmentPlan(
                index=i, rows=int(rows),
                length=_effective_length(n, pad_lengths, i),
                config=configs[i]))
        return cls(n=n, entries=tuple(entries))

    @classmethod
    def homogeneous(cls, config: PlanConfig, n: int, d=None,
                    pad_lengths=None) -> "SegmentSchedule":
        """The degenerate schedule one global config used to imply — the
        bridge that keeps the PR-2 ``config=`` API a thin shim."""
        p = 1 if d is None else len(np.asarray(d))
        return cls.from_parts(n, d, pad_lengths, [config] * p)

    # ---- views ----------------------------------------------------------

    def __iter__(self) -> Iterator[SegmentPlan]:
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def total_rows(self) -> int:
        return sum(e.rows for e in self.entries)

    @property
    def common_config(self) -> PlanConfig | None:
        """The single config shared by every entry, or None when mixed."""
        cfgs = {e.config for e in self.entries}
        return next(iter(cfgs)) if len(cfgs) == 1 else None

    @property
    def configs(self) -> tuple[PlanConfig, ...]:
        """Distinct configs in first-appearance order."""
        seen: dict[PlanConfig, None] = {}
        for e in self.entries:
            seen.setdefault(e.config, None)
        return tuple(seen)

    @property
    def anchor_config(self) -> PlanConfig:
        """The representative config: the common one, else the entry with
        the most rows (the makespan-dominant segment) — what
        ``PfftPlan.config`` reports for a heterogeneous schedule."""
        common = self.common_config
        if common is not None:
            return common
        return max(self.entries, key=lambda e: e.rows).config

    def matches(self, d, pad_lengths=None) -> bool:
        """Does this schedule describe exactly the non-empty segments of
        ``d`` (+ pad lengths)?  Wisdom hits from another partition are
        treated as misses via this check."""
        if d is None:
            probe = [(0, self.n)]
        else:
            d = np.asarray(d)
            probe = [(i, int(rows)) for i, rows in enumerate(d) if rows > 0]
        if len(probe) != len(self.entries):
            return False
        return all(e.index == i and e.rows == rows
                   and e.length == _effective_length(self.n, pad_lengths, i)
                   for e, (i, rows) in zip(self.entries, probe))

    # ---- the dispatch plan ----------------------------------------------

    def batch_groups(self) -> list[tuple[int, PlanConfig, np.ndarray]]:
        """Dispatch groups ``[(length, config, row_indices), ...]``.

        Entries sharing ``(length, config)`` share one FFT dispatch —
        ``plan_segment_batches`` generalised from by-length to
        by-(length, config), so a slow segment on the library FFT and a
        same-length fast segment on the kernel land in *different*
        dispatches while same-variant segments still share one.  An entry
        whose config says ``batched=False`` opts out of sharing and gets
        a dispatch of its own (the paper's literal per-group call).
        """
        groups: dict[tuple, tuple[int, PlanConfig, list[np.ndarray]]] = {}
        off = 0
        for e in self.entries:
            key: tuple = (e.length, e.config)
            if not e.config.batched:
                key += (e.index,)
            rows = np.arange(off, off + e.rows, dtype=np.int64)
            if key in groups:
                groups[key][2].append(rows)
            else:
                groups[key] = (e.length, e.config, [rows])
            off += e.rows
        return [(length, cfg, np.concatenate(idx))
                for length, cfg, idx in groups.values()]

    # ---- wisdom wire format ---------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return {"n": self.n, "entries": [e.to_dict() for e in self.entries]}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SegmentSchedule":
        known = {"n", "entries"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown SegmentSchedule fields: {sorted(unknown)}")
        return cls(n=int(d["n"]),
                   entries=tuple(SegmentPlan.from_dict(e) for e in d["entries"]))

    def describe(self) -> str:
        """Compact human tag: one ``rows@length:variant`` term per dispatch
        group, e.g. ``24@96:radix=xla,batched + 72@128:radix=4,batched``."""
        return " + ".join(
            f"{len(idx)}@{length}:{cfg.describe()}"
            for length, cfg, idx in self.batch_groups())
