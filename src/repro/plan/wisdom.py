"""Persistent planner wisdom — FFTW's wisdom lifecycle for this repo.

A wisdom file is a small versioned JSON document mapping a plan key

    n=<N>|dtype=<dtype>|p=<p>|method=<method>|backend=<backend>

to the plan a previous tuning run chose (plus how it was chosen and the
measured time, when there is one).  ``plan_pfft(tune=..., wisdom=path)``
consults it before tuning, so a process that measured once warms every
later session — the serving story the ROADMAP needs: plans for hot sizes
are selected once and then served from disk.

Since schema v2 an entry's value is either a single ``PlanConfig``
(``"config"``, the degenerate case — e.g. microbenchmark sweeps) or a
full heterogeneous ``SegmentSchedule`` (``"schedule"``), so a tuner that
once picked per-segment variants serves the exact mix back.  v1 stores
predate schedules and are treated as whole-file misses.

Schema v3 adds *per-topology* keys for distributed plans: a key may end
in ``|topo=<topology_digest>`` (device count, mesh axis name, platform,
candidate pipeline-panel counts), so a plan measured end-to-end on a
4-device mesh is never served to an 8-device one.  Heterogeneous
*device-group* picks (``repro.plan.groups``) need no bump of their own:
they are ordinary ``SegmentSchedule`` values under the same topo keys —
the v2 schedule wire format already round-trips them; serving-side
validation (does the stored schedule still lower to *this* mesh?) lives
with the lookup callers, never in the store.  v2 files keep being
served for *single-host* keys (their entry schema is unchanged), but any
``topo=`` lookup against a v2 file is a miss: v2 predates distributed
measurement, so whatever a v2 store claims about a topology key was not
measured on that topology.  v1 stays a whole-file miss.

Writes are atomic (write a sibling ``.tmp``, then ``os.replace`` — the
same idiom as ``save_fpms``) so concurrent readers never observe a torn
file.  A version bump invalidates the whole store: old entries were
chosen under a different cost model / config schema, so a mismatch is
treated as a miss, never an error.
"""

from __future__ import annotations

import json
import os
import time
import zlib

import numpy as np

from repro.plan.config import PlanConfig
from repro.plan.schedule import SegmentSchedule

__all__ = [
    "WISDOM_VERSION",
    "wisdom_key",
    "partition_digest",
    "topology_digest",
    "load_wisdom",
    "lookup_wisdom",
    "record_wisdom",
]

WISDOM_VERSION = 3
# v2 entries are schema-compatible (config/schedule values); serving them
# for single-host keys spares a re-tune.  Distributed (topo=) lookups
# treat a v2 file as a miss — see module docstring and lookup_wisdom.
_SERVED_VERSIONS = (2, WISDOM_VERSION)
_TOPO_FIELD = "|topo="


def wisdom_key(*, n: int, dtype: str, p: int, method: str, backend: str,
               detail: str | None = None, topology: str | None = None) -> str:
    """Canonical store key; every field that changes the best config is in it.

    ``detail`` carries anything beyond (n, dtype, p, method, backend) the
    best config depends on — for the FPM methods, a digest of the
    partition and pad lengths (different FPMSets/eps give different
    partitions, which change the dispatch counts the tuner prices).
    Method 'lb' needs none: its partition is a function of (n, p).
    ``topology`` marks a *distributed* plan: the ``topology_digest`` of
    the mesh the plan was (or is to be) measured on — an end-to-end
    all_to_all time is a property of the topology, so the same problem on
    a different mesh must be a different key.
    """
    base = f"n={int(n)}|dtype={dtype}|p={int(p)}|method={method}|backend={backend}"
    if detail is not None:
        base = f"{base}|part={detail}"
    if topology is not None:
        base = f"{base}{_TOPO_FIELD}{topology}"
    return base


def partition_digest(d, pad_lengths=None) -> str:
    """The ``detail`` digest of an FPM partition (+ pad lengths).

    Shared by ``plan_pfft`` and the microbenchmark's wisdom warmer so
    both sides key FPM-method entries identically — a different
    FPMSet/eps gives a different partition, which must not be served
    another model's plan.
    """
    raw = np.asarray(d, dtype=np.int64).tobytes()
    if pad_lengths is not None:
        raw += np.asarray(pad_lengths, dtype=np.int64).tobytes()
    return format(zlib.crc32(raw), "08x")


def _mesh_hosts(mesh, axis_names) -> int:
    """Host count a mesh's axes span (1 when jax/mesh offer no host
    structure) — the digest's ``h`` component, derived lazily so a
    ``devices=``-only digest never has to import jax."""
    try:
        from repro.launch.mesh import mesh_host_shape
        return max(mesh_host_shape(mesh, a)[0] for a in axis_names)
    except Exception:  # pragma: no cover - defensive: digest must not raise
        return 1


def topology_digest(mesh=None, axis_name="fft", *,
                    devices: int | None = None, platform: str | None = None,
                    panels=(1,), hosts: int | None = None) -> str:
    """The ``topology`` field of a distributed wisdom key.

    Everything an end-to-end distributed measurement is conditioned on:
    the device count along the FFT mesh axis, the axis name (it names the
    collective's communicator), the device platform, and the candidate
    pipeline-panel counts the tuner raced (a different panel space is a
    different tuning experiment).  Deliberately human-readable — a store
    should say *which* pod an entry was measured on, not just hash it.

    ``axis_name`` may be a *sequence* of axis names (the pencil-parallel
    3-D pipeline's 2-D mesh): the digest then carries one ``<size>x<name>``
    term per axis, '+'-joined (e.g. ``4xfft_r+2xfft_c.cpu.k1-2``).  The
    form is injective against 1-D digests ('+' never appears there) and
    against the transposed mesh (``4xfft_r+2xfft_c != 2xfft_r+4xfft_c``),
    so a plan measured on one pencil shape is never served to another.

    A mesh spanning more than one host prefixes a host-count component:
    ``2hx4xfft.cpu.k1-2-4`` is two hosts of two devices — comm times on
    it are two-tier quantities that must not be served to the one-host
    ``4xfft.cpu.k1-2-4`` (nor to ``4hx4xfft...``).  The prefix's ``<n>h``
    cannot occur at the head of a single-host digest (those start
    ``<devices>x``), so multi-host digests are injective against every
    pre-multi-host form — and single-host digests are *unchanged*, so
    existing stores keep serving single-host lookups.  ``hosts`` may be
    passed explicitly (``devices=`` callers); with a mesh it is derived
    from the device process layout / emulated-host registry.
    """
    if not isinstance(axis_name, str):
        if mesh is None:
            raise ValueError("a multi-axis topology_digest needs mesh=")
        if hosts is None:
            hosts = _mesh_hosts(mesh, axis_name)
        axes = "+".join(f"{int(mesh.shape[a])}x{a}" for a in axis_name)
        if platform is None:
            platform = mesh.devices.flat[0].platform
        ks = "-".join(str(int(k)) for k in sorted(set(panels))) or "1"
        prefix = f"{int(hosts)}hx" if int(hosts) > 1 else ""
        return f"{prefix}{axes}.{platform}.k{ks}"
    if devices is None:
        if mesh is None:
            raise ValueError("topology_digest needs a mesh or devices=")
        devices = int(mesh.shape[axis_name])
    if hosts is None:
        hosts = _mesh_hosts(mesh, (axis_name,)) if mesh is not None else 1
    if platform is None:
        if mesh is not None and mesh.devices.size:
            platform = mesh.devices.flat[0].platform
        else:  # pragma: no cover - devices= callers normally pass platform
            import jax
            platform = jax.default_backend()
    ks = "-".join(str(int(k)) for k in sorted(set(panels))) or "1"
    prefix = f"{int(hosts)}hx" if int(hosts) > 1 else ""
    return f"{prefix}{int(devices)}x{axis_name}.{platform}.k{ks}"


def _load_doc(path: str) -> tuple[int, dict]:
    """(version, entries) of a wisdom file; (0, {}) on missing, corrupt,
    or unserveable-version files (all are cache misses, never errors)."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return 0, {}
    if not isinstance(doc, dict) or doc.get("version") not in _SERVED_VERSIONS:
        return 0, {}
    entries = doc.get("entries")
    return int(doc["version"]), entries if isinstance(entries, dict) else {}


def load_wisdom(path: str) -> dict:
    """Entries of a wisdom file; {} on missing, corrupt, or version-mismatched
    files (all are cache misses, never errors).  Serves v2 stores as well
    as v3 — per-key version rules live in ``lookup_wisdom``."""
    return _load_doc(path)[1]


def lookup_wisdom(path: str, key: str
                  ) -> tuple[PlanConfig | SegmentSchedule, dict] | None:
    """(plan, full entry) for ``key``, or None on any kind of miss.

    The plan is a ``SegmentSchedule`` when the entry persisted one, else
    the single ``PlanConfig`` — callers (``plan_pfft``) lift a bare
    config into the degenerate schedule for the current partition.
    A distributed (``topo=``) key against a v2 store is always a miss,
    whatever the file contains: v2 predates per-topology measurement.
    """
    version, entries = _load_doc(path)
    if version < WISDOM_VERSION and _TOPO_FIELD in key:
        return None
    entry = entries.get(key)
    if not isinstance(entry, dict):
        return None
    try:
        if "schedule" in entry:
            return SegmentSchedule.from_dict(entry["schedule"]), entry
        return PlanConfig.from_dict(entry["config"]), entry
    except (KeyError, TypeError, ValueError):
        return None  # schema drift inside an entry is also just a miss


def _acquire_lock(path: str, timeout_s: float | None):
    """Exclusive flock on the store's ``.lock`` sibling, or None when the
    platform has no ``fcntl`` (the write is then merely atomic).

    ``timeout_s=None`` blocks, the historical behavior.  A finite timeout
    polls non-blocking acquisitions with backoff and raises
    ``TimeoutError`` when a wedged writer still holds the lock — callers
    for whom the store is advisory (the self-healing re-planner) catch it
    and move on rather than hang recovery behind a stuck process.
    """
    try:
        import fcntl
        lock_fh = open(path + ".lock", "w")
    except (ImportError, OSError):
        return None
    if timeout_s is None:
        try:
            fcntl.flock(lock_fh, fcntl.LOCK_EX)
        except OSError:
            lock_fh.close()
            return None
        return lock_fh
    deadline = time.monotonic() + float(timeout_s)
    delay = 0.01
    while True:
        try:
            fcntl.flock(lock_fh, fcntl.LOCK_EX | fcntl.LOCK_NB)
            return lock_fh
        except OSError:
            if time.monotonic() >= deadline:
                lock_fh.close()
                raise TimeoutError(
                    f"wisdom lock {path + '.lock'} still held after "
                    f"{timeout_s:g}s")
            time.sleep(delay)
            delay = min(delay * 2.0, 0.25)


def record_wisdom(path: str, key: str, config: PlanConfig | SegmentSchedule,
                  *, mode: str, time_s: float | None = None,
                  extra: dict | None = None, retries: int = 0,
                  backoff_s: float = 0.05,
                  lock_timeout_s: float | None = None) -> None:
    """Insert/overwrite one entry, atomically rewriting the store.

    The load-modify-replace cycle holds an exclusive flock on a ``.lock``
    sibling so concurrent writers (a benchmark warming sizes while a
    serving process records its own measure) don't drop each other's
    entries; on platforms without ``fcntl`` the write is merely atomic.

    ``retries`` re-attempts a failed write (``OSError``) with exponential
    backoff — transient I/O pressure should not cost a measured plan.
    ``lock_timeout_s`` bounds the wait for a contended lock (raises
    ``TimeoutError`` — see ``_acquire_lock``); the default ``None``
    blocks, preserving historical behavior.
    """
    lock_fh = _acquire_lock(path, lock_timeout_s)
    try:
        entries = load_wisdom(path)
        if isinstance(config, SegmentSchedule):
            entry: dict = {"schedule": config.to_dict(), "mode": mode}
        else:
            entry = {"config": config.to_dict(), "mode": mode}
        if time_s is not None:
            entry["time_s"] = float(time_s)
        if extra:
            entry.update(extra)
        entries[key] = entry
        doc = {"version": WISDOM_VERSION, "entries": entries}
        delay = float(backoff_s)
        for attempt in range(int(retries) + 1):
            try:
                tmp = path + ".tmp"
                with open(tmp, "w") as fh:
                    json.dump(doc, fh, indent=2, sort_keys=True)
                os.replace(tmp, path)
                break
            except OSError:
                if attempt >= retries:
                    raise
                time.sleep(delay)
                delay *= 2.0
    finally:
        if lock_fh is not None:
            lock_fh.close()
