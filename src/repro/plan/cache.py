"""Bounded LRU plan cache — the in-memory tier of the serving cache
hierarchy (request -> plan cache -> wisdom store -> tuner).

The wisdom store answers "which execution schedule is best for this
problem" without re-tuning, but consulting it still costs a file read,
a partition, and a fresh ``jax.jit`` of the executor.  A serving loop
handling a mixed stream of sizes cannot pay that per request, so
``PlanCache`` keeps the *built* ``PfftPlan`` objects (jitted executors
included) hot in memory behind a bounded LRU: a hit is a dict lookup and
returns the very same plan object — zero re-tune, zero re-trace.

Counters make the cache auditable from service stats:

* ``hits``/``misses``/``evictions`` — the usual LRU accounting; the
  bound keeps a long-tailed size mix from pinning one executable per
  size ever seen.
* ``retunes`` — how many *built* plans actually ran the tuner
  (``tuning["source"]`` of ``"estimate"``/``"measure"``) rather than
  being served from wisdom or an explicit config.  A warm serve run
  against a warm wisdom store must report zero: that is the acceptance
  counter the serving benchmark asserts.

Builds run under the cache lock, so two callers racing the same cold key
tune once, not twice — the same single-flight property the wisdom file
lock provides across processes, applied in-process.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable

__all__ = ["CacheStats", "PlanCache"]

# Tuning sources that mean the builder actually ran the tuner (device
# work for "measure", a cost-model sweep for "estimate") instead of
# being served a stored or explicit plan.
_TUNED_SOURCES = ("estimate", "measure")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    retunes: int = 0

    def as_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)


class PlanCache:
    """Bounded LRU mapping plan keys to built plans (see module docstring).

    ``get`` is the single entry point: a hit refreshes recency and
    returns the cached plan; a miss calls ``build()`` (under the lock —
    single-flight per key), records whether the built plan re-tuned, and
    evicts the least-recently-used entry past ``maxsize``.
    """

    def __init__(self, maxsize: int = 64):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.RLock()
        self.stats = CacheStats()

    def get(self, key: Hashable, build: Callable[[], Any]
            ) -> tuple[Any, bool]:
        """(plan, hit) for ``key``, building and inserting on a miss."""
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return self._entries[key], True
            plan = build()
            self.stats.misses += 1
            if getattr(plan, "tuning", None) and \
                    plan.tuning.get("source") in _TUNED_SOURCES:
                self.stats.retunes += 1
            self._entries[key] = plan
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
            return plan, False

    def peek(self, key: Hashable) -> Any | None:
        """The cached plan without touching recency or counters (the
        admission pricer peeks so pricing never distorts the LRU)."""
        with self._lock:
            return self._entries.get(key)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self) -> list[Hashable]:
        with self._lock:
            return list(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def reset_stats(self) -> None:
        """Zero the counters, keeping the entries — a warm second run
        starts its audit from a clean slate."""
        with self._lock:
            self.stats = CacheStats()

    def stats_dict(self) -> dict[str, int]:
        with self._lock:
            d = self.stats.as_dict()
            d["size"] = len(self._entries)
            d["maxsize"] = self.maxsize
            return d
