"""Calibrate ``CostParams`` from measured wisdom entries.

The estimate cost model ships hard-coded per-backend constants
(``CostParams.for_backend``).  Once a machine has accumulated measured
wisdom — tuner measure runs, microbenchmark sweeps — those entries *are*
ground truth for this host, so the constants can be fit back from them
instead of trusted: the paper's model-over-heuristics thesis applied to
our own cost model.

``fit_cost_params`` solves the model's own per-phase equation

    time/2 = base_seconds · factor[backend] + dispatches · c_d
             + traffic_bytes · (1/BW_hbm)

as a least-squares system over the measured entries, with one unknown
per backend factor (xla / stockham / pallas / fused), the dispatch
overhead ``c_d``, and the inverse HBM bandwidth (the traffic term used
to be subtracted with the hard-coded constant; with varied measured
sizes it is identifiable, so it is now a fitted column — the ROADMAP's
``hbm_bytes_per_s`` calibration.  ``nominal_flops`` stays fixed: the
backend factors multiply it, so a flop-rate error is absorbed by them
and a separate unknown would be unidentifiable).  The symbolic factor
decomposition comes from ``cost._factor_term`` — the estimate model and
this fit share one branch logic and cannot drift.  Each entry
contributes its makespan-dominant segment's flop-time as the factor
feature (schedule entries carry exact (rows, length, config) structure;
bare-config entries assume the even LB partition — the shape the
microbenchmark warms).  With fewer than ``min_entries`` measured
entries, or when the fit degenerates (a factor column absent or a
non-positive solution), the hard-coded constants are kept
component-wise — calibration refines, never breaks.

Distributed (``topo=``) entries additionally carry measured comm
samples; the interconnect constants are fit per *tier* as the line

    time = msgs · latency + bytes · (1/BW)

Legacy end-to-end samples (``comm_bytes`` + ``comm_time_s``, two
all_to_all phases per transform, so ``time = comm_time/2, msgs = 1``)
feed the intra tier — on a single-host axis the whole exchange rides
the legacy = intra-tier constants.  Tier-tagged ``comm_samples`` from
multi-host tuning runs feed their own tier, fitting
``inter_bytes_per_s``/``inter_latency_s`` separately (the two-tier comm
model of DESIGN.md §Multi-host topology).  Per tier: two or more
samples with distinct byte counts fit both constants, one pins the
bandwidth alone, zero keeps the defaults.

File-path fits are cached per (path, mtime): ``plan_pfft(wisdom=...)``
calibrates on every tuned call, and re-running lstsq over an unchanged
store would tax the plan-once hot path for nothing.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.fpm import fft_flops
from repro.plan.config import PlanConfig
from repro.plan.cost import (_COMPLEX64_BYTES, _factor_term, CostParams,
                             phase_dispatch_count)
from repro.plan.schedule import SegmentSchedule
from repro.plan.wisdom import load_wisdom

__all__ = ["fit_cost_params"]

_COLS = ("dispatch", "xla", "stockham", "pallas", "fused", "hbm")
_FIT_CACHE: dict[tuple, CostParams] = {}


def _parse_key(key: str) -> dict[str, str]:
    out = {}
    for part in key.split("|"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def _entry_structure(entry: dict, n: int, p: int):
    """((rows, length, config) per segment, dispatches, fused) of one entry."""
    if "schedule" in entry:
        sched = SegmentSchedule.from_dict(entry["schedule"])
        segs = [(e.rows, e.length, e.config) for e in sched.entries]
        common = sched.common_config
        fused = common is not None and common.fused
        dispatches = 1 if fused else len(sched.batch_groups())
        return segs, dispatches, fused
    cfg = PlanConfig.from_dict(entry["config"])
    from repro.core.partition import lb_partition  # lazy: core imports plan
    d = lb_partition(n, p).d
    segs = [(int(rows), n, cfg) for rows in d if rows > 0]
    dispatches = phase_dispatch_count(cfg, n, d, None)
    return segs, dispatches, cfg.fused


def _factor_feature(rows: int, length: int, cfg: PlanConfig,
                    nominal_flops: float) -> tuple[str, float]:
    """(factor column, base seconds) such that the modelled segment time
    is ``base * factor[column]`` — ``cost._factor_term`` with the flop
    time folded in."""
    name, scale = _factor_term(cfg, length)
    return name, float(fft_flops(rows, length)) / nominal_flops * scale


def _fit_tier(samples: list, latency: float, bw: float
              ) -> tuple[float, float]:
    """Fit one comm tier's ``(latency_s, bytes_per_s)`` from per-launch
    samples ``(bytes, seconds, msgs)``: the line ``t = msgs·lat + b/BW``
    (``msgs`` is the launch's slow-tier message count — 1 for an
    intra-tier or legacy flat launch, ``hosts − 1`` for the inter stage
    of a hierarchical exchange).  >= 2 samples with distinct byte counts
    fit both constants, exactly 1 fits the bandwidth with the default
    latency, non-positive solutions keep the defaults component-wise.
    """
    if not samples:
        return latency, bw
    if len({b for b, _, _ in samples}) >= 2:
        A = np.array([[m, b] for b, _, m in samples])
        y = np.array([t for _, t, _ in samples])
        try:
            x, *_ = np.linalg.lstsq(A, y, rcond=None)
        except np.linalg.LinAlgError:
            x = None
        if x is not None:
            if x[0] > 0:
                latency = float(x[0])
            if x[1] > 0:
                bw = 1.0 / float(x[1])
    else:
        b0, t0, m0 = samples[0]
        if t0 > m0 * latency:
            bw = b0 / (t0 - m0 * latency)
    return latency, bw


def _fit_comm_params(entries: dict, backend: str,
                     params: CostParams) -> CostParams:
    """Fold measured comm samples into ``params``'s interconnect constants.

    Samples are distributed wisdom entries (``topo=`` keys) carrying the
    extras ``tune_dist_config`` records, in two forms fit as two tiers
    (``_fit_tier``):

    * ``comm_bytes``/``comm_time_s`` — the legacy end-to-end sample;
      ``comm_time_s`` covers both phases, so it contributes
      ``(bytes, time/2, 1)`` to the *intra* tier (on a single-host axis
      the whole exchange rides the legacy = intra-tier constants);
    * ``comm_samples`` — tier-tagged per-launch samples from the grouped
      tier microbench (``_measure_tier_exchange``), each
      ``{tier, bytes, time_s, msgs}`` already per-exchange (no halving);
      the ``inter`` ones are what make ``inter_bytes_per_s`` /
      ``inter_latency_s`` fittable at all.
    """
    tiers: dict[str, list] = {"intra": [], "inter": []}
    for key, entry in entries.items():
        if not isinstance(entry, dict) or "|topo=" not in key:
            continue
        if _parse_key(key).get("backend") != backend:
            continue
        try:
            bytes_, t = float(entry["comm_bytes"]), float(entry["comm_time_s"])
            if bytes_ > 0 and t > 0:
                tiers["intra"].append((bytes_, t / 2.0, 1))
        except (KeyError, TypeError, ValueError):
            pass
        for s in entry.get("comm_samples") or []:
            try:
                tier = s["tier"]
                bytes_, t = float(s["bytes"]), float(s["time_s"])
                msgs = int(s.get("msgs", 1))
            except (KeyError, TypeError, ValueError):
                continue
            if tier in tiers and bytes_ > 0 and t > 0 and msgs > 0:
                tiers[tier].append((bytes_, t, msgs))
    if not tiers["intra"] and not tiers["inter"]:
        return params
    latency, bw = _fit_tier(tiers["intra"], params.comm_latency_s,
                            params.interconnect_bytes_per_s)
    inter_lat, inter_bw = _fit_tier(tiers["inter"], params.inter_latency_s,
                                    params.inter_bytes_per_s)
    return dataclasses.replace(params, comm_latency_s=latency,
                               interconnect_bytes_per_s=bw,
                               inter_latency_s=inter_lat,
                               inter_bytes_per_s=inter_bw)


def fit_cost_params(store: str | dict, *, backend: str | None = None,
                    min_entries: int = 8) -> CostParams:
    """Least-squares ``CostParams`` from a wisdom store's measured entries.

    ``store`` is a wisdom file path or the entries dict ``load_wisdom``
    returns.  Only entries measured on ``backend`` (default: the current
    jax backend) contribute.  Returns the fitted params, or the
    hard-coded ``CostParams.for_backend(backend)`` when fewer than
    ``min_entries`` measured entries exist; degenerate components fall
    back individually.  Interconnect constants are fit separately from
    the distributed entries' comm samples (``_fit_comm_params``) and need
    no minimum beyond their own — one dist measurement already beats the
    hard-coded bandwidth guess.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    cache_key = None
    if isinstance(store, str):
        try:
            mtime = os.stat(store).st_mtime_ns
        except OSError:
            mtime = None
        cache_key = (os.path.abspath(store), mtime, backend, min_entries)
        if cache_key in _FIT_CACHE:
            return _FIT_CACHE[cache_key]
        entries = load_wisdom(store)
    else:
        entries = store
    defaults = CostParams.for_backend(backend)

    A_rows, b_rows = [], []
    for key, entry in entries.items():
        if not isinstance(entry, dict) or "time_s" not in entry:
            continue
        if "|topo=" in key:
            # Distributed entries time the *whole* pipeline, all_to_all
            # included; feeding them into the compute-side equation would
            # bill comm seconds to a backend factor.  They contribute
            # through _fit_comm_params instead.
            continue
        fields = _parse_key(key)
        if fields.get("backend") != backend:
            continue
        try:
            n, p = int(fields["n"]), int(fields["p"])
            segs, dispatches, fused = _entry_structure(entry, n, p)
        except (KeyError, TypeError, ValueError):
            continue  # schema drift is never an error, just not a sample
        if not segs:
            continue
        traffic_bytes = 0.0 if fused else 2.0 * n * n * _COMPLEX64_BYTES
        b = float(entry["time_s"]) / 2.0
        # Makespan-dominant segment: largest *modeled* time under the
        # default factors (a tiny interpret-mode pallas segment can
        # dominate a large xla one, so raw flop-time would credit the
        # measured seconds to the wrong backend column).  Mixed schedules
        # attribute the whole makespan to that segment's backend — an
        # approximation, exact for homogeneous entries.
        def modeled(cb):
            col, base = cb
            factor = (defaults.fused_factor if col == "fused"
                      else defaults.backend_factor[col])
            return base * factor
        col, base = max(
            (_factor_feature(rows, length, cfg, defaults.nominal_flops)
             for rows, length, cfg in segs),
            key=modeled)
        row = np.zeros(len(_COLS))
        row[0] = dispatches
        row[_COLS.index(col)] = base
        row[_COLS.index("hbm")] = traffic_bytes
        A_rows.append(row)
        b_rows.append(b)

    fitted = defaults
    if len(b_rows) >= min_entries:
        A = np.asarray(A_rows)
        b = np.asarray(b_rows)
        try:
            x, *_ = np.linalg.lstsq(A, b, rcond=None)
        except np.linalg.LinAlgError:
            x = None
        if x is not None:
            c_d = float(x[0]) if x[0] > 0 else defaults.dispatch_overhead_s
            factors = dict(defaults.backend_factor)
            for name in ("xla", "stockham", "pallas"):
                j = _COLS.index(name)
                if np.any(A[:, j] > 0) and x[j] > 0:
                    factors[name] = float(x[j])
            j = _COLS.index("fused")
            fused_factor = (float(x[j]) if np.any(A[:, j] > 0) and x[j] > 0
                            else defaults.fused_factor)
            j = _COLS.index("hbm")
            hbm = (1.0 / float(x[j]) if np.any(A[:, j] > 0) and x[j] > 0
                   else defaults.hbm_bytes_per_s)
            fitted = dataclasses.replace(defaults, dispatch_overhead_s=c_d,
                                         backend_factor=factors,
                                         fused_factor=fused_factor,
                                         hbm_bytes_per_s=hbm)
    fitted = _fit_comm_params(entries, backend, fitted)
    if cache_key is not None:
        if len(_FIT_CACHE) > 64:
            _FIT_CACHE.clear()
        _FIT_CACHE[cache_key] = fitted
    return fitted
