"""Model-driven execution planning (the FFTW plan/wisdom lifecycle).

One selection point for every execution variant: ``PlanConfig`` names a
variant, ``cost`` prices it from the FPMs plus structural counts,
``tune`` picks one (estimate = model only, measure = time the finalists),
``wisdom`` persists the choice per (n, dtype, p, method, backend), and
``pads`` holds the shared FPM pad/CZT-length selection.  The user entry
point is ``repro.core.api.plan_pfft(tune=..., wisdom=...)``.
"""

from repro.plan.config import PlanConfig
from repro.plan.pads import czt_fft_lengths, fpm_pad_lengths
from repro.plan.cost import CostParams, estimate_cost, phase_dispatch_count
from repro.plan.wisdom import (WISDOM_VERSION, load_wisdom, lookup_wisdom,
                               record_wisdom, wisdom_key)
from repro.plan.tune import candidate_configs, measure_configs, tune_config

__all__ = [
    "PlanConfig",
    "czt_fft_lengths", "fpm_pad_lengths",
    "CostParams", "estimate_cost", "phase_dispatch_count",
    "WISDOM_VERSION", "load_wisdom", "lookup_wisdom", "record_wisdom",
    "wisdom_key",
    "candidate_configs", "measure_configs", "tune_config",
]
