"""Model-driven execution planning (the FFTW plan/wisdom lifecycle).

One selection point for every execution variant: ``PlanConfig`` names a
variant, ``SegmentSchedule`` assigns one per segment (the heterogeneous
generalisation — slow processors keep the library FFT while fast ones
take the kernel), ``groups`` lowers heterogeneous schedules to
single-SPMD device-group programs for the distributed pipeline,
``cost`` prices all of it from the FPMs plus structural counts,
``tune`` picks one (estimate = model only, measure = time the
finalists; ``tune_schedule`` prices per distinct effective FFT length,
``tune_dist_schedule`` races grouped finalists end to end on a mesh),
``wisdom`` persists the choice per (n, dtype, p, method, backend),
``cache`` keeps built plans hot in a bounded LRU fronting the wisdom
store (the serving layer's in-memory tier), ``calibrate`` fits the cost
constants back from measured wisdom, and ``pads`` holds the shared FPM
pad/CZT-length selection.  The user entry point is
``repro.core.api.plan_pfft(tune=..., wisdom=...)``.
"""

from repro.plan.config import PlanConfig, normalize_pad
from repro.plan.cache import CacheStats, PlanCache
from repro.plan.schedule import SegmentPlan, SegmentSchedule
from repro.plan.groups import (DeviceGroupProgram, device_group_program,
                               spmd_program_config)
from repro.plan.pads import (czt_fft_lengths, fpm_pad_lengths,
                             rfft_pad_lengths)
from repro.plan.cost import (CommTiers, CostParams, comm_phase_time,
                             dist_comm_bytes, dist_comm_time, estimate_cost,
                             estimate_grouped_cost, estimate_pfft3_cost,
                             estimate_schedule_cost, exchange_time,
                             halfspec_cols, pfft3_comm_bytes,
                             phase_dispatch_count)
from repro.plan.wisdom import (WISDOM_VERSION, load_wisdom, lookup_wisdom,
                               partition_digest, record_wisdom,
                               topology_digest, wisdom_key)
from repro.plan.tune import (candidate_configs, dist_panel_space,
                             grouped_dist_schedule, measure_configs,
                             measure_dist_configs, measure_pfft3_configs,
                             measure_rfft_configs,
                             measure_rfft_dist_configs, pfft3_panel_space,
                             segment_candidate_configs, tune_config,
                             tune_dist_config, tune_dist_schedule,
                             tune_pfft1_large, tune_pfft3,
                             tune_rfft, tune_rfft_dist, tune_schedule)
from repro.plan.calibrate import fit_cost_params

__all__ = [
    "PlanConfig", "normalize_pad",
    "CacheStats", "PlanCache",
    "SegmentPlan", "SegmentSchedule",
    "DeviceGroupProgram", "device_group_program", "spmd_program_config",
    "czt_fft_lengths", "fpm_pad_lengths", "rfft_pad_lengths",
    "CommTiers", "CostParams", "comm_phase_time", "dist_comm_bytes",
    "dist_comm_time", "estimate_cost",
    "estimate_grouped_cost", "estimate_pfft3_cost",
    "estimate_schedule_cost", "exchange_time", "halfspec_cols",
    "pfft3_comm_bytes", "phase_dispatch_count",
    "WISDOM_VERSION", "load_wisdom", "lookup_wisdom", "partition_digest",
    "record_wisdom", "topology_digest", "wisdom_key",
    "candidate_configs", "dist_panel_space", "grouped_dist_schedule",
    "measure_configs", "measure_dist_configs", "measure_pfft3_configs",
    "measure_rfft_configs",
    "measure_rfft_dist_configs", "pfft3_panel_space",
    "segment_candidate_configs",
    "tune_config", "tune_dist_config", "tune_dist_schedule",
    "tune_pfft1_large", "tune_pfft3",
    "tune_rfft", "tune_rfft_dist", "tune_schedule",
    "fit_cost_params",
]
