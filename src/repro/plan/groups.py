"""Device-group programs — heterogeneous schedules lowered to one SPMD program.

The paper's thesis is that per-processor performance models should drive
*per-processor* execution choices, yet until this module the distributed
pipeline rejected every heterogeneous ``SegmentSchedule`` outright: SPMD
runs one program per device, and a schedule mixing per-segment configs
looked unloweable.  It isn't.  The collective structure of the pipeline
(the ``all_to_all`` axes, how many collectives a phase issues) must be
identical on every device, but the *local row-FFT computation* between
collectives may branch freely: one ``jax.lax.switch`` over
``jax.lax.axis_index(axis_name)`` with one traced branch per distinct
config is still a single SPMD program — every device traces every
branch, executes its own, and meets the others at the same collectives.

``device_group_program`` performs that lowering: it maps a schedule's
entries onto contiguous mesh-axis device groups (entry ``rows`` must
tile the even ``N/p`` SPMD shards) and dedups the distinct configs into
switch branches.  The effective FFT length is made *uniform* — every
branch transforms at the schedule's max entry length — because the two
``all_to_all`` phases exchange the transformed blocks, so a device
cannot privately change the global bin semantics mid-pipeline.  This is
the program-level analog of ``ragged_row_layout``: there, a slower
group's surplus rows are masked padding; here, a shorter entry's surplus
*length* is — the price of one SPMD program, paid in flops instead of a
refusal.

What genuinely cannot lower (``spmd_program_config`` raises the named
SPMD error):

* mixed ``pad`` strategies — crop vs czt vs none are different
  *transforms*, not different speeds; mixing them across devices would
  produce a mathematically meaningless matrix;
* any ``fused`` entry in a mixed schedule — fused local phases exchange
  *transposed* blocks with swapped ``all_to_all`` axes, so a fused and
  an unfused device would disagree on the collective's layout;
* mixed ``pipeline_panels`` — the panel count is the number of
  collectives a phase issues, which SPMD requires to match everywhere;
* mixed ``exchange`` — flat and hierarchical transposes issue different
  collectives (one axis-wide all_to_all vs two grouped stages), and a
  device cannot be on one side of a collective its peer never issues.
"""

from __future__ import annotations

import dataclasses

from repro.plan.config import PlanConfig
from repro.plan.schedule import SegmentSchedule

__all__ = ["DeviceGroupProgram", "device_group_program",
           "spmd_program_config"]


def spmd_program_config(schedule: SegmentSchedule) -> PlanConfig:
    """Validate a schedule's program-level knobs; return its program config.

    The program config is the single config of a homogeneous schedule, or
    the ``anchor_config`` (makespan-dominant entry) of a heterogeneous
    one — its ``pad``/``fused``/``pipeline_panels`` are shared by every
    entry (validated here), so callers may read the phase-shaping knobs
    off it.  Raises ``ValueError`` — the named SPMD error, carrying the
    schedule's ``describe()`` — for the mixes the module docstring lists
    as genuinely unloweable.
    """
    configs = schedule.configs
    if len(configs) == 1:
        return configs[0]
    knobs = {(c.pad, c.fused, c.pipeline_panels, c.exchange) for c in configs}
    if len(knobs) > 1 or any(c.fused for c in configs):
        raise ValueError(
            "pfft2_distributed runs one SPMD program per device; the "
            f"heterogeneous schedule [{schedule.describe()}] mixes "
            "program-level knobs (pad / fused / pipeline_panels / exchange "
            "shape the collective structure, which SPMD requires to match "
            "on every device) and cannot be lowered to shard_map — only the "
            "local row-FFT variant (radix/backend) may differ per device "
            "group; use the single-host executor (repro.core.pfft) for the "
            "rest")
    return schedule.anchor_config


@dataclasses.dataclass(frozen=True)
class DeviceGroupProgram:
    """A heterogeneous schedule lowered onto ``p`` mesh-axis devices.

    ``configs`` are the schedule's distinct configs in first-appearance
    order — one traced ``lax.switch`` branch each; ``group_of_device[i]``
    names the branch device ``i`` executes; ``pad_len`` is the uniform
    effective FFT length every branch transforms at (the max over the
    schedule's entries unless explicitly overridden — see the module
    docstring's uniform-length rule).
    """

    n: int
    p: int
    configs: tuple[PlanConfig, ...]
    group_of_device: tuple[int, ...]
    pad_len: int

    def describe(self) -> str:
        """Compact human tag: ``branch@devices`` terms, e.g.
        ``radix=xla,batched@[0,1] + radix=2,batched@[2,3]``."""
        terms = []
        for g, cfg in enumerate(self.configs):
            devs = [i for i, gi in enumerate(self.group_of_device) if gi == g]
            terms.append(f"{cfg.describe()}@{devs}")
        return " + ".join(terms)


def device_group_program(schedule: SegmentSchedule, p: int,
                         pad_len: int | None = None) -> DeviceGroupProgram:
    """Map ``schedule``'s entries onto contiguous device groups of a
    ``p``-device mesh axis.

    Each entry must cover a whole number of the even ``N/p`` SPMD row
    shards (an entry spanning ``k·N/p`` rows owns ``k`` contiguous
    devices), and together the entries must cover all ``N`` rows — every
    device needs a branch.  Violations raise the named SPMD error; the
    program-level knob mix is validated first (``spmd_program_config``).
    """
    spmd_program_config(schedule)
    n = schedule.n
    if p <= 0 or n % p:
        raise ValueError(
            f"N={n} must be divisible by the mesh axis size p={p}")
    n_loc = n // p
    if schedule.total_rows != n:
        raise ValueError(
            "pfft2_distributed runs one SPMD program per device; the "
            f"schedule [{schedule.describe()}] covers {schedule.total_rows} "
            f"of N={n} rows, so some device would have no branch — a "
            "device-group program needs the full matrix")
    configs: list[PlanConfig] = []
    groups: list[int] = []
    for e in schedule.entries:
        if e.rows % n_loc:
            raise ValueError(
                "pfft2_distributed runs one SPMD program per device over "
                f"contiguous equal N/p={n_loc} row shards; segment "
                f"{e.index} of [{schedule.describe()}] covers {e.rows} "
                "rows — not a whole number of shards — so it cannot be "
                "assigned a device group (SPMD shards are equal-sized; "
                "express uneven row counts through ragged_row_layout)")
        try:
            g = configs.index(e.config)
        except ValueError:
            g = len(configs)
            configs.append(e.config)
        groups.extend([g] * (e.rows // n_loc))
    length = max(e.length for e in schedule.entries)
    if pad_len is not None:
        length = int(pad_len)
    return DeviceGroupProgram(n=n, p=p, configs=tuple(configs),
                              group_of_device=tuple(groups), pad_len=length)
