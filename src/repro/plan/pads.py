"""Pad-length selection shared by the plan API and the algorithm layer.

Before this module, ``core/api.py::plan_pfft`` and
``core/pfft.py::pfft_fpm_czt`` each re-implemented the same
``smooth_candidates`` + ``time_at`` argmin loop (and the FPM-PAD pad
vector was built inline in both).  These helpers are the single home for
both decisions:

* ``fpm_pad_lengths`` — paper Alg. 7 Step 2 per processor: the FPM-chosen
  ``N_padded_i`` (pad-and-crop semantics).
* ``czt_fft_lengths`` — beyond-paper: the FPM-chosen smooth FFT length
  ``m_i >= 2N-1`` for the exact Bluestein transform of each segment.
* ``rfft_pad_lengths`` — the real-pipeline variant of ``fpm_pad_lengths``
  restricted to *even* padded lengths (the pack-two-rows rfft needs an
  even transform length to keep its half-spectrum crop well defined).
"""

from __future__ import annotations

import numpy as np

from repro.core.fpm import FPMSet
from repro.core.padding import determine_pad_length, smooth_candidates

__all__ = ["fpm_pad_lengths", "czt_fft_lengths", "rfft_pad_lengths"]


def fpm_pad_lengths(fpms: FPMSet, d: np.ndarray, n: int) -> np.ndarray:
    """Per-processor padded row lengths for PFFT-FPM-PAD (paper §III-D).

    ``result[i] == n`` means no beneficial padding exists for processor i.
    """
    return np.array(
        [determine_pad_length(fpms[i], int(d[i]), n) for i in range(fpms.p)],
        dtype=np.int64,
    )


def czt_fft_lengths(fpms: FPMSet, d: np.ndarray, n: int, *,
                    limit_ratio: float = 2.0) -> np.ndarray:
    """Per-processor Bluestein FFT lengths for PFFT-FPM-CZT.

    Each processor picks the smooth, lane-aligned length ``m >= 2N-1``
    minimising its FPM-predicted time for its ``d[i]`` rows; idle
    processors (``d[i] == 0``) take the smallest candidate.
    """
    cands = smooth_candidates(2 * n - 1, limit_ratio=limit_ratio)

    def best_len(i: int) -> int:
        d_i = int(d[i])
        if d_i == 0:
            return int(cands[0])
        times = [fpms[i].time_at(d_i, int(c)) for c in cands]
        return int(cands[int(np.argmin(times))])

    return np.array([best_len(i) for i in range(fpms.p)], dtype=np.int64)


def rfft_pad_lengths(fpms: FPMSet, d: np.ndarray, n: int) -> np.ndarray:
    """Per-processor padded row lengths for the real FPM-PAD variant.

    Same argmin as ``determine_pad_length`` but only over *even*
    candidate lengths: the rfft half spectrum of an odd-length row has a
    different bin layout, and cropping it back to the first ``n//2+1``
    bins of the length-``n`` transform only matches for even pads.  In
    practice the FPM grid columns are lane-aligned smooth sizes (all
    even), so the restriction rarely binds; ``n`` (no pad) is the
    fallback exactly as in the complex path.
    """
    d = np.asarray(d)

    def best_len(i: int) -> int:
        fpm = fpms[i]
        d_i = int(d[i])
        best_y, best_t = n, fpm.time_at(d_i, n)
        for y in np.asarray(fpm.ys):
            y = int(y)
            if y <= n or y % 2:
                continue
            t = fpm.time_at(d_i, y)
            if t < best_t:
                best_y, best_t = y, t
        return best_y

    return np.array([best_len(i) for i in range(fpms.p)], dtype=np.int64)
