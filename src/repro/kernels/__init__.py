"""Pallas TPU kernels for the paper's compute hot-spots (row FFTs and the
blocked transpose), each with a jit'd op wrapper and a pure-jnp oracle.
Validated with interpret=True on CPU; compiled path targets TPU."""

from repro.kernels.fft.ops import fft_rows_op
from repro.kernels.fused.ops import fft_rows_transpose_op
from repro.kernels.transpose.ops import transpose_op

__all__ = ["fft_rows_op", "fft_rows_transpose_op", "transpose_op"]
