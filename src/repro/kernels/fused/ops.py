"""jit'd public op for the fused row-FFT -> transpose kernel.

Same contract shape as ``repro.kernels.fft.ops.fft_rows_op`` (complex in,
complex out, row padding to the block multiple, radix auto-selection, CPU
interpret fallback) except the result comes back transposed: input
``(rows, n)`` -> output ``(n, rows)`` holding ``FFT_rows(x).T``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fft.ops import resolve_call_params, rows_to_padded_planes
from repro.kernels.fused.kernel import fft_rows_transpose_pallas

__all__ = ["fft_rows_transpose_op"]


@functools.partial(jax.jit,
                   static_argnames=("inverse", "block_rows", "radix",
                                    "interpret"))
def fft_rows_transpose_op(
    x: jnp.ndarray,
    *,
    inverse: bool = False,
    block_rows: int | None = None,
    radix: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused ``FFT_rows(x).T`` via one Pallas dispatch.  x: (rows, n) complex."""
    if x.ndim != 2:
        raise ValueError(f"fused op takes a 2-D matrix, got shape {x.shape}")
    rows, n = x.shape
    block_rows, radix, interpret = resolve_call_params(n, block_rows, radix,
                                                       interpret)
    re, im, _ = rows_to_padded_planes(x, block_rows)
    ore, oim = fft_rows_transpose_pallas(re, im, block_rows=block_rows,
                                         inverse=inverse, radix=radix,
                                         interpret=interpret)
    out = (ore[:, :rows] + 1j * oim[:, :rows])
    return out.astype(jnp.result_type(x, jnp.complex64))
