"""Pallas TPU kernel: fused *real* row FFT -> transposed write.

The real-pipeline sibling of ``kernels.fused.kernel``: each grid program
packs two real rows per complex Stockham FFT (see ``kernels.fft.real``),
unpacks the pair in registers, transposes both spectra in registers, and
writes them to their transposed tile positions.  The half-spectrum crop
happens host-side after reassembly — output tiles are full transform
length ``n`` high for lane alignment, exactly like the complex fused
kernel's output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft.kernel import apply_stockham
from repro.kernels.fft.ops import resolve_call_params
from repro.kernels.fft.real import _pack_real_rows, unpack_packed_fft

__all__ = ["rfft_rows_transpose_pallas", "rfft_rows_transpose_op"]


def _rfused_kernel(a_ref, b_ref, aor_ref, aoi_ref, bor_ref, boi_ref, *,
                   radix: int):
    zr, zi = apply_stockham(a_ref[...], b_ref[...], radix=radix)
    a_re, a_im, b_re, b_im = unpack_packed_fft(zr, zi)
    aor_ref[...] = a_re.T
    aoi_ref[...] = a_im.T
    bor_ref[...] = b_re.T
    boi_ref[...] = b_im.T


def rfft_rows_transpose_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_rows: int = 8,
    radix: int = 2,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Two (pairs, n) real row planes -> four transposed (n, pairs) planes
    ``(FFT(a).T.re, FFT(a).T.im, FFT(b).T.re, FFT(b).T.im)``.

    pairs must be a multiple of block_rows (the op pads); n a power of two.
    """
    pairs, n = a.shape
    if pairs % block_rows:
        raise ValueError(
            f"pairs={pairs} not a multiple of block_rows={block_rows}")
    grid = (pairs // block_rows,)
    in_spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((n, block_rows), lambda i: (0, i))
    out_shape = [jax.ShapeDtypeStruct((n, pairs), a.dtype)] * 4
    fn = pl.pallas_call(
        functools.partial(_rfused_kernel, radix=radix),
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=[out_spec] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(a, b)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "radix", "interpret"))
def rfft_rows_transpose_op(
    x: jnp.ndarray,
    *,
    block_rows: int | None = None,
    radix: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused ``rfft_rows(x).T`` via one Pallas dispatch.

    x: (rows, n) real -> (n//2+1, rows) complex, the transposed half
    spectrum — the phase-1 output of ``rfft2`` without the intermediate
    HBM matrix.
    """
    if x.ndim != 2:
        raise ValueError(f"fused op takes a 2-D matrix, got shape {x.shape}")
    rows, n = x.shape
    nh = n // 2 + 1
    block_rows, radix, interpret = resolve_call_params(n, block_rows, radix,
                                                       interpret)
    a, b, total = _pack_real_rows(x, block_rows)
    ar, ai, br, bi = rfft_rows_transpose_pallas(a, b, block_rows=block_rows,
                                                radix=radix,
                                                interpret=interpret)
    spec_a = ar + 1j * ai   # (n, padded_pairs): columns are even rows
    spec_b = br + 1j * bi   # (n, padded_pairs): columns are odd rows
    # Re-interleave pair columns, then crop bins (rows here) and columns.
    out = jnp.stack([spec_a, spec_b], axis=2).reshape(n, -1)[:nh, :total]
    return out.astype(jnp.result_type(x, jnp.complex64))
