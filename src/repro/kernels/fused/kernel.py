"""Pallas TPU kernel: fused row FFT -> transposed write.

The unfused pipeline (steps 1-2 / 3-4 of ``fft2d_rowcol``) materialises the
row-transformed matrix in HBM, then a second kernel streams it back through
VMEM to transpose it.  This kernel fuses the two: each grid program loads a
``block_rows x n`` row block, runs the full Stockham stage loop in VMEM,
transposes the block *in registers*, and writes it directly to its
transposed tile position ``(0, i)`` of the ``(n, rows)`` output.  The
intermediate HBM matrix — 2 planes x rows x n x 4B of write + read traffic
per phase — disappears entirely; the transform pass IS the transpose pass
(the EFFT / Korotkevich fused-transform structure, arXiv:1409.5757 /
arXiv:2008.07031).

Output block height is the full transform length ``n``, so VMEM holds
2 planes x block_rows x n (input) + 2 x n x block_rows (output) — the same
footprint as the unfused FFT kernel's ping-pong, and ``ops.pick_block_rows``
already budgets for it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft.kernel import apply_stockham

__all__ = ["fft_rows_transpose_pallas"]


def _fused_kernel(re_ref, im_ref, ore_ref, oim_ref, *, inverse: bool,
                  radix: int):
    re, im = apply_stockham(re_ref[...], im_ref[...], radix=radix,
                            inverse=inverse)
    ore_ref[...] = re.T
    oim_ref[...] = im.T


def fft_rows_transpose_pallas(
    re: jnp.ndarray,
    im: jnp.ndarray,
    *,
    block_rows: int = 8,
    inverse: bool = False,
    radix: int = 2,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(rows, n) planes -> FFT along rows, written transposed as (n, rows).

    rows must be a multiple of block_rows (ops.py pads); n a power of two.
    """
    rows, n = re.shape
    if rows % block_rows:
        raise ValueError(f"rows={rows} not a multiple of block_rows={block_rows}")
    grid = (rows // block_rows,)
    in_spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((n, block_rows), lambda i: (0, i))
    out_shape = [
        jax.ShapeDtypeStruct((n, rows), re.dtype),
        jax.ShapeDtypeStruct((n, rows), im.dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_fused_kernel, inverse=inverse, radix=radix),
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im)
