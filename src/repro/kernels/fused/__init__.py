from repro.kernels.fused.kernel import fft_rows_transpose_pallas
from repro.kernels.fused.ops import fft_rows_transpose_op

__all__ = ["fft_rows_transpose_pallas", "fft_rows_transpose_op"]
