"""Pallas TPU kernel: blocked matrix transpose (paper Appendix A analogue).

The paper's ``hcl_transpose_block`` swaps cache-sized tiles; the TPU analogue
swaps VMEM tiles: grid (N/b, N/b), program (i, j) reads tile (i, j), writes
its transpose to tile (j, i) of the output.  Tile 128x128 matches the
8x128 native layout (16 sublane rounds) and keeps both tiles well under
VMEM.  Complex matrices are transposed as two f32 planes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["transpose_pallas"]


def _tr_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def transpose_pallas(x: jnp.ndarray, *, block: int = 128,
                     interpret: bool = False) -> jnp.ndarray:
    """Blocked transpose of a 2-D array; dims must divide by ``block``
    (ops.py pads)."""
    r, c = x.shape
    if r % block or c % block:
        raise ValueError(f"shape {x.shape} not divisible by block={block}")
    grid = (r // block, c // block)
    fn = pl.pallas_call(
        _tr_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((c, r), x.dtype),
        interpret=interpret,
    )
    return fn(x)
