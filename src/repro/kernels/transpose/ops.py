"""jit'd public op for the blocked transpose kernel (pads to the block grid,
handles complex via planes, interprets on CPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.transpose.kernel import transpose_pallas

__all__ = ["transpose_op"]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def transpose_op(x: jnp.ndarray, *, block: int = 128,
                 interpret: bool | None = None) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    r, c = x.shape
    pr = (r + block - 1) // block * block
    pc = (c + block - 1) // block * block

    def run(plane):
        p = jnp.pad(plane, ((0, pr - r), (0, pc - c)))
        return transpose_pallas(p, block=block, interpret=interpret)[:c, :r]

    if jnp.iscomplexobj(x):
        return run(jnp.real(x)) + 1j * run(jnp.imag(x))
    return run(x)
