"""Pure-jnp oracle for the blocked transpose kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["transpose_ref"]


def transpose_ref(x: jnp.ndarray) -> jnp.ndarray:
    return x.T
