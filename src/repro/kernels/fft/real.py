"""Pallas TPU kernel: batched *real* row FFT (pack-two-rows trick).

A real length-``n`` row has a conjugate-symmetric spectrum, so only the
``n//2+1`` Hermitian-unique bins need computing/storing.  Rather than a
separate split-real Stockham, this kernel packs **two real rows per
complex FFT** — the classic trick (Korotkevich's SMP 2-D Fourier code is
built on the same r2c subroutine structure):

    z = a + i*b          (a, b: consecutive real rows)
    Z = FFT(z)           (one complex Stockham pass, shared with the
                          complex kernel's ``apply_stockham``)
    A[k] = (Z[k] + conj(Z[n-k])) / 2     = FFT(a)[k]
    B[k] = (Z[k] - conj(Z[n-k])) / 2i    = FFT(b)[k]

so the row phase runs *half* the complex FFTs.  The reversed-bin plane
``Z[(n-k) mod n]`` is a lane flip of bins 1..n-1 with bin 0 fixed — a
cheap VPU shuffle, no gather.

The kernel emits **full-width** ``(block_rows, n)`` output planes (lane
alignment: a ``n//2+1``-wide block would be misaligned for every n), and
the host-side op crops to the half spectrum after reassembly.  The crop
is free in practice — it fuses into the surrounding jit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft.kernel import apply_stockham
from repro.kernels.fft.ops import resolve_call_params

__all__ = ["rfft_rows_pallas", "rfft_rows_op", "unpack_packed_fft"]


def _reverse_bins(x: jnp.ndarray) -> jnp.ndarray:
    """``x[..., (n - k) mod n]``: bin 0 stays, bins 1..n-1 reverse."""
    return jnp.concatenate([x[..., :1], jnp.flip(x[..., 1:], axis=-1)],
                           axis=-1)


def unpack_packed_fft(zr: jnp.ndarray, zi: jnp.ndarray):
    """Split ``Z = FFT(a + i*b)`` planes into FFT(a) and FFT(b) planes.

    Returns ``(a_re, a_im, b_re, b_im)``, each full-width (callers crop
    to the half spectrum).  Pure jnp — runs inside the Pallas kernels and
    is unit-tested standalone against the complex oracle.
    """
    rzr = _reverse_bins(zr)
    rzi = _reverse_bins(zi)
    a_re = (zr + rzr) * 0.5
    a_im = (zi - rzi) * 0.5
    b_re = (zi + rzi) * 0.5
    b_im = (rzr - zr) * 0.5
    return a_re, a_im, b_re, b_im


def _rfft_kernel(a_ref, b_ref, aor_ref, aoi_ref, bor_ref, boi_ref, *,
                 radix: int):
    zr, zi = apply_stockham(a_ref[...], b_ref[...], radix=radix)
    a_re, a_im, b_re, b_im = unpack_packed_fft(zr, zi)
    aor_ref[...] = a_re
    aoi_ref[...] = a_im
    bor_ref[...] = b_re
    boi_ref[...] = b_im


def rfft_rows_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_rows: int = 8,
    radix: int = 2,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """pallas_call wrapper: two (pairs, n) real row planes -> four planes
    ``(FFT(a).re, FFT(a).im, FFT(b).re, FFT(b).im)``, each (pairs, n).

    pairs must be a multiple of block_rows (the op pads); n a power of two.
    """
    pairs, n = a.shape
    if pairs % block_rows:
        raise ValueError(
            f"pairs={pairs} not a multiple of block_rows={block_rows}")
    grid = (pairs // block_rows,)
    spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((pairs, n), a.dtype)] * 4
    fn = pl.pallas_call(
        functools.partial(_rfft_kernel, radix=radix),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(a, b)


def _pack_real_rows(x2: jnp.ndarray, block_rows: int
                    ) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """(rows, n) real -> f32 (even-row, odd-row) planes padded so the pair
    count is a block multiple, plus the original row count for cropping."""
    total = x2.shape[0]
    pairs = (total + 1) // 2
    padded_pairs = (pairs + block_rows - 1) // block_rows * block_rows
    padded_rows = 2 * padded_pairs
    if padded_rows != total:
        x2 = jnp.pad(x2, ((0, padded_rows - total), (0, 0)))
    x2 = x2.astype(jnp.float32)
    return x2[0::2], x2[1::2], total


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "radix", "interpret"))
def rfft_rows_op(
    x: jnp.ndarray,
    *,
    block_rows: int | None = None,
    radix: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Real row FFT via the packed Pallas kernel.

    x: (..., rows, n) real -> (..., rows, n//2+1) complex half spectrum,
    matching ``jnp.fft.rfft(x, axis=-1)``.  ``radix=None`` auto-selects.
    """
    n = x.shape[-1]
    nh = n // 2 + 1
    block_rows, radix, interpret = resolve_call_params(n, block_rows, radix,
                                                       interpret)
    lead = x.shape[:-2]
    rows = x.shape[-2]
    x2 = x.reshape((-1, n))
    a, b, total = _pack_real_rows(x2, block_rows)
    ar, ai, br, bi = rfft_rows_pallas(a, b, block_rows=block_rows,
                                      radix=radix, interpret=interpret)
    spec_a = ar + 1j * ai
    spec_b = br + 1j * bi
    # Re-interleave the even/odd row pairs, then crop rows and bins.
    out = jnp.stack([spec_a, spec_b], axis=1).reshape(-1, n)[:total, :nh]
    out = out.astype(jnp.result_type(x, jnp.complex64))
    return out.reshape(lead + (rows, nh)) if lead else out.reshape((rows, nh))
