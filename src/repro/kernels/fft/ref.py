"""Pure-jnp oracle for the Pallas row-FFT kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["fft_rows_ref"]


def fft_rows_ref(re: jnp.ndarray, im: jnp.ndarray, *, inverse: bool = False):
    """Reference: complex FFT along the last axis, returned as planes."""
    x = re.astype(jnp.float32) + 1j * im.astype(jnp.float32)
    y = jnp.fft.ifft(x, axis=-1) if inverse else jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(re.dtype), jnp.imag(y).astype(im.dtype)
