"""Pallas TPU kernel: batched row FFT (Stockham autosort, radix-4/radix-2).

TPU adaptation of the paper's 1D_ROW_FFTS_LOCAL hot loop.  Design notes:

* Complex data is carried as two f32 planes (re, im) — TPU Pallas has no
  complex dtype; the MXU/VPU operate on real lanes.
* The Stockham autosort formulation is chosen *because* it needs no
  bit-reversal gather: every stage is a reshape + broadcast-multiply +
  stack, all of which stay in VMEM registers/lanes.  A DIT kernel would
  need a lane gather, which is slow on the VPU.
* Radix 4 halves the pass count — ceil(log2 n / 2) stages instead of
  log2 n — so every intermediate plane makes half as many trips through
  the VPU register file; lengths with odd log2 get one radix-2 tail
  stage.  ``stockham_stage_count`` reports the pass count per radix and
  is what the microbenchmark records.
* Grid is over row blocks: each program transforms ``block_rows`` rows of
  length ``n`` entirely in VMEM.  The stage loop is unrolled at trace
  time.  VMEM budget: 2 planes x block_rows x n x 4B (+ ping-pong),
  so block_rows is chosen by ``ops.pick_block_rows`` to fit ~8 MiB.
* Twiddles are computed in-kernel from an iota (cheap transcendental on
  VPU) — no HBM traffic for twiddle tables.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "fft_rows_pallas",
    "stockham_planes",
    "stockham_planes_radix4",
    "stockham_stage_count",
]


def stockham_stage_count(n: int, radix: int = 2) -> int:
    """Number of Stockham passes over the data for a length-``n`` transform.

    radix 2: log2(n) passes.  radix 4 (with a radix-2 tail when log2(n) is
    odd): ceil(log2(n) / 2) passes.
    """
    if n & (n - 1) or n < 1:
        raise ValueError(f"length {n} must be a power of two")
    log2n = int(np.log2(n)) if n > 1 else 0
    if radix == 2:
        return log2n
    if radix == 4:
        return (log2n + 1) // 2
    raise ValueError(f"unsupported radix {radix}")


def stockham_planes(re: jnp.ndarray, im: jnp.ndarray, *, inverse: bool = False):
    """Stockham radix-2 FFT over the last axis of real/imag planes.

    Shapes (..., n), n a power of two.  Returns (re, im).  Pure jnp — this
    exact function body runs inside the Pallas kernel and is also unit-tested
    standalone against the complex oracle.
    """
    n = re.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length {n} must be a power of two")
    batch = re.shape[:-1]
    sign = 1.0 if inverse else -1.0
    ncur, s = n, 1
    while ncur > 1:
        m = ncur // 2
        vre = re.reshape(batch + (ncur, s))
        vim = im.reshape(batch + (ncur, s))
        are, aim = vre[..., :m, :], vim[..., :m, :]
        bre, bim = vre[..., m:, :], vim[..., m:, :]
        ang = sign * np.pi / m * jnp.arange(m, dtype=re.dtype)
        wre = jnp.cos(ang)[:, None]
        wim = jnp.sin(ang)[:, None]
        top_re, top_im = are + bre, aim + bim
        dre, dim = are - bre, aim - bim
        bot_re = dre * wre - dim * wim
        bot_im = dre * wim + dim * wre
        re = jnp.stack([top_re, bot_re], axis=-2).reshape(batch + (n,))
        im = jnp.stack([top_im, bot_im], axis=-2).reshape(batch + (n,))
        ncur, s = m, 2 * s
    if inverse:
        re = re / n
        im = im / n
    return re, im


def stockham_planes_radix4(re: jnp.ndarray, im: jnp.ndarray, *,
                           inverse: bool = False):
    """Mixed radix-4/radix-2 Stockham FFT over the last axis of planes.

    Same contract as ``stockham_planes`` but each radix-4 pass combines two
    radix-2 levels, so the data makes ceil(log2 n / 2) trips instead of
    log2 n.  When log2(n) is odd the final pass (ncur == 2) is radix-2.

    Derivation: with the stage view (..., ncur, s) and m = ncur // r, part
    t is v[..., t*m:(t+1)*m, :]; output slot u of butterfly j is
    ``w_j^u * sum_t part_t * omega_r^{u t}`` with w_j = exp(sign*2*pi*i*
    j/(r*m)) — for r=2 this reduces exactly to ``stockham_planes``'s
    update, for r=4 omega_4 = -+i so the inner DFT-4 is adds/swaps only.
    """
    n = re.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length {n} must be a power of two")
    batch = re.shape[:-1]
    sign = 1.0 if inverse else -1.0
    ncur, s = n, 1
    while ncur > 1:
        if ncur % 4:  # ncur == 2: one radix-2 tail stage
            m = ncur // 2
            vre = re.reshape(batch + (ncur, s))
            vim = im.reshape(batch + (ncur, s))
            are, aim = vre[..., :m, :], vim[..., :m, :]
            bre, bim = vre[..., m:, :], vim[..., m:, :]
            ang = sign * np.pi / m * jnp.arange(m, dtype=re.dtype)
            wre = jnp.cos(ang)[:, None]
            wim = jnp.sin(ang)[:, None]
            dre, dim = are - bre, aim - bim
            re = jnp.stack([are + bre, dre * wre - dim * wim],
                           axis=-2).reshape(batch + (n,))
            im = jnp.stack([aim + bim, dre * wim + dim * wre],
                           axis=-2).reshape(batch + (n,))
            ncur, s = m, 2 * s
            continue
        m = ncur // 4
        vre = re.reshape(batch + (ncur, s))
        vim = im.reshape(batch + (ncur, s))
        p0re, p0im = vre[..., 0 * m:1 * m, :], vim[..., 0 * m:1 * m, :]
        p1re, p1im = vre[..., 1 * m:2 * m, :], vim[..., 1 * m:2 * m, :]
        p2re, p2im = vre[..., 2 * m:3 * m, :], vim[..., 2 * m:3 * m, :]
        p3re, p3im = vre[..., 3 * m:4 * m, :], vim[..., 3 * m:4 * m, :]
        # DFT-4 across parts: even/odd sums, omega_4 = sign * i.
        e0re, e0im = p0re + p2re, p0im + p2im   # x0 + x2
        e1re, e1im = p0re - p2re, p0im - p2im   # x0 - x2
        o0re, o0im = p1re + p3re, p1im + p3im   # x1 + x3
        # sign*i * (x1 - x3): multiply by i flips planes.
        d3re, d3im = p1re - p3re, p1im - p3im
        o1re, o1im = -sign * d3im, sign * d3re
        s0re, s0im = e0re + o0re, e0im + o0im   # S0 = x0 + x1 + x2 + x3
        s1re, s1im = e1re + o1re, e1im + o1im   # S1 = x0 + w x1 - x2 + w^3 x3
        s2re, s2im = e0re - o0re, e0im - o0im   # S2 = x0 - x1 + x2 - x3
        s3re, s3im = e1re - o1re, e1im - o1im   # S3
        ang = sign * 2.0 * np.pi / (4 * m) * jnp.arange(m, dtype=re.dtype)
        w1re, w1im = jnp.cos(ang)[:, None], jnp.sin(ang)[:, None]
        w2re = w1re * w1re - w1im * w1im
        w2im = 2.0 * w1re * w1im
        w3re = w2re * w1re - w2im * w1im
        w3im = w2re * w1im + w2im * w1re
        u1re = s1re * w1re - s1im * w1im
        u1im = s1re * w1im + s1im * w1re
        u2re = s2re * w2re - s2im * w2im
        u2im = s2re * w2im + s2im * w2re
        u3re = s3re * w3re - s3im * w3im
        u3im = s3re * w3im + s3im * w3re
        re = jnp.stack([s0re, u1re, u2re, u3re], axis=-2).reshape(batch + (n,))
        im = jnp.stack([s0im, u1im, u2im, u3im], axis=-2).reshape(batch + (n,))
        ncur, s = m, 4 * s
    if inverse:
        re = re / n
        im = im / n
    return re, im


def apply_stockham(re: jnp.ndarray, im: jnp.ndarray, *, radix: int = 2,
                   inverse: bool = False):
    """Dispatch to the radix-2 or mixed radix-4 stage loop."""
    if radix == 4:
        return stockham_planes_radix4(re, im, inverse=inverse)
    if radix == 2:
        return stockham_planes(re, im, inverse=inverse)
    raise ValueError(f"unsupported radix {radix}")


def _fft_kernel(re_ref, im_ref, ore_ref, oim_ref, *, inverse: bool,
                radix: int):
    re, im = apply_stockham(re_ref[...], im_ref[...], radix=radix,
                            inverse=inverse)
    ore_ref[...] = re
    oim_ref[...] = im


def fft_rows_pallas(
    re: jnp.ndarray,
    im: jnp.ndarray,
    *,
    block_rows: int = 8,
    inverse: bool = False,
    radix: int = 2,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """pallas_call wrapper: (rows, n) planes -> transformed planes.

    rows must be a multiple of block_rows (ops.py pads); n a power of two.
    ``radix=4`` runs the mixed radix-4/2 stage loop (half the passes).
    """
    rows, n = re.shape
    if rows % block_rows:
        raise ValueError(f"rows={rows} not a multiple of block_rows={block_rows}")
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((rows, n), re.dtype),
        jax.ShapeDtypeStruct((rows, n), im.dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_fft_kernel, inverse=inverse, radix=radix),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im)
