"""Pallas TPU kernel: batched row FFT (Stockham autosort, radix-2).

TPU adaptation of the paper's 1D_ROW_FFTS_LOCAL hot loop.  Design notes:

* Complex data is carried as two f32 planes (re, im) — TPU Pallas has no
  complex dtype; the MXU/VPU operate on real lanes.
* The Stockham autosort formulation is chosen *because* it needs no
  bit-reversal gather: every stage is a reshape + broadcast-multiply +
  stack, all of which stay in VMEM registers/lanes.  A DIT kernel would
  need a lane gather, which is slow on the VPU.
* Grid is over row blocks: each program transforms ``block_rows`` rows of
  length ``n`` entirely in VMEM.  The log2(n) stage loop is unrolled at
  trace time.  VMEM budget: 2 planes x block_rows x n x 4B (+ ping-pong),
  so block_rows is chosen by ``ops.pick_block_rows`` to fit ~8 MiB.
* Twiddles are computed in-kernel from an iota (cheap transcendental on
  VPU) — no HBM traffic for twiddle tables.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fft_rows_pallas", "stockham_planes"]


def stockham_planes(re: jnp.ndarray, im: jnp.ndarray, *, inverse: bool = False):
    """Stockham radix-2 FFT over the last axis of real/imag planes.

    Shapes (..., n), n a power of two.  Returns (re, im).  Pure jnp — this
    exact function body runs inside the Pallas kernel and is also unit-tested
    standalone against the complex oracle.
    """
    n = re.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length {n} must be a power of two")
    batch = re.shape[:-1]
    sign = 1.0 if inverse else -1.0
    ncur, s = n, 1
    while ncur > 1:
        m = ncur // 2
        vre = re.reshape(batch + (ncur, s))
        vim = im.reshape(batch + (ncur, s))
        are, aim = vre[..., :m, :], vim[..., :m, :]
        bre, bim = vre[..., m:, :], vim[..., m:, :]
        ang = sign * np.pi / m * jnp.arange(m, dtype=re.dtype)
        wre = jnp.cos(ang)[:, None]
        wim = jnp.sin(ang)[:, None]
        top_re, top_im = are + bre, aim + bim
        dre, dim = are - bre, aim - bim
        bot_re = dre * wre - dim * wim
        bot_im = dre * wim + dim * wre
        re = jnp.stack([top_re, bot_re], axis=-2).reshape(batch + (n,))
        im = jnp.stack([top_im, bot_im], axis=-2).reshape(batch + (n,))
        ncur, s = m, 2 * s
    if inverse:
        re = re / n
        im = im / n
    return re, im


def _fft_kernel(re_ref, im_ref, ore_ref, oim_ref, *, inverse: bool):
    re, im = stockham_planes(re_ref[...], im_ref[...], inverse=inverse)
    ore_ref[...] = re
    oim_ref[...] = im


def fft_rows_pallas(
    re: jnp.ndarray,
    im: jnp.ndarray,
    *,
    block_rows: int = 8,
    inverse: bool = False,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """pallas_call wrapper: (rows, n) planes -> transformed planes.

    rows must be a multiple of block_rows (ops.py pads); n a power of two.
    """
    rows, n = re.shape
    if rows % block_rows:
        raise ValueError(f"rows={rows} not a multiple of block_rows={block_rows}")
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((rows, n), re.dtype),
        jax.ShapeDtypeStruct((rows, n), im.dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_fft_kernel, inverse=inverse),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im)
