"""jit'd public op for the Pallas row-FFT kernel.

Handles: complex <-> plane conversion, row padding to the block multiple,
VMEM-aware block-rows selection, and CPU fallback to interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fft.kernel import fft_rows_pallas

__all__ = ["fft_rows_op", "pick_block_rows", "pick_radix",
           "resolve_call_params", "rows_to_padded_planes"]

_VMEM_BUDGET = 8 * 1024 * 1024  # ~half of a v5e core's 16 MiB VMEM


def pick_block_rows(n: int, dtype_bytes: int = 4) -> int:
    """Largest power-of-two block_rows with ~6 plane buffers under budget."""
    per_row = 6 * n * dtype_bytes  # in re/im + out re/im + ping-pong
    b = _VMEM_BUDGET // max(per_row, 1)
    b = 1 << max(int(b).bit_length() - 1, 0)
    return int(max(1, min(b, 256)))


def pick_radix(n: int) -> int:
    """Radix for a power-of-two length: 4 whenever a radix-4 pass exists
    (n >= 4) — half the Stockham passes — else 2."""
    return 4 if n >= 4 else 2


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve_call_params(n: int, block_rows: int | None, radix: int | None,
                        interpret: bool | None) -> tuple[int, int, bool]:
    """Shared prologue for the row-FFT op wrappers (plain and fused):
    validate the length and fill in block_rows/radix/interpret defaults."""
    if n & (n - 1):
        raise ValueError(f"pallas fft kernel requires power-of-two length, got {n}")
    if interpret is None:
        interpret = _on_cpu()
    if radix is None:
        radix = pick_radix(n)
    if block_rows is None:
        block_rows = pick_block_rows(n)
    return block_rows, radix, interpret


def rows_to_padded_planes(x2: jnp.ndarray, block_rows: int
                          ) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """(rows, n) complex -> f32 (re, im) planes row-padded to the block
    multiple, plus the original row count for cropping the result."""
    total = x2.shape[0]
    padded = (total + block_rows - 1) // block_rows * block_rows
    if padded != total:
        x2 = jnp.pad(x2, ((0, padded - total), (0, 0)))
    return (jnp.real(x2).astype(jnp.float32),
            jnp.imag(x2).astype(jnp.float32), total)


@functools.partial(jax.jit,
                   static_argnames=("inverse", "block_rows", "radix",
                                    "interpret"))
def fft_rows_op(
    x: jnp.ndarray,
    *,
    inverse: bool = False,
    block_rows: int | None = None,
    radix: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Complex row FFT via the Pallas kernel. x: (..., rows, n) complex.

    ``radix=None`` auto-selects (radix 4 with radix-2 tail for n >= 4).
    """
    n = x.shape[-1]
    block_rows, radix, interpret = resolve_call_params(n, block_rows, radix,
                                                       interpret)
    lead = x.shape[:-2]
    rows = x.shape[-2]
    x2 = x.reshape((-1, n)) if lead else x.reshape((rows, n))
    re, im, total = rows_to_padded_planes(x2, block_rows)
    ore, oim = fft_rows_pallas(re, im, block_rows=block_rows, inverse=inverse,
                               radix=radix, interpret=interpret)
    out = (ore[:total] + 1j * oim[:total]).astype(jnp.result_type(x, jnp.complex64))
    return out.reshape(lead + (rows, n)) if lead else out.reshape((rows, n))
