"""Straggler detection + FPM-based work re-partitioning.

At pod scale a host that slows down (thermal throttle, failing HBM, noisy
neighbour) drags every synchronous step.  The monitor keeps an EWMA of each
group's observed step time; when a group drifts past ``threshold`` x the
median, it synthesises *degraded speed functions* (observed slowdown folded
into the group's FPM) and re-runs HPOPTA — i.e. the paper's heterogeneous
partitioning case applied online.  The caller applies the new distribution
at the next checkpointable boundary.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fpm import FPMSet, SpeedFunction
from repro.core.partition import PartitionResult, hpopta

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerMonitor:
    n_groups: int
    alpha: float = 0.2          # EWMA factor
    threshold: float = 1.3      # drift multiple of the median that triggers

    def __post_init__(self):
        self._ewma = np.full(self.n_groups, np.nan)

    def record(self, group: int, step_time: float) -> None:
        if np.isnan(self._ewma[group]):
            self._ewma[group] = step_time
        else:
            self._ewma[group] = (self.alpha * step_time
                                 + (1 - self.alpha) * self._ewma[group])

    def reset(self) -> None:
        """Forget all observations.  The self-healing runtime calls this
        after a hot-swap: the drift that triggered the re-plan must not
        re-trigger against the new schedule's (different) step times."""
        self._ewma = np.full(self.n_groups, np.nan)

    @property
    def ewma(self) -> np.ndarray:
        return self._ewma.copy()

    def slow_groups(self) -> list[int]:
        if np.any(np.isnan(self._ewma)):
            return []
        med = float(np.median(self._ewma))
        return [i for i, t in enumerate(self._ewma) if t > self.threshold * med]

    def relative_speeds(self) -> np.ndarray:
        """Normalised observed speeds (1.0 = median group).

        Groups without a sample yet are neutral 1.0 — the same warm-up
        guard ``slow_groups`` has, so a partially-warmed monitor never
        leaks NaN into FPM synthesis (the median is taken over the
        sampled groups only)."""
        rel = np.ones(self.n_groups)
        seen = ~np.isnan(self._ewma)
        if not seen.any():
            return rel
        med = float(np.median(self._ewma[seen]))
        if med > 0:
            rel[seen] = med / self._ewma[seen]
        return rel

    def degraded_fpms(self, base: SpeedFunction | FPMSet) -> FPMSet:
        """Per-group speed functions with the observed drift folded in.

        Group ``i``'s baseline speed grid (its own ``FPMSet`` entry, or a
        shared ``SpeedFunction``) is scaled by its observed relative
        speed — the paper's heterogeneous-FPM input, synthesised online.
        This is what the self-healing re-planner hands to
        ``tune_dist_schedule``."""
        rel = self.relative_speeds()
        fns = []
        for i in range(self.n_groups):
            f = base[i] if isinstance(base, FPMSet) else base
            fns.append(SpeedFunction(f.xs, f.ys, f.speed * rel[i],
                                     name=f"group{i}"))
        return FPMSet(fns)

    def repartition(self, base_fpm: SpeedFunction, n_rows: int,
                    y: int) -> PartitionResult | None:
        """If stragglers exist, scale the baseline FPM by each group's
        observed relative speed and re-run HPOPTA.  Returns None when no
        repartition is needed (keeps the current distribution stable)."""
        if not self.slow_groups():
            return None
        curves = [f.time_curve(n_rows, y)
                  for f in self.degraded_fpms(base_fpm)]
        return hpopta(curves, n_rows)
