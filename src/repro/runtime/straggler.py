"""Straggler detection + FPM-based work re-partitioning.

At pod scale a host that slows down (thermal throttle, failing HBM, noisy
neighbour) drags every synchronous step.  The monitor keeps an EWMA of each
group's observed step time; when a group drifts past ``threshold`` x the
median, it synthesises *degraded speed functions* (observed slowdown folded
into the group's FPM) and re-runs HPOPTA — i.e. the paper's heterogeneous
partitioning case applied online.  The caller applies the new distribution
at the next checkpointable boundary.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fpm import FPMSet, SpeedFunction
from repro.core.partition import PartitionResult, hpopta

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerMonitor:
    n_groups: int
    alpha: float = 0.2          # EWMA factor
    threshold: float = 1.3      # drift multiple of the median that triggers

    def __post_init__(self):
        self._ewma = np.full(self.n_groups, np.nan)

    def record(self, group: int, step_time: float) -> None:
        if np.isnan(self._ewma[group]):
            self._ewma[group] = step_time
        else:
            self._ewma[group] = (self.alpha * step_time
                                 + (1 - self.alpha) * self._ewma[group])

    @property
    def ewma(self) -> np.ndarray:
        return self._ewma.copy()

    def slow_groups(self) -> list[int]:
        if np.any(np.isnan(self._ewma)):
            return []
        med = float(np.median(self._ewma))
        return [i for i, t in enumerate(self._ewma) if t > self.threshold * med]

    def relative_speeds(self) -> np.ndarray:
        """Normalised observed speeds (1.0 = median group)."""
        med = float(np.median(self._ewma))
        return med / self._ewma

    def repartition(self, base_fpm: SpeedFunction, n_rows: int,
                    y: int) -> PartitionResult | None:
        """If stragglers exist, scale the baseline FPM by each group's
        observed relative speed and re-run HPOPTA.  Returns None when no
        repartition is needed (keeps the current distribution stable)."""
        if not self.slow_groups():
            return None
        rel = self.relative_speeds()
        fpms = FPMSet([
            SpeedFunction(base_fpm.xs, base_fpm.ys, base_fpm.speed * rel[i],
                          name=f"group{i}")
            for i in range(self.n_groups)
        ])
        curves = [f.time_curve(n_rows, y) for f in fpms]
        return hpopta(curves, n_rows)
