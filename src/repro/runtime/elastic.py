"""Elastic scaling: rebuild the mesh from the surviving device set and
re-shard live state onto it.

On a device/host failure the controller (launch/train.py) catches the
error, queries ``jax.devices()`` again, calls ``rebuild_mesh`` to get the
largest usable (data, model) grid, re-shards the last checkpoint (or the
live state, if intact) with ``reshard``, re-partitions the batch via
POPTA/HPOPTA, and resumes.  The deterministic data pipeline (keyed by step)
makes the resumed stream identical regardless of the new topology.
"""

from __future__ import annotations

from typing import Any, Sequence

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["rebuild_mesh", "reshard", "largest_grid"]


def largest_grid(n_devices: int, model_axis: int) -> tuple[int, int]:
    """Largest (data, model) grid using <= n_devices, preserving the model
    axis if possible (TP degree is fixed by the model's sharding), else the
    largest power-of-two model axis that fits."""
    while model_axis > 1 and n_devices < model_axis:
        model_axis //= 2
    data = max(1, n_devices // model_axis)
    return data, model_axis


def rebuild_mesh(devices: Sequence[Any] | None = None, *,
                 model_axis: int = 16) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    data, model = largest_grid(len(devices), model_axis)
    grid = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(grid, ("data", "model"))


def reshard(tree: Any, mesh: Mesh, pspecs: Any) -> Any:
    """Move a (possibly differently-sharded or host-local) pytree onto
    ``mesh`` with the given PartitionSpecs."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, pspecs)
