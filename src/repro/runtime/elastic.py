"""Elastic scaling: rebuild the mesh from the surviving device set and
re-shard live state onto it.

On a device/host failure the controller (launch/train.py, or the
self-healing ``runtime.resilient`` wrapper) catches the error, queries
``jax.devices()`` again, calls ``rebuild_mesh`` (training grids) or
``rebuild_fft_mesh`` (the 1-D PFFT axis) to get the largest usable
topology, re-shards the last checkpoint (or the live state, if intact)
with ``reshard``, re-partitions work via POPTA/HPOPTA, and resumes.  The
deterministic data pipeline (keyed by step) makes the resumed stream
identical regardless of the new topology.

Rebuilds return a ``RebuildResult``: a grid that does not fill (7
survivors on a model_axis-4 grid) necessarily leaves devices idle, and
that used to happen *silently* — the result now carries the dropped
count so the caller can log capacity it is leaving on the floor.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["RebuildResult", "rebuild_mesh", "rebuild_fft_mesh", "reshard",
           "largest_grid", "largest_fft_axis"]


@dataclasses.dataclass(frozen=True)
class RebuildResult:
    """Outcome of a mesh rebuild.

    ``used`` devices are in the mesh; ``dropped`` survivors did not fit
    the grid (non-filling (data, model) product, or an FFT axis capped by
    N's divisors) and sit idle — surfaced, never silent.
    """

    mesh: Mesh
    used: int
    dropped: int


def largest_grid(n_devices: int, model_axis: int) -> tuple[int, int]:
    """Largest (data, model) grid using <= n_devices, preserving the model
    axis if possible (TP degree is fixed by the model's sharding), else
    halving it until it fits (a non-power-of-two axis bottoms out at 1)."""
    model_axis = max(int(model_axis), 1)
    while model_axis > 1 and n_devices < model_axis:
        model_axis //= 2
    model_axis = max(model_axis, 1)
    data = max(1, n_devices // model_axis)
    return data, model_axis


def rebuild_mesh(devices: Sequence[Any] | None = None, *,
                 model_axis: int = 16) -> RebuildResult:
    devices = list(devices if devices is not None else jax.devices())
    data, model = largest_grid(len(devices), model_axis)
    used = data * model
    grid = np.asarray(devices[:used]).reshape(data, model)
    return RebuildResult(mesh=Mesh(grid, ("data", "model")), used=used,
                         dropped=len(devices) - used)


def largest_fft_axis(n_devices: int, n: int) -> int:
    """Largest p <= n_devices with n % p == 0 — the distributed PFFT
    pipeline requires the row count to divide evenly over the mesh axis,
    so after a device loss the rebuilt axis is N's largest divisor that
    the survivors can still staff."""
    for p in range(min(int(n_devices), int(n)), 1, -1):
        if n % p == 0:
            return p
    return 1


def rebuild_fft_mesh(n: int, devices: Sequence[Any] | None = None, *,
                     axis_name: str = "fft",
                     hosts: int | None = None) -> RebuildResult:
    """Rebuild the 1-D PFFT mesh from the surviving devices.

    Unlike the (data, model) grids, the FFT axis is additionally capped
    by N's divisibility — 3 survivors for N=64 can only staff a 2-wide
    axis, and the third device is *dropped* (reported, like every other
    non-filling rebuild).

    The rebuilt axis is *host-major*: survivors are ordered by
    ``(process_index, id)`` before the axis is cut, so surviving whole
    hosts stay contiguous and the hierarchical exchange (and the
    host-aware topology digest) remain applicable after recovery.
    ``hosts`` carries the caller's surviving-host count on emulated-host
    rigs (single process, ``mesh_host_shape`` cannot see real
    ``process_index`` structure): when it divides the rebuilt axis it is
    re-registered on the new mesh; when it does not — a *partial* host
    loss — the axis degrades to flat, which is exactly the topology the
    re-tune should price.  Either way the reduced topology gets a
    distinct digest, so the re-plan is a correct wisdom miss, never a
    stale multi-host hit.
    """
    from repro.launch.mesh import (host_major_devices,
                                   register_emulated_hosts)

    devices = host_major_devices(
        devices if devices is not None else jax.devices())
    p = largest_fft_axis(len(devices), n)
    grid = np.asarray(devices[:p])
    mesh = Mesh(grid, (axis_name,))
    if jax.process_count() == 1:
        eff = int(hosts) if hosts else 1
        if eff < 1 or p % eff:
            eff = 1
        register_emulated_hosts(mesh, axis_name, eff)
    return RebuildResult(mesh=mesh, used=p, dropped=len(devices) - p)


def reshard(tree: Any, mesh: Mesh, pspecs: Any) -> Any:
    """Move a (possibly differently-sharded or host-local) pytree onto
    ``mesh`` with the given PartitionSpecs."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, pspecs)
