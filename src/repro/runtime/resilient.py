"""Self-healing execution: detect -> re-plan -> hot-swap.

``ResilientPlan`` closes the loop the ROADMAP called *online
re-planning*: it wraps a distributed ``PfftPlan``, times every execute,
probes per-device local-phase speeds, and feeds a ``StragglerMonitor``.

**Drift path.**  When a device group drifts past the monitor's threshold
the wrapper synthesises *degraded FPMs* (the observed slowdown folded
into each group's speed function — the paper's heterogeneous-FPM input,
built online instead of measured offline) and re-runs
``tune_dist_schedule`` with them.  The winning ``SegmentSchedule`` —
typically a device-group program, so the slow group genuinely gets
different work — is lowered through ``PfftPlan.with_schedule`` and
hot-swapped at the *next call boundary*; in-flight executes always
finish on the plan they started on.  Re-planned picks are recorded to
wisdom under a degradation-digest key, so a recurring drift signature is
served from disk.

**Loss path.**  A raised ``DeviceLostError`` (injected by
``runtime.faults`` or translated from a real runtime error by the
caller) triggers elastic recovery instead: rebuild the 1-D FFT mesh from
the survivors (``rebuild_fft_mesh`` — the axis is capped by N's
divisors, and any unplaceable survivors are reported), re-plan via
``plan_pfft`` on the new mesh — whose wisdom key carries the new
``topology_digest``, so a previously-measured reduced topology is
*served* with zero re-measurement (serve-or-retune) — re-shard
registered in-flight state via ``reshard``, and retry the failed call.

Every recovery appends a structured event (detect/re-plan/swap timings)
to ``.events`` — the raw material of ``benchmarks/resilience_bench.py``.

The wrapper also re-traces its jitted executor whenever the fault
injector's ``epoch`` moves: injection is only visible at trace time, so
a stale trace would keep running the old world (exactly like a real
compiled binary under hardware drift — which is why detection is driven
by *measured* probes, not by asking the injector).
"""

from __future__ import annotations

import functools
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.api import _PAD_STRATEGY, PfftPlan, plan_pfft
from repro.core.fpm import FPMSet, SpeedFunction
from repro.plan.cost import CostParams
from repro.plan.groups import device_group_program
from repro.plan.schedule import SegmentSchedule
from repro.plan.tune import dist_panel_space, tune_dist_schedule
from repro.plan.wisdom import (lookup_wisdom, partition_digest, record_wisdom,
                               topology_digest, wisdom_key)
from repro.runtime.elastic import rebuild_fft_mesh, reshard
from repro.runtime.faults import (DeviceLostError, get_injector,
                                  repeated, retry_with_backoff)
from repro.runtime.straggler import StragglerMonitor

__all__ = ["ResilientPlan"]


class ResilientPlan:
    """Self-healing wrapper around a distributed ``PfftPlan``.

    Parameters mirror ``plan_pfft`` (``method``/``fpms``/``tune``/
    ``wisdom``/``config``/``dtype`` build the initial plan on ``mesh``)
    plus the runtime knobs:

    * ``alpha``/``drift_threshold`` — the ``StragglerMonitor``'s EWMA
      factor and trigger multiple.
    * ``probe_every`` — run the per-device local-phase probe every k-th
      execute (the probe times each device's *own* schedule branch — the
      injected-fault wrapper included — on its own device, so it sees
      what the device group genuinely runs).
    * ``cooldown`` — calls after a recovery during which drift does not
      re-trigger (the new plan needs fresh, settled samples).
    * ``retune_mode``/``retune_params`` — how the drift re-plan tunes
      (defaults to the initial ``tune`` mode, or ``"estimate"`` when the
      initial plan was untuned).
    * ``measure_retries`` / ``wisdom_lock_timeout_s`` — the
      retry-with-backoff budget around measure-mode re-tuning and the
      bound on waiting for a wedged wisdom lock (a stuck store must
      never stall recovery).
    """

    def __init__(self, n: int, *, mesh=None, axis_name: str = "fft",
                 method: str = "lb", fpms: FPMSet | None = None,
                 tune: str = "estimate", wisdom: str | None = None,
                 config=None, dtype: str = "complex64", eps: float = 0.05,
                 alpha: float = 0.3, drift_threshold: float = 1.3,
                 probe_every: int = 1, cooldown: int = 4,
                 retune_mode: str | None = None,
                 retune_params: CostParams | None = None,
                 min_probe_rounds: int = 3,
                 measure_retries: int = 2,
                 wisdom_lock_timeout_s: float | None = 5.0):
        if mesh is None:
            from repro.launch.mesh import make_fft_mesh
            mesh = make_fft_mesh(axis_name=axis_name)
        self.n = int(n)
        self.mesh = mesh
        self.axis_name = axis_name
        self.method = method
        self.fpms = fpms
        self.tune = tune
        self.wisdom = wisdom
        self.dtype = dtype
        self.eps = eps
        self.alpha = alpha
        self.drift_threshold = drift_threshold
        self.probe_every = max(int(probe_every), 1)
        self.cooldown = max(int(cooldown), 0)
        self.retune_mode = retune_mode or (tune if tune != "off" else "estimate")
        self.retune_params = retune_params
        self.min_probe_rounds = max(int(min_probe_rounds), 1)
        self.measure_retries = int(measure_retries)
        self.wisdom_lock_timeout_s = wisdom_lock_timeout_s

        self.plan = plan_pfft(self.n, fpms=fpms, method=method, eps=eps,
                              tune=tune, wisdom=wisdom, config=config,
                              dtype=dtype, mesh=mesh, axis_name=axis_name)
        self.monitor = StragglerMonitor(self.p, alpha=alpha,
                                        threshold=drift_threshold)
        self.events: list[dict] = []
        self.step_times: list[float] = []
        self.last_degraded_fpms: FPMSet | None = None
        self._calls = 0
        self._pending: PfftPlan | None = None
        self._cooldown_until = 0
        self._probe_rounds = 0
        self._probe_fns: dict = {}
        self._probe_blocks: dict = {}
        self._state = None
        self._state_specs = None
        self._epoch_seen = get_injector().epoch

    # ---- introspection ----

    @property
    def p(self) -> int:
        return int(self.mesh.shape[self.axis_name])

    @property
    def schedule(self) -> SegmentSchedule:
        return self.plan.schedule

    @property
    def calls(self) -> int:
        return self._calls

    # ---- in-flight state (re-sharded across elastic recovery) ----

    def register_state(self, tree: Any, pspecs: Any) -> None:
        """Attach in-flight state to carry across device loss: on
        recovery it is re-sharded onto the rebuilt mesh via ``reshard``
        before the failed call retries."""
        self._state, self._state_specs = tree, pspecs

    @property
    def state(self) -> Any:
        return self._state

    # ---- the hot path ----

    def execute(self, m) -> jnp.ndarray:
        inj = get_injector()
        if self._pending is not None:
            self.plan, self._pending = self._pending, None
            for ev in reversed(self.events):   # stamp the swap boundary
                if ev.get("kind") == "replan" and ev.get("swap_call") is None:
                    ev["swap_call"] = self._calls
                    ev["swap_wall"] = time.perf_counter()
                    break
        if inj.epoch != self._epoch_seen:
            # The fault layer changed under an already-traced program:
            # rebuild the jitted executor (and the probes) so the trace
            # reflects the new world.
            self._epoch_seen = inj.epoch
            self.plan = self.plan.with_schedule(self.plan.schedule)
            self._probe_fns.clear()
        call = self._calls
        self._calls += 1
        try:
            inj.check_execute(call)
            out, dt = self._timed_execute(m)
        except DeviceLostError as err:
            self._recover_device_loss(err, call)
            self._epoch_seen = get_injector().epoch
            out, dt = self._timed_execute(m)   # retry on the rebuilt plan
        self.step_times.append(dt)
        if call % self.probe_every == 0:
            self._observe(call)
        return out

    def _timed_execute(self, m):
        x = jax.device_put(jnp.asarray(m),
                           NamedSharding(self.mesh, P(self.axis_name, None)))
        jax.block_until_ready(x)
        t0 = time.perf_counter()
        out = self.plan.execute(x)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0

    # ---- drift detection ----

    def _device_configs(self):
        """(per-device config list, uniform pad_len) of the current plan —
        exactly what each device's branch of the SPMD program runs."""
        sched = self.plan.schedule
        if len(sched.configs) > 1:
            prog = device_group_program(sched, self.p)
            return ([prog.configs[g] for g in prog.group_of_device],
                    prog.pad_len)
        pad_len = max((e.length for e in sched), default=self.n)
        return [sched.anchor_config] * self.p, pad_len

    # Probe blocks carry at least this many rows: at small N a single
    # N/p-row shard is dispatch-dominated on CPU, and a compute-side
    # slowdown would hide under the constant overhead.  More rows only
    # scale the per-row work, so relative speeds are unaffected.
    PROBE_MIN_ROWS = 256

    def _probe_group_times(self) -> list[float]:
        """Best-of-3 seconds of each device's own local-phase program —
        its schedule branch, the fault layer's ``repeated`` wrapper
        included, *placed on that device* — the honest per-group sample
        the monitor's EWMA digests."""
        from repro.core.pfft_dist import _local_fft  # lazy: core imports plan
        inj = get_injector()
        cfgs, pad_len = self._device_configs()
        n_loc = max(self.n // self.p, 1, self.PROBE_MIN_ROWS)
        devices = list(self.mesh.devices.flat)
        times = []
        for i, cfg in enumerate(cfgs):
            reps = inj.repeat_for(i)
            key = (cfg, pad_len, n_loc, reps, i)
            cached = self._probe_fns.get(key)
            if cached is None:
                block = self._probe_blocks.get(n_loc)
                if block is None:
                    rng = np.random.default_rng(0)
                    block = jnp.asarray(
                        (rng.standard_normal((n_loc, self.n))
                         + 1j * rng.standard_normal((n_loc, self.n))
                         ).astype(self.dtype))
                    self._probe_blocks[n_loc] = block
                base = functools.partial(_local_fft, n=self.n,
                                         padded=cfg.dist_padded,
                                         pad_len=pad_len, config=cfg,
                                         backend=None)
                x = jax.device_put(block, devices[i])
                fn = jax.jit(repeated(base, reps))
                jax.block_until_ready(fn(x))   # compile
                cached = (fn, x)
                self._probe_fns[key] = cached
            fn, x = cached
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(x))
                best = min(best, time.perf_counter() - t0)
            times.append(best)
        return times

    def _observe(self, call: int) -> None:
        for g, t in enumerate(self._probe_group_times()):
            self.monitor.record(g, t)
        self._probe_rounds += 1
        if self._probe_rounds < self.min_probe_rounds:
            return   # single noisy rounds must not look like drift
        if self._calls <= self._cooldown_until:
            return
        slow = self.monitor.slow_groups()
        if slow:
            self._replan(call, slow)

    # ---- drift recovery: degraded-FPM re-plan + hot-swap ----

    def _d_even(self) -> np.ndarray:
        return np.full(self.p, self.n // self.p, dtype=np.int64)

    def _baseline_fpms(self) -> FPMSet:
        """The healthy per-device FPMs the degradation folds into: the
        user's, or a flat nominal-rate synthetic set (drift is relative,
        so a flat baseline still yields correctly-shaped degraded FPMs)."""
        if self.fpms is not None and self.fpms.p == self.p:
            return self.fpms
        n_loc = max(self.n // self.p, 1)
        xs = np.array(sorted({1, n_loc, self.n}))
        pow2 = 1 << int(np.ceil(np.log2(max(self.n, 2))))
        ys = np.array(sorted({self.n, pow2, 2 * pow2}))
        params = self.retune_params or CostParams.for_backend()
        speed = np.full((len(xs), len(ys)), params.nominal_flops)
        return FPMSet([SpeedFunction(xs, ys, speed.copy(), name=f"dev{i}")
                       for i in range(self.p)])

    def _pad_lengths(self, fpms: FPMSet):
        d = self._d_even()
        if self.method == "fpm-pad":
            from repro.plan.pads import fpm_pad_lengths
            return fpm_pad_lengths(fpms, d, self.n)
        if self.method == "fpm-czt":
            from repro.plan.pads import czt_fft_lengths
            return czt_fft_lengths(fpms, d, self.n, limit_ratio=2.0)
        return None

    def _degraded_key(self, rel: np.ndarray, pads) -> tuple[str, str]:
        """(wisdom key, topology digest) for a drift re-plan.

        The degradation signature — relative speeds quantised to 1/16 —
        digests into the key's ``part=`` detail, so a recurring drift
        pattern is served from wisdom while the *healthy* plan's entry
        (no such detail, or the FPM partition digest) is never
        overwritten by a degraded pick.
        """
        panels = dist_panel_space(self.n, self.p)
        topo = topology_digest(self.mesh, self.axis_name, panels=panels)
        rel_q = np.asarray(np.round(np.asarray(rel) * 16.0), dtype=np.int64)
        detail = partition_digest(np.concatenate([self._d_even(), rel_q]),
                                  pads)
        key = wisdom_key(n=self.n, dtype=np.dtype(self.dtype).name, p=self.p,
                         method=self.method, backend=jax.default_backend(),
                         detail=f"degraded-{detail}", topology=topo)
        return key, topo

    def _replan(self, call: int, slow: list[int]) -> None:
        detect_wall = time.perf_counter()
        rel = self.monitor.relative_speeds()
        degraded = self.monitor.degraded_fpms(self._baseline_fpms())
        self.last_degraded_fpms = degraded
        pad_strategy = _PAD_STRATEGY[self.method]
        pads = self._pad_lengths(degraded)
        key, topo = self._degraded_key(rel, pads)
        t0 = time.perf_counter()

        schedule = None
        source = None
        info: dict = {}
        if self.wisdom is not None:
            hit = lookup_wisdom(self.wisdom, key)
            if hit is not None:
                cand, _entry = hit
                if isinstance(cand, SegmentSchedule):
                    ok = (cand.n == self.n
                          and cand.matches(self._d_even(), pads)
                          and all(e.config.pad == pad_strategy
                                  for e in cand))
                    if ok:
                        try:
                            if cand.common_config is None:
                                device_group_program(cand, self.p)
                        except ValueError:
                            ok = False
                    if ok:
                        schedule, source = cand, "wisdom"

        if schedule is None:
            def _tune():
                return tune_dist_schedule(
                    self.n, self.mesh, self.axis_name, pad_lengths=pads,
                    mode=self.retune_mode, pad=pad_strategy, fpms=degraded,
                    params=self.retune_params, dtype=np.dtype(self.dtype),
                    measure_retries=self.measure_retries)
            schedule, info = retry_with_backoff(_tune, attempts=2,
                                               base_s=0.1)
            source = self.retune_mode
            if self.wisdom is not None and self.retune_mode == "measure" \
                    and info.get("time_s") is not None:
                try:
                    record_wisdom(self.wisdom, key, schedule, mode="measure",
                                  time_s=info["time_s"],
                                  extra={"topology": topo,
                                         "origin": "resilient-replan"},
                                  retries=2,
                                  lock_timeout_s=self.wisdom_lock_timeout_s)
                except (TimeoutError, OSError) as err:
                    # An advisory store must never stall recovery.
                    self.events.append({"kind": "wisdom_error",
                                        "call": call, "wall": time.perf_counter(),
                                        "error": repr(err)})

        replan_s = time.perf_counter() - t0
        event = {
            "kind": "replan", "call": call, "wall": detect_wall,
            "detect_wall": detect_wall,
            "slow_groups": [int(g) for g in slow],
            "relative_speeds": [float(v) for v in rel],
            "replan_s": float(replan_s), "source": source,
            "chosen": info.get("chosen"),
            "schedule": schedule.describe(),
            "wisdom_key": key, "swap_call": None,
        }
        self.events.append(event)
        self.monitor.reset()
        self._probe_rounds = 0
        self._cooldown_until = self._calls + self.cooldown
        if schedule == self.plan.schedule:
            event["kind"] = "replan_noop"   # same plan: nothing to swap
            event["swap_call"] = call
            return
        tuning = {"mode": self.retune_mode, "source": source,
                  "wisdom_key": key, "topology": topo}
        self._pending = self.plan.with_schedule(schedule, tuning=tuning)

    # ---- loss recovery: rebuild mesh, serve-or-retune, reshard ----

    def _recover_device_loss(self, err: DeviceLostError, call: int) -> None:
        t0 = time.perf_counter()
        axis_devices = list(self.mesh.devices.flat)
        old_p = self.p
        lost = sorted({int(i) for i in getattr(err, "lost", ()) or ()
                       if 0 <= int(i) < old_p})
        if lost:
            survivors = [d for i, d in enumerate(axis_devices)
                         if i not in lost]
        else:
            live = set(jax.devices())
            survivors = [d for d in axis_devices if d in live]
        if not survivors:
            raise err
        # Surviving-host hint: when the loss is whole-host-granular under
        # the old mesh's host-major layout, the rebuilt axis keeps its
        # (reduced) multi-host shape — a distinct topology digest, so the
        # re-plan is a correct wisdom miss, never a stale multi-host hit.
        # A partial host loss breaks host-majority and degrades to flat.
        from repro.launch.mesh import mesh_host_shape
        hosts_hint = None
        h_old, l_old = mesh_host_shape(self.mesh, self.axis_name)
        if h_old > 1:
            surv_set = set(survivors)
            gone = {i for i, d in enumerate(axis_devices)
                    if d not in surv_set}
            per_host = [sum(1 for i in gone if i // l_old == h)
                        for h in range(h_old)]
            if all(g in (0, l_old) for g in per_host):
                hosts_hint = sum(1 for g in per_host if g == 0)
        rebuilt = rebuild_fft_mesh(self.n, survivors,
                                   axis_name=self.axis_name,
                                   hosts=hosts_hint)
        kept = [i for i in range(old_p) if i not in lost][:rebuilt.used]
        self.mesh = rebuilt.mesh
        if self.fpms is not None and self.fpms.p == old_p:
            self.fpms = FPMSet([self.fpms[i] for i in kept])
        self.monitor = StragglerMonitor(rebuilt.used, alpha=self.alpha,
                                        threshold=self.drift_threshold)
        self._probe_rounds = 0
        self._probe_fns.clear()
        self._pending = None
        self._cooldown_until = self._calls + self.cooldown
        # Serve-or-retune: plan_pfft keys wisdom by the *new* mesh's
        # topology_digest — a reduced topology measured once is served
        # with zero re-measurement on the next loss to the same shape.
        self.plan = plan_pfft(self.n, fpms=self.fpms, method=self.method,
                              eps=self.eps, tune=self.tune,
                              wisdom=self.wisdom, dtype=self.dtype,
                              mesh=self.mesh, axis_name=self.axis_name)
        if self._state is not None:
            self._state = reshard(self._state, self.mesh, self._state_specs)
        self.events.append({
            "kind": "device_loss", "call": call, "wall": time.perf_counter(),
            "lost": lost, "survivors": len(survivors),
            "devices": rebuilt.used, "dropped": rebuilt.dropped,
            "topology": self.plan.tuning.get("topology"),
            "plan_source": self.plan.tuning.get("source"),
            "recover_s": float(time.perf_counter() - t0),
        })
