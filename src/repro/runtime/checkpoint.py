"""Checkpointing: atomic, async, keep-last-k, restart-exact.

Layout: <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
os.replace'd (atomic on POSIX), so a crash mid-write can never corrupt the
latest checkpoint.  ``save(..., blocking=False)`` hands the host-side write
to a background thread (compute continues; the arrays are first fetched to
host synchronously, which is the only device-blocking part — the standard
async-checkpoint split).

Restart is exact: optimizer state, params, and the data-pipeline cursor are
all saved; ``latest_step`` + ``restore`` resume a killed run bit-for-bit
(tests/test_runtime.py proves loss-curve continuity across a kill/restart).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import numpy as np
import jax

__all__ = ["CheckpointManager"]


_BF16_SUFFIX = "__BF16__"
_STEP_DIR = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    """npz has no bfloat16: bf16 leaves are stored bit-exact as uint16 views
    with a key suffix and viewed back on restore."""
    import ml_dtypes
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == ml_dtypes.bfloat16:
            key += _BF16_SUFFIX
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _unflatten_into(tree: Any, flat: dict[str, np.ndarray]) -> Any:
    import ml_dtypes
    lookup = {}
    for k, v in flat.items():
        if k.endswith(_BF16_SUFFIX):
            lookup[k[: -len(_BF16_SUFFIX)]] = v.view(ml_dtypes.bfloat16)
        else:
            lookup[k] = v
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in leaves_paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = lookup[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- write --

    def save(self, step: int, state: Any, extra: dict | None = None,
             blocking: bool = True) -> None:
        flat = _flatten(state)           # device->host fetch happens here
        meta = {"step": step, "extra": extra or {}}
        if blocking:
            self._write(step, flat, meta)
        else:
            self.wait()                  # at most one in-flight write
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, flat, meta),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join any in-flight background write; re-raise its failure.

        A background write that died (disk full, permissions) used to
        vanish with its daemon thread — ``wait()`` returned as if the
        checkpoint landed.  The error is captured in the thread wrapper
        and re-raised here (and by the next ``save(blocking=False)``,
        which waits first), so a lost checkpoint is loud exactly once."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_guarded(self, step: int, flat, meta) -> None:
        try:
            self._write(step, flat, meta)
        except BaseException as err:  # surfaced by wait()/next save
            self._error = err

    def _write(self, step: int, flat, meta) -> None:
        final = os.path.join(self.dir, f"step_{step:012d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as fh:
            np.savez(fh, **{k.replace("/", "__SLASH__"): v for k, v in flat.items()})
        with open(os.path.join(tmp, "meta.json"), "w") as fh:
            json.dump(meta, fh)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    # -- read --

    def steps(self) -> list[int]:
        """Sorted step numbers present in the directory.  Only exact
        ``step_<digits>`` entries count — stray names (a user's
        ``step_backup``, an editor's ``step_5~``, in-flight ``.tmp``
        dirs) are skipped instead of crashing the listing."""
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_DIR.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any) -> tuple[Any, dict]:
        """Restore into the structure of ``like`` (a state pytree or its
        eval_shape); returns (state, extra)."""
        path = os.path.join(self.dir, f"step_{step:012d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k.replace("__SLASH__", "/"): data[k] for k in data.files}
        with open(os.path.join(path, "meta.json")) as fh:
            meta = json.load(fh)
        return _unflatten_into(like, flat), meta["extra"]
