"""Deterministic fault injection for the self-healing runtime.

Chaos testing a planner needs faults that are *reproducible* and that the
compiler cannot optimise away.  The process-global ``FaultInjector``
carries three fault families:

* **Per-device slowdown** — ``slow_group(device, factor)`` multiplies the
  local-phase work of one mesh position.  The hook
  (``repro.core.pfft_dist._local_phase``) wraps the row-FFT in
  ``repeated``: the FFT genuinely runs ``factor`` times on
  exactly-rescaled inputs, so wall time scales like a real straggler
  (thermal throttle, noisy neighbour) while the output stays
  bit-identical — no sleeps, nothing XLA can CSE or DCE.
* **Fail-the-kth-execute** — ``fail_execute(call)`` schedules one call of
  a ``ResilientPlan`` to raise (default: ``DeviceLostError``, the elastic
  recovery trigger).  One-shot: the fault clears when it fires, so the
  wrapper's retry proceeds.
* **Wisdom-store chaos** — ``corrupt_wisdom`` tears the JSON in place
  (a crashed writer), ``locked_wisdom`` holds the store's exclusive flock
  (a wedged writer) so ``record_wisdom(lock_timeout_s=...)`` can be
  driven into its timeout path.

Faults are visible to *traced* programs only at trace time, so every
mutation bumps ``epoch``; runtimes that cache jitted executors (the
``ResilientPlan`` hot path) re-trace when the epoch moves.

This module deliberately imports nothing from ``repro`` — the injection
hook in ``core.pfft_dist`` imports *it* lazily, so no cycle forms.
"""

from __future__ import annotations

import contextlib
import time
from typing import Callable, Sequence

__all__ = ["DeviceLostError", "FaultInjector", "get_injector", "inject",
           "lost_host", "repeated", "retry_with_backoff", "corrupt_wisdom",
           "locked_wisdom"]


class DeviceLostError(RuntimeError):
    """A device (or host) dropped out of the mesh.

    ``lost`` names the positions along the FFT mesh axis that died; empty
    means "unknown — re-derive survivors from ``jax.devices()``".
    """

    def __init__(self, lost: Sequence[int] = (), message: str | None = None):
        self.lost = tuple(int(i) for i in lost)
        super().__init__(message or f"device(s) lost at mesh positions "
                         f"{list(self.lost) or '<unknown>'}")


def lost_host(host: int, local: int) -> tuple[int, ...]:
    """Mesh-axis positions of host ``host`` on a host-major FFT axis with
    ``local`` devices per host — the ``lost=`` payload of a whole-host
    ``DeviceLostError``."""
    host, local = int(host), int(local)
    return tuple(range(host * local, (host + 1) * local))


class FaultInjector:
    """Process-global fault switchboard (see module docstring)."""

    def __init__(self):
        self.epoch = 0
        self.log: list[dict] = []
        self._slow: dict[int, int] = {}      # mesh position -> repeat count
        self._fail_at: dict[int, BaseException] = {}  # call index -> exc

    def _record(self, kind: str, **fields) -> None:
        # "wall" stamps are monotonic (perf_counter), only ever *subtracted*
        # against other stamps — never interpreted as an absolute epoch.
        self.log.append({"kind": kind, "wall": time.perf_counter(), **fields})

    # ---- per-device slowdown ----

    def slow_group(self, device: int, factor: float) -> None:
        """Multiply mesh position ``device``'s local-phase work by
        ``factor`` (rounded to an integer repeat count; <= 1 clears)."""
        reps = max(int(round(factor)), 1)
        if reps <= 1:
            self._slow.pop(int(device), None)
        else:
            self._slow[int(device)] = reps
        self.epoch += 1
        self._record("slow_group", device=int(device), repeats=reps)

    def local_repeats(self, p: int) -> list[int] | None:
        """Per-position repeat counts for a ``p``-device FFT axis, or
        None when no slowdown is active (the hook's zero-overhead path)."""
        if not self._slow:
            return None
        reps = [int(self._slow.get(i, 1)) for i in range(int(p))]
        return reps if any(r > 1 for r in reps) else None

    def repeat_for(self, device: int) -> int:
        return int(self._slow.get(int(device), 1))

    # ---- scheduled execute failures ----

    def fail_execute(self, call: int, exc: BaseException | None = None, *,
                     lost: Sequence[int] = ()) -> None:
        """Make the ``call``-th execute (0-based) raise ``exc`` (default:
        ``DeviceLostError`` over ``lost``)."""
        if exc is None:
            exc = DeviceLostError(lost=lost)
        self._fail_at[int(call)] = exc
        self._record("fail_execute", call=int(call), exc=type(exc).__name__)

    def fail_host(self, call: int, host: int, local: int) -> None:
        """Make the ``call``-th execute raise ``DeviceLostError`` over a
        *whole host* of a host-major FFT axis: positions
        ``host*local .. host*local + local - 1`` (``local`` devices per
        host).  The whole-host-granular loss is the one the elastic
        rebuild can keep host-major — the recovery path must re-plan
        under a reduced host count, not a flat axis."""
        self.fail_execute(call, lost=lost_host(host, local))

    def check_execute(self, call: int) -> None:
        exc = self._fail_at.pop(int(call), None)
        if exc is not None:
            self._record("execute_failed", call=int(call),
                         exc=type(exc).__name__)
            raise exc

    # ---- lifecycle ----

    @property
    def active(self) -> bool:
        return bool(self._slow or self._fail_at)

    def clear(self) -> None:
        had_slow = bool(self._slow)
        self._slow.clear()
        self._fail_at.clear()
        if had_slow:
            self.epoch += 1   # traced slowdowns must be re-traced away
        self._record("clear")


_INJECTOR = FaultInjector()


def get_injector() -> FaultInjector:
    return _INJECTOR


@contextlib.contextmanager
def inject():
    """Scoped injection: yields the global injector, clears it on exit
    (the epoch advances, so executors traced under the fault rebuild)."""
    inj = get_injector()
    try:
        yield inj
    finally:
        inj.clear()


def repeated(fn: Callable, reps: int) -> Callable:
    """Run linear ``fn`` ``reps`` times with the extra work un-removable,
    returning output bit-identical to one run.

    Repeat ``k`` feeds ``x * 2**e_k`` and rescales by the same power of
    two — exact in floating point — then folds with ``0.5 * (out +
    out_k)`` (exact when the operands are equal).  Distinct scales per
    repeat stop XLA from CSE-ing the calls, and the fold feeds the
    output so none can be dead-code-eliminated: wall time genuinely
    multiplies by ``reps``.  Exponents cycle through 1..20, so repeats
    beyond 21 start sharing scales (and some work may re-fuse); the
    realistic straggler range (2-8x) is far below that.
    """
    reps = int(reps)
    if reps <= 1:
        return fn

    def run(x):
        out = fn(x)
        for k in range(1, reps):
            scale = 2.0 ** (1 + (k - 1) % 20)
            out = 0.5 * (out + fn(x * scale) / scale)
        return out

    return run


def retry_with_backoff(fn: Callable, *, attempts: int = 3,
                       base_s: float = 0.05, factor: float = 2.0,
                       exceptions: tuple = (Exception,),
                       sleep: Callable[[float], None] = time.sleep):
    """Call ``fn()`` up to ``attempts`` times with exponential backoff;
    re-raises the last failure when the budget is exhausted."""
    delay = float(base_s)
    for attempt in range(max(int(attempts), 1)):
        try:
            return fn()
        except exceptions:
            if attempt >= attempts - 1:
                raise
            sleep(delay)
            delay *= factor


# ---- wisdom-store chaos ----

def corrupt_wisdom(path: str) -> None:
    """Tear the wisdom store in place — truncated JSON, as a writer that
    crashed mid-write (without the atomic-replace discipline) would
    leave.  Readers must treat it as a miss, never an error."""
    with open(path, "w") as fh:
        fh.write('{"version": 3, "entries": {')


@contextlib.contextmanager
def locked_wisdom(path: str):
    """Hold the store's exclusive flock for the duration of the block, so
    a concurrent ``record_wisdom(lock_timeout_s=...)`` sees a wedged
    writer and times out instead of blocking forever."""
    import fcntl
    fh = open(path + ".lock", "w")
    try:
        fcntl.flock(fh, fcntl.LOCK_EX)
        yield
    finally:
        fh.close()
