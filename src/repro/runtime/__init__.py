from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.elastic import (RebuildResult, largest_fft_axis,
                                   largest_grid, rebuild_fft_mesh,
                                   rebuild_mesh, reshard)
from repro.runtime.faults import (DeviceLostError, FaultInjector,
                                  corrupt_wisdom, get_injector, inject,
                                  locked_wisdom, repeated, retry_with_backoff)

__all__ = [
    "CheckpointManager",
    "StragglerMonitor",
    "RebuildResult",
    "largest_fft_axis",
    "largest_grid",
    "rebuild_fft_mesh",
    "rebuild_mesh",
    "reshard",
    "DeviceLostError",
    "FaultInjector",
    "corrupt_wisdom",
    "get_injector",
    "inject",
    "locked_wisdom",
    "repeated",
    "retry_with_backoff",
    "ResilientPlan",
]


def __getattr__(name):
    # ResilientPlan pulls in core.api (jax tracing machinery); keep the
    # package import light for callers that only want the monitors.
    if name == "ResilientPlan":
        from repro.runtime.resilient import ResilientPlan
        return ResilientPlan
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
