from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.elastic import rebuild_mesh, reshard

__all__ = ["CheckpointManager", "StragglerMonitor", "rebuild_mesh", "reshard"]
