from repro.data.pipeline import SyntheticTokenPipeline, make_batch
from repro.data.specs import input_specs

__all__ = ["SyntheticTokenPipeline", "make_batch", "input_specs"]
