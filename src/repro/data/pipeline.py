"""Deterministic synthetic data pipeline.

Produces per-step batches keyed by (seed, step, host_shard) so that a
restarted / re-sharded job regenerates exactly the same global stream —
this is what makes checkpoint-restart and elastic re-sharding exact (the
pipeline cursor is just the step counter, saved with the checkpoint).

The "dataset" is a reproducible integer stream with enough structure for a
~100M-param model to visibly learn (a noisy Markov chain over the vocab),
so the quickstart example shows a real falling loss curve.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.configs.base import ArchConfig

__all__ = ["SyntheticTokenPipeline", "make_batch"]


def _markov_tokens(rng: np.random.Generator, batch: int, seq: int, vocab: int):
    """Noisy Markov stream: next = (3*cur + noise) mod vocab."""
    x = np.empty((batch, seq + 1), np.int32)
    x[:, 0] = rng.integers(0, vocab, batch)
    noise = rng.integers(0, 7, (batch, seq))
    for t in range(seq):
        x[:, t + 1] = (3 * x[:, t] + noise[:, t]) % vocab
    return x


def make_batch(cfg: ArchConfig, batch: int, seq: int, *, seed: int, step: int,
               host_shard: int = 0, n_hosts: int = 1):
    """One global-batch slice for this host.  Deterministic in (seed, step)."""
    if batch % n_hosts:
        raise ValueError(f"global batch {batch} not divisible by hosts {n_hosts}")
    b_local = batch // n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host_shard]))
    if cfg.modality == "audio":
        feats = rng.standard_normal((b_local, seq, cfg.d_model)).astype(np.float32)
        mask = rng.random((b_local, seq)) < 0.08
        targets = rng.integers(0, cfg.vocab, (b_local, seq)).astype(np.int32)
        targets = np.where(mask, targets, -1)  # loss only on masked frames
        return {"features": jnp.asarray(feats), "mask": jnp.asarray(mask),
                "targets": jnp.asarray(targets)}
    if cfg.modality == "vision":
        P = cfg.n_prefix_embeds
        s_text = seq - P
        toks = _markov_tokens(rng, b_local, s_text, cfg.vocab)
        patches = rng.standard_normal((b_local, P, cfg.d_model)).astype(np.float32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "patches": jnp.asarray(patches),
                "targets": jnp.asarray(toks[:, 1:])}
    toks = _markov_tokens(rng, b_local, seq, cfg.vocab)
    return {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}


@dataclasses.dataclass
class SyntheticTokenPipeline:
    """Stateful cursor over the deterministic stream (cursor == step)."""

    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    step: int = 0
    host_shard: int = 0
    n_hosts: int = 1

    def next(self):
        b = make_batch(self.cfg, self.batch, self.seq, seed=self.seed,
                       step=self.step, host_shard=self.host_shard,
                       n_hosts=self.n_hosts)
        self.step += 1
        return b

    def state_dict(self):
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, s):
        self.step = int(s["step"])
        self.seed = int(s["seed"])
