"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape)`` returns the exact pytree a real batch would
have, as shape/dtype structs (weak-type-correct, shardable, no device
allocation).  For decode shapes it also includes the (token, pos) decode
inputs; the KV-cache struct comes from ``jax.eval_shape`` over
``init_cache`` in the dry-run itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCfg

__all__ = ["input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if shape.kind == "decode":
        return {"tokens": _sds((B,), jnp.int32), "pos": _sds((), jnp.int32)}

    if cfg.modality == "audio":
        d = {"features": _sds((B, S, cfg.d_model), dt),
             "mask": _sds((B, S), jnp.bool_)}
        if shape.kind == "train":
            d["targets"] = _sds((B, S), jnp.int32)
        return d
    if cfg.modality == "vision":
        P = cfg.n_prefix_embeds
        d = {"tokens": _sds((B, S - P), jnp.int32),
             "patches": _sds((B, P, cfg.d_model), dt)}
        if shape.kind == "train":
            d["targets"] = _sds((B, S - P), jnp.int32)
        return d
    d = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        d["targets"] = _sds((B, S), jnp.int32)
    return d
