"""Transform-serving: continuous batching of mixed-size request streams.

The schedule executor already coalesces *one matrix's* segments into one
dispatch per distinct (length, config) group (``batch_groups``); this
service generalises that idea to *many users' concurrent requests*: an
async queue plus a tick loop that coalesces every same-``(n, dtype,
method)`` request waiting at tick time into a single batch-stacked
dispatch (``PfftPlan.execute_many`` — plans already vmap leading batch
dims, so one jitted program serves the whole cohort).

    svc = FFTService(wisdom="wisdom.json", tune="estimate")
    async with svc:
        half = await svc.submit(image, method="rfft-lb")

Three layers, each doing one job:

* **Plan resolution** is a cache hierarchy: request -> in-memory
  ``PlanCache`` (bounded LRU of built plans, jitted executors included;
  a hit is zero-retune *and* zero-retrace) -> wisdom store (a stored
  schedule skips the tuner) -> tuner (estimate/measure).  Freshly tuned
  picks are written back to the wisdom store, so a restarted service —
  or another process sharing the file — starts warm; the cache's
  ``retunes`` counter audits the whole stack (a warm second run must
  report zero).
* **Admission and shedding are cost-priced**, not count-based: the FPM
  cost model (``repro.plan.cost``, ``batch=`` cohorts) predicts every
  cohort's makespan.  A request whose *single-transform* prediction
  exceeds ``max_request_s`` is rejected at submit with a priced
  ``AdmissionError`` (an oversized outlier must not stall the queue
  behind it); a tick whose predicted makespan would exceed
  ``tick_budget_s`` splits the marginal cohort (the cohort cost is
  affine in the batch, so the largest admissible prefix is solved in
  closed form) and defers lower-priority cohorts to later ticks;
  requests whose deadline lapses before dispatch are shed with a priced
  ``DeadlineExceeded``.
* **The tick loop is the batching window**: while one tick's cohorts
  run on device, new submissions queue up, and the next tick coalesces
  whatever accumulated — continuous batching, no timer to tune.  Batch
  sizes are bucketed to powers of two (``execute_many(pad_to=...)``) so
  the jitted program count stays logarithmic in the largest cohort.

The synchronous core (``enqueue``/``tick``) is fully deterministic —
tests and benchmarks drive it tick by tick — and ``submit``/
``serve_forever`` are the thin asyncio surface over it.  The service is
single-loop (one jax host program); cross-process concurrency is the
wisdom store's flock business, not ours.
"""

from __future__ import annotations

import asyncio
import time
from typing import Any, NamedTuple, Sequence

import numpy as np

from repro.plan.cache import PlanCache
from repro.plan.config import PlanConfig
from repro.plan.cost import (CostParams, estimate_cost, estimate_pfft3_cost,
                             estimate_schedule_cost)

__all__ = ["AdmissionError", "DeadlineExceeded", "CohortKey",
           "RequestTicket", "FFTService"]

_clock = time.perf_counter   # monotonic: latency math must not see NTP steps

_REAL_PREFIX = "rfft-"
_CTYPES = {"complex64", "complex128"}
_RTYPES = {"float32", "float64"}

# Method families with non-square request shapes: cubic N^3 signals
# (``plan_pfft3``) and huge 1-D lines (``plan_pfft1_large``).  Everything
# else serves the square (N, N) transform through ``plan_pfft``.
_PFFT3_METHODS = frozenset({"pfft3-lb"})
_LARGE1D_METHODS = frozenset({"pfft1-large"})


def _bucket(b: int, quantum: int = 4) -> int:
    """The batch-shape bucket dispatch pads to: powers of two up to
    ``quantum``, then multiples of ``quantum``.

    jit specialises on the stacked shape, so every distinct cohort size
    would otherwise be its own trace+compile; pure pow2 bucketing keeps
    the program count logarithmic but wastes up to half the batch on
    zero padding — ruinous when the padded transforms are the expensive
    sizes.  Quantised buckets cap the waste at ``quantum - 1`` signals
    while the per-plan program count stays bounded by
    ``max_cohort / quantum`` — and a lone request still pays no padding
    (1 and 2 are their own buckets).
    """
    b = max(int(b), 1)
    if b <= quantum:
        return 1 << (b - 1).bit_length()
    return -(-b // quantum) * quantum


class AdmissionError(RuntimeError):
    """Priced rejection: the cost model's prediction and the budget it
    broke ride the exception, so a client (or a load balancer above it)
    can see *why* — and by how much — the request was refused."""

    def __init__(self, reason: str, *, predicted_s: float, budget_s: float):
        super().__init__(
            f"{reason} (predicted {predicted_s * 1e3:.3f} ms vs "
            f"budget {budget_s * 1e3:.3f} ms)")
        self.predicted_s = float(predicted_s)
        self.budget_s = float(budget_s)


class DeadlineExceeded(AdmissionError):
    """Shed: the request's deadline lapsed while it waited for a tick."""


class CohortKey(NamedTuple):
    """The coalescing key: requests agreeing on all three share one
    plan, one jitted program, and one stacked dispatch per tick.

    A ``NamedTuple`` rather than a dataclass: the key is hashed on
    every enqueue, price lookup, and cohort grouping — the tuple's
    C-level hash/eq keeps the per-request queue tax in the microseconds.
    """
    n: int
    method: str
    dtype: str


class RequestTicket:
    """A submitted request's handle: resolved by a later tick.

    ``result()`` returns the transform (or re-raises the failure) once
    ``done``; the asyncio surface awaits ``_ensure_event()`` instead of
    polling.  ``latency_s`` is submit-to-resolution on the service's
    monotonic clock — the number the benchmark's percentiles are built
    from.
    """

    __slots__ = ("key", "priority", "t_submit", "deadline", "m", "done",
                 "latency_s", "_value", "_error", "_event")

    def __init__(self, key: CohortKey, m: np.ndarray, priority: int,
                 t_submit: float, deadline: float | None):
        self.key = key
        self.m = m
        self.priority = int(priority)
        self.t_submit = t_submit
        self.deadline = deadline
        self.done = False
        self.latency_s: float | None = None
        self._value: Any = None
        self._error: BaseException | None = None
        self._event: asyncio.Event | None = None

    def result(self):
        if not self.done:
            raise RuntimeError("request not served yet (tick pending)")
        if self._error is not None:
            raise self._error
        return self._value

    def _ensure_event(self) -> asyncio.Event:
        if self._event is None:
            self._event = asyncio.Event()
            if self.done:
                self._event.set()
        return self._event

    def _resolve(self, value: Any, error: BaseException | None,
                 latency_s: float | None) -> None:
        self._value, self._error = value, error
        self.latency_s = latency_s
        self.done = True
        self.m = None   # drop the payload reference once served
        if self._event is not None:
            self._event.set()


class FFTService:
    """Coalescing transform server over ``plan_pfft`` (module docstring).

    Parameters
    ----------
    p, fpms, tune, wisdom, eps:
        Forwarded to ``plan_pfft`` when a cohort's plan is built:
        ``p`` abstract processors for the ``lb`` methods, ``fpms`` for
        the FPM ones, ``tune`` the planner rigor, ``wisdom`` the
        persistent store the plan cache fronts.
    methods:
        The admissible ``method`` values (defense against a client
        naming an arbitrary plan method); default ``("lb", "rfft-lb")``.
        ``"pfft3-lb"`` (cubic N^3 signals via ``plan_pfft3``) and
        ``"pfft1-large"`` (huge 1-D lines via ``plan_pfft1_large``) are
        also servable when listed here — their requests are validated
        against their own shapes and priced with their own cost terms.
    tick_budget_s:
        Predicted-makespan budget of one tick — the latency the queue
        is allowed to add while coalescing.  Cohorts beyond it are
        split or deferred.
    max_request_s:
        Admission bound on a *single* transform's predicted cost
        (default: ``tick_budget_s``).  Oversized outliers are rejected
        with a priced error rather than wedging every later tick.
    max_queue:
        Queue-depth bound; past it submissions are rejected (priced
        with the predicted backlog of the queue ahead).
    max_cohort:
        Largest single coalesced dispatch.  Batching returns diminish
        well before this, while pow2 bucket padding grows with the
        cohort (a 130-request cohort in a 256 bucket computes nearly
        half its work on zeros) — so a huge cohort is served as
        full-cap chunks across consecutive ticks, bounding padding
        waste to the final chunk and the per-plan compile count to
        ``log2(max_cohort) + 1`` buckets.
    cache_size:
        The plan LRU bound (``repro.plan.cache.PlanCache``).
    params:
        ``CostParams`` override for pricing (default: this backend's).
    write_back:
        Record freshly tuned picks into the wisdom store so restarts
        (and sibling processes) are warm.  Measure-mode picks are
        already recorded by ``plan_pfft`` itself; this covers the
        estimate-mode picks a serving process otherwise re-derives
        every boot.
    """

    def __init__(self, *, p: int = 1, fpms=None, tune: str = "estimate",
                 wisdom: str | None = None, eps: float = 0.05,
                 methods: Sequence[str] = ("lb", "rfft-lb"),
                 tick_budget_s: float = 0.05,
                 max_request_s: float | None = None,
                 max_queue: int = 4096, max_cohort: int = 32,
                 cache_size: int = 64,
                 params: CostParams | None = None,
                 write_back: bool = True):
        self.p = int(p)
        self.fpms = fpms
        self.tune = tune
        self.wisdom = wisdom
        self.eps = float(eps)
        self.methods = tuple(methods)
        self.tick_budget_s = float(tick_budget_s)
        self.max_request_s = max_request_s
        self.max_queue = int(max_queue)
        self.max_cohort = max(int(max_cohort), 1)
        self.write_back = bool(write_back)
        self._params = params if params is not None \
            else CostParams.for_backend()
        self._cache = PlanCache(maxsize=cache_size)
        self._price_memo: dict[CohortKey, tuple[float, float]] = {}
        self._pending: list[RequestTicket] = []
        self._running = False
        self._wake: asyncio.Event | None = None
        self._stats = self._fresh_stats()

    # ---- pricing -------------------------------------------------------

    def price(self, n: int, method: str = "lb", *, dtype: str | None = None,
              batch: int = 1) -> float:
        """Predicted seconds for ``batch`` coalesced (n, n) transforms.

        Priced with the cached plan's own schedule when one is built
        (its configs carry backend multipliers), else with the method's
        default config — the same numbers every admission and tick
        decision uses, exposed so clients and tests can reason about
        budgets in the model's units.
        """
        real = method.startswith(_REAL_PREFIX)
        if dtype is None:
            dtype = "float32" if real else "complex64"
        p1, var = self._cohort_price(CohortKey(int(n), method, dtype))
        return p1 + (max(int(batch), 1) - 1) * var

    def _cohort_price(self, key: CohortKey) -> tuple[float, float]:
        """(p1, var): the cohort's affine price law — ``batch`` coalesced
        transforms cost ``p1 + (batch - 1) * var`` predicted seconds.

        Memoized per key (invalidated when the key's plan is built, since
        a real schedule reprices its default config): the cost model runs
        twice per cohort *kind*, not once per request — admission and
        tick assembly stay O(1) model evaluations on the hot path.
        """
        cached = self._price_memo.get(key)
        if cached is not None:
            return cached
        plan = self._cache.peek(key)
        if key.method in _PFFT3_METHODS:
            # Cubes: three 2-D-sized passes per signal; no cross-signal
            # dispatch amortisation is modelled, so the law is linear.
            cfg = plan.config if plan is not None else PlanConfig()
            p1 = estimate_pfft3_cost(cfg, n=key.n, params=self._params)
            law = (p1, p1)
        elif key.method in _LARGE1D_METHODS:
            # Lines: the four-step estimate at the plan's factorization.
            from repro.plan.tune import tune_pfft1_large
            if plan is not None:
                _, info = tune_pfft1_large(key.n, n1=plan.n1, n2=plan.n2,
                                           params=self._params)
            else:
                _, info = tune_pfft1_large(key.n, params=self._params)
            p1 = float(info["ranked"][0][1])
            law = (p1, p1)
        elif plan is not None:
            p1 = estimate_schedule_cost(plan.schedule, params=self._params)
            p2 = estimate_schedule_cost(plan.schedule, params=self._params,
                                        batch=2)
            law = (p1, max(p2 - p1, 0.0))
        else:
            cfg = PlanConfig(real=key.method.startswith(_REAL_PREFIX))
            p1 = estimate_cost(cfg, n=key.n, params=self._params)
            p2 = estimate_cost(cfg, n=key.n, params=self._params, batch=2)
            law = (p1, max(p2 - p1, 0.0))
        self._price_memo[key] = law
        return law

    def _max_request_s(self) -> float:
        return self.tick_budget_s if self.max_request_s is None \
            else float(self.max_request_s)

    # ---- admission + queue ---------------------------------------------

    @staticmethod
    def _canonical_dtype(kind: np.dtype, method: str) -> str:
        if method.startswith(_REAL_PREFIX):
            return "float64" if kind == np.float64 else "float32"
        return "complex128" if kind in (np.complex128, np.float64) \
            else "complex64"

    def enqueue(self, m, *, method: str = "lb", priority: int = 0,
                deadline_s: float | None = None) -> RequestTicket:
        """Admit one (n, n) request into the queue (synchronous core).

        Raises a priced ``AdmissionError`` when the queue is full or the
        request's own predicted cost exceeds ``max_request_s``; returns
        a ``RequestTicket`` a later ``tick()`` resolves.  ``priority``:
        larger serves earlier; ``deadline_s`` is relative to now — a
        request still queued past it is shed, never served late.
        """
        arr = np.asarray(m)
        if method in _PFFT3_METHODS:
            if arr.ndim != 3 or len(set(arr.shape)) != 1:
                raise ValueError(
                    f"method {method!r} serves cubic (N, N, N) signals, "
                    f"got {arr.shape}")
        elif method in _LARGE1D_METHODS:
            if arr.ndim != 1:
                raise ValueError(
                    f"method {method!r} serves 1-D length-N lines, "
                    f"got {arr.shape}")
        elif arr.ndim != 2 or arr.shape[0] != arr.shape[1]:
            raise ValueError(
                f"serve_fft transforms square (N, N) signals, got "
                f"{arr.shape}; batch by submitting one request per signal")
        if method not in self.methods:
            raise ValueError(f"method {method!r} not served (admissible: "
                             f"{self.methods})")
        n = int(arr.shape[0])
        key = CohortKey(n, method, self._canonical_dtype(arr.dtype, method))
        self._stats["submitted"] += 1
        predicted = self._cohort_price(key)[0]
        cap = self._max_request_s()
        if predicted > cap:
            self._stats["rejected"] += 1
            raise AdmissionError(
                f"oversized transform n={n} method={method}",
                predicted_s=predicted, budget_s=cap)
        if len(self._pending) >= self.max_queue:
            self._stats["rejected"] += 1
            backlog = sum(self._cohort_price(r.key)[0]
                          for r in self._pending[:64])
            raise AdmissionError(
                f"queue full ({len(self._pending)} pending)",
                predicted_s=backlog, budget_s=self.tick_budget_s)
        now = _clock()
        # asarray, not astype: a payload already in the canonical dtype
        # (the common case) is enqueued by reference, no copy.
        ticket = RequestTicket(
            key, np.asarray(arr, dtype=key.dtype), priority, now,
            None if deadline_s is None else now + float(deadline_s))
        self._pending.append(ticket)
        if self._wake is not None:
            self._wake.set()
        return ticket

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    # ---- plans ---------------------------------------------------------

    def _get_plan(self, key: CohortKey):
        def build():
            if key.method in _PFFT3_METHODS:
                from repro.core.api import plan_pfft3
                plan = plan_pfft3(key.n, p=min(self.p, key.n),
                                  tune=self.tune, wisdom=self.wisdom,
                                  dtype=key.dtype)
            elif key.method in _LARGE1D_METHODS:
                from repro.core.api import plan_pfft1_large
                plan = plan_pfft1_large(key.n, tune=self.tune,
                                        wisdom=self.wisdom, dtype=key.dtype)
            else:
                from repro.core.api import plan_pfft
                plan = plan_pfft(key.n, p=self.p, fpms=self.fpms,
                                 method=key.method, eps=self.eps,
                                 tune=self.tune, wisdom=self.wisdom,
                                 dtype=key.dtype)
            src = plan.tuning.get("source", "?")
            self._stats["sources"][src] = \
                self._stats["sources"].get(src, 0) + 1
            if self.write_back and self.wisdom and src == "estimate":
                # Measure picks were recorded by plan_pfft already; the
                # store is advisory here, so a wedged lock is a counter,
                # not a stalled tick.  The 3-D/1-D families persist their
                # single config (they have no segment schedule).
                from repro.plan.wisdom import record_wisdom
                payload = getattr(plan, "schedule", None)
                if payload is None:
                    payload = plan.config
                try:
                    record_wisdom(self.wisdom, plan.tuning["wisdom_key"],
                                  payload, mode="estimate",
                                  retries=2, lock_timeout_s=5.0)
                except TimeoutError:
                    self._stats["wisdom_write_timeouts"] += 1
            # The built plan's schedule reprices this cohort.
            self._price_memo.pop(key, None)
            return plan

        plan, _hit = self._cache.get(key, build)
        return plan

    # ---- the tick ------------------------------------------------------

    def _shed_expired(self, now: float) -> None:
        kept = []
        for r in self._pending:
            if r.deadline is not None and now > r.deadline:
                err = DeadlineExceeded(
                    f"deadline lapsed before dispatch (n={r.key.n}, "
                    f"method={r.key.method})",
                    predicted_s=self.price(r.key.n, r.key.method,
                                           dtype=r.key.dtype),
                    budget_s=max(r.deadline - r.t_submit, 0.0))
                r._resolve(None, err, None)
                self._stats["shed_deadline"] += 1
            else:
                kept.append(r)
        self._pending = kept

    def _assemble(self, now: float) -> list[tuple[CohortKey, list[RequestTicket]]]:
        """Pick this tick's cohorts under the predicted-makespan budget.

        Cohorts are ordered by (priority desc, oldest submit); each is
        priced as one coalesced dispatch (``price(batch=k)`` is affine
        in k, so the largest prefix fitting the remaining budget is a
        closed-form solve).  A partial fit is a *split* (the suffix
        waits), a nonfit is a *deferral* — and the head cohort always
        gets at least one request, so a nonempty queue always makes
        progress whatever the budget says.
        """
        groups: dict[CohortKey, list[RequestTicket]] = {}
        for r in self._pending:
            groups.setdefault(r.key, []).append(r)
        ordered = sorted(
            groups.items(),
            key=lambda kv: (-max(r.priority for r in kv[1]),
                            min(r.t_submit for r in kv[1])))
        remaining = self.tick_budget_s
        picked: list[tuple[CohortKey, list[RequestTicket]]] = []
        taken: set[int] = set()
        for key, reqs in ordered:
            p1, var = self._cohort_price(key)
            if p1 <= remaining:
                k = len(reqs) if var <= 0.0 else \
                    min(len(reqs), max(int((remaining - (p1 - var)) // var), 1))
            elif not picked:
                k = 1   # progress guarantee: the head never starves
            else:
                self._stats["deferred_cohorts"] += 1
                continue
            k = min(k, self.max_cohort)   # bound padding waste + compiles
            if k < len(reqs):
                self._stats["splits"] += 1
            remaining -= p1 + (k - 1) * var
            picked.append((key, reqs[:k]))
            taken.update(id(r) for r in reqs[:k])
        if taken:
            self._pending = [r for r in self._pending if id(r) not in taken]
        return picked

    def _dispatch(self, key: CohortKey, reqs: list[RequestTicket]) -> int:
        try:
            plan = self._get_plan(key)
            # execute_many returns host arrays — already synchronized.
            outs = plan.execute_many([r.m for r in reqs],
                                     pad_to=_bucket(len(reqs)))
        except Exception as e:   # a bad cohort fails its own requests only
            for r in reqs:
                r._resolve(None, e, None)
            self._stats["failed"] += len(reqs)
            return 0
        t_done = _clock()
        for r, out in zip(reqs, outs):
            lat = t_done - r.t_submit
            r._resolve(out, None, lat)
            self._stats["latencies_s"].append(lat)
        self._stats["dispatches"] += 1
        self._stats["served"] += len(reqs)
        if len(reqs) >= 2:
            self._stats["coalesced_dispatches"] += 1
        self._stats["max_coalesced"] = max(self._stats["max_coalesced"],
                                           len(reqs))
        return len(reqs)

    def tick(self) -> int:
        """One serving tick: shed expired, assemble cohorts, dispatch.

        Returns the number of requests served.  Deterministic and
        synchronous — the asyncio loop calls it, and so can a test.
        """
        if not self._pending:
            return 0
        self._stats["ticks"] += 1
        now = _clock()
        self._shed_expired(now)
        served = 0
        for key, reqs in self._assemble(now):
            served += self._dispatch(key, reqs)
        return served

    def drain(self) -> int:
        """Tick until the queue is empty (synchronous drivers/tests)."""
        total = 0
        while self._pending:
            total += self.tick()
        return total

    # ---- asyncio surface -----------------------------------------------

    async def submit(self, m, *, method: str = "lb", priority: int = 0,
                     deadline_s: float | None = None):
        """Enqueue and await the result (run ``serve_forever`` alongside)."""
        ticket = self.enqueue(m, method=method, priority=priority,
                              deadline_s=deadline_s)
        await ticket._ensure_event().wait()
        return ticket.result()

    async def serve_forever(self) -> None:
        """The tick loop: dispatch whatever queued, yield, repeat.

        Each dispatch *is* the batching window — submissions landing
        while a tick runs on device are coalesced by the next one.
        Exits once ``stop()`` was called and the queue is drained.
        """
        self._running = True
        # Fresh per run: asyncio primitives bind to their first loop, and
        # a service is reused across asyncio.run calls (warm second pass).
        self._wake = asyncio.Event()
        try:
            while self._running or self._pending:
                if not self._pending:
                    self._wake.clear()
                    await self._wake.wait()
                    continue
                self.tick()
                # Let submitters (and their resolved awaits) run between
                # ticks — this yield is what accumulates the next cohort.
                await asyncio.sleep(0)
        finally:
            self._running = False
            self._wake = None

    def stop(self) -> None:
        """Ask ``serve_forever`` to exit after draining the queue."""
        self._running = False
        if self._wake is not None:
            self._wake.set()

    async def __aenter__(self) -> "FFTService":
        self._task = asyncio.ensure_future(self.serve_forever())
        return self

    async def __aexit__(self, *exc) -> None:
        self.stop()
        await self._task

    # ---- stats ---------------------------------------------------------

    @staticmethod
    def _fresh_stats() -> dict[str, Any]:
        return {
            "submitted": 0, "served": 0, "rejected": 0, "failed": 0,
            "shed_deadline": 0, "ticks": 0, "dispatches": 0,
            "coalesced_dispatches": 0, "max_coalesced": 0,
            "splits": 0, "deferred_cohorts": 0,
            "wisdom_write_timeouts": 0,
            "sources": {}, "latencies_s": [],
        }

    def reset_stats(self) -> None:
        """Zero every counter but keep the plan cache warm — the 'second
        run' audit starts here (its ``retunes`` must stay zero)."""
        self._stats = self._fresh_stats()
        self._cache.reset_stats()

    def stats(self) -> dict[str, Any]:
        s = dict(self._stats)
        s["latencies_s"] = list(s["latencies_s"])
        s["sources"] = dict(s["sources"])
        s["batching_efficiency"] = (s["served"] / s["dispatches"]
                                    if s["dispatches"] else 0.0)
        s["plan_cache"] = self._cache.stats_dict()
        return s
