"""Batched serving driver: prefill a batch of prompts, then decode with a
jitted serve_step (greedy or temperature sampling).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
        --batch 8 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import make_batch
from repro.models import transformer as model
from repro.models.registry import get_config, get_smoke_config

__all__ = ["serve_batch", "main"]


def serve_batch(arch: str, *, smoke: bool = True, batch: int = 8,
                prompt_len: int = 64, gen: int = 32, temperature: float = 0.0,
                seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if not cfg.supports_decode():
        raise ValueError(f"{arch} is encoder-only; no decode path")
    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    max_len = prompt_len + gen

    prompts = make_batch(cfg, batch, prompt_len, seed=seed, step=0)
    prompts.pop("targets", None)

    prefill_fn = jax.jit(
        lambda p, b, c: model.prefill(p, b, cfg, c))
    decode_fn = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg),
        donate_argnums=(1,))

    cache = model.init_cache(cfg, batch, max_len)
    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, prompts, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(seed + 1)
    toks = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(gen):
        toks.append(tok)
        logits, cache = decode_fn(params, cache, tok, jnp.int32(prompt_len + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, -1).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(toks[-1])
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in toks], axis=1)  # (B, gen)
    stats = {
        "prefill_s": t_prefill,
        "prefill_tok_s": batch * prompt_len / t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": batch * gen / max(t_decode, 1e-9),
    }
    return out, stats


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out, stats = serve_batch(args.arch, smoke=args.smoke, batch=args.batch,
                             prompt_len=args.prompt_len, gen=args.gen,
                             temperature=args.temperature)
    print(f"[serve] generated shape={out.shape}")
    for k, v in stats.items():
        print(f"[serve] {k}={v:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
