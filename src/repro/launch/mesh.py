"""Production mesh construction and multi-host launch.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked on first jax init, and
smoke tests must see 1 device while the dry-run sees 512).

Multi-host support (see DESIGN.md §Multi-host topology):

* ``init_multihost`` / ``init_multihost_from_env`` bring a process into a
  ``jax.distributed`` cluster before any other jax use — on CPU they
  select the gloo collectives backend, which is what the localhost
  emulation rig (tests/CI) runs on.
* ``make_fft_mesh(hosts=, local=)`` builds the FFT axis *host-major*:
  device ``H*local + L`` is local device ``L`` of host ``H``, so the
  hierarchical exchange's intra-host groups are contiguous runs along
  the axis.  ``make_pfft3_mesh(hosts=)`` does the same with the host
  dimension riding the ``r`` axis (each host owns ``r/hosts`` contiguous
  mesh rows; ``c``-axis communicators never leave a host).
* ``mesh_host_shape`` recovers ``(hosts, local)`` along a mesh axis —
  from the device ``process_index`` pattern on a real multi-process
  cluster, or from the emulated-host registry that single-process tests
  populate via ``hosts=`` so the hierarchical code paths are exercised
  without multi-process launches.
"""

from __future__ import annotations

import os

import numpy as np

import jax

try:  # AxisType landed after jax 0.4.37; Auto is the pre-AxisType default.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

__all__ = ["make_production_mesh", "make_local_mesh", "make_fft_mesh",
           "make_pfft3_mesh", "mesh_host_shape", "register_emulated_hosts",
           "init_multihost", "init_multihost_from_env"]

# Single-process emulation of host structure: (axis_name, flat device ids)
# -> host count along that axis.  Populated by ``hosts=`` mesh builders
# (and ``register_emulated_hosts``) when there is only one real process;
# consulted by ``mesh_host_shape`` before the process_index derivation.
_EMULATED_HOSTS: dict[tuple[str, tuple[int, ...]], int] = {}


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def _mesh_from_devices(grid, axes):
    """Mesh over an *explicit* device array (host-major orderings must not
    be re-shuffled by ``jax.make_mesh``'s own placement heuristics)."""
    from jax.sharding import Mesh
    if AxisType is None:
        return Mesh(np.asarray(grid), axes)
    return Mesh(np.asarray(grid), axes,
                axis_types=(AxisType.Auto,) * len(axes))


def host_major_devices(devices=None):
    """Visible devices sorted host-major: by (process_index, id)."""
    devices = list(devices if devices is not None else jax.devices())
    return sorted(devices,
                  key=lambda d: (getattr(d, "process_index", 0), d.id))


def register_emulated_hosts(mesh, axis_name: str, hosts: int) -> None:
    """Declare that ``mesh``'s ``axis_name`` axis is ``hosts`` host-major
    groups — the single-process stand-in for ``process_index`` structure,
    used by tests and the elastic rebuild path on forced-device rigs.

    ``hosts=1`` clears any prior declaration: the registry is keyed by
    (axis name, device ids), so the *last builder wins* — building a flat
    mesh over devices that previously carried an emulated hierarchy must
    not inherit it.
    """
    ids = tuple(int(d.id) for d in np.asarray(mesh.devices).flat)
    if int(hosts) <= 1:
        _EMULATED_HOSTS.pop((axis_name, ids), None)
    else:
        _EMULATED_HOSTS[(axis_name, ids)] = int(hosts)


def mesh_host_shape(mesh, axis_name: str = "fft") -> tuple[int, int]:
    """``(hosts, local)`` along ``mesh``'s ``axis_name`` axis.

    Returns ``(1, p)`` — no exploitable hierarchy — unless the axis is
    *host-major*: equal-sized contiguous runs of same-host devices (the
    layout the ``hosts=`` builders produce).  A flat or shuffled layout
    degrades to single-tier treatment rather than raising: the exchange
    still works, it just has no fast-tier grouping to exploit.
    """
    axis_names = tuple(mesh.axis_names)
    if axis_name not in axis_names:
        raise ValueError(f"mesh has no axis {axis_name!r}: {axis_names}")
    p = int(mesh.shape[axis_name])
    ids = tuple(int(d.id) for d in np.asarray(mesh.devices).flat)
    hosts = _EMULATED_HOSTS.get((axis_name, ids))
    if hosts is not None:
        if hosts >= 1 and p % hosts == 0:
            return int(hosts), p // hosts
        return 1, p
    axis_pos = axis_names.index(axis_name)
    along = np.moveaxis(np.asarray(mesh.devices), axis_pos, 0).reshape(p, -1)
    # Host pattern must agree across every communicator of this axis.
    procs = [[getattr(d, "process_index", 0) for d in along[:, j]]
             for j in range(along.shape[1])]
    pattern = procs[0]
    if any(q != pattern for q in procs[1:]):
        return 1, p
    hosts = len(dict.fromkeys(pattern))
    if hosts <= 1 or p % hosts:
        return 1, p
    local = p // hosts
    blocks = [pattern[i * local:(i + 1) * local] for i in range(hosts)]
    if any(len(set(b)) != 1 for b in blocks) \
            or len({b[0] for b in blocks}) != hosts:
        return 1, p
    return hosts, local


def init_multihost(coordinator_address: str, num_processes: int,
                   process_id: int) -> None:
    """Join a ``jax.distributed`` cluster; call before any other jax use.

    On CPU this selects the gloo collectives backend — XLA's default CPU
    collectives cannot cross process boundaries — which is exactly what
    the localhost emulation rig (2 processes x 2 forced devices) runs on
    in CI.  Idempotent per process: a second call is a no-op.
    """
    if getattr(jax.distributed, "global_state", None) is not None \
            and jax.distributed.global_state.client is not None:
        return
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # pragma: no cover - non-CPU builds
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=int(num_processes),
                               process_id=int(process_id))


def init_multihost_from_env() -> bool:
    """``init_multihost`` from ``REPRO_MH_COORD`` / ``REPRO_MH_NPROCS`` /
    ``REPRO_MH_PID`` (the launcher contract of the multihost test rig and
    any external process manager); returns False when unset."""
    coord = os.environ.get("REPRO_MH_COORD")
    if not coord:
        return False
    init_multihost(coord, int(os.environ["REPRO_MH_NPROCS"]),
                   int(os.environ["REPRO_MH_PID"]))
    return True


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ('data', 'model').  Multi-pod: 2 pods
    = 512 chips ('pod', 'data', 'model'); the pod axis carries pure DP so
    only gradient all-reduces cross the (slow) pod interconnect."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests and the quickstart example."""
    return _make_mesh((data, model), ("data", "model"))


def make_fft_mesh(p: int | None = None, axis_name: str = "fft", *,
                  hosts: int | None = None, local: int | None = None):
    """1-D mesh for the distributed PFFT pipeline (and its tuner).

    ``p`` defaults to every visible device — on a forced-multi-device CPU
    host (``--xla_force_host_platform_device_count=k``) that is the faked
    topology the dist test rig and the microbench ``dist`` sweep run on.
    The axis name is part of the plan's ``topology_digest``, so callers
    who rename it get distinct wisdom keys by construction.

    ``hosts``/``local`` build the axis *host-major* over ``hosts x local``
    devices (either may be derived from the other and the device count):
    on a real ``jax.distributed`` cluster devices are ordered by
    ``(process_index, id)``; in a single process the host structure is
    *emulated* — registered so ``mesh_host_shape`` (and with it the
    hierarchical exchange, the two-tier cost model, and the topology
    digest) treats the mesh as multi-host.  ``hosts=1`` is the flat mesh.
    """
    if hosts is None and local is None:
        if p is None:
            p = jax.device_count()
        mesh = _make_mesh((int(p),), (axis_name,))
        if jax.process_count() == 1:
            register_emulated_hosts(mesh, axis_name, 1)
        return mesh
    devices = host_major_devices()
    if hosts is None:
        total = int(p) if p is not None else len(devices)
        hosts = total // int(local)
    if local is None:
        total = int(p) if p is not None else len(devices)
        local = total // int(hosts)
    hosts, local = int(hosts), int(local)
    p = hosts * local
    if hosts < 1 or local < 1:
        raise ValueError(f"hosts x local must be positive, got {hosts}x{local}")
    if p > len(devices):
        raise ValueError(
            f"host-major mesh needs {hosts}x{local}={p} devices, "
            f"only {len(devices)} visible")
    mesh = _mesh_from_devices(np.asarray(devices[:p]), (axis_name,))
    if jax.process_count() == 1:
        register_emulated_hosts(mesh, axis_name, hosts)
    return mesh


def make_pfft3_mesh(r: int | None = None, c: int | None = None,
                    axis_names: tuple[str, str] = ("fft_r", "fft_c"), *,
                    hosts: int | None = None):
    """2-D ``r x c`` mesh for the pencil-parallel 3-D PFFT.

    Defaults to the most-square factorization of every visible device
    (``r <= c``); passing one of ``r``/``c`` derives the other from the
    device count.  Both axis names enter the plan's ``topology_digest``,
    so a transposed mesh gets distinct wisdom keys by construction.

    ``hosts`` builds the grid host-major with the host dimension riding
    the ``r`` axis: each host owns ``r/hosts`` contiguous mesh rows, so
    every ``c``-axis communicator stays inside one host and only the
    ``r``-axis exchange crosses the slow tier (where the hierarchical
    form applies).  Requires ``hosts | r``.
    """
    if r is None and c is None:
        q = jax.device_count()
        if hosts is not None and int(hosts) > 1:
            # Host-major default: whole hosts stack on the r axis.
            r = int(hosts)
            c = q // r
        else:
            r = 1
            for f in range(int(q ** 0.5), 0, -1):
                if q % f == 0:
                    r = f
                    break
            c = q // r
    elif r is None:
        c = int(c)
        r = jax.device_count() // c
    elif c is None:
        r = int(r)
        c = jax.device_count() // r
    r, c = int(r), int(c)
    if hosts is None:
        mesh = _make_mesh((r, c), tuple(axis_names))
        if jax.process_count() == 1:
            register_emulated_hosts(mesh, axis_names[0], 1)
        return mesh
    hosts = int(hosts)
    if hosts < 1 or r % hosts:
        raise ValueError(
            f"host count must divide the r axis: hosts={hosts}, r={r}")
    devices = host_major_devices()
    if r * c > len(devices):
        raise ValueError(
            f"host-major pencil mesh needs {r}x{c}={r * c} devices, "
            f"only {len(devices)} visible")
    grid = np.asarray(devices[:r * c]).reshape(r, c)
    mesh = _mesh_from_devices(grid, tuple(axis_names))
    if jax.process_count() == 1:
        register_emulated_hosts(mesh, axis_names[0], hosts)
    return mesh
