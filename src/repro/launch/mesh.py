"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked on first jax init, and
smoke tests must see 1 device while the dry-run sees 512).
"""

from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.37; Auto is the pre-AxisType default.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

__all__ = ["make_production_mesh", "make_local_mesh", "make_fft_mesh",
           "make_pfft3_mesh"]


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ('data', 'model').  Multi-pod: 2 pods
    = 512 chips ('pod', 'data', 'model'); the pod axis carries pure DP so
    only gradient all-reduces cross the (slow) pod interconnect."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests and the quickstart example."""
    return _make_mesh((data, model), ("data", "model"))


def make_fft_mesh(p: int | None = None, axis_name: str = "fft"):
    """1-D mesh for the distributed PFFT pipeline (and its tuner).

    ``p`` defaults to every visible device — on a forced-multi-device CPU
    host (``--xla_force_host_platform_device_count=k``) that is the faked
    topology the dist test rig and the microbench ``dist`` sweep run on.
    The axis name is part of the plan's ``topology_digest``, so callers
    who rename it get distinct wisdom keys by construction.
    """
    if p is None:
        p = jax.device_count()
    return _make_mesh((p,), (axis_name,))


def make_pfft3_mesh(r: int | None = None, c: int | None = None,
                    axis_names: tuple[str, str] = ("fft_r", "fft_c")):
    """2-D ``r x c`` mesh for the pencil-parallel 3-D PFFT.

    Defaults to the most-square factorization of every visible device
    (``r <= c``); passing one of ``r``/``c`` derives the other from the
    device count.  Both axis names enter the plan's ``topology_digest``,
    so a transposed mesh gets distinct wisdom keys by construction.
    """
    if r is None and c is None:
        q = jax.device_count()
        r = 1
        for f in range(int(q ** 0.5), 0, -1):
            if q % f == 0:
                r = f
                break
        c = q // r
    elif r is None:
        c = int(c)
        r = jax.device_count() // c
    elif c is None:
        r = int(r)
        c = jax.device_count() // r
    return _make_mesh((int(r), int(c)), tuple(axis_names))
