"""End-to-end training driver with checkpoint/restart, straggler
monitoring, and elastic recovery.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Fault tolerance contract:
  * SIGKILL at any point: rerun with the same --ckpt-dir resumes from the
    last complete checkpoint (atomic dirs), with the data pipeline cursor
    restored — the loss curve continues exactly (tests/test_runtime.py).
  * Device-set change (real pods): the elastic wrapper rebuilds the mesh
    from jax.devices(), re-shards the restored state, re-partitions the
    batch via POPTA/HPOPTA and continues.
  * Straggler drift: per-step times feed StragglerMonitor; on detection the
    FPM-based repartition is logged (and applied to the host batch split on
    multi-controller deployments).
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs.base import TrainCfg
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.models.registry import get_config, get_smoke_config
from repro.models.sharding import batch_pspecs, param_pspecs, sanitize_pspecs
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import rebuild_mesh, reshard
from repro.runtime.straggler import StragglerMonitor
from repro.train.step import init_train_state, make_train_step

__all__ = ["run_training", "main"]


def run_training(arch: str, *, smoke: bool = True, steps: int = 20,
                 lr: float = 3e-3,
                 batch: int = 8, seq: int = 64, ckpt_dir: str | None = None,
                 ckpt_every: int = 10, microbatches: int = 2,
                 data_axis: int = 1, model_axis: int = 1,
                 grad_compress: str = "none", seed: int = 0,
                 log_every: int = 1, async_ckpt: bool = True) -> list[float]:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    tcfg = TrainCfg(lr=lr, microbatches=microbatches, total_steps=steps,
                    warmup=max(1, steps // 10), grad_compress=grad_compress,
                    seed=seed)
    mesh = make_local_mesh(data_axis, model_axis)

    state = init_train_state(jax.random.PRNGKey(seed), cfg, tcfg)
    pipe = SyntheticTokenPipeline(cfg, batch, seq, seed=seed)
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        s = ckpt.latest_step()
        state, extra = ckpt.restore(s, state)
        pipe.load_state_dict(extra["pipeline"])
        start_step = int(extra["step"])
        print(f"[train] resumed from checkpoint step {start_step}")

    sspec = sanitize_pspecs(param_pspecs(state), state, mesh)
    state = reshard(state, mesh, sspec)

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    monitor = StragglerMonitor(n_groups=max(1, data_axis))
    losses: list[float] = []

    with mesh:
        for step in range(start_step, steps):
            batch_data = pipe.next()
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_data)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.record(0, dt)
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} dt={dt:.2f}s")
            if ckpt is not None and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, state,
                          extra={"step": step + 1,
                                 "pipeline": pipe.state_dict()},
                          blocking=not async_ckpt)
    if ckpt is not None:
        ckpt.wait()
        ckpt.save(steps, state, extra={"step": steps,
                                       "pipeline": pipe.state_dict()})
    return losses


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    attempts = 0
    while True:
        try:
            run_training(args.arch, smoke=args.smoke, steps=args.steps, lr=args.lr,
                         batch=args.batch, seq=args.seq,
                         microbatches=args.microbatches,
                         ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         data_axis=args.data_axis, model_axis=args.model_axis,
                         grad_compress=args.grad_compress, seed=args.seed)
            return 0
        except RuntimeError as e:  # device failure path: elastic restart
            attempts += 1
            if attempts > 2 or args.ckpt_dir is None:
                raise
            print(f"[train] runtime error ({e}); rebuilding mesh from "
                  f"surviving devices and resuming from checkpoint")
            rebuilt = rebuild_mesh(model_axis=args.model_axis)
            if rebuilt.dropped:
                print(f"[train] rebuilt grid uses {rebuilt.used} devices; "
                      f"{rebuilt.dropped} survivor(s) do not fit and idle")


if __name__ == "__main__":
    raise SystemExit(main())
