import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: .lower().compile() for every (arch x shape x mesh).

This is the scale proof without hardware: 512 placeholder host devices let
``make_production_mesh`` build the real 16x16 (single-pod) and 2x16x16
(multi-pod) meshes; every cell lowers its real step function (train_step
with optimizer / prefill / serve_step against the full KV cache) with the
production shardings, compiles it through the XLA SPMD partitioner, and
records memory_analysis / cost_analysis / the collective schedule for the
roofline report.

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx_132b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeCfg, TrainCfg
from repro.data.specs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (active_param_count, model_flops,
                                   param_count, roofline_terms)
from repro.models import transformer as model
from repro.models.registry import ARCH_IDS, get_config
from repro.models.sharding import (batch_pspecs, cache_pspecs, embed_dshard,
                                   param_pspecs, sanitize_pspecs)
from repro.train.step import (TrainState, init_train_state, make_serve_step,
                              make_train_step)

__all__ = ["cell_plan", "run_cell", "main"]

DEFAULT_OUT = "experiments/dryrun"


def cell_plan() -> list[tuple[str, str]]:
    """All runnable (arch, shape) cells with the skips from DESIGN.md §4."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if shape.kind == "decode" and not cfg.supports_decode():
                continue  # encoder-only: no autoregressive decode
            if sname == "long_500k" and not cfg.subquadratic():
                continue  # 500k dense-KV decode needs sub-quadratic archs
            cells.append((arch, sname))
    return cells


def _train_cfg_for(cfg: ArchConfig, shape: ShapeCfg, mesh) -> TrainCfg:
    # Microbatch count keeps per-microbatch global batch >= the DP extent.
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    nmb = max(1, shape.global_batch // dp)
    nmb = min(nmb, 8)
    while shape.global_batch % nmb:
        nmb -= 1
    return TrainCfg(microbatches=nmb, remat=True)


def _named(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def _drop_fsdp(specs):
    """Remove the 'data' axis from every param spec (inference serving)."""
    def fix(s):
        out = []
        for e in tuple(s):
            if e == "data":
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != "data")
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                out.append(e)
        return P(*out)
    return jax.tree.map(fix, specs)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               analysis: bool = False, opts: dict | None = None):
    """Build mesh + shardings and lower the cell's step function.

    Returns (parts, meta): parts is a list of (name, lowered, weight) whose
    weighted cost sum is one production step.  ``analysis=True`` unrolls
    layer scans / q-chunk maps and lowers grad-microbatch and optimizer
    separately (XLA cost_analysis counts while bodies once, so the scanned
    compile-proof lowering cannot be used for roofline flops — see
    EXPERIMENTS.md §Roofline method).

    ``opts``: perf-iteration overrides (EXPERIMENTS.md §Perf):
      shard_grad_accum: bool — pin grad-accum carry to param shardings
      ssd_remat: bool        — rematerialize SSD intra-chunk tensors
      ssd_chunk: int         — SSD chunk length override
      capacity_factor: float — MoE capacity override
      cache_data_shard: bool — shard KV-cache seq over ('data','model')
      no_fsdp: bool          — inference-only: drop the 'data' storage dim
                               from param specs (weights replicated over
                               data, no per-layer FSDP all-gathers)
      seq_shard: bool        — sequence-parallel activations (Ulysses-style)
    """
    import dataclasses as _dc
    from repro.models.sharding import set_seq_shard
    opts = opts or {}
    set_seq_shard(bool(opts.get("seq_shard", False)))
    cfg = get_config(arch)
    if opts.get("capacity_factor") and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(
            cfg.moe, capacity_factor=float(opts["capacity_factor"])))
    if cfg.ssm is not None and (opts.get("ssd_remat") or opts.get("ssd_chunk")):
        cfg = _dc.replace(cfg, ssm=_dc.replace(
            cfg.ssm,
            remat_chunk=bool(opts.get("ssd_remat", cfg.ssm.remat_chunk)),
            chunk=int(opts.get("ssd_chunk", cfg.ssm.chunk))))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    have_pod = multi_pod
    chips = int(mesh.devices.size)

    params_shape = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    n_params = param_count(params_shape)
    n_active = active_param_count(cfg, n_params)
    mflops = model_flops(cfg, shape, n_params, n_active)

    batch_struct = input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = _train_cfg_for(cfg, shape, mesh)
        state_shape = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg))
        sspec = sanitize_pspecs(param_pspecs(state_shape, have_pod),
                                state_shape, mesh)
        bspec = sanitize_pspecs(batch_pspecs(batch_struct, have_pod),
                                batch_struct, mesh)
        if not analysis:
            grad_sh = (_named(mesh, sspec.params)
                       if opts.get("shard_grad_accum") else None)
            step = make_train_step(cfg, tcfg, grad_shardings=grad_sh)
            jfn = jax.jit(step,
                          in_shardings=(_named(mesh, sspec), _named(mesh, bspec)),
                          out_shardings=(_named(mesh, sspec), None),
                          donate_argnums=(0,))
            with mesh:
                lowered = jfn.lower(state_shape, batch_struct)
            parts = [("train_step", lowered, 1.0)]
        else:
            # part 1: one unrolled grad microbatch (weight = n_microbatches)
            nmb = tcfg.microbatches
            mb_struct = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((s.shape[0] // nmb,) + s.shape[1:],
                                               s.dtype), batch_struct)
            mbspec = sanitize_pspecs(batch_pspecs(mb_struct, have_pod),
                                     mb_struct, mesh)
            from repro.models.transformer import loss_fn as _loss

            # remat=False halves the unrolled-graph compile cost; the
            # production program DOES remat, so run_cell applies the 4/3
            # analytic flop correction (fwd 2ND + bwd 4ND + remat-fwd 2ND)
            # to train cells — stated in EXPERIMENTS.md §Roofline method.
            def grad_mb(params, mb):
                return jax.grad(
                    lambda p: _loss(p, mb, cfg, remat=False, q_chunk=None,
                                    vocab_chunk=None, scan_layers=False)[0]
                )(params)

            pspec_only = sanitize_pspecs(param_pspecs(params_shape, have_pod),
                                         params_shape, mesh)
            jg = jax.jit(grad_mb,
                         in_shardings=(_named(mesh, pspec_only),
                                       _named(mesh, mbspec)),
                         out_shardings=_named(mesh, pspec_only))
            # part 2: optimizer update, once per step
            from repro.optim.adamw import adamw_update

            def opt_fn(grads, opt, params):
                return adamw_update(grads, opt, params, tcfg,
                                    jnp.float32(1e-4))

            opt_shape = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg)).opt
            ospec = sanitize_pspecs(param_pspecs(opt_shape, have_pod),
                                    opt_shape, mesh)
            jo = jax.jit(opt_fn,
                         in_shardings=(_named(mesh, pspec_only),
                                       _named(mesh, ospec),
                                       _named(mesh, pspec_only)),
                         out_shardings=(_named(mesh, pspec_only),
                                        _named(mesh, ospec), None))
            with mesh:
                parts = [("grad_mb", jg.lower(params_shape, mb_struct), float(nmb)),
                         ("opt", jo.lower(params_shape, opt_shape, params_shape), 1.0)]

    elif shape.kind == "prefill":
        pspec = sanitize_pspecs(param_pspecs(params_shape, have_pod),
                                params_shape, mesh)
        pspec = embed_dshard(pspec, params_shape)  # §Perf Q2
        pspec = sanitize_pspecs(pspec, params_shape, mesh)
        if opts.get("no_fsdp"):
            pspec = _drop_fsdp(pspec)
        bspec = sanitize_pspecs(batch_pspecs(batch_struct, have_pod),
                                batch_struct, mesh)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspec = sanitize_pspecs(cache_pspecs(cache_shape, have_pod),
                                cache_shape, mesh)

        def prefill_fn(params, batch):
            cache = model.init_cache(cfg, shape.global_batch, shape.seq_len)
            return model.prefill(params, batch, cfg, cache,
                                 q_chunk=None if analysis else 512,
                                 scan_layers=not analysis)

        jfn = jax.jit(prefill_fn,
                      in_shardings=(_named(mesh, pspec), _named(mesh, bspec)),
                      out_shardings=(None, _named(mesh, cspec)))
        with mesh:
            parts = [("prefill", jfn.lower(params_shape, batch_struct), 1.0)]

    else:  # decode
        pspec = sanitize_pspecs(param_pspecs(params_shape, have_pod),
                                params_shape, mesh)
        pspec = embed_dshard(pspec, params_shape)  # §Perf Q2
        pspec = sanitize_pspecs(pspec, params_shape, mesh)
        if opts.get("no_fsdp"):
            pspec = _drop_fsdp(pspec)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))
        seq_axes = (("data", "model") if opts.get("cache_data_shard")
                    else "model")
        cspec = sanitize_pspecs(
            cache_pspecs(cache_shape, have_pod, seq_axes=seq_axes),
            cache_shape, mesh)
        tok_struct = batch_struct["tokens"]
        tok_spec = sanitize_pspecs(P(("pod", "data") if have_pod else "data"),
                                   tok_struct, mesh)

        def serve(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos, cfg,
                                     scan_layers=not analysis)

        jfn = jax.jit(serve,
                      in_shardings=(_named(mesh, pspec), _named(mesh, cspec),
                                    NamedSharding(mesh, tok_spec), None),
                      out_shardings=(None, _named(mesh, cspec)),
                      donate_argnums=(1,))
        with mesh:
            parts = [("serve_step",
                      jfn.lower(params_shape, cache_shape, tok_struct,
                                jax.ShapeDtypeStruct((), jnp.int32)), 1.0)]

    meta = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "chips": chips, "n_params": n_params, "n_active": n_active,
            "model_flops": mflops, "kind": shape.kind,
            "remat_flop_correction": (4.0 / 3.0 if analysis and
                                      shape.kind == "train" else 1.0)}
    return parts, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = DEFAULT_OUT, tag: str = "baseline",
             analysis: bool = False, opts: dict | None = None) -> dict:
    t0 = time.perf_counter()
    parts, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                             analysis=analysis, opts=opts)
    meta["opts"] = opts or {}
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    cost_sum: dict[str, float] = {}
    coll_sum: dict[str, int] = {}
    mems = []
    for name, lowered, weight in parts:
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        corr = meta.get("remat_flop_correction", 1.0) if name == "grad_mb" else 1.0
        cost_sum["flops"] = cost_sum.get("flops", 0.0) + \
            weight * corr * float(cost.get("flops", 0.0))
        cost_sum["bytes accessed"] = cost_sum.get("bytes accessed", 0.0) + \
            weight * float(cost.get("bytes accessed", 0.0))
        from repro.launch.roofline import collective_bytes
        for k, v in collective_bytes(compiled.as_text()).items():
            coll_sum[k] = coll_sum.get(k, 0) + int(weight * v)
        mems.append((name, compiled.memory_analysis()))
    t_compile = time.perf_counter() - t0

    terms = roofline_terms(cost_sum, "", meta["chips"], meta["model_flops"])
    terms.coll_bytes = coll_sum
    terms.collective_s = float(sum(coll_sum.values())) / 50e9

    mem = mems[0][1]
    rec = {
        **meta, "tag": tag, "analysis": analysis,
        "parts": [n for n, _, _ in parts],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "roofline": terms.to_dict(),
    }
    print(f"[dryrun] {arch} x {shape_name} mesh={'2x16x16' if multi_pod else '16x16'}"
          f" tag={tag} compile={t_compile:.1f}s dominant={terms.dominant}"
          f" useful={terms.useful_ratio:.3f}")
    for name, m in mems:
        print(f"  memory_analysis[{name}]: {m}")
    print(f"  cost_analysis(step-weighted): flops={cost_sum.get('flops', 0):.3e}"
          f" bytes={cost_sum.get('bytes accessed', 0):.3e}")
    print(f"  collectives: {coll_sum}")

    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "pod"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}__{tag}.json")
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--analysis", action="store_true",
                    help="unrolled lowering for roofline-accurate costs")
    ap.add_argument("--opt", action="append", default=[],
                    help="perf override key=value (repeatable)")
    args = ap.parse_args()

    opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        try:
            opts[k] = json.loads(v)
        except json.JSONDecodeError:
            opts[k] = v

    cells = cell_plan() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         tag=args.tag, analysis=args.analysis, opts=opts)
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((arch, shape, mp, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"FAILED {len(failures)} cells: {failures}")
        return 1
    print("dry-run: all requested cells compiled")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
