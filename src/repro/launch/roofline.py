"""Roofline-term extraction from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
parsed from the *post-SPMD* HLO text: we sum the output-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (per-device bytes moved, since the HLO is
the per-device program).  MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) gives
the useful-compute ratio.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.configs.base import ArchConfig, ShapeCfg

__all__ = ["HW", "RooflineTerms", "collective_bytes", "roofline_terms",
           "model_flops", "param_count"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-class target constants."""
    peak_flops: float = 197e12     # bf16 FLOP/s per chip
    hbm_bw: float = 819e9          # B/s per chip
    ici_bw: float = 50e9           # B/s per link


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[a-z0-9\[\],{}/ ]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind output bytes summed over the HLO module.
    '-start' ops counted, '-done' skipped (same buffer)."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        kind = m.group(2).lower()
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1))
    return out


def param_count(params_shape_tree) -> int:
    import jax
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_shape_tree)))


def model_flops(cfg: ArchConfig, shape: ShapeCfg, n_params: int,
                n_active: int | None = None) -> float:
    """6*N*D (training) / 2*N*D (inference fwd) with D = processed tokens.
    MoE uses active params."""
    n = n_active if n_active is not None else n_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


def active_param_count(cfg: ArchConfig, n_params: int) -> int:
    """Approximate active params for MoE archs (experts scaled by top_k/E)."""
    if cfg.moe is None:
        return n_params
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    expert_params = cfg.n_layers * 3 * cfg.d_model * cfg.moe.d_expert * E
    if cfg.mlp == "gelu":
        expert_params = cfg.n_layers * 2 * cfg.d_model * cfg.moe.d_expert * E
    rest = n_params - expert_params
    return int(rest + expert_params * k / E)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: dict[str, int]
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline actually achievable: useful
        model FLOPs over (bound time x fleet peak)."""
        denom = self.bound_s * self.chips * HW().peak_flops
        return self.model_flops / denom if denom else float("nan")

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "flops": self.flops,
            "bytes_accessed": self.bytes_accessed, "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops, "chips": self.chips,
            "dominant": self.dominant, "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(cost: dict, hlo_text: str, chips: int,
                   mflops: float, hw: HW = HW()) -> RooflineTerms:
    """cost: compiled.cost_analysis() dict.  HLO flops/bytes there are for
    the per-device partitioned program; multiply by chips for fleet totals
    where needed (the terms below are per-step wall-clock seconds)."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    coll_total = float(sum(coll.values()))
    return RooflineTerms(
        compute_s=flops_dev / hw.peak_flops,
        memory_s=bytes_dev / hw.hbm_bw,
        collective_s=coll_total / hw.ici_bw,
        flops=flops_dev * chips,
        bytes_accessed=bytes_dev * chips,
        coll_bytes=coll,
        model_flops=mflops,
        chips=chips,
    )
