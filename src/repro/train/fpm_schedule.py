"""FPM-guided training-schedule selection — the paper's technique applied
to LM training knobs.

The paper's insight: measured speed is a non-monotonic function of problem
size, so the fastest configuration is found from a functional performance
model, not by assuming "bigger/balanced is better".  Applied here to:

* ``choose_schedule``: pick (microbatch size, padded seq len) minimising
  predicted time-per-token from a measured speed function over
  (mb, seq) — the LM analogue of PFFT-FPM-PAD's N -> N_padded;
* ``fpm_batch_partition``: HPOPTA over per-group speed functions to assign
  global-batch rows unevenly across heterogeneous pods (the straggler /
  mixed-fleet case; see runtime.straggler).
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from repro.core.fpm import FPMSet, SpeedFunction, build_fpm
from repro.core.partition import PartitionResult, partition_rows

__all__ = ["build_step_fpm", "choose_schedule", "fpm_batch_partition"]


def build_step_fpm(timer: Callable[[int, int], float],
                   mb_sizes: Sequence[int], seq_lens: Sequence[int],
                   name: str = "trainer") -> SpeedFunction:
    """timer(mb, seq) -> seconds per step; speed normalised to tokens/s via
    the FPM flop convention (x rows of length y <-> mb sequences of len seq)."""
    return build_fpm(mb_sizes, seq_lens, timer, name=name)


def choose_schedule(fpm: SpeedFunction, tokens_per_device: int,
                    seq_len: int, pad_candidates: Sequence[int]) -> tuple[int, int]:
    """Pick (microbatch, padded_seq) minimising predicted time per *useful*
    token.  Padded positions are waste, hence the seq/pad ratio weighting."""
    best = (int(fpm.xs[0]), seq_len)
    best_tpt = float("inf")
    for mb in fpm.xs:
        mb = int(mb)
        if mb * seq_len > tokens_per_device * max(int(fpm.xs[-1]), 1):
            continue
        for pad in [seq_len, *pad_candidates]:
            if pad < seq_len:
                continue
            t = fpm.time_at(mb, pad)
            if not np.isfinite(t):
                continue
            tpt = t / (mb * seq_len)  # useful tokens only
            if tpt < best_tpt:
                best_tpt, best = tpt, (mb, int(pad))
    return best


def fpm_batch_partition(fpms: FPMSet, global_batch: int, seq_len: int,
                        eps: float = 0.05) -> PartitionResult:
    """Distribute global-batch rows across device groups from their FPMs
    (paper Alg. 2 verbatim, with batch rows in place of matrix rows)."""
    return partition_rows(global_batch, fpms, eps, y=seq_len)
