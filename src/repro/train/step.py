"""train_step / serve_step builders.

train_step: microbatched grad accumulation (scan over microbatches — this
is also what bounds MoE dispatch and attention score memory), global-norm
clip, AdamW, cosine-warmup schedule, optional int8 error-feedback gradient
compression (the cross-pod bandwidth saver; the quantisation is applied to
the accumulated gradient exactly as the pod-boundary reduction would see
it).

serve_step: one decode token against the KV cache.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, TrainCfg
from repro.models import transformer as model
from repro.optim.adamw import OptState, adamw_init, adamw_update
from repro.optim.grad_compress import error_feedback_update
from repro.optim.schedule import cosine_warmup

__all__ = ["TrainState", "init_train_state", "make_train_step", "make_serve_step"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residual: Any  # error-feedback residuals (empty dict when compression off)


def init_train_state(key, cfg: ArchConfig, tcfg: TrainCfg) -> TrainState:
    params = model.init_params(key, cfg)
    opt = adamw_init(params)
    residual = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                if tcfg.grad_compress != "none" else {})
    return TrainState(params, opt, residual)


def _split_microbatches(batch, n: int):
    def rs(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by microbatches {n}"
        return jnp.moveaxis(x.reshape((n, b // n) + x.shape[1:]), 0, 0)
    return jax.tree.map(rs, batch)


def make_train_step(cfg: ArchConfig, tcfg: TrainCfg, grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_shardings``: optional pytree of NamedSharding matching params.
    Pinning the grad-accumulation carry to the parameter sharding makes the
    per-microbatch gradient reduction a reduce-scatter into FSDP shards
    instead of a replicated all-reduce (see EXPERIMENTS.md §Perf) — without
    it XLA may carry fully-replicated f32 gradients through the scan.
    """

    def loss_of(params, mb):
        loss, metrics = model.loss_fn(params, mb, cfg, remat=tcfg.remat)
        return loss, metrics

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.lax.with_sharding_constraint(tree, grad_shardings)

    def train_step(state: TrainState, batch):
        nmb = tcfg.microbatches
        mbs = _split_microbatches(batch, nmb)
        zero_g = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  state.params))

        def acc(carry, mb):
            gsum, lsum = carry
            (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                state.params, mb)
            g = pin(g)
            gsum = pin(jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g))
            return (gsum, lsum + loss), None

        (gsum, lsum), _ = jax.lax.scan(acc, (zero_g, jnp.zeros((), jnp.float32)),
                                       mbs)
        grads = jax.tree.map(lambda g: g / nmb, gsum)

        if tcfg.grad_compress != "none":
            pairs = jax.tree.map(
                functools.partial(error_feedback_update,
                                  codec=tcfg.grad_compress),
                grads, state.residual)
            grads = jax.tree.map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            residual = jax.tree.map(lambda t: t[1], pairs,
                                    is_leaf=lambda x: isinstance(x, tuple))
        else:
            residual = state.residual

        lr = cosine_warmup(state.opt.step, lr=tcfg.lr, warmup=tcfg.warmup,
                           total=tcfg.total_steps)
        params, opt, om = adamw_update(grads, state.opt, state.params, tcfg, lr)
        metrics = {"loss": lsum / nmb, "lr": lr, **om}
        return TrainState(params, opt, residual), metrics

    return train_step


def make_serve_step(cfg: ArchConfig):
    """serve_step(params, cache, tokens (B,), pos ()) -> (logits, cache)."""
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, cfg)
    return serve_step
