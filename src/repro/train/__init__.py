from repro.train.step import TrainState, make_train_step, make_serve_step, init_train_state
from repro.train.fpm_schedule import choose_schedule, fpm_batch_partition

__all__ = ["TrainState", "make_train_step", "make_serve_step",
           "init_train_state", "choose_schedule", "fpm_batch_partition"]
