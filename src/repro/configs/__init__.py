"""One config module per assigned architecture (FULL = exact assigned
config; SMOKE = reduced same-family config for CPU tests), plus the paper's
own 2-D FFT workload configs in ``paper_fft``."""

from repro.configs.base import (ArchConfig, MoECfg, MLACfg, SSMCfg, XLSTMCfg,
                                HybridCfg, ShapeCfg, SHAPES, TrainCfg)

__all__ = ["ArchConfig", "MoECfg", "MLACfg", "SSMCfg", "XLSTMCfg",
           "HybridCfg", "ShapeCfg", "SHAPES", "TrainCfg"]
