"""The paper's own workload: 2-D DFT of complex N x N signal matrices.
Problem-size sweep follows the paper (N in {128, 192, ...} step 64), scaled
to the benchmark budget of this container."""

PAPER_N_STEP = 64
PAPER_N_MIN = 128
PAPER_N_MAX = 64000          # full paper sweep (reference)
BENCH_N_VALUES = list(range(128, 1153, 64))   # CPU-budget sweep
BENCH_ABSTRACT_PROCS = 4     # paper uses p in {2, 4} groups
EPS_TOLERANCE = 0.05         # paper's 5% identical-speed tolerance
