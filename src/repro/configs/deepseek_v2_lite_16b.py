"""deepseek-v2-lite-16b [moe]: 27L d2048 16H, MLA kv_lora=512,
expert_ff=1408 vocab=102400, MoE 64 routed top-6 + 2 shared.
[arXiv:2405.04434; hf]  (Brief lists both "64e" and "160 routed"; published
v2-lite has 64 routed — we use 64, noted in DESIGN.md.)"""
from repro.configs.base import ArchConfig, MoECfg, MLACfg

FULL = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    mla=MLACfg(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
)

SMOKE = ArchConfig(
    name="deepseek-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=96, vocab=256,
    mla=MLACfg(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoECfg(n_experts=8, top_k=2, d_expert=96, n_shared=1),
)
