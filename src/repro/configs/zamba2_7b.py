"""zamba2-7b [hybrid]: 81 Mamba2 blocks d3584 + shared attention block
(32H, MHA, d_ff=14336) every 6 blocks; ssm_state=64.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ArchConfig, SSMCfg, HybridCfg

FULL = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab=32000,
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid=HybridCfg(shared_attn_every=6),
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256,
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    hybrid=HybridCfg(shared_attn_every=2),
)
