"""Architecture / run configuration schema.

One ``ArchConfig`` fully describes a model; ``src/repro/configs/<id>.py``
each export ``FULL`` (the exact assigned config) and ``SMOKE`` (a reduced
same-family config for CPU tests).  Shapes are the assigned input-shape set.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["MoECfg", "MLACfg", "SSMCfg", "XLSTMCfg", "HybridCfg", "ArchConfig",
           "ShapeCfg", "SHAPES", "TrainCfg"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert FFN hidden dim
    n_shared: int = 0          # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64         # mamba2 SSD head dim
    chunk: int = 256
    remat_chunk: bool = False  # rematerialize intra-chunk SSD tensors in bwd


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    slstm_every: int = 2       # one sLSTM block every k blocks (rest mLSTM)
    proj_factor: float = 2.0
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    shared_attn_every: int = 6  # shared attention block every k SSM blocks


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    qkv_bias: bool = False
    rope_mode: Literal["full", "half", "partial25", "none"] = "full"
    rope_theta: float = 10000.0
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssm: SSMCfg | None = None
    xlstm: XLSTMCfg | None = None
    hybrid: HybridCfg | None = None
    encoder_only: bool = False
    modality: Literal["text", "vision", "audio"] = "text"
    n_prefix_embeds: int = 0             # VLM patch / audio frame stub length
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def supports_decode(self) -> bool:
        return not self.encoder_only

    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context (state-based, no dense KV)?"""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 8          # grad-accumulation steps per train_step
    remat: bool = True
    grad_compress: Literal["none", "int8", "topk"] = "none"
    seed: int = 0
