"""qwen2.5-3b [dense]: 36L d2048 16H (GQA kv=2) d_ff=11008 vocab=151936,
QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048, n_heads=16,
    n_kv_heads=2, d_ff=11008, vocab=151936, qkv_bias=True,
)

SMOKE = ArchConfig(
    name="qwen-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=256, qkv_bias=True,
)
