"""xlstm-125m [ssm]: 12L d768 4H, alternating sLSTM + mLSTM blocks
(d_ff=0: blocks carry their own projections). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig, XLSTMCfg

FULL = ArchConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, rope_mode="none",
    xlstm=XLSTMCfg(slstm_every=2, chunk=64),
)

SMOKE = ArchConfig(
    name="xlstm-smoke", family="ssm", n_layers=2, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=0, vocab=256, rope_mode="none",
    xlstm=XLSTMCfg(slstm_every=2, chunk=16),
)
