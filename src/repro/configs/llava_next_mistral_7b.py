"""llava-next-mistral-7b [vlm]: Mistral backbone 32L d4096 32H (GQA kv=8)
d_ff=14336 vocab=32000; anyres vision frontend is a STUB — input_specs
provides precomputed patch embeddings (576 base-res patches).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000, modality="vision",
    n_prefix_embeds=576,
)

SMOKE = ArchConfig(
    name="llava-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=256, modality="vision", n_prefix_embeds=16,
)
