"""stablelm-3b [dense]: 32L d2560 32H (MHA kv=32) d_ff=6912 vocab=50304,
partial (25%) rotary, layernorm. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=6912, vocab=50304, rope_mode="partial25",
    norm="layernorm",
)

SMOKE = ArchConfig(
    name="stablelm-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab=256, rope_mode="partial25", norm="layernorm",
)
