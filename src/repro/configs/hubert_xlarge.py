"""hubert-xlarge [audio]: encoder-only, 48L d1280 16H (MHA) d_ff=5120
vocab=504 (masked-prediction cluster targets); the conv feature frontend is
a STUB — input_specs provides precomputed frame embeddings.
[arXiv:2106.07447; unverified]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504, mlp="gelu",
    norm="layernorm", rope_mode="none", encoder_only=True, modality="audio",
)

SMOKE = ArchConfig(
    name="hubert-smoke", family="audio", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=64, mlp="gelu", norm="layernorm",
    rope_mode="none", encoder_only=True, modality="audio",
)
