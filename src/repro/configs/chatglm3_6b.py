"""chatglm3-6b [dense]: 28L d4096 32H (GQA kv=2) d_ff=13696 vocab=65024,
2d RoPE (rotate half the head dims). [arXiv:2406.12793; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096, n_heads=32,
    n_kv_heads=2, d_ff=13696, vocab=65024, rope_mode="half",
)

SMOKE = ArchConfig(
    name="chatglm3-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=256, rope_mode="half",
)
