"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory with recurrent gating, sequential scan).

mLSTM is linear-attention-like, so train/prefill use a chunkwise algorithm
(intra-chunk quadratic with log-gate decay matrix, inter-chunk recurrence of
the (hd x hd) matrix memory); decode is an O(1) state update.  sLSTM has a
true nonlinear recurrence through the hidden state (recurrent weights R), so
it is a lax.scan over time in all modes — this is inherent to the
architecture, not an implementation shortcut.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import XLSTMCfg
from repro.models.layers import dense_init, dense, norm_init, apply_norm

__all__ = [
    "mlstm_init", "mlstm_apply", "mlstm_init_cache",
    "slstm_init", "slstm_apply", "slstm_init_cache",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d: int, n_heads: int, hd: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, n_heads * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, n_heads * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, n_heads * hd, dtype=dtype),
        "wi": dense_init(ks[3], d, n_heads, dtype=dtype),   # input gate (exp)
        "wf": dense_init(ks[4], d, n_heads, dtype=dtype),   # forget gate
        "out_norm": norm_init(n_heads * hd),
        "wo": dense_init(ks[5], n_heads * hd, d, dtype=dtype),
    }


def mlstm_init_cache(batch: int, n_heads: int, hd: int):
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def _mlstm_chunk(state, inputs, hd: int):
    """One chunk of the stabilised chunkwise mLSTM.
    q,k,v: (B,L,H,hd); logi,logf: (B,L,H)."""
    C, n, m = state
    q, k, v, logi, logf = inputs
    f32 = jnp.float32
    q, k, v = q.astype(f32) / np.sqrt(hd), k.astype(f32), v.astype(f32)
    cumf = jnp.cumsum(logf, axis=1)                     # (B,L,H) inclusive
    # log weight of source s at target t (s<=t): cumf_t - cumf_s + logi_s
    lw = cumf[:, :, None, :] - cumf[:, None, :, :] + logi[:, None, :, :]
    L = q.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
    lw = jnp.where(mask, lw, -jnp.inf)
    # carried-state weight at target t: cumf_t + m  (m is the running max)
    lw_state = cumf + m[:, None, :]
    m_new_t = jnp.maximum(lw.max(axis=2), lw_state)     # (B,L,H) per-target max
    w = jnp.exp(lw - m_new_t[:, :, None, :])            # (B,t,s,H)
    w_state = jnp.exp(lw_state - m_new_t)               # (B,L,H)

    qk = jnp.einsum("btkh,bskh->btsk", q.reshape(q.shape[:2] + (-1, hd)),
                    k.reshape(k.shape[:2] + (-1, hd)))  # (B,t,s,H)
    num_intra = jnp.einsum("btsh,bshd->bthd", qk * w, v)
    num_state = jnp.einsum("bthd,bhde->bthe", q, C) * w_state[..., None]
    # Normaliser: n_t = sum_s w_ts k_s accumulated, then dotted with q_t.
    ksum = jnp.einsum("btsh,bshd->bthd", w, k)          # (B,t,H,hd)
    den = jnp.einsum("bthd,bthd->bth", q, ksum) + \
          jnp.einsum("bthd,bhd->bth", q, n) * w_state
    h = (num_intra + num_state) / jnp.maximum(jnp.abs(den), 1.0)[..., None]

    # State carry to next chunk.
    mc = m_new_t[:, -1]                                  # (B,H) new running max
    dec_state = jnp.exp(cumf[:, -1] + m - mc)            # (B,H)
    src_w = jnp.exp(cumf[:, -1][:, None, :] - cumf + logi - mc[:, None, :])
    C_new = dec_state[..., None, None] * C + \
        jnp.einsum("bsh,bshd,bshe->bhde", src_w, k, v)
    n_new = dec_state[..., None] * n + jnp.einsum("bsh,bshd->bhd", src_w, k)
    return (C_new, n_new, mc), h


def mlstm_apply(p, x, *, n_heads: int, hd: int, chunk: int = 64, cache=None):
    B, T, _ = x.shape
    q = dense(p["wq"], x).reshape(B, T, n_heads, hd)
    k = dense(p["wk"], x).reshape(B, T, n_heads, hd)
    v = dense(p["wv"], x).reshape(B, T, n_heads, hd)
    logi = dense(p["wi"], x).astype(jnp.float32)         # log input gate
    logf = jax.nn.log_sigmoid(dense(p["wf"], x).astype(jnp.float32))

    st = (cache["C"], cache["n"], cache["m"]) if cache is not None else \
        (jnp.zeros((B, n_heads, hd, hd), jnp.float32),
         jnp.zeros((B, n_heads, hd), jnp.float32),
         jnp.full((B, n_heads), -1e30, jnp.float32))

    Lc = min(chunk, T)
    nc = T // Lc
    assert nc * Lc == T, "sequence must divide by mlstm chunk"

    def rs(a):
        return jnp.moveaxis(a.reshape((B, nc, Lc) + a.shape[2:]), 1, 0)

    (C, n, m), hs = jax.lax.scan(
        lambda s, i: _mlstm_chunk(s, i, hd), st,
        (rs(q), rs(k), rs(v), rs(logi), rs(logf)))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, n_heads * hd).astype(x.dtype)
    h = apply_norm(p["out_norm"], h)
    out = dense(p["wo"], h)
    new_cache = {"C": C, "n": n, "m": m} if cache is not None else None
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d: int, n_heads: int, hd: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    # 4 gates (i, f, z, o), feedforward W and block-diagonal recurrent R.
    return {
        "w": dense_init(ks[0], d, 4 * n_heads * hd, dtype=dtype),
        "r": (jax.random.normal(ks[1], (n_heads, hd, 4 * hd), jnp.float32)
              / np.sqrt(hd)).astype(dtype),
        "out_norm": norm_init(n_heads * hd),
        "wo": dense_init(ks[2], n_heads * hd, d, dtype=dtype),
    }


def slstm_init_cache(batch: int, n_heads: int, hd: int):
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((batch, n_heads, hd), jnp.float32)}


def slstm_apply(p, x, *, n_heads: int, hd: int, cache=None):
    B, T, _ = x.shape
    wx = dense(p["w"], x).reshape(B, T, n_heads, 4 * hd).astype(jnp.float32)
    r = p["r"].astype(jnp.float32)

    def step(state, wxt):
        c, n, h, m = state
        rec = jnp.einsum("bkd,kdf->bkf", h, r)            # (B,H,4hd)
        g = wxt + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        # stabilised exponential gating
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        i = jnp.exp(gi - m_new)
        f = jnp.exp(logf + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c_new = f * c + i * z
        n_new = f * n + i
        h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    st = ((cache["c"], cache["n"], cache["h"], cache["m"]) if cache is not None
          else tuple(jnp.zeros((B, n_heads, hd), jnp.float32) for _ in range(4)))
    (c, n, h, m), hs = jax.lax.scan(step, st, jnp.moveaxis(wx, 1, 0))
    out = jnp.moveaxis(hs, 0, 1).reshape(B, T, n_heads * hd).astype(x.dtype)
    out = dense(p["wo"], apply_norm(p["out_norm"], out))
    new_cache = ({"c": c, "n": n, "h": h, "m": m} if cache is not None else None)
    return out, new_cache
