"""Generic LM assembly for every assigned architecture family.

* dense / moe / vlm / audio: homogeneous transformer layers, scanned
  (scan-over-layers keeps the HLO size O(1) in depth) with optional remat.
* ssm (xLSTM): alternating mLSTM/sLSTM blocks (python loop — 12 layers).
* hybrid (Zamba2): Mamba2 backbone scanned in groups, with a *shared*
  attention block (one parameter set, distinct KV cache per application)
  applied every ``shared_attn_every`` blocks.

All entry points are pure functions over plain-dict param pytrees:

    init_params(key, cfg)
    forward(params, batch, cfg)                  -> (hidden, aux)
    loss_fn(params, batch, cfg)                  -> (loss, metrics)
    init_cache(cfg, batch, max_len)
    prefill(params, batch, cfg, cache)           -> (last_logits, cache)
    decode_step(params, cache, tokens, pos, cfg) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (apply_mlp, apply_norm, dense, dense_init,
                                 embed_init, mlp_init, norm_init)
from repro.models.sharding import constrain_batch

__all__ = ["init_params", "loss_fn", "init_cache", "prefill", "decode_step",
           "forward", "Q_CHUNK"]

Q_CHUNK = 512  # query-chunk for causal attention (memory bound at 32k)


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# transformer layer (dense / moe / mla)
# ---------------------------------------------------------------------------

def _init_tf_layer(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": norm_init(d, cfg.norm), "ln2": norm_init(d, cfg.norm)}
    if cfg.mla is not None:
        m = cfg.mla
        p["attn"] = attn.mla_init(ks[0], d, cfg.n_heads, kv_lora=m.kv_lora_rank,
                                  nope=m.qk_nope_dim, rope=m.qk_rope_dim,
                                  v_dim=m.v_head_dim, dtype=_dt(cfg))
    else:
        p["attn"] = attn.gqa_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                  bias=cfg.qkv_bias, dtype=_dt(cfg))
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(ks[1], d, cfg.moe, mlp_kind=cfg.mlp, dtype=_dt(cfg))
    else:
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, kind=cfg.mlp, dtype=_dt(cfg))
    return p


def _apply_tf_layer(p, x, cfg: ArchConfig, *, cache=None, pos0=0,
                    causal=True, q_chunk=Q_CHUNK):
    h = apply_norm(p["ln1"], x, cfg.norm)
    if cfg.mla is not None:
        m = cfg.mla
        a, new_cache = attn.mla_apply(p["attn"], h, n_heads=cfg.n_heads,
                                      kv_lora=m.kv_lora_rank, nope=m.qk_nope_dim,
                                      rope=m.qk_rope_dim, v_dim=m.v_head_dim,
                                      rope_theta=cfg.rope_theta, q_chunk=q_chunk,
                                      cache=cache, pos0=pos0)
    else:
        a, new_cache = attn.gqa_apply(p["attn"], h, n_heads=cfg.n_heads,
                                      n_kv=cfg.n_kv_heads, hd=cfg.hd,
                                      rope_mode=cfg.rope_mode,
                                      rope_theta=cfg.rope_theta, causal=causal,
                                      q_chunk=q_chunk, cache=cache, pos0=pos0)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        f, aux = moe_mod.moe_apply(p["moe"], h, cfg.moe, mlp_kind=cfg.mlp)
    else:
        f, aux = apply_mlp(p["mlp"], h, kind=cfg.mlp), jnp.zeros((), jnp.float32)
    return constrain_batch(x + f), new_cache, aux


def _layer_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.mla is not None:
        return attn.mla_init_cache(batch, max_len, cfg.mla.kv_lora_rank,
                                   cfg.mla.qk_rope_dim, _dt(cfg))
    return attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, _dt(cfg))


# ---------------------------------------------------------------------------
# xLSTM stack
# ---------------------------------------------------------------------------

def _xlstm_block_kinds(cfg: ArchConfig) -> list[str]:
    k = cfg.xlstm.slstm_every
    return ["slstm" if (i % k == k - 1) else "mlstm" for i in range(cfg.n_layers)]


def _init_xlstm(key, cfg: ArchConfig):
    hd = cfg.hd
    blocks = []
    for i, kind in enumerate(_xlstm_block_kinds(cfg)):
        kk = jax.random.fold_in(key, i)
        ln = norm_init(cfg.d_model, cfg.norm)
        if kind == "mlstm":
            blocks.append({"ln": ln,
                           "core": xlstm_mod.mlstm_init(kk, cfg.d_model,
                                                        cfg.n_heads, hd, _dt(cfg))})
        else:
            blocks.append({"ln": ln,
                           "core": xlstm_mod.slstm_init(kk, cfg.d_model,
                                                        cfg.n_heads, hd, _dt(cfg))})
    return blocks


def _apply_xlstm(params, x, cfg: ArchConfig, caches=None):
    new_caches = [] if caches is not None else None
    kinds = _xlstm_block_kinds(cfg)
    for i, blk in enumerate(params["blocks"]):
        h = apply_norm(blk["ln"], x, cfg.norm)
        c = caches[i] if caches is not None else None
        if kinds[i] == "mlstm":
            o, nc = xlstm_mod.mlstm_apply(blk["core"], h, n_heads=cfg.n_heads,
                                          hd=cfg.hd, chunk=cfg.xlstm.chunk, cache=c)
        else:
            o, nc = xlstm_mod.slstm_apply(blk["core"], h, n_heads=cfg.n_heads,
                                          hd=cfg.hd, cache=c)
        x = constrain_batch(x + o)
        if new_caches is not None:
            new_caches.append(nc)
    return x, new_caches, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# hybrid (Zamba2): scanned Mamba2 groups + shared attention block
# ---------------------------------------------------------------------------

def _hybrid_layout(cfg: ArchConfig):
    g = cfg.hybrid.shared_attn_every
    n_groups = int(np.ceil(cfg.n_layers / g))
    padded = n_groups * g
    return g, n_groups, padded


def _init_hybrid(key, cfg: ArchConfig):
    g, n_groups, padded = _hybrid_layout(cfg)
    ks = jax.random.split(key, 3)
    mamba_keys = jax.random.split(ks[0], padded)
    mamba = jax.vmap(lambda k: ssm_mod.mamba2_init(k, cfg.d_model, cfg.ssm,
                                                   _dt(cfg)))(mamba_keys)
    # reshape stacked leaves to (n_groups, g, ...)
    mamba = jax.tree.map(lambda a: a.reshape((n_groups, g) + a.shape[1:]), mamba)
    shared = {
        "ln": norm_init(cfg.d_model, cfg.norm),
        "attn": attn.gqa_init(ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, dtype=_dt(cfg)),
        "ln2": norm_init(cfg.d_model, cfg.norm),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, kind=cfg.mlp, dtype=_dt(cfg)),
    }
    return {"mamba": mamba, "shared": shared}


def _hybrid_valid(cfg: ArchConfig) -> jnp.ndarray:
    g, n_groups, padded = _hybrid_layout(cfg)
    return jnp.asarray(np.arange(padded).reshape(n_groups, g) < cfg.n_layers)


def _apply_hybrid(params, x, cfg: ArchConfig, caches=None, pos0=0,
                  q_chunk=Q_CHUNK, remat=False, scan_groups=True):
    """caches: {'attn': stacked (n_groups, ...), 'mamba': stacked (n_groups, g, ...)}"""
    g, n_groups, _ = _hybrid_layout(cfg)
    shared = params["shared"]

    def group_body(x, xs):
        mparams, valid, cache_g = xs
        # shared attention block (pre-norm attn + mlp), params closed over
        h = apply_norm(shared["ln"], x, cfg.norm)
        a, new_attn_cache = attn.gqa_apply(
            shared["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            hd=cfg.hd, rope_mode=cfg.rope_mode, rope_theta=cfg.rope_theta,
            causal=True, q_chunk=q_chunk,
            cache=cache_g["attn"] if cache_g is not None else None, pos0=pos0)
        x = x + a
        x = constrain_batch(
            x + apply_mlp(shared["mlp"], apply_norm(shared["ln2"], x, cfg.norm),
                          kind=cfg.mlp))

        new_mamba = [] if cache_g is not None else None
        for j in range(g):
            pj = jax.tree.map(lambda a: a[j], mparams)
            cj = (jax.tree.map(lambda a: a[j], cache_g["mamba"])
                  if cache_g is not None else None)
            o, nc = ssm_mod.mamba2_apply(pj, x, cfg.ssm, cache=cj)
            x = constrain_batch(jnp.where(valid[j], x + o, x))
            if new_mamba is not None:
                new_mamba.append(jax.tree.map(
                    lambda new, old: jnp.where(valid[j], new, old), nc, cj))
        new_cache = None
        if cache_g is not None:
            new_cache = {"attn": new_attn_cache,
                         "mamba": jax.tree.map(lambda *a: jnp.stack(a), *new_mamba)}
        return x, new_cache

    body = jax.checkpoint(group_body) if remat else group_body
    if caches is None:
        if not scan_groups:
            _, n_groups, _ = _hybrid_layout(cfg)
            for gi in range(n_groups):
                mp = jax.tree.map(lambda a: a[gi], params["mamba"])
                x, _ = body(x, (mp, _hybrid_valid(cfg)[gi], None))
            return x, None, jnp.zeros((), jnp.float32)
        def scan_fn(x, xs):
            mp, v = xs
            x, _ = body(x, (mp, v, None))
            return x, None
        x, _ = jax.lax.scan(scan_fn, x, (params["mamba"], _hybrid_valid(cfg)))
        return x, None, jnp.zeros((), jnp.float32)

    if not scan_groups:
        _, n_groups, _ = _hybrid_layout(cfg)
        ncs = []
        for gi in range(n_groups):
            mp = jax.tree.map(lambda a: a[gi], params["mamba"])
            cg = jax.tree.map(lambda a: a[gi], caches)
            x, nc = body(x, (mp, _hybrid_valid(cfg)[gi], cg))
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
        return x, new_caches, jnp.zeros((), jnp.float32)

    def scan_fn(x, xs):
        mp, v, cg = xs
        x, nc = body(x, (mp, v, cg))
        return x, nc
    x, new_caches = jax.lax.scan(scan_fn, x, (params["mamba"], _hybrid_valid(cfg), caches))
    return x, new_caches, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 5)
    p: dict[str, Any] = {"embed": embed_init(ks[0], cfg.vocab, cfg.d_model, _dt(cfg)),
                         "final_norm": norm_init(cfg.d_model, cfg.norm)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab, dtype=_dt(cfg))
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        lk = jax.random.split(ks[2], cfg.n_layers)
        p["layers"] = jax.vmap(lambda k: _init_tf_layer(k, cfg))(lk)
    elif cfg.family == "ssm":
        p["blocks"] = _init_xlstm(ks[2], cfg)
    elif cfg.family == "hybrid":
        p.update(_init_hybrid(ks[2], cfg))
    else:
        raise ValueError(cfg.family)
    if cfg.modality == "audio":
        p["mask_embed"] = (jax.random.normal(ks[3], (cfg.d_model,), jnp.float32)
                           * 0.02).astype(_dt(cfg))
    return p


def _embed_inputs(params, batch, cfg: ArchConfig):
    """Returns (x, loss_mask): token/frame/patch embeddings."""
    if cfg.modality == "audio":
        x = batch["features"].astype(_dt(cfg))
        if "mask" in batch:
            m = batch["mask"][..., None]
            x = jnp.where(m, params["mask_embed"][None, None, :], x)
        return x
    tok = params["embed"]["table"][batch["tokens"]]
    if cfg.modality == "vision" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(_dt(cfg)), tok], axis=1)
        return x
    return tok


def forward(params, batch, cfg: ArchConfig, *, remat: bool = False,
            q_chunk: int | None = Q_CHUNK, scan_layers: bool = True):
    """Full-sequence forward (train / encoder / prefill-style). Returns
    (hidden (B,T,d), aux_loss).  ``scan_layers=False`` unrolls the layer
    loop — used by the dry-run's analysis lowering, where XLA's
    cost_analysis must see every layer (while-loop bodies are counted once).
    """
    x = constrain_batch(_embed_inputs(params, batch, cfg))
    causal = not cfg.encoder_only
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(x, lp):
            x, _, aux = _apply_tf_layer(lp, x, cfg, causal=causal, q_chunk=q_chunk)
            return x, aux
        body_fn = jax.checkpoint(body) if remat else body
        if scan_layers:
            x, auxs = jax.lax.scan(lambda c, lp: body_fn(c, lp), x, params["layers"])
            aux = auxs.sum()
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, a = body_fn(x, lp)
                aux = aux + a
    elif cfg.family == "ssm":
        x, _, aux = _apply_xlstm(params, x, cfg)
    else:
        x, _, aux = _apply_hybrid(params, x, cfg, q_chunk=q_chunk, remat=remat,
                                  scan_groups=scan_layers)
    return apply_norm(params["final_norm"], x, cfg.norm), aux


def logits_fn(params, hidden, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return hidden @ params["embed"]["table"].T
    return dense(params["lm_head"], hidden)


def loss_fn(params, batch, cfg: ArchConfig, *, remat: bool = True,
            vocab_chunk: int = 512, q_chunk: int | None = Q_CHUNK,
            scan_layers: bool = True):
    """Causal-LM CE (decoder) or masked-prediction CE (encoder).  The vocab
    projection + CE run seq-chunked so (T, V) f32 logits never materialise."""
    hidden, aux = forward(params, batch, cfg, remat=remat, q_chunk=q_chunk,
                          scan_layers=scan_layers)
    targets = batch["targets"]
    if cfg.modality == "vision":
        pad = jnp.full(batch["patches"].shape[:2], -1, targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    B, T, d = hidden.shape
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"]["w"])

    hidden2 = hidden.reshape(B * T, d)
    tflat = targets.reshape(B * T)
    if vocab_chunk is None:  # analysis mode: single full-logits CE
        vocab_chunk = B * T
    n_chunks = (B * T + vocab_chunk - 1) // vocab_chunk
    pad_n = n_chunks * vocab_chunk - B * T
    if pad_n:
        hidden2 = jnp.pad(hidden2, ((0, pad_n), (0, 0)))
        tflat = jnp.pad(tflat, (0, pad_n), constant_values=-1)

    # remat the per-chunk CE: the (chunk, V) f32 logits are recomputed in
    # backward instead of being saved per map step (~10 GB/device on dbrx
    # train_4k — §Perf iter D2), and the chunk axis (batch-major) is pinned
    # to the data axes so logits chunks never replicate.
    @jax.checkpoint
    def chunk_ce(args):
        h, t = args
        lg = (h @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, jnp.maximum(t, 0)[:, None], axis=-1)[:, 0]
        valid = t >= 0
        return jnp.where(valid, lse - tgt, 0.0).sum(), valid.sum()

    hs = constrain_batch(hidden2.reshape(n_chunks, vocab_chunk, d))
    ts = constrain_batch(tflat.reshape(n_chunks, vocab_chunk))
    sums, counts = jax.lax.map(chunk_ce, (hs, ts))
    loss = sums.sum() / jnp.maximum(counts.sum(), 1)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux, "tokens": counts.sum()}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        caches = [_layer_cache(cfg, batch, max_len) for _ in range(cfg.n_layers)]
        return jax.tree.map(lambda *a: jnp.stack(a), *caches)
    if cfg.family == "ssm":
        kinds = _xlstm_block_kinds(cfg)
        return [xlstm_mod.mlstm_init_cache(batch, cfg.n_heads, cfg.hd)
                if k == "mlstm" else
                xlstm_mod.slstm_init_cache(batch, cfg.n_heads, cfg.hd)
                for k in kinds]
    if cfg.family == "hybrid":
        g, n_groups, _ = _hybrid_layout(cfg)
        attn_c = [attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd,
                                      _dt(cfg)) for _ in range(n_groups)]
        mamba_c = [[ssm_mod.mamba2_init_cache(batch, cfg.d_model, cfg.ssm)
                    for _ in range(g)] for _ in range(n_groups)]
        return {
            "attn": jax.tree.map(lambda *a: jnp.stack(a), *attn_c),
            "mamba": jax.tree.map(
                lambda *a: jnp.stack(a),
                *[jax.tree.map(lambda *b: jnp.stack(b), *row) for row in mamba_c]),
        }
    raise ValueError(cfg.family)


def _stacked_layer_step(params, x, cfg, caches, pos0, q_chunk,
                        scan_layers=True):
    """Scan over stacked transformer layers threading per-layer caches."""
    def body(x, xs):
        lp, c = xs
        x, nc, _ = _apply_tf_layer(lp, x, cfg, cache=c, pos0=pos0, q_chunk=q_chunk)
        return x, nc
    if scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
        return x, new_caches
    ncs = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        c = jax.tree.map(lambda a: a[i], caches)
        x, nc = body(x, (lp, c))
        ncs.append(nc)
    return x, jax.tree.map(lambda *a: jnp.stack(a), *ncs)


def prefill(params, batch, cfg: ArchConfig, cache, *, q_chunk: int | None = Q_CHUNK,
            scan_layers: bool = True):
    """Process the prompt, filling the cache from position 0.  Returns
    (last-position logits, cache)."""
    x = _embed_inputs(params, batch, cfg)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        x, cache = _stacked_layer_step(params, x, cfg, cache, 0, q_chunk,
                                       scan_layers)
    elif cfg.family == "ssm":
        x, cache, _ = _apply_xlstm(params, x, cfg, caches=cache)
    else:
        x, cache, _ = _apply_hybrid(params, x, cfg, caches=cache, pos0=0,
                                    q_chunk=q_chunk, scan_groups=scan_layers)
    h = apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    return logits_fn(params, h, cfg)[:, 0], cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, *,
                scan_layers: bool = True):
    """One decode step: tokens (B,) int32, pos scalar int32 (current length).
    Returns (logits (B, V), new cache)."""
    batch = {"tokens": tokens[:, None]}
    x = _embed_inputs(params, batch, cfg)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        x, cache = _stacked_layer_step(params, x, cfg, cache, pos, None,
                                       scan_layers)
    elif cfg.family == "ssm":
        x, cache, _ = _apply_xlstm(params, x, cfg, caches=cache)
    else:
        x, cache, _ = _apply_hybrid(params, x, cfg, caches=cache, pos0=pos,
                                    q_chunk=None, scan_groups=scan_layers)
    h = apply_norm(params["final_norm"], x, cfg.norm)
    return logits_fn(params, h, cfg)[:, 0], cache
