"""Mamba2 (SSD) blocks — chunked scan for train/prefill, O(1)-state decode.

State-space recurrence with scalar-per-head decay (Mamba2's SSD form):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)      h: (H, P, N)
    y_t = C_t · h_t + D * x_t

Train/prefill uses the chunkwise algorithm (intra-chunk quadratic in log-
decay space + inter-chunk recurrence over chunk states), so sequence memory
is O(T * chunk) and the 500k-decode shape needs only the (H, P, N) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMCfg
from repro.models.layers import dense_init, dense, norm_init, apply_norm

__all__ = ["mamba2_init", "mamba2_apply", "mamba2_init_cache"]


def _dims(d_model: int, cfg: SSMCfg):
    d_inner = cfg.expand * d_model
    H = d_inner // cfg.head_dim
    return d_inner, H


def mamba2_init(key, d_model: int, cfg: SSMCfg, dtype=jnp.bfloat16):
    d_inner, H = _dims(d_model, cfg)
    N = cfg.d_state
    conv_ch = d_inner + 2 * N  # x-part + B + C go through the short conv
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner + 2 * N + H, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_ch), jnp.float32)
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": norm_init(d_inner),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype=dtype),
    }


def mamba2_init_cache(batch: int, d_model: int, cfg: SSMCfg, dtype=jnp.float32):
    d_inner, H = _dims(d_model, cfg)
    N = cfg.d_state
    conv_ch = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, cfg.head_dim, N), dtype),
    }


def _split(p, x, d_inner: int, N: int, H: int):
    zxbcdt = dense(p["in_proj"], x)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = jax.nn.softplus(zxbcdt[..., -H:].astype(jnp.float32) + p["dt_bias"])
    return z, xbc, dt


def _causal_conv(p, xbc, cfg: SSMCfg, conv_state=None):
    """Depthwise causal conv width d_conv; returns (out, new_state)."""
    B = xbc.shape[0]
    if conv_state is None:
        pad = jnp.zeros((B, cfg.d_conv - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    w = p["conv_w"]
    out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(cfg.d_conv))
    out = jax.nn.silu(out + p["conv_b"])
    new_state = full[:, -(cfg.d_conv - 1):] if cfg.d_conv > 1 else pad
    return out, new_state


def _ssd_chunked(xh, Bm, Cm, dt, A, chunk: int, h0, remat: bool = False):
    """Chunked SSD scan.
    xh: (B,T,H,P); Bm/Cm: (B,T,N); dt: (B,T,H); A: (H,) (positive decay rate);
    h0: (B,H,P,N) initial state.  Returns (y, h_final)."""
    Bsz, T, H, P = xh.shape
    N = Bm.shape[-1]
    nc = T // chunk
    assert nc * chunk == T, "sequence must divide by ssm chunk"
    f32 = jnp.float32

    def per_chunk(h, inputs):
        xc, bc, cc, dtc = inputs          # (B,L,H,P), (B,L,N), (B,L,N), (B,L,H)
        L = xc.shape[1]
        dA = dtc * (-A)                   # (B,L,H) log-decay per step (negative)
        cum = jnp.cumsum(dA, axis=1)      # (B,L,H) inclusive
        # Intra-chunk: y_t += sum_{s<=t} C_t·B_s exp(cum_t - cum_s) dt_s x_s
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,L_t,L_s,H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        # mask in log space BEFORE exp: exp of the (positive) upper-triangle
        # entries overflows and poisons the backward pass via inf * 0.
        seg = jnp.where(mask[None, :, :, None], seg, -jnp.inf)
        decay = jnp.exp(seg)
        cb = jnp.einsum("btn,bsn->bts", cc.astype(f32), bc.astype(f32))
        w = cb[..., None] * decay * dtc[:, None, :, :]         # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xc.astype(f32))
        # Inter-chunk: y_t += C_t · (exp(cum_t) * h_in)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", cc.astype(f32), h,
                             jnp.exp(cum))
        # State update: h_out = exp(cum_L) h_in + sum_s exp(cum_L - cum_s) dt_s B_s x_s
        tot = cum[:, -1]                                       # (B,H)
        rdec = jnp.exp(tot[:, None, :] - cum) * dtc            # (B,L,H)
        h_new = (jnp.exp(tot)[:, :, None, None] * h
                 + jnp.einsum("blh,bln,blhp->bhpn", rdec, bc.astype(f32),
                              xc.astype(f32)))
        return h_new, y_intra + y_inter

    def rs(a):  # (B, T, ...) -> (nc, B, chunk, ...)
        return jnp.moveaxis(a.reshape((Bsz, nc, chunk) + a.shape[2:]), 1, 0)

    body = jax.checkpoint(per_chunk) if remat else per_chunk
    h_fin, ys = jax.lax.scan(body, h0.astype(f32),
                             (rs(xh), rs(Bm), rs(Cm), rs(dt)))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T, H, P)
    return y, h_fin


def mamba2_apply(p, x, cfg: SSMCfg, *, cache=None):
    """x: (B, T, d_model) -> (B, T, d_model).  cache: {'conv','ssm'} for
    decode/prefill; T==1 decode takes the fast recurrent path."""
    Bsz, T, d_model = x.shape
    d_inner, H = _dims(d_model, cfg)
    N, P = cfg.d_state, cfg.head_dim
    z, xbc, dt = _split(p, x, d_inner, N, H)
    A = jnp.exp(p["A_log"])

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(p, xbc, cfg, conv_state)
    xpart = xbc[..., :d_inner].reshape(Bsz, T, H, P)
    Bm = xbc[..., d_inner:d_inner + N]
    Cm = xbc[..., d_inner + N:]

    if cache is not None and T == 1:
        h = cache["ssm"]
        dA = jnp.exp(-dt[:, 0] * A)                              # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0].astype(jnp.float32),
                         xpart[:, 0].astype(jnp.float32))
        h_new = dA[:, :, None, None] * h + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h_new)
        y = y[:, None] + p["D"][None, None, :, None] * xpart.astype(jnp.float32)
    else:
        h0 = (cache["ssm"] if cache is not None
              else jnp.zeros((Bsz, H, P, N), jnp.float32))
        y, h_new = _ssd_chunked(xpart, Bm, Cm, dt, A, min(cfg.chunk, T), h0,
                                remat=cfg.remat_chunk)
        y = y + p["D"][None, None, :, None] * xpart.astype(jnp.float32)

    y = y.reshape(Bsz, T, d_inner).astype(x.dtype)
    y = apply_norm(p["out_norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y)
    new_cache = ({"conv": new_conv.astype(cache["conv"].dtype), "ssm": h_new}
                 if cache is not None else None)
    return out, new_cache
