"""Shared model layers: norms, RoPE variants, MLPs, embeddings.

Parameters are plain nested dicts of jnp arrays; every layer is a pair of
``init_*`` / ``apply_*`` pure functions.  Compute dtype is bf16 with f32
accumulation for norms/softmax (standard large-model practice).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init", "dense", "norm_init", "apply_norm", "rope_freqs",
    "apply_rope", "mlp_init", "apply_mlp", "embed_init",
]


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None, dtype=jnp.bfloat16):
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, kind: str = "rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


def rope_freqs(hd: int, mode: str, theta: float = 10000.0) -> tuple[int, np.ndarray]:
    """Return (n_rot, inv_freq) — how many leading dims of the head get
    rotated and their inverse frequencies.

    mode: 'full' (all dims), 'half' (chatglm-style 2d rope: first half),
    'partial25' (stablelm-style: first quarter), 'none'.
    """
    frac = {"full": 1.0, "half": 0.5, "partial25": 0.25, "none": 0.0}[mode]
    n_rot = int(hd * frac) // 2 * 2
    if n_rot == 0:
        return 0, np.zeros((0,), np.float32)
    inv = 1.0 / (theta ** (np.arange(0, n_rot, 2, dtype=np.float32) / n_rot))
    return n_rot, inv


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, mode: str,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    n_rot, inv = rope_freqs(hd, mode, theta)
    if n_rot == 0:
        return x
    inv = jnp.asarray(inv)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., T, n_rot/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    xr = x[..., :n_rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    rot = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([rot, x[..., n_rot:]], axis=-1)


def mlp_init(key, d: int, d_ff: int, kind: str = "swiglu", dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wg": dense_init(ks[0], d, d_ff, dtype=dtype),
            "wu": dense_init(ks[1], d, d_ff, dtype=dtype),
            "wd": dense_init(ks[2], d_ff, d, dtype=dtype),
        }
    return {
        "wu": dense_init(ks[0], d, d_ff, dtype=dtype),
        "wd": dense_init(ks[1], d_ff, d, dtype=dtype),
    }


def apply_mlp(p, x, kind: str = "swiglu"):
    if kind == "swiglu":
        return dense(p["wd"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wu"], x))
    return dense(p["wd"], jax.nn.gelu(dense(p["wu"], x)))


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}
