"""Architecture registry: --arch <id> -> (FULL config, SMOKE config)."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config"]

ARCH_IDS = [
    "dbrx_132b",
    "deepseek_v2_lite_16b",
    "internlm2_1_8b",
    "qwen2_5_3b",
    "chatglm3_6b",
    "stablelm_3b",
    "llava_next_mistral_7b",
    "xlstm_125m",
    "zamba2_7b",
    "hubert_xlarge",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def _module(arch: str):
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).FULL


def get_smoke_config(arch: str) -> ArchConfig:
    return _module(arch).SMOKE
