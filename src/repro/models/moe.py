"""Mixture-of-Experts block: top-k token-choice routing with *grouped*
capacity dispatch (Mesh-TF / T5X-style, pjit-native einsums) + optional
shared experts (DeepSeek style).

Tokens are routed within groups (the batch rows), so the dispatch tensor is
(G, T, E, C) with per-group capacity C = T*k/E*cf — sharding G over 'data'
and E over 'model' makes the XLA SPMD partitioner emit the expert-parallel
all-to-alls for the dispatch/combine einsums, and the one-hot never exceeds
~T*k*cf entries per group.  Capacity is a *padding* choice in the paper's
sense (tokens per expert padded to a model-chosen size); ``capacity_factor``
is FPM-tunable (see repro.train.fpm_schedule).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoECfg
from repro.models.layers import dense_init, mlp_init, apply_mlp

__all__ = ["moe_init", "moe_apply", "moe_capacity"]


def moe_init(key, d: int, cfg: MoECfg, *, mlp_kind: str = "swiglu",
             dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    E, f = cfg.n_experts, cfg.d_expert
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, scale=scale, dtype=jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(jax.random.fold_in(key, 7), d,
                               cfg.n_shared * f, kind=mlp_kind, dtype=dtype)
    return p


def moe_capacity(tokens_per_group: int, cfg: MoECfg) -> int:
    c = int(np.ceil(tokens_per_group * cfg.top_k / cfg.n_experts
                    * cfg.capacity_factor))
    return max(8, (c + 7) // 8 * 8)  # sublane-multiple padding


def moe_apply(p, x, cfg: MoECfg, *, mlp_kind: str = "swiglu"):
    """x: (G, T, d) -> (G, T, d) plus aux load-balancing loss (scalar).
    G (batch rows) are the routing groups."""
    G, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(T, cfg)
    f32 = jnp.float32

    logits = x.astype(f32) @ p["router"]["w"]                       # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                   # (G, T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, choice) within its expert's capacity buffer,
    # counted in (choice-major, token) order within the group.
    oh = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)               # (G,T,k,E)
    ohf = jnp.moveaxis(oh, 2, 1).reshape(G, k * T, E)               # choice-major
    pos_f = jnp.cumsum(ohf, axis=1) - ohf                           # (G,kT,E)
    pos = jnp.moveaxis(pos_f.reshape(G, k, T, E), 1, 2)             # (G,T,k,E)
    pos = (pos * oh).sum(-1)                                        # (G,T,k)
    keep = pos < C

    # dispatch: (G, T, E, C) one-hot accumulated over choices; gates as a
    # separate (G, T, E) factor folded into the combine einsum.
    dispatch = jnp.zeros((G, T, E, C), x.dtype)
    gates_te = jnp.zeros((G, T, E), x.dtype)
    for j in range(k):
        oe = jax.nn.one_hot(gate_idx[..., j], E, dtype=x.dtype)     # (G,T,E)
        oc = jax.nn.one_hot(jnp.where(keep[..., j], pos[..., j], C), C + 1,
                            dtype=x.dtype)[..., :C]                 # (G,T,C)
        dispatch = dispatch + oe[..., :, None] * oc[..., None, :]
        gates_te = gates_te + oe * gate_vals[..., j, None].astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, x)                  # (G,E,C,d)
    if mlp_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"]))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["wu"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])                   # (G,E,C,d)
    y = jnp.einsum("gtec,gte,gecd->gtd", dispatch, gates_te, ye)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, kind=mlp_kind)

    # Aux load-balance loss (Switch-style): E * mean_e f_e * P_e.
    frac_tokens = jax.nn.one_hot(gate_idx[..., 0], E, dtype=f32).mean((0, 1))
    frac_probs = probs.mean((0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
