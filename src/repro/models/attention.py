"""Attention: GQA (train / prefill / decode with KV cache) and MLA
(DeepSeek-V2 multi-head latent attention with compressed-KV cache).

Memory discipline: causal attention is q-chunked (``q_chunk``) via lax.map,
so peak score memory is (B, H, q_chunk, S) — required for the 32k-prefill
shapes.  Softmax in f32.  Decode attention contracts against a KV cache
whose sequence axis may be sharded over the 'model' mesh axis (sequence-
parallel decode); the softmax/psum pattern is XLA-SPMD native.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense, dense_init, apply_rope, norm_init, apply_norm

__all__ = [
    "gqa_init", "gqa_apply", "gqa_init_cache",
    "mla_init", "mla_apply", "mla_init_cache",
]


# ---------------------------------------------------------------------------
# core scaled-dot-product with GQA grouping, causal masking, q-chunking
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, q_pos, kv_len, *, causal: bool, q_chunk: int | None):
    """q: (B,Tq,H,hd); k,v: (B,Tk,KV,hd); q_pos: (Tq,) absolute positions;
    kv_len: scalar or None — valid prefix length of k/v (cache)."""
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    kpos = jnp.arange(Tk)

    def block(q_blk, pos_blk):
        # q_blk: (B, t, H, hd)
        t = q_blk.shape[1]
        qg = q_blk.reshape(B, t, KV, G, hd)
        s = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32) * scale
        mask = jnp.ones((t, Tk), bool)
        if causal:
            mask &= kpos[None, :] <= pos_blk[:, None]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgts,bskh->btkgh", p, v)
        return o.reshape(B, t, H, v.shape[-1])  # v head dim may differ (MLA)

    if q_chunk is None or Tq <= q_chunk or Tq % q_chunk:
        return block(q, q_pos)
    nc = Tq // q_chunk
    qs = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, hd), 1, 0)
    ps = q_pos.reshape(nc, q_chunk)
    outs = jax.lax.map(lambda args: block(*args), (qs, ps))  # (nc, B, qc, H, vd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, d: int, n_heads: int, n_kv: int, hd: int, *,
             bias: bool = False, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, n_heads * hd, bias=bias, dtype=dtype),
        "wk": dense_init(ks[1], d, n_kv * hd, bias=bias, dtype=dtype),
        "wv": dense_init(ks[2], d, n_kv * hd, bias=bias, dtype=dtype),
        "wo": dense_init(ks[3], n_heads * hd, d, dtype=dtype),
    }


def gqa_init_cache(batch: int, max_len: int, n_kv: int, hd: int, dtype=jnp.bfloat16):
    z = jnp.zeros((batch, max_len, n_kv, hd), dtype)
    return {"k": z, "v": z}


def gqa_apply(p, x, *, n_heads: int, n_kv: int, hd: int, rope_mode: str,
              rope_theta: float, causal: bool = True, q_chunk: int | None = 1024,
              cache=None, pos0=0):
    """x: (B, T, d).  cache=None: full self-attention over x (train / encoder).
    cache given: prefill (T>1) writes [pos0, pos0+T), decode (T==1) appends.
    Returns (out, new_cache)."""
    B, T, _ = x.shape
    q = dense(p["wq"], x).reshape(B, T, n_heads, hd)
    k = dense(p["wk"], x).reshape(B, T, n_kv, hd)
    v = dense(p["wv"], x).reshape(B, T, n_kv, hd)
    pos = pos0 + jnp.arange(T)
    q = apply_rope(q, pos, rope_mode, rope_theta)
    k = apply_rope(k, pos, rope_mode, rope_theta)

    if cache is None:
        o = _sdpa(q, k, v, pos, None, causal=causal, q_chunk=q_chunk)
        new_cache = None
    else:
        # dynamic_update_slice needs all start indices in one dtype; under
        # JAX_ENABLE_X64 literal 0s canonicalize to int64 while a traced
        # pos0 stays int32 — cast everything to pos0's dtype.
        p0 = jnp.asarray(pos0)
        z = jnp.zeros((), p0.dtype)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (z, p0, z, z))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (z, p0, z, z))
        new_cache = {"k": ck, "v": cv}
        o = _sdpa(q, ck, cv, pos, pos0 + T, causal=True, q_chunk=q_chunk)
    return dense(p["wo"], o.reshape(B, T, n_heads * hd)), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed latent KV + decoupled RoPE key
# ---------------------------------------------------------------------------

def mla_init(key, d: int, n_heads: int, *, kv_lora: int, nope: int, rope: int,
             v_dim: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, n_heads * (nope + rope), dtype=dtype),
        "w_dkv": dense_init(ks[1], d, kv_lora + rope, dtype=dtype),
        "kv_norm": norm_init(kv_lora),
        "w_uk": dense_init(ks[2], kv_lora, n_heads * nope, dtype=dtype),
        "w_uv": dense_init(ks[3], kv_lora, n_heads * v_dim, dtype=dtype),
        "wo": dense_init(ks[4], n_heads * v_dim, d, dtype=dtype),
    }


def mla_init_cache(batch: int, max_len: int, kv_lora: int, rope: int,
                   dtype=jnp.bfloat16):
    # The MLA memory win: cache holds the compressed latent + shared rope key,
    # (kv_lora + rope) per token instead of 2*H*hd.
    return {
        "ckv": jnp.zeros((batch, max_len, kv_lora), dtype),
        "krope": jnp.zeros((batch, max_len, rope), dtype),
    }


def mla_apply(p, x, *, n_heads: int, kv_lora: int, nope: int, rope: int,
              v_dim: int, rope_theta: float, q_chunk: int | None = 1024,
              cache=None, pos0=0):
    B, T, _ = x.shape
    H = n_heads
    q = dense(p["wq"], x).reshape(B, T, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    pos = pos0 + jnp.arange(T)
    q_rope = apply_rope(q_rope, pos, "full", rope_theta)

    dkv = dense(p["w_dkv"], x)
    ckv = apply_norm(p["kv_norm"], dkv[..., :kv_lora])
    krope = apply_rope(dkv[..., kv_lora:][:, :, None, :], pos, "full",
                       rope_theta)[:, :, 0, :]

    if cache is not None:
        p0 = jnp.asarray(pos0)
        z = jnp.zeros((), p0.dtype)
        ckv_all = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (z, p0, z))
        krope_all = jax.lax.dynamic_update_slice(
            cache["krope"], krope.astype(cache["krope"].dtype), (z, p0, z))
        new_cache = {"ckv": ckv_all, "krope": krope_all}
        kv_len = pos0 + T
    else:
        ckv_all, krope_all, new_cache, kv_len = ckv, krope, None, None

    # Expanded (prefill/train) form: decompress k/v per head.
    k_nope = dense(p["w_uk"], ckv_all).reshape(B, -1, H, nope)
    v = dense(p["w_uv"], ckv_all).reshape(B, -1, H, v_dim)
    k_rope_h = jnp.broadcast_to(krope_all[:, :, None, :],
                                (B, krope_all.shape[1], H, rope))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = _sdpa(qfull, k, v, pos, kv_len, causal=True, q_chunk=q_chunk)
    return dense(p["wo"], o.reshape(B, T, H * v_dim)), new_cache
