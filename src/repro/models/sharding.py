"""Parameter / batch / cache PartitionSpec rules for the production mesh.

Mesh axes: ('pod', 'data', 'model') multi-pod, ('data', 'model') single-pod.

Policy (standard megatron-style TP + ZeRO-ish FSDP over 'data', pure DP over
'pod' so no parameter collectives cross the pod boundary):

  * up-projections  (wq/wk/wv/wu/wg, mamba in_proj, xlstm gates):
      last dim -> 'model' (TP), second-to-last -> 'data' (FSDP storage)
  * down-projections (wo/wd, out_proj):
      last dim -> 'data',  second-to-last -> 'model'
  * MoE expert banks (E, d, f): E -> 'model' (EP), f/d -> 'data' (FSDP)
  * embeddings (V, d): V -> 'model'
  * norms / biases / gates / small vectors: replicated

KV caches: sequence axis -> 'model' (sequence-parallel decode attention),
batch axis -> ('pod', 'data');  SSM states: batch -> ('pod','data'), heads
-> 'model'.  Activations/batches: batch -> ('pod', 'data').
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "sanitize_pspecs",
           "constrain_batch", "embed_dshard", "DATA_AXES"]

DATA_AXES = ("pod", "data")

_UP_NAMES = ("wq", "wk", "wv", "wu", "wg", "wi", "wf", "in_proj", "w_dkv",
             "w_uk", "w_uv", "lm_head", "w")
_DOWN_NAMES = ("wo", "wd", "out_proj")


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def _spec_for(names: list[str], shape: tuple[int, ...], have_pod: bool) -> P:
    data = "data"
    nd = len(shape)
    joined = set(names)

    def pad(spec_tail: tuple) -> P:
        # stacked-layer / group leading dims replicate
        return P(*((None,) * (nd - len(spec_tail)) + spec_tail))

    if "table" in joined or "embed" in joined:
        # Vocab over 'model' (training default — safe through the grad
        # path).  Inference lowerings flip this to d-sharded (§Perf Q2,
        # `embed_dshard`): the lookup then needs no table gather, but the
        # XLA partitioner mishandles that layout inside the train scan.
        return pad(("model", None)) if nd >= 2 else P()
    if nd >= 2 and ("moe" in joined) and names[-1] in ("wg", "wu"):
        return pad(("model", None, data))       # (E, d, f): EP + FSDP-f
    if nd >= 2 and ("moe" in joined) and names[-1] == "wd":
        return pad(("model", data, None))       # (E, f, d)
    if "router" in joined:
        return P(*([None] * nd))
    if names[-1] == "r":                        # xlstm recurrent (H, hd, 4hd)
        return pad(("model", None, None)) if nd >= 3 else P(*([None] * nd))
    if nd >= 2:
        # dict-style dense params: the array is named "w"/"b" under a module
        mod = names[-2] if names[-1] in ("w", "b") else names[-1]
        if names[-1] == "b":
            return P(*([None] * nd))
        if any(mod == u or mod.startswith(u) for u in _DOWN_NAMES):
            return pad(("model", data))
        if any(mod == u or mod.startswith(u) for u in _UP_NAMES):
            return pad((data, "model"))
        if mod == "conv_w":
            return pad((None, "model"))
    return P(*([None] * nd))


def param_pspecs(params: Any, have_pod: bool = False):
    """Pytree of PartitionSpec matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_names(path), leaf.shape, have_pod),
        params)


def batch_pspecs(batch: Any, have_pod: bool = False):
    dax = (DATA_AXES if have_pod else "data")
    def spec(path, leaf):
        return P(*((dax,) + (None,) * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec, batch)


def _cache_spec(names: list[str], shape, have_pod: bool,
                seq_axes="model") -> P:
    dax = (DATA_AXES if have_pod else "data")
    nd = len(shape)
    name = names[-1]
    if name in ("k", "v"):        # (L?, B, S, KV, hd): seq -> seq_axes
        tail = (dax, seq_axes, None, None)
        return P(*((None,) * (nd - 4) + tail))
    if name in ("ckv", "krope"):  # (L?, B, S, r): seq -> seq_axes
        tail = (dax, seq_axes, None)
        return P(*((None,) * (nd - 3) + tail))
    if name == "ssm":             # (..., B, H, P, N): heads -> model
        tail = (dax, "model", None, None)
        return P(*((None,) * (nd - 4) + tail))
    if name == "conv":            # (..., B, w, ch)
        tail = (dax, None, "model")
        return P(*((None,) * (nd - 3) + tail))
    if name in ("C", "n", "h", "c", "m"):
        # xLSTM states: head counts are small (4) — batch-shard only.
        return P(*((dax,) + (None,) * (nd - 1)))
    return P(*((dax,) + (None,) * (nd - 1)))


def cache_pspecs(cache: Any, have_pod: bool = False, seq_axes="model"):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_spec(_path_names(path), leaf.shape, have_pod,
                                       seq_axes),
        cache)


def embed_dshard(specs: Any, params_shape: Any) -> Any:
    """Flip embedding tables to d-sharded P(None, 'model') — inference
    lowerings only (§Perf Q2: removes the full-table gather; 4.6x fewer
    collective bytes on qwen prefill)."""
    def fix(path, spec, leaf):
        names = _path_names(path)
        if ("table" in names or "embed" in names) and len(leaf.shape) >= 2:
            return P(*((None,) * (len(leaf.shape) - 1) + ("model",)))
        return spec
    return jax.tree_util.tree_map_with_path(fix, specs, params_shape)


def _context_mesh():
    """The mesh installed by ``with mesh:`` at trace time (or None)."""
    try:
        from jax._src import mesh as _m
        env_mesh = _m.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


SEQ_SHARD = False  # sequence-parallel activations (set via set_seq_shard)


def set_seq_shard(enabled: bool) -> None:
    """Ulysses-style sequence parallelism for full-sequence activations:
    constrain (B, T, d) tensors to (data-axes, 'model', None) between
    blocks.  Attention/k-v gathers stay small under GQA; the per-layer
    activation regathers disappear.  §Perf iter Q3."""
    global SEQ_SHARD
    SEQ_SHARD = bool(enabled)


def constrain_batch(x):
    """Pin an activation's leading (batch) dim to the data axes (and, when
    sequence parallelism is on, the seq dim to 'model').

    Without this, the SPMD partitioner may drop data-parallel sharding of
    activations inside scan bodies and fall back to fully-replicated batch
    with TP-only layouts (observed on zamba2_7b train: 16x activation
    blow-up + 24 GB/step of collective-permute churn — EXPERIMENTS.md §Perf
    iter Z3).  No-op outside a mesh context or when the batch dim does not
    divide the data axes.
    """
    mesh = _context_mesh()
    if mesh is None or not hasattr(x, "ndim") or x.ndim < 2:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dax = tuple(a for a in DATA_AXES if a in sizes and sizes[a] > 1)
    if not dax:
        return x
    ext = 1
    for a in dax:
        ext *= sizes[a]
    if x.shape[0] % ext:
        return x
    rest = [None] * (x.ndim - 1)
    if (SEQ_SHARD and x.ndim == 3 and sizes.get("model", 1) > 1
            and x.shape[1] % sizes["model"] == 0):
        rest[0] = "model"
    spec = P(dax if len(dax) > 1 else dax[0], *rest)
    return jax.lax.with_sharding_constraint(x, spec)


def sanitize_pspecs(specs: Any, shapes: Any, mesh) -> Any:
    """Drop mesh axes from any dim they don't divide evenly (e.g. a 504-way
    vocab over a 16-way model axis, or batch=1 over the data axes) — the
    leaf falls back to replication on that dim.  Keeps every lowering legal
    without per-arch special cases."""
    from jax.sharding import PartitionSpec as PS

    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, leaf):
        dims = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        out = []
        for dim_size, entry in zip(leaf.shape, dims):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            axes = tuple(a for a in axes if a in axis_size)
            ext = 1
            for a in axes:
                ext *= axis_size[a]
            if ext <= 1 or dim_size % ext:
                # try a prefix of the axes that still divides
                kept = []
                ext = 1
                for a in axes:
                    if dim_size % (ext * axis_size[a]) == 0:
                        kept.append(a)
                        ext *= axis_size[a]
                axes = tuple(kept)
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(axes)
        return PS(*out)

    return jax.tree.map(fix, specs, shapes)
