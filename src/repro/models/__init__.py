from repro.models.transformer import (init_params, loss_fn, forward,
                                      init_cache, prefill, decode_step)
from repro.models.sharding import param_pspecs, batch_pspecs, cache_pspecs
from repro.models.registry import ARCH_IDS, get_config, get_smoke_config

__all__ = [
    "init_params", "loss_fn", "forward", "init_cache", "prefill", "decode_step",
    "param_pspecs", "batch_pspecs", "cache_pspecs",
    "ARCH_IDS", "get_config", "get_smoke_config",
]
