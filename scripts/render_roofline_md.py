"""Render the §Roofline markdown table from dry-run JSON records into
EXPERIMENTS.md (replaces the <!-- ROOFLINE_TABLE --> marker)."""

import glob
import json
import os
import sys

DIR = "experiments/dryrun"
MARK = "<!-- ROOFLINE_TABLE -->"


def load(tag):
    out = {}
    for p in sorted(glob.glob(os.path.join(DIR, f"*__{tag}.json"))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["multi_pod"])] = r
    return out


def fmt(x, digits=4):
    return f"{x:.{digits}g}"


def main():
    analysis = load("analysis")
    baseline = load("baseline")
    rows = []
    # roofline table is single-pod per the brief; one row per runnable cell
    cells = sorted({k[:2] for k in baseline if not k[2]})
    for arch, shape in cells:
        a = analysis.get((arch, shape, False))
        b = baseline.get((arch, shape, False))
        src = a or b
        t = src["roofline"]
        method = "analysis" if a else "scanned*"
        ssm_note = "†" if (arch in ("xlstm_125m", "zamba2_7b")
                           and a is not None) else ""
        rows.append(
            f"| {arch} | {shape} | {fmt(t['compute_s'])} | {fmt(t['memory_s'])} "
            f"| {fmt(t['collective_s'])} | {t['dominant']} "
            f"| {fmt(t['useful_ratio'], 3)}{ssm_note} "
            f"| {fmt(t['roofline_fraction'], 3)} | {method} |")

    hdr = (
        "Single-pod (16×16 = 256 chips), per-device seconds per step.  "
        "`useful` = MODEL_FLOPS / HLO_FLOPs; `fraction` = MODEL_FLOPS / "
        "(bound_term × 256 × 197 TFLOP/s).\n\n"
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| useful | fraction | method |\n"
        "|---|---|---|---|---|---|---|---|---|\n")
    foot = (
        "\n\\* scanned = analysis lowering unavailable (compile timeout); "
        "scan bodies counted once — terms are lower bounds for these rows.\n"
        "† SSM/xLSTM inner chunk/time scans remain scans even in analysis "
        "mode (unrolling 512–32k trips is infeasible); their flops are "
        "undercounted, which can push `useful` above 1 — the recurrence "
        "contribution is excluded from HLO_FLOPs but present in "
        "MODEL_FLOPS.\n\n"
        "**One-line bottleneck summary per dominant term**: decode cells "
        "are memory/collective-bound at trivial fractions (batch-1 or "
        "128-token steps on 256 chips are inherently launch-bound — batch "
        "or multi-tenant packing is the lever); prefill/train cells are "
        "memory-term-bound under the pre-fusion bytes metric with the "
        "collective term next — seq_shard (Q3/D3) is the collective "
        "lever, grad reduce-scatter the next one (§Perf).\n")
    table = hdr + "\n".join(rows) + foot

    md = open("EXPERIMENTS.md").read()
    assert MARK in md
    md = md.replace(MARK, table, 1)
    open("EXPERIMENTS.md", "w").write(md)
    print(f"rendered {len(rows)} rows "
          f"({sum(1 for a, s in cells if (a, s, False) in analysis)} analysis)")


if __name__ == "__main__":
    sys.exit(main())
