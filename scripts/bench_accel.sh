#!/usr/bin/env bash
# One-command accelerator-backend benchmark run.
#
#   bash scripts/bench_accel.sh                     # all sweeps
#   bash scripts/bench_accel.sh --sweeps dist,multihost --quick
#
# Runs the kernel microbench on whatever backend jax resolves (TPU/GPU
# when present, CPU interpret mode otherwise) and warms the wisdom store
# next to the output, so a single invocation on real hardware both
# refreshes benchmarks/BENCH_kernels.json with accelerator-tagged
# records and leaves a store later planning sessions are served from.
# Every record is stamped with backend + interpret-mode, and the
# microbench's overwrite guard refuses to let a later CPU run silently
# replace accelerator-measured records (--force passes through).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

OUT="${BENCH_OUT:-benchmarks/BENCH_kernels.json}"
WISDOM="${BENCH_WISDOM:-benchmarks/wisdom.json}"

BACKEND=$(python -c "import jax; print(jax.default_backend())")
echo "benching on backend: ${BACKEND} -> ${OUT} (wisdom: ${WISDOM})"
if [ "${BACKEND}" = "cpu" ]; then
    # No accelerator visible: force a multi-device CPU topology so the
    # dist/multihost/pfft3 sweeps still measure real collectives.
    export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4"
fi

exec python -m benchmarks.kernel_microbench \
    --out "${OUT}" --wisdom "${WISDOM}" "$@"
