#!/usr/bin/env bash
# Tier-1 test entry point (mirrors ROADMAP.md "Tier-1 verify").
#
#   bash scripts/test.sh                 # full suite
#   bash scripts/test.sh tests/test_fused.py -k radix   # pass-through args
#
# Env idiom per SNIPPETS.md (ClashLuke/olmax test.sh): fp64 enabled so the
# float64/complex128 paths are exercised; PYTHONPATH points at src.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_ENABLE_X64="${JAX_ENABLE_X64:-1}"   # allow fp64
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -q "$@"
