"""2-D FFT convolution on the real-input half-spectrum pipeline.

Convolution is the workload the real path was built for: images and
filters are real, so the circular convolution theorem needs only the
(N, N//2+1) half spectrum — half the row FFTs (two real rows packed per
complex transform) and half the spectral multiply, with ``irfft2``
folding the Hermitian half back to a real image.

``plan_pfft(method="rfft-lb", tune="estimate")`` is the planner doing
the choosing: the cost model prices the real pipeline against the
upcast-and-crop complex fallback and the plan routes on the winner
(``plan.tuning["chosen_path"]``).  The plan is built once and executed
for every image/kernel pair — fftw's plan/execute lifecycle.

Run:  PYTHONPATH=src python examples/fft_convolution.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import irfft2, plan_pfft

N = 128

rng = np.random.default_rng(0)
image = jnp.asarray(rng.standard_normal((N, N)).astype(np.float32))

# A small blur kernel, zero-padded to N x N (circular convolution).
kernel = np.zeros((N, N), np.float32)
kernel[:3, :3] = np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], np.float32)
kernel /= kernel.sum()
kernel = jnp.asarray(kernel)

plan = plan_pfft(N, p=1, method="rfft-lb", tune="estimate",
                 dtype="float32")
print(f"planned config: {plan.config.describe()} "
      f"(chosen_path={plan.tuning['chosen_path']})")

half_img = plan.execute(image)      # (N, N//2+1) — the Hermitian half
half_ker = plan.execute(kernel)
print(f"half spectrum: {half_img.shape} vs full ({N}, {N}) — "
      f"{half_img.shape[-1] / N:.0%} of the columns")

blurred = irfft2(half_img * half_ker, n=N)

ref = jnp.real(jnp.fft.ifft2(jnp.fft.fft2(image) * jnp.fft.fft2(kernel)))
err = float(jnp.max(jnp.abs(blurred - ref)))
print(f"fft-convolution vs full-complex reference: max_err={err:.2e}")
assert err < 1e-4, "half-spectrum convolution must match the complex path"

# The plan is reusable: a batch of images rides the same jitted program.
batch = jnp.stack([image, 2.0 * image])
half_batch = plan.execute(batch)
print(f"batched execute: {batch.shape} -> {half_batch.shape}")
print("convolution theorem on the half spectrum: "
      "rfft2(a) * rfft2(b) -> irfft2 == a (*) b")
