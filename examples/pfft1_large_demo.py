"""One enormous 1-D FFT via 2-D decomposition — the EFFT four-step.

A length-N transform that dwarfs any single kernel's sweet spot factors
as N = n1 * n2 and becomes a 2-D problem the rest of this repo already
solves: n1 row FFTs of length n2, a twiddle multiply, n2 row FFTs of
length n1, plus transposes.  Both row-FFT phases run through the same
``_group_row_ffts`` machinery as ``pfft2``, so every kernel/backend the
planner can pick is available at each factor's own length — and
``plan_pfft1_large`` gives the whole thing the fftw lifecycle: tune
once, persist the winner in wisdom, serve every later plan from disk
with zero re-measurement.

Run:  PYTHONPATH=src python examples/pfft1_large_demo.py
"""

import os
import tempfile

import numpy as np
import jax.numpy as jnp

from repro.core import pfft1_large, plan_pfft1_large
from repro.core.pfft_large import four_step_factors

N = 4096 * 9            # 36864 = 192 * 192: far past one kernel's sweet spot

n1, n2 = four_step_factors(N)
print(f"four-step factorization: N={N} -> {n1} x {n2} "
      f"(row FFTs at lengths {n2} and {n1} instead of one at {N})")

rng = np.random.default_rng(0)
x = jnp.asarray((rng.standard_normal(N)
                 + 1j * rng.standard_normal(N)).astype(np.complex64))

# One-shot convenience entry point (plan built and executed inline).
out = pfft1_large(x)
ref = np.fft.fft(np.asarray(x))
err = float(np.max(np.abs(np.asarray(out) - ref)) / np.max(np.abs(ref)))
print(f"pfft1_large vs np.fft.fft: rel_err={err:.2e}")
assert err < 1e-4

# The planner lifecycle: measure once, then every later plan is served
# from the wisdom store without re-measuring.
wis = os.path.join(tempfile.mkdtemp(), "wisdom.json")
p1 = plan_pfft1_large(N, tune="measure", wisdom=wis)
print(f"measured plan: {p1.config.describe()} "
      f"(source={p1.tuning['source']}, n1={p1.n1}, n2={p1.n2})")
p2 = plan_pfft1_large(N, tune="measure", wisdom=wis)
assert p2.tuning["source"] == "wisdom" and "measured" not in p2.tuning
print(f"second plan served from wisdom, zero re-measurement "
      f"(key {p2.tuning['wisdom_key']})")

out2 = p2.execute(x)
err2 = float(np.max(np.abs(np.asarray(out2) - ref)) / np.max(np.abs(ref)))
print(f"wisdom-served plan executes identically: rel_err={err2:.2e}")
assert err2 < 1e-4

# Pinning one factor re-plans the decomposition (a pow2 n1 lets a radix
# kernel take that phase); prime N degenerates to n1=1, still correct.
p3 = plan_pfft1_large(N, n1=256)
print(f"pinned factors: n1={p3.n1}, n2={p3.n2} "
      f"({p3.tuning['wisdom_key']})")
print("four-step pattern: reshape -> row FFTs(n2) -> twiddle "
      "-> row FFTs(n1) -> transpose read-out")
