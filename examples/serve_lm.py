"""End-to-end serving driver: batched prefill + jitted decode loop with KV
cache, for any decoder arch in the registry.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen2_5_3b]
"""

import argparse

from repro.launch.serve import serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    out, stats = serve_batch(args.arch, smoke=not args.full,
                             batch=args.batch, prompt_len=args.prompt_len,
                             gen=args.gen)
    print(f"[serve_lm] batch={args.batch} generated {out.shape[1]} tokens/seq")
    for k, v in stats.items():
        print(f"[serve_lm] {k}={v:.2f}")


if __name__ == "__main__":
    main()
