"""Transform serving: many clients, mixed sizes, one coalescing service.

A handful of async clients submit square FFT requests of different
sizes and kinds (complex ``lb`` and real ``rfft-lb``) to one
``FFTService``.  The tick loop coalesces every same-``(n, dtype,
method)`` request waiting at tick time into a single batch-stacked
dispatch, the bounded plan cache (fronting the wisdom store) keeps each
cohort's plan hot, and admission is priced by the cost model — the
deliberately oversized request below is refused with the model's
prediction attached instead of stalling everyone behind it.

Run:  PYTHONPATH=src python examples/serve_fft_demo.py
"""

import asyncio
import tempfile

import numpy as np

from repro.launch.serve_fft import AdmissionError, FFTService

rng = np.random.default_rng(0)


def make_request(n, method):
    if method.startswith("rfft"):
        return rng.standard_normal((n, n)).astype(np.float32)
    return (rng.standard_normal((n, n))
            + 1j * rng.standard_normal((n, n))).astype(np.complex64)


async def client(name, svc, n, method):
    m = make_request(n, method)
    out = await svc.submit(m, method=method)
    ref = np.fft.rfft2(m) if method.startswith("rfft") else np.fft.fft2(m)
    ok = np.allclose(np.asarray(out), ref, atol=1e-2)
    print(f"  {name}: n={n:3d} {method:8s} -> {np.asarray(out).shape} "
          f"{'matches numpy' if ok else 'MISMATCH'}")
    return ok


async def main():
    wisdom = tempfile.mktemp(suffix="_wisdom.json")
    svc = FFTService(wisdom=wisdom, tune="estimate", tick_budget_s=0.05)

    # One deliberately oversized request: priced rejection, not a stall.
    try:
        svc.enqueue(np.zeros((4096, 4096), np.complex64), method="lb")
    except AdmissionError as e:
        print(f"oversized request refused: {e}")

    # A burst of mixed-size clients served concurrently.
    jobs = [(32, "lb"), (32, "lb"), (32, "rfft-lb"), (64, "lb"),
            (64, "rfft-lb"), (32, "lb"), (128, "lb"), (64, "lb")]
    async with svc:
        results = await asyncio.gather(
            *(client(f"client{i}", svc, n, meth)
              for i, (n, meth) in enumerate(jobs)))
    assert all(results)

    s = svc.stats()
    print(f"\nserved {s['served']} requests in {s['dispatches']} dispatches "
          f"({s['batching_efficiency']:.1f} requests/dispatch, "
          f"largest cohort {s['max_coalesced']})")
    print(f"plan cache: {s['plan_cache']}")
    print(f"plan sources: {s['sources']} "
          f"(a second service on this wisdom store would be all 'wisdom')")


if __name__ == "__main__":
    asyncio.run(main())
