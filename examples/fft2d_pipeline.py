"""Distributed 2-D FFT pipeline on a device mesh — the paper's algorithm
with the transpose steps realised as all_to_all collectives (TPU-pod form).

Every variant is named by a ``PlanConfig`` (the planner's currency): the
explicit configs below show the space, and the last run lets the
estimate-mode tuner price pipeline_panels candidates (comm volume
included) and pick one — the same selection point ``plan_pfft`` uses.

Runs on CPU with 8 placeholder devices; the same code drives a v5e pod.

Run:  PYTHONPATH=src python examples/fft2d_pipeline.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pfft_dist import make_pfft2_fn
from repro.launch.mesh import make_local_mesh
from repro.plan import PlanConfig, tune_config

N = 256
P = 8
mesh = jax.make_mesh((P,), ("fft",))

rng = np.random.default_rng(0)
sig = (rng.standard_normal((N, N)) + 1j * rng.standard_normal((N, N))
       ).astype(np.complex64)
sig = jnp.asarray(sig)

# Each phase exchanges the whole matrix minus the diagonal block.
comm_bytes = N * N * 8 * (P - 1) / P
planned, info = tune_config(N, mode="estimate", panels=(1, 2, 4),
                            comm_bytes=comm_bytes)

configs = [
    (PlanConfig(), "plain"),
    (PlanConfig(pad="czt"), "czt-padded (exact)"),
    (PlanConfig(radix=2), "stockham local FFT"),
    (PlanConfig(pipeline_panels=4), "4-panel overlap pipeline"),
    (planned, f"estimate-planned [{planned.describe()}]"),
]
for cfg, label in configs:
    fn = make_pfft2_fn(mesh, N, "fft", config=cfg)
    out = fn(sig)
    err = float(jnp.max(jnp.abs(out - jnp.fft.fft2(sig))))
    print(f"distributed pfft2 [{label:40s}] max_err={err:.2e} "
          f"shards={len(out.sharding.device_set)}")
print("collective transpose pattern:",
      "row FFT -> all_to_all -> col FFT -> all_to_all")
