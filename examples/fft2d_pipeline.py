"""Distributed 2-D FFT pipeline on a device mesh — the paper's algorithm
with the transpose steps realised as all_to_all collectives (TPU-pod form).

Runs on CPU with 8 placeholder devices; the same code drives a v5e pod.

Run:  PYTHONPATH=src python examples/fft2d_pipeline.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pfft_dist import make_pfft2_fn
from repro.launch.mesh import make_local_mesh

N = 256
mesh = jax.make_mesh((8,), ("fft",))

rng = np.random.default_rng(0)
sig = (rng.standard_normal((N, N)) + 1j * rng.standard_normal((N, N))
       ).astype(np.complex64)
sig = jnp.asarray(sig)

for kw, label in [({}, "plain"),
                  ({"padded": "czt"}, "czt-padded (exact)"),
                  ({"use_stockham": True}, "stockham local FFT"),
                  ({"pipeline_panels": 4}, "4-panel overlap pipeline")]:
    fn = make_pfft2_fn(mesh, N, "fft", **kw)
    out = fn(sig)
    err = float(jnp.max(jnp.abs(out - jnp.fft.fft2(sig))))
    print(f"distributed pfft2 [{label:24s}] max_err={err:.2e} "
          f"shards={len(out.sharding.device_set)}")
print("collective transpose pattern:",
      "row FFT -> all_to_all -> col FFT -> all_to_all")
