"""End-to-end driver: train a small LM with the full production stack —
microbatched train_step, AdamW, checkpoints, restart, straggler monitor.

The default model is a ~20M-param dense transformer (CPU-budget); pass
--arch xlstm_125m --full for the ~125M assigned config if you have time.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 40]
"""

import argparse
import tempfile

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        losses = run_training(args.arch, smoke=not args.full, lr=args.lr,
                              steps=args.steps, batch=args.batch,
                              seq=args.seq, ckpt_dir=ckpt_dir,
                              ckpt_every=max(10, args.steps // 3),
                              microbatches=2, log_every=5)
    first, last = losses[0], sum(losses[-5:]) / 5
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.3 else 'no clear drop'})")


if __name__ == "__main__":
    main()
