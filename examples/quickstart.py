"""Quickstart: the paper's method in 30 lines.

1. Build functional performance models (FPMs) for p abstract processors by
   timing row-FFT batches at a grid of problem sizes.
2. PARTITION the rows (POPTA/HPOPTA choose automatically per the epsilon
   tolerance test).
3. Plan with the model-driven tuner (``tune="estimate"`` prices every
   execution variant from the FPMs and picks one — no boolean kwargs) and
   execute PFFT-FPM / PFFT-FPM-PAD against the basic 2-D FFT.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FPMSet, build_fpm, plan_pfft

N = 512
P = 4

# -- 1. measure speed functions ------------------------------------------
def timer(x: int, y: int) -> float:
    m = jnp.ones((x, y), jnp.complex64)
    f = jax.jit(lambda a: jnp.fft.fft(a, axis=-1))
    f(m).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        f(m).block_until_ready()
    return (time.perf_counter() - t0) / 3

xs = sorted({N // 8, N // 4, N // 2, N})
ys = sorted({N // 2, N - 64, N, N + 64, 640, 768, 1024})
fpms = FPMSet([build_fpm(xs, ys, timer, name=f"P{i}") for i in range(P)])

# -- 2+3. plan & execute ---------------------------------------------------
signal = (np.random.default_rng(0).standard_normal((N, N))
          + 1j * np.random.default_rng(1).standard_normal((N, N))).astype(np.complex64)
signal = jnp.asarray(signal)

oracle = jnp.fft.fft2(signal)
for method in ("lb", "fpm", "fpm-czt"):
    plan = plan_pfft(N, p=P, fpms=fpms, method=method, tune="estimate")
    out = plan.execute(signal)
    err = float(jnp.max(jnp.abs(out - oracle)))
    print(f"method={method:8s} d={plan.d} config=[{plan.config.describe()}] "
          f"(chosen: {plan.tuning['source']}) max_err={err:.2e}")

plan = plan_pfft(N, fpms=fpms, method="fpm-pad", tune="estimate")
out = plan.execute(signal)
print(f"method=fpm-pad  d={plan.d} pad_lengths={plan.pad_lengths} "
      f"config=[{plan.config.describe()}] "
      f"(padded-signal DFT semantics; see DESIGN.md)")

# Batched execute: the plan vmaps over leading batch dims.
batch = jnp.stack([signal, signal[::-1]])
print("batched execute:", plan.execute(batch).shape)
